"""Measured-tier benchmark: roofline-only vs roofline + measured
re-rank, plus the kernel-cell tile-sweep arm.

Three synthetic arms over the PR-2 4-cell batch on the deterministic
model/truth surface pair (benchmarks/measured_surface.py: the truth
penalizes the model's favourite ``remat_policy=none`` train move), and
one real arm timing interpret-mode Pallas kernels.  The truth surface
penalizes the model's favourite last-stage move (``attn_block_q=256``),
so every cell whose walk accepted it must be overturned:

  * **model_only** — the historical campaign (``measure_top_k=0``);
    the walk-decision oracle every re-rank arm is diffed against;
  * **rerank** — ``measure_top_k=K``: walk fingerprints must be
    bit-identical to model_only (the measured tier only *appends*),
    each cell pays at most K real measured evaluations (ledger-counted
    through the truth surface), every cell publishes a measured
    winner, and measurement overturns the model ranking wherever the
    top-K candidates disagree on the flip delta;
  * **rerank_repeat** — fresh checkpoints, same disk timing cache:
    zero real evaluations (every measured trial is a cache hit) and
    the published winners are identical — repeat campaigns re-pay
    nothing;
  * **kernel_tiles** — real end-to-end tile autotuning
    (``kernel:flash_attention:tiny`` + ``kernel:ssm_scan:tiny``
    through the default dispatch evaluator, interpret-mode Pallas on
    CPU): reports per-cell winning tiles and whether a non-default
    tile configuration won at least one (arch, shape).

Results land in results/benchmarks/BENCH_measured.json and a copy at
the repo root (BENCH_measured.json) for CI tracking.

Run:  PYTHONPATH=src python -m benchmarks.bench_measured
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import shutil
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent

DEFAULT_CELLS = ("smollm-135m:train_4k,smollm-135m:prefill_32k,"
                 "xlstm-1.3b:prefill_32k,xlstm-1.3b:decode_32k")
KERNEL_CELLS = "kernel:flash_attention:tiny,kernel:ssm_scan:tiny"
TOP_K = 2


def _baseline(spec=None):
    from repro.core.params import default_config
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def _campaign(cells, ckpt, **kw):
    from benchmarks.measured_surface import make_evaluator
    from repro.core.campaign import Campaign
    camp = Campaign(cells, strategy="tree", checkpoint_dir=ckpt,
                    evaluator=make_evaluator(),
                    baseline_factory=_baseline, **kw)
    t0 = time.time()
    reports = camp.run()
    return camp, reports, round(time.time() - t0, 3)


def _fingerprints(cells, reports):
    from repro.core.campaign import tuning_fingerprint
    return {c.key(): tuning_fingerprint(reports[c.key()])
            for c in cells}


def _ledger_counts(path):
    counts = {}
    if path.exists():
        for line in path.read_text().splitlines():
            cell = json.loads(line)["cell"]
            counts[cell] = counts.get(cell, 0) + 1
    return counts


def run_model_only(cells, scratch):
    _, reports, wall = _campaign(cells, scratch / "model_only")
    evals = sum(r.n_trials for r in reports.values())
    return {"wall_s": wall, "evaluations": evals,
            "fingerprints": _fingerprints(cells, reports)}


def run_rerank(cells, scratch, model_only, repeat=False):
    from benchmarks.measured_surface import (CACHE_ENV, LEDGER_ENV,
                                             make_measured_evaluator)
    name = "rerank_repeat" if repeat else "rerank"
    ledger = scratch / f"{name}.ledger"
    os.environ[LEDGER_ENV] = str(ledger)
    os.environ[CACHE_ENV] = str(scratch / "timings")  # shared across arms
    try:
        _, reports, wall = _campaign(
            cells, scratch / name, measure_top_k=TOP_K,
            measured_evaluator=make_measured_evaluator())
    finally:
        os.environ.pop(LEDGER_ENV, None)
        os.environ.pop(CACHE_ENV, None)
    counts = _ledger_counts(ledger)
    measured = {c.key(): reports[c.key()].measured for c in cells}
    overturned = sorted(k for k, m in measured.items()
                        if m and m.get("overturned"))
    return {
        "wall_s": wall,
        "walk_identical_to_model_only":
            _fingerprints(cells, reports) == model_only["fingerprints"],
        "measured_evaluations": counts,
        "max_evaluations_per_cell": max(counts.values(), default=0),
        "total_evaluations": sum(counts.values()),
        "cells_with_winner": sorted(
            k for k, m in measured.items()
            if m and m.get("winner") is not None),
        "overturned_cells": overturned,
        "winners": {k: {"name": m.get("winner_name"),
                        "model_cost_s": m["candidates"][0]["model_cost_s"]
                        if m.get("candidates") else None,
                        "measured_cost_s": m.get("winner_cost_s")}
                    for k, m in measured.items() if m},
    }


def run_kernel_tiles(scratch):
    from repro.core.campaign import Campaign, parse_cells
    cells = parse_cells(KERNEL_CELLS)
    camp = Campaign(cells, strategy="tree",
                    checkpoint_dir=scratch / "kernels")
    t0 = time.time()
    reports = camp.run()
    wall = round(time.time() - t0, 3)
    out = {"wall_s": wall, "cells": {}}
    nondefault = []
    for c in cells:
        rep = reports[c.key()]
        final = {k: v for k, v in rep.final_config.items()
                 if k.startswith("attn_block")}
        base = {k: v for k, v in rep.log[0]["config"].items()
                if k.startswith("attn_block")}
        if final != base:
            nondefault.append(c.key())
        out["cells"][c.key()] = {
            "trials": rep.n_trials,
            "baseline_tiles": base, "final_tiles": final,
            "baseline_cost_s": rep.baseline_cost,
            "final_cost_s": rep.final_cost,
            "speedup": rep.speedup,
        }
    out["nondefault_tile_winners"] = nondefault
    return out


# ------------------------------------------------------------------ main
def main(cells_spec: str):
    from repro.core.campaign import parse_cells
    cells = parse_cells(cells_spec)
    print(f"batch: {len(cells)} cells "
          f"({', '.join(c.key() for c in cells)})")
    scratch = ROOT / "results" / "bench_measured_scratch"
    shutil.rmtree(scratch, ignore_errors=True)
    scratch.mkdir(parents=True, exist_ok=True)

    model_only = run_model_only(cells, scratch)
    print(f"model_only: {model_only['evaluations']} evaluations, "
          f"{model_only['wall_s']}s")

    rerank = run_rerank(cells, scratch, model_only)
    print(f"rerank: {rerank['total_evaluations']} measured evaluations "
          f"(max {rerank['max_evaluations_per_cell']}/cell, bound "
          f"{TOP_K}), overturned: {rerank['overturned_cells']}")

    repeat = run_rerank(cells, scratch, model_only, repeat=True)
    print(f"rerank_repeat: {repeat['total_evaluations']} real "
          f"evaluations (timing cache), winners identical="
          f"{repeat['winners'] == rerank['winners']}")

    kernels = run_kernel_tiles(scratch)
    print(f"kernel_tiles: {kernels['wall_s']}s, non-default winners: "
          f"{kernels['nondefault_tile_winners']}")

    out = {
        "cells": [c.key() for c in cells],
        "top_k": TOP_K,
        "model_only": {k: v for k, v in model_only.items()
                       if k != "fingerprints"},
        "rerank": rerank,
        "rerank_repeat": repeat,
        "kernel_tiles": kernels,
    }
    res_dir = ROOT / "results" / "benchmarks"
    res_dir.mkdir(parents=True, exist_ok=True)
    (res_dir / "BENCH_measured.json").write_text(json.dumps(out, indent=1))
    (ROOT / "BENCH_measured.json").write_text(json.dumps(out, indent=1))
    shutil.rmtree(scratch, ignore_errors=True)
    print(json.dumps(out, indent=1))
    assert rerank["walk_identical_to_model_only"], \
        "the measured tier changed walk decisions!"
    assert rerank["max_evaluations_per_cell"] <= TOP_K, \
        "a cell paid more than k measured evaluations!"
    assert len(rerank["cells_with_winner"]) == len(cells), \
        "a cell finished without a measured winner!"
    assert rerank["overturned_cells"], \
        "the truth surface disagreed but nothing was overturned!"
    assert repeat["total_evaluations"] == 0, \
        "repeat run re-paid measured evaluations despite the cache!"
    assert repeat["winners"] == rerank["winners"], \
        "cached re-rank published different winners!"
    assert kernels["nondefault_tile_winners"], \
        "no kernel cell found a non-default tile!"
    print("\nbench_measured: all invariants hold")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=DEFAULT_CELLS)
    args = ap.parse_args()
    main(args.cells)
