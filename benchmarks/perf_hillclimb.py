"""§Perf beyond-paper hillclimbing for the three selected cells.

Sequence per the assignment: (1) the paper-faithful tuning tree produces
the PAPER BASELINE config (recorded by benchmarks/case_studies.py);
(2) THIS driver continues from that config with hypothesis-driven changes
the paper doesn't have — Pallas flash attention (+VMEM tile sweep),
attention batch-resharding, wire-dtype refinements — following the
hypothesis -> napkin-math -> change -> measure -> verdict loop.  Stops
after 3 consecutive <5% improvements on the dominant term.

Run:  PYTHONPATH=src python -m benchmarks.perf_hillclimb
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import dataclasses
import json
import pathlib
from typing import Dict, List, Optional

ROOT = pathlib.Path(__file__).resolve().parent.parent
PERF = ROOT / "results" / "perf"


def candidate_moves(kind: str) -> List[Dict]:
    """Ordered (by napkin-math predicted win) hypothesis list."""
    moves = [
        dict(name="ABLATION: unfused XLA attention",
             targets="ablation",
             delta=dict(attn_impl="xla"),
             hypothesis=("quantify the flash kernel the baseline ships "
                         "with: the XLA path round-trips the (B,H,S,S) "
                         "f32 score/softmax tensors through HBM ~4x "
                         "(n_layers*B*H*S^2*16B/chips of traffic). "
                         "Expect a large memory-term regression — kept "
                         "only as the measured ablation, always rejected.")),
        dict(name="bigger VMEM tiles (file.buffer up)",
             targets="memory",
             delta=dict(attn_block_q=512, attn_block_kv=512),
             hypothesis=("with flash on, K/V are re-fetched once per "
                         "Q-tile: S/block_q passes. 128->512 cuts the "
                         "refetch factor 4x; VMEM still fits "
                         "(512*128*4B*4 buffers ~ 1MB).")),
        dict(name="smaller VMEM tiles (file.buffer down)",
             targets="memory",
             delta=dict(attn_block_q=256, attn_block_kv=256),
             hypothesis="midpoint of the tile sweep (paper tests both "
                        "directions of file.buffer)."),
        dict(name="attention batch-reshard over model axis",
             targets="compute",
             delta=dict(attn_tp_fallback="batch_shard"),
             hypothesis=("archs whose head count does not divide the "
                         "model axis replicate attention compute 16x over "
                         "it; resharding batch over (data, model) for the "
                         "attention op costs 2 all-to-alls but divides "
                         "attention FLOPs+bytes by 16.")),
        dict(name="sequence-parallel residual stream",
             targets="memory",
             delta=dict(seq_parallel=True),
             hypothesis=("memory-bound train cells keep the (B,S,d) "
                         "residual + norms replicated over the 16-wide "
                         "model axis; seq-sharding it divides those bytes "
                         "by 16 for the cost of an all-gather at the "
                         "attention boundary (bytes ~ B*S*d*2/16 per "
                         "block — cheaper than the saved HBM traffic "
                         "when d is small relative to S).")),
        dict(name="bf16 remat-save (spill.compress)",
             targets="memory",
             delta=dict(remat_save_dtype="bfloat16"),
             hypothesis="halves the saved-residual bytes between layers "
                        "when compute is f32; no-op if bf16 already won.")
        ,
        dict(name="int8 collective codec",
             targets="collective",
             delta=dict(comm_codec="int8"),
             hypothesis=("collective term: MoE all-to-all bytes halve vs "
                         "bf16 (quant scales add <1%). Only bites "
                         "all-to-all-bound cells.")),
        dict(name="int8+EF gradient all-reduce (dp)",
             targets="collective",
             delta=dict(shard_strategy="dp", grad_comm_dtype="int8_ef",
                        fuse_grad_collectives=True),
             hypothesis=("for models whose replicated params fit HBM, dp "
                         "with 2-phase int8 error-feedback reduction cuts "
                         "grad wire bytes 4x vs f32 ring and removes the "
                         "per-layer FSDP all-gathers entirely; napkin: "
                         "only wins when params*4B < HBM/3 — expect a "
                         "crash verdict for >=7B archs (the trial decides).")),
        dict(name="4-way microbatching",
             targets="memory",
             delta=dict(microbatches=4),
             hypothesis=("peak-memory lever (maxSizeInFlight): 4x smaller "
                         "live activation set at ~same FLOPs; helps only "
                         "if the cell is peak-limited, not bandwidth-"
                         "limited — expect a small memory-term win; "
                         "verify it does not regress collectives.")),
    ]
    if kind == "decode":
        moves.insert(0, dict(
            name="int8 KV cache (rdd.compress)",
            targets="memory",
            delta=dict(kv_cache_dtype="int8"),
            hypothesis=("decode is KV-bandwidth-bound: reading the cache "
                        "dominates memory_s; int8 halves cache bytes vs "
                        "bf16 at per-(token,head) scales.")))
        moves.insert(1, dict(
            name="revisit shuffle.manager AFTER rdd.compress",
            targets="collective",
            delta=dict(shard_strategy="fsdp"),
            hypothesis=("tree-ordering artifact the paper acknowledges: "
                        "the manager stage ran BEFORE int8-KV was "
                        "accepted, so fsdp crashed on the bf16 cache and "
                        "was rejected; with the int8 cache in place, "
                        "fsdp removes the per-token replicated-weight "
                        "traffic — its rejected trial already showed the "
                        "collective term collapsing.")))
        moves.insert(2, dict(
            name="revisit manager: tp after rdd.compress",
            targets="collective",
            delta=dict(shard_strategy="tp"),
            hypothesis="second manager alternative on the revisit pass."))
    return moves


def hillclimb(arch: str, shape: str, paper_config: Optional[dict] = None,
              threshold: float = 0.05, patience: int = 3,
              executor=None, lookahead: int = 4):
    """Sequential accept/reject loop with speculative lookahead: while
    the verdict on move i is being decided, the executor warms the
    evaluator caches for moves i+1..i+lookahead applied to the *current*
    incumbent.  An accepted move invalidates the speculation (different
    base config) — the results are simply never used, so verdicts are
    identical to the sequential climb."""
    from repro.core import costmodel
    from repro.core.executor import SweepExecutor
    from repro.core.params import TunableConfig, default_config
    from repro.core.trial import RooflineEvaluator, TrialRunner, Workload

    wl = Workload(arch, shape)
    ev = executor.evaluator if executor is not None else RooflineEvaluator()
    own_executor = executor is None
    if own_executor:
        executor = SweepExecutor(ev)
    try:
        return _climb(wl, ev, executor, paper_config, threshold, patience,
                      lookahead)
    finally:
        if own_executor:
            # drop queued speculation; a running compile still lands in
            # the shared cache for the next call
            executor.shutdown(wait=False, cancel_futures=True)


def _climb(wl, ev, executor, paper_config, threshold, patience, lookahead):
    from repro.core import costmodel
    from repro.core.params import TunableConfig, default_config
    incumbent = (TunableConfig(**paper_config) if paper_config
                 else default_config(shard_strategy="fsdp_tp"))
    log = []
    base = ev(wl, incumbent)
    best = base.cost_s if not base.crashed else float("inf")
    model_s = (costmodel.model_flops(wl.cfg, wl.shp) / 256 /
               costmodel.HW["flops_bf16"])
    log.append(dict(step="paper-faithful tuned baseline",
                    hypothesis="(output of the Fig-4 tree)",
                    config=incumbent.as_dict(), cost_s=best,
                    roofline=base.roofline, verdict="baseline",
                    frac=model_s / best if best > 0 else 0.0))
    stale = 0
    bottleneck = (base.roofline or {}).get("bottleneck", "memory")
    moves = candidate_moves(wl.shp.kind)
    # hit the dominant term first (hypothesis ordering by predicted win)
    moves.sort(key=lambda m: (m.get("targets") != "ablation",
                              m.get("targets") != bottleneck))
    for i, mv in enumerate(moves):
        if stale >= patience:
            break
        if all(getattr(incumbent, k) == v for k, v in mv["delta"].items()):
            continue
        cand = incumbent.replace(**mv["delta"])
        # speculate on the next few moves against the current incumbent
        executor.prefetch(wl, [incumbent.replace(**m["delta"])
                               for m in moves[i + 1:i + 1 + lookahead]
                               if not all(getattr(incumbent, k) == v
                                          for k, v in m["delta"].items())])
        res = executor.submit(wl, cand).result()
        entry = dict(step=mv["name"], hypothesis=mv["hypothesis"],
                     delta=mv["delta"], cost_s=res.cost_s,
                     roofline=res.roofline)
        ablation = mv.get("targets") == "ablation"
        if res.crashed:
            entry["verdict"] = "crashed — rejected"
            stale += 0 if ablation else 1
        elif res.cost_s < best * (1 - threshold):
            entry["verdict"] = (f"confirmed — {best*1e3:.1f}ms -> "
                                f"{res.cost_s*1e3:.1f}ms "
                                f"({100*(1-res.cost_s/best):.0f}%)")
            incumbent, best, stale = cand, res.cost_s, 0
        else:
            gain = 100 * (1 - res.cost_s / max(best, 1e-12))
            entry["verdict"] = f"refuted/marginal ({gain:+.1f}%) — rejected"
            stale += 0 if ablation else 1
        entry["frac"] = model_s / res.cost_s if res.cost_s > 0 else 0.0
        log.append(entry)
    return dict(workload=wl.key(), final_config=incumbent.as_dict(),
                baseline_cost=log[0]["cost_s"], final_cost=best,
                roofline_fraction=model_s / best if best > 0 else 0.0,
                log=log)


def to_markdown(result: dict) -> str:
    out = [f"### Beyond-paper hillclimb: `{result['workload']}`", "",
           f"* paper-faithful tuned: {result['baseline_cost']*1e3:.2f} ms"
           f" -> beyond-paper: {result['final_cost']*1e3:.2f} ms "
           f"(x{result['baseline_cost']/max(result['final_cost'],1e-12):.2f})",
           f"* final roofline fraction: "
           f"**{result['roofline_fraction']:.3f}** of 256-chip bf16 peak",
           ""]
    for e in result["log"]:
        rl = e.get("roofline") or {}
        out += [f"**{e['step']}**",
                f"- hypothesis: {e['hypothesis']}",
                f"- result: {e['cost_s']*1e3:.2f} ms "
                f"(compute {rl.get('compute_s', 0)*1e3:.1f} / memory "
                f"{rl.get('memory_s', 0)*1e3:.1f} / collective "
                f"{rl.get('collective_s', 0)*1e3:.1f}; bottleneck "
                f"{rl.get('bottleneck','-')}; frac {e.get('frac',0):.3f})",
                f"- verdict: {e['verdict']}", ""]
    return "\n".join(out)


def main():
    from benchmarks.case_studies import select_cells
    from repro.core.executor import SweepExecutor
    from repro.core.params import default_config
    from repro.core.tree import run_tuning
    from repro.core.trial import RooflineEvaluator, TrialRunner, Workload
    PERF.mkdir(parents=True, exist_ok=True)
    # one evaluator + executor: all cells share the compile cache and pool
    executor = SweepExecutor(RooflineEvaluator())
    for arch, shape, why in select_cells():
        key = f"{arch}__{shape}__pod"
        # phase 1 (paper-faithful): the Fig-4 tree's output is the
        # hillclimb starting point (cache-hit instant after case studies)
        rep = run_tuning(
            TrialRunner(Workload(arch, shape), executor.evaluator),
            default_config(shard_strategy="fsdp_tp", attn_impl="pallas"),
            threshold=0.05, executor=executor)
        res = hillclimb(arch, shape, rep.final_config, executor=executor)
        md = f"Selection criterion: **{why}**\n\n" + to_markdown(res)
        (PERF / f"hillclimb_{key}.md").write_text(md)
        (PERF / f"hillclimb_{key}.json").write_text(
            json.dumps(res, indent=1, default=str))
        print(f"{key}: frac {res['roofline_fraction']:.3f} "
              f"({res['baseline_cost']*1e3:.1f} -> "
              f"{res['final_cost']*1e3:.1f} ms)")
    executor.shutdown()


if __name__ == "__main__":
    main()
