"""Fabric benchmark: lease-based multi-process cell throughput,
crash recovery, and warm-start trials-to-convergence.

Three arms over the PR-2 4-cell batch, all on the deterministic
synthetic surface (benchmarks/fabric_surface.py) with a fixed per-trial
latency — on this CPU-only box real XLA compiles are core-bound, so the
synthetic latency isolates what this benchmark is about: the *fabric
layer* (lease claiming, checkpointing, recovery, scheduling), whose
scaling carries over to compile-bound workers on real multi-core /
multi-host hardware.  The cost surface is independent of the latency,
so every arm's tuning decisions are comparable bit-for-bit.

  * **scaling** — 1 → 2 → 4 worker processes over one shared directory
    (subprocess workers via ``launch/tune.py --worker``).  Workers
    initialize behind a ready/go file barrier, so measured wall covers
    fabric work, not interpreter/JAX cold start (reported separately
    as ``startup_s``).  Per-cell decisions must be bit-identical to the
    single-process campaign in every arm;
  * **kill-recovery** — worker A is SIGKILL'd mid-campaign (lease left
    held, heartbeat frozen); worker B steals the expired lease,
    resumes from the checkpoints and completes the batch.  An
    evaluation ledger (every trial each process actually ran) is
    diffed against the checkpoint state captured at kill time: zero
    *absorbed* trials may be re-paid (in-flight unabsorbed trials are
    legitimately re-run — batch-boundary replay);
  * **warm-start** — a cold campaign populates the trial history; a
    second campaign over a fresh checkpoint dir warm-starts from it.
    Per cell: the number of evaluated trials until the cold run's best
    config first appears.  Warm must be strictly lower on >= 2 of the
    4 cells.

Results land in results/benchmarks/BENCH_fabric.json and a copy at the
repo root (BENCH_fabric.json) for CI tracking.

Run:  PYTHONPATH=src python -m benchmarks.bench_fabric
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import shutil
import signal
import sys
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent

DEFAULT_CELLS = ("smollm-135m:train_4k,smollm-135m:prefill_32k,"
                 "xlstm-1.3b:prefill_32k,xlstm-1.3b:decode_32k")
TRIAL_LATENCY_S = 0.5
KILL_LATENCY_S = 0.35
KILL_TTL_S = 2.0
EVALUATOR_SPEC = "benchmarks.fabric_surface:make_evaluator"


def _baseline(spec=None):
    from repro.core.params import default_config
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def _env(sleep_s=0.0, ledger=None):
    from benchmarks.fabric_surface import LEDGER_ENV, SLEEP_ENV
    env = os.environ.copy()
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT / "src"), str(ROOT)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env[SLEEP_ENV] = str(sleep_s)
    if ledger:
        env[LEDGER_ENV] = str(ledger)
    else:
        env.pop(LEDGER_ENV, None)
    return env


def _wait_files(paths, timeout_s=120.0):
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        if all(p.exists() for p in paths):
            return
        time.sleep(0.05)
    missing = [str(p) for p in paths if not p.exists()]
    raise TimeoutError(f"barrier files never appeared: {missing}")


def _absorbed_state(directory, cells):
    """(cell, config-json) pairs already absorbed per the checkpoints,
    plus which cells are done."""
    absorbed, done = set(), set()
    for spec in cells:
        path = directory / f"{spec.key()}.json"
        try:
            d = json.loads(path.read_text())
        except (OSError, ValueError):
            continue
        for e in d.get("log") or []:
            absorbed.add((d["cell"],
                          json.dumps(e["config"], sort_keys=True)))
        if d.get("done"):
            done.add(spec.key())
    return absorbed, done


def _reference_reports(cells):
    """Single-process campaign on the same surface — the decision
    oracle every fabric arm must reproduce bit-for-bit."""
    from benchmarks.fabric_surface import surface_cost
    from repro.core.campaign import Campaign
    return Campaign(cells, evaluator=surface_cost,
                    baseline_factory=_baseline,
                    checkpoint_dir=None).run()


def _fabric_reports(directory, cells):
    from repro.core.strategy import get_strategy
    spec = get_strategy("tree")
    out = {}
    for c in cells:
        d = json.loads((directory / f"{c.key()}.json").read_text())
        assert d.get("done"), f"{c.key()} incomplete"
        out[c.key()] = spec.load_report(d["report"])
    return out


def _identical(reports, ref):
    from repro.core.campaign import tuning_fingerprint
    return all(tuning_fingerprint(reports[k]) == tuning_fingerprint(ref[k])
               for k in ref)


# ------------------------------------------------------------- scaling
def run_scaling_arm(cells, n_workers, scratch):
    from repro.core.fabric import LeaseBoard, spawn_worker
    d = scratch / f"scale-{n_workers}w"
    barrier = d / "barrier"
    t_spawn = time.time()
    procs, readies = [], []
    go = barrier / "go"
    for i in range(n_workers):
        ready = barrier / f"ready-{i}"
        readies.append(ready)
        procs.append(spawn_worker(
            cells, d, strategy="tree", evaluator_spec=EVALUATOR_SPEC,
            ttl_s=30.0, worker_id=f"w{i}", ready_file=ready, go_file=go,
            log_path=d / "logs" / f"worker-{i}.log",
            env=_env(sleep_s=TRIAL_LATENCY_S)))
    _wait_files(readies)
    startup_s = time.time() - t_spawn
    t0 = time.time()
    go.parent.mkdir(parents=True, exist_ok=True)
    go.touch()
    rcs = [p.wait(timeout=300) for p in procs]
    wall = time.time() - t0
    assert not any(rcs), f"worker rcs {rcs}"
    assert LeaseBoard(d).held() == [], "lease left held"
    reports = _fabric_reports(d, cells)
    return {
        "workers": n_workers,
        "wall_s": round(wall, 2),
        "startup_s": round(startup_s, 2),
        "cells_per_hour": round(len(cells) * 3600.0 / max(wall, 1e-9), 1),
    }, reports


# ------------------------------------------------------- kill recovery
def run_kill_recovery_arm(cells, scratch):
    from repro.core.fabric import LeaseBoard, spawn_worker
    d = scratch / "kill"
    ledger_a, ledger_b = d / "ledger-a.jsonl", d / "ledger-b.jsonl"
    a = spawn_worker(cells, d, strategy="tree",
                     evaluator_spec=EVALUATOR_SPEC, ttl_s=KILL_TTL_S,
                     worker_id="worker-a",
                     log_path=d / "logs" / "worker-a.log",
                     env=_env(sleep_s=KILL_LATENCY_S, ledger=ledger_a))
    # wait until real progress is absorbed, then SIGKILL mid-campaign
    deadline = time.time() + 120
    while time.time() < deadline:
        absorbed, done = _absorbed_state(d, cells)
        if len(done) == len(cells):
            raise RuntimeError("worker A finished before the kill — "
                               "raise KILL_LATENCY_S")
        if len(absorbed) >= 4:
            break
        time.sleep(0.05)
    a.send_signal(signal.SIGKILL)
    a.wait(timeout=30)
    absorbed_at_kill, done_at_kill = _absorbed_state(d, cells)
    held = LeaseBoard(d).held()
    assert held, "SIGKILL'd worker should leave its lease on the board"
    t_kill = time.time()
    b = spawn_worker(cells, d, strategy="tree",
                     evaluator_spec=EVALUATOR_SPEC, ttl_s=KILL_TTL_S,
                     worker_id="worker-b",
                     log_path=d / "logs" / "worker-b.log",
                     env=_env(sleep_s=KILL_LATENCY_S, ledger=ledger_b))
    rc = b.wait(timeout=300)
    assert rc == 0, f"recovery worker rc {rc}"
    recovery_wall = time.time() - t_kill
    assert LeaseBoard(d).held() == [], "lease left held after recovery"

    evaluated_b = set()
    for line in ledger_b.read_text().splitlines():
        rec = json.loads(line)
        evaluated_b.add((rec["cell"],
                         json.dumps(rec["config"], sort_keys=True)))
    repaid = evaluated_b & absorbed_at_kill
    reports = _fabric_reports(d, cells)
    return {
        "killed_with_absorbed_trials": len(absorbed_at_kill),
        "cells_done_at_kill": len(done_at_kill),
        "lease_held_after_kill": [st.worker for st in held],
        "lease_ttl_s": KILL_TTL_S,
        "recovery_wall_s": round(recovery_wall, 2),
        "trials_evaluated_by_recoverer": len(evaluated_b),
        "repaid_absorbed_trials": len(repaid),
        "completed": True,
    }, reports


# ----------------------------------------------------------- warm-start
def trials_to_best(rep, target_config):
    for i, e in enumerate(rep.log):
        if e["config"] == target_config:
            return i + 1
    return None                          # never reached


def run_warmstart_arm(cells, scratch):
    from benchmarks.fabric_surface import surface_cost
    from repro.core.campaign import Campaign
    from repro.core.history import TrialHistory
    d = scratch / "warm"
    cold = Campaign(cells, evaluator=surface_cost,
                    baseline_factory=_baseline,
                    checkpoint_dir=d / "cold").run()
    hist = TrialHistory(d / "cold" / "history.jsonl")
    warm_camp = Campaign(cells, evaluator=surface_cost,
                         baseline_factory=_baseline,
                         checkpoint_dir=d / "warm",
                         history=hist, warm_start=True)
    warm = warm_camp.run()
    per_cell = {}
    improved = []
    for c in cells:
        target = cold[c.key()].final_config
        t_cold = trials_to_best(cold[c.key()], target)
        t_warm = trials_to_best(warm[c.key()], target)
        per_cell[c.key()] = {
            "cold_trials_to_best": t_cold,
            "warm_trials_to_best": t_warm,
            "cold_trials": cold[c.key()].n_trials,
            "warm_trials": warm[c.key()].n_trials,
        }
        if t_warm is not None and t_warm < t_cold:
            improved.append(c.key())
    return {
        "warmstarted_cells": warm_camp.last_stats["warmstarted_cells"],
        "per_cell": per_cell,
        "improved_cells": improved,
        "n_improved": len(improved),
    }


# ------------------------------------------------------------------ main
def main(cells_spec: str):
    from repro.core.campaign import parse_cells
    cells = parse_cells(cells_spec)
    print(f"batch: {len(cells)} cells "
          f"({', '.join(c.key() for c in cells)})")
    scratch = ROOT / "results" / "bench_fabric_scratch"
    shutil.rmtree(scratch, ignore_errors=True)

    ref = _reference_reports(cells)
    scaling, identical = {}, True
    for n in (1, 2, 4):
        stats, reports = run_scaling_arm(cells, n, scratch)
        identical &= _identical(reports, ref)
        scaling[str(n)] = stats
        print(f"scaling {n}w: {stats['wall_s']}s "
              f"({stats['cells_per_hour']} cells/h, "
              f"startup {stats['startup_s']}s)")
    speedup_2w = round(scaling["1"]["wall_s"]
                       / max(scaling["2"]["wall_s"], 1e-9), 2)
    speedup_4w = round(scaling["1"]["wall_s"]
                       / max(scaling["4"]["wall_s"], 1e-9), 2)
    print(f"speedup: 2w x{speedup_2w}, 4w x{speedup_4w}, "
          f"decisions identical={identical}")

    kill, kill_reports = run_kill_recovery_arm(cells, scratch)
    identical_kill = _identical(kill_reports, ref)
    print(f"kill-recovery: {kill['killed_with_absorbed_trials']} trials "
          f"absorbed at kill, {kill['repaid_absorbed_trials']} re-paid, "
          f"identical={identical_kill}")

    warm = run_warmstart_arm(cells, scratch)
    print(f"warm-start: fewer trials-to-best on {warm['n_improved']}"
          f"/{len(cells)} cells ({', '.join(warm['improved_cells'])})")

    out = {
        "cells": [c.key() for c in cells],
        "trial_latency_s": TRIAL_LATENCY_S,
        "evaluator": EVALUATOR_SPEC,
        "scaling": scaling,
        "speedup_2w": speedup_2w,
        "speedup_4w": speedup_4w,
        "identical_to_single_process": identical,
        "kill_recovery": {**kill,
                          "identical_to_single_process": identical_kill},
        "warmstart": warm,
    }
    res_dir = ROOT / "results" / "benchmarks"
    res_dir.mkdir(parents=True, exist_ok=True)
    (res_dir / "BENCH_fabric.json").write_text(json.dumps(out, indent=1))
    (ROOT / "BENCH_fabric.json").write_text(json.dumps(out, indent=1))
    shutil.rmtree(scratch, ignore_errors=True)
    print(json.dumps(out, indent=1))
    assert identical and identical_kill, \
        "fabric changed tuning decisions!"
    assert speedup_2w >= 1.6, \
        f"2-worker cell-throughput speedup {speedup_2w} < 1.6x"
    assert kill["repaid_absorbed_trials"] == 0, \
        "lease recovery re-paid absorbed trials!"
    assert warm["n_improved"] >= 2, \
        "warm-start failed to cut trials-to-best on >= 2 cells"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=DEFAULT_CELLS,
                    help="comma-separated arch:shape[:pod|multipod]")
    a = ap.parse_args()
    main(a.cells)
