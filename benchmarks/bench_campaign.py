"""Campaign-throughput benchmark: the concurrent multi-cell campaign
(core/campaign.py) vs. the sequential per-cell tuning loop on the same
batch of cells.

Three arms, all cache-cold:

  * ``sequential`` — the paper's per-cell loop: every cell tuned on its
    own, one trial at a time, every trial paying its four calibration
    compiles (no engine; what the pre-trial-throughput reproduction and
    the naive methodology cost per cell);
  * ``sequential_engine`` — one ``tune_cell``-style process per cell:
    per-cell executor + per-cell cold compile cache, cells run one
    after another (no state shared across cells — today's
    one-cell-per-process reality);
  * ``campaign`` — one shared executor + one shared compile cache, all
    cells' tree cursors interleaved, per-cell checkpoints.

Every arm must produce the same tuning decisions per cell
(``identical_reports`` checks the deterministic projection of each
report: costs, crash flags, accept/reject, final configs — the compile
wall-clock accounting fields are environment noise and excluded).  The
campaign arm is additionally resumed from its checkpoints to prove an
interrupted campaign re-pays nothing (``resume.evaluated_trials == 0``).

A fourth arm exercises the Strategy API: the full Table-2 sensitivity
matrix for the same batch, scheduled as a ``--strategy sensitivity``
campaign (cache-cold, shared compile cache), then re-derived per cell
with the blocking ``run_sensitivity`` on the SAME evaluator — the
KnobImpact tables must match exactly and the direct pass must pay zero
extra compiles (proof the campaign populated the shared cache).

A fifth arm measures the learned proposer (core/proposer.py):
budget-matched ``model`` vs ``tree`` vs ``random`` walks on the
deterministic fabric surface with a pre-seeded trial history (three
finished same-kind tree walks — the cumulative-campaign situation the
strategy exists for).  Reported per arm: trials-to-best (how many
trials until the walk first evaluates its best-found config) and
trials-to-first-improvement.  The ``model`` arm must reach its best
in strictly fewer trials than both baselines.

Results land in results/benchmarks/BENCH_campaign.json and a copy at
the repo root (BENCH_campaign.json) for CI tracking.

Run:  PYTHONPATH=src python -m benchmarks.bench_campaign [--cells ...]
      (``--proposer-only`` re-runs just the proposer arm — it is
      synthetic-surface and seconds, not minutes — and merges it into
      the existing JSON.)
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import pathlib
import shutil
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent

# Default batch: four cells over two archs (so arch-grouping matters),
# all serving/small-train cells that compile quickly on CPU.
DEFAULT_CELLS = ("smollm-135m:train_4k,smollm-135m:prefill_32k,"
                 "xlstm-1.3b:prefill_32k,xlstm-1.3b:decode_32k")


def _baseline(spec):
    from repro.core.params import default_config
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def run_sequential(cells, threshold, engine: bool, scratch: pathlib.Path):
    """Per-cell loop.  engine=False: no cache, no executor (the naive
    methodology).  engine=True: per-cell executor + per-cell cold cache
    (today's one-process-per-cell path)."""
    from repro.core.executor import SweepExecutor
    from repro.core.tree import run_tuning
    from repro.core.trial import CompileCache, RooflineEvaluator, \
        TrialRunner
    reports, compiles = {}, 0
    t0 = time.time()
    for spec in cells:
        if engine:
            cache = CompileCache(directory=scratch / spec.key())
            ev = RooflineEvaluator(compile_cache=cache)
            with SweepExecutor(ev) as ex:
                runner = TrialRunner(spec.workload(), ev)
                rep = run_tuning(runner, _baseline(spec),
                                 threshold=threshold, executor=ex)
        else:
            ev = RooflineEvaluator(use_cache=False)
            runner = TrialRunner(spec.workload(), ev)
            rep = run_tuning(runner, _baseline(spec), threshold=threshold)
        compiles += ev.total_compiles
        reports[spec.key()] = rep
    return reports, compiles, time.time() - t0


def run_campaign(cells, threshold, scratch: pathlib.Path):
    from repro.core.campaign import Campaign
    from repro.core.trial import CompileCache, RooflineEvaluator
    ev = RooflineEvaluator(
        compile_cache=CompileCache(directory=scratch / "shared"))
    camp = Campaign(cells, threshold=threshold, evaluator=ev,
                    baseline_factory=_baseline,
                    checkpoint_dir=scratch / "checkpoints")
    t0 = time.time()
    reports = camp.run()
    wall = time.time() - t0
    return reports, ev.total_compiles, wall, camp.last_stats, ev


def run_sensitivity_arm(cells, scratch: pathlib.Path):
    """Table-2 matrix as a campaign strategy, cache-cold, then the
    blocking per-cell driver warm on the same evaluator."""
    import dataclasses
    import json as _json
    from repro.core.campaign import Campaign
    from repro.core.sensitivity import run_sensitivity
    from repro.core.trial import CompileCache, RooflineEvaluator, \
        TrialRunner
    ev = RooflineEvaluator(
        compile_cache=CompileCache(directory=scratch / "shared"))
    camp = Campaign(cells, strategy="sensitivity", evaluator=ev,
                    baseline_factory=_baseline,
                    checkpoint_dir=scratch / "checkpoints")
    t0 = time.time()
    reports = camp.run()
    wall = time.time() - t0
    campaign_compiles = ev.total_compiles

    def fp(rep):
        return _json.dumps(dataclasses.asdict(rep), sort_keys=True,
                           default=str)

    identical = True
    for spec in cells:
        runner = TrialRunner(spec.workload(), ev)
        ref = run_sensitivity(runner, _baseline(spec))
        # compile accounting differs warm-vs-cold; the decisions (the
        # KnobImpact table, baseline cost, run count) may not
        if ref.table() != reports[spec.key()].table() \
                or fp(ref) != fp(reports[spec.key()]):
            identical = False
    direct_extra = ev.total_compiles - campaign_compiles
    return {
        "compiles": campaign_compiles,
        "wall_s": round(wall, 1),
        "trials": camp.last_stats["trials"],
        "cells_per_hour": camp.last_stats["cells_per_hour"],
        "cache": ev.compile_cache.stats(),
        "identical_to_run_sensitivity": identical,
        "direct_rerun_extra_compiles": direct_extra,
    }


PROPOSER_SEED_CELLS = ("smollm-135m:train_4k,glm4-9b:train_4k,"
                       "xlstm-1.3b:train_4k")
PROPOSER_TARGET_CELLS = "olmoe-1b-7b:train_4k,zamba2-7b:train_4k"


def _walk_metrics(rep):
    """Trials-to-best / -to-first-improvement of one walk's log."""
    costs = []
    for e in rep.log:
        r = e["result"] if isinstance(e["result"], dict) \
            else e["result"].__dict__
        costs.append(r.get("cost_s", float("inf")))
    finite = [c for c in costs if c == c and c != float("inf")]
    best = min(finite) if finite else float("inf")
    to_best = next((i + 1 for i, c in enumerate(costs) if c == best),
                   len(costs))
    to_improve = next((i + 1 for i, c in enumerate(costs)
                       if c < rep.baseline_cost), None)
    return {"final_cost_s": rep.final_cost, "n_trials": rep.n_trials,
            "trials_to_best": to_best,
            "trials_to_first_improvement": to_improve}


def run_proposer_arm(scratch: pathlib.Path, budget: int = 10,
                     threshold: float = 0.05):
    """Budget-matched model vs tree vs random on the deterministic
    fabric surface, with a history pre-seeded by three finished
    same-kind tree walks (the cumulative-campaign situation the
    ``model`` strategy exists for)."""
    from benchmarks.fabric_surface import surface_cost
    from repro.core.campaign import Campaign, parse_cells
    seed_cells = parse_cells(PROPOSER_SEED_CELLS)
    targets = parse_cells(PROPOSER_TARGET_CELLS)
    scratch.mkdir(parents=True, exist_ok=True)
    Campaign(seed_cells, evaluator=surface_cost,
             baseline_factory=_baseline, threshold=threshold,
             checkpoint_dir=scratch / "seed").run()
    seed_history = scratch / "seed" / "history.jsonl"

    arms = {}
    for arm, options in (("tree", {}),
                         ("random", {"budget": budget, "seed": 0}),
                         ("model", {"budget": budget, "seed": 0})):
        arm_dir = scratch / arm
        arm_dir.mkdir(parents=True, exist_ok=True)
        shutil.copy(seed_history, arm_dir / "history.jsonl")
        camp = Campaign(targets, strategy=arm,
                        strategy_options=options,
                        evaluator=surface_cost,
                        baseline_factory=_baseline,
                        threshold=threshold,
                        checkpoint_dir=arm_dir)
        reports = camp.run()
        cells = {k: _walk_metrics(r) for k, r in reports.items()}
        arms[arm] = {
            "cells": cells,
            "trials_to_best": sum(m["trials_to_best"]
                                  for m in cells.values()),
            "final_cost_s": round(sum(m["final_cost_s"]
                                      for m in cells.values()), 6),
        }
    out = {
        "seed_cells": [c.key() for c in seed_cells],
        "target_cells": [c.key() for c in targets],
        "budget": budget,
        "seed_history_records": sum(
            1 for _ in seed_history.open()),
        "arms": arms,
        "model_fewest_trials_to_best":
            arms["model"]["trials_to_best"]
            < min(arms["tree"]["trials_to_best"],
                  arms["random"]["trials_to_best"]),
        "model_final_no_worse":
            arms["model"]["final_cost_s"]
            <= min(arms["tree"]["final_cost_s"],
                   arms["random"]["final_cost_s"]) + 1e-9,
    }
    return out


def main(cells_spec: str, threshold: float = 0.05):
    from repro.core.campaign import parse_cells, tuning_fingerprint
    from repro.core.trial import RooflineEvaluator
    from repro.core.campaign import Campaign
    cells = parse_cells(cells_spec)
    print(f"batch: {len(cells)} cells "
          f"({', '.join(c.key() for c in cells)})")

    scratch = ROOT / "results" / "bench_campaign_scratch"
    shutil.rmtree(scratch, ignore_errors=True)

    naive_reports, naive_compiles, naive_wall = run_sequential(
        cells, threshold, engine=False, scratch=scratch)
    print(f"sequential (naive): {naive_compiles} compiles, "
          f"{naive_wall:.0f}s")
    seq_reports, seq_compiles, seq_wall = run_sequential(
        cells, threshold, engine=True, scratch=scratch / "seq")
    print(f"sequential (engine, per-cell): {seq_compiles} compiles, "
          f"{seq_wall:.0f}s")
    camp_reports, camp_compiles, camp_wall, camp_stats, ev = run_campaign(
        cells, threshold, scratch=scratch / "camp")
    print(f"campaign: {camp_compiles} compiles, {camp_wall:.0f}s")
    sens = run_sensitivity_arm(cells, scratch=scratch / "sens")
    print(f"sensitivity campaign: {sens['compiles']} compiles, "
          f"{sens['wall_s']:.0f}s, "
          f"identical={sens['identical_to_run_sensitivity']}")
    proposer = run_proposer_arm(scratch / "proposer")
    print("proposer arm trials-to-best: " + ", ".join(
        f"{arm}={d['trials_to_best']}"
        for arm, d in proposer["arms"].items()))

    # resume from the checkpoints: must replay everything, evaluate nothing
    camp2 = Campaign(cells, threshold=threshold,
                     evaluator=RooflineEvaluator(use_cache=False),
                     baseline_factory=_baseline,
                     checkpoint_dir=scratch / "camp" / "checkpoints")
    resumed = camp2.run()
    resume_ok = (camp2.last_stats["evaluated_trials"] == 0
                 and all(tuning_fingerprint(resumed[k])
                         == tuning_fingerprint(camp_reports[k])
                         for k in camp_reports))

    mismatches = []
    for key in (c.key() for c in cells):
        fps = {arm: tuning_fingerprint(r[key]) for arm, r in
               [("naive", naive_reports), ("seq", seq_reports),
                ("campaign", camp_reports)]}
        if not (fps["naive"] == fps["seq"] == fps["campaign"]):
            mismatches.append(key)

    out = {
        "cells": [c.key() for c in cells],
        "threshold": threshold,
        "trials_per_batch": sum(r.n_trials
                                for r in camp_reports.values()),
        "sequential": {"compiles": naive_compiles,
                       "wall_s": round(naive_wall, 1),
                       "cells_per_hour": round(
                           len(cells) * 3600.0 / max(naive_wall, 1e-9), 1)},
        "sequential_engine": {"compiles": seq_compiles,
                              "wall_s": round(seq_wall, 1),
                              "cells_per_hour": round(
                                  len(cells) * 3600.0
                                  / max(seq_wall, 1e-9), 1)},
        "campaign": {"compiles": camp_compiles,
                     "wall_s": round(camp_wall, 1),
                     "cells_per_hour": camp_stats["cells_per_hour"],
                     "trials": camp_stats["trials"],
                     "cache": ev.compile_cache.stats()},
        "sensitivity_campaign": sens,
        "proposer": proposer,
        "compile_reduction_x": round(naive_compiles
                                     / max(1, camp_compiles), 2),
        "wall_speedup_x": round(naive_wall / max(1e-9, camp_wall), 2),
        "interleave_speedup_x": round(seq_wall / max(1e-9, camp_wall), 2),
        "resume_repaid_nothing": resume_ok,
        "identical_reports": not mismatches,
        "mismatches": mismatches,
    }
    res_dir = ROOT / "results" / "benchmarks"
    res_dir.mkdir(parents=True, exist_ok=True)
    (res_dir / "BENCH_campaign.json").write_text(json.dumps(out, indent=1))
    (ROOT / "BENCH_campaign.json").write_text(json.dumps(out, indent=1))
    shutil.rmtree(scratch, ignore_errors=True)
    print(json.dumps(out, indent=1))
    assert not mismatches, "campaign changed tuning decisions!"
    assert resume_ok, "campaign resume re-paid trials!"
    assert sens["identical_to_run_sensitivity"], \
        "sensitivity-via-campaign changed the KnobImpact table!"
    assert proposer["model_fewest_trials_to_best"], \
        "model arm did not beat tree/random on trials-to-best!"
    return out


def proposer_only():
    """Re-run just the (synthetic, seconds-long) proposer arm and merge
    it into the existing BENCH_campaign.json — the compile-bound arms
    are untouched."""
    scratch = ROOT / "results" / "bench_campaign_scratch"
    shutil.rmtree(scratch, ignore_errors=True)
    proposer = run_proposer_arm(scratch / "proposer")
    shutil.rmtree(scratch, ignore_errors=True)
    res = ROOT / "results" / "benchmarks" / "BENCH_campaign.json"
    out = json.loads(res.read_text()) if res.exists() else {}
    out["proposer"] = proposer
    res.parent.mkdir(parents=True, exist_ok=True)
    res.write_text(json.dumps(out, indent=1))
    (ROOT / "BENCH_campaign.json").write_text(json.dumps(out, indent=1))
    print(json.dumps(proposer, indent=1))
    assert proposer["model_fewest_trials_to_best"], \
        "model arm did not beat tree/random on trials-to-best!"
    return proposer


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=DEFAULT_CELLS,
                    help="comma-separated arch:shape[:pod|multipod]")
    ap.add_argument("--threshold", type=float, default=0.05)
    ap.add_argument("--proposer-only", action="store_true",
                    help="re-run just the learned-proposer arm and "
                         "merge it into the existing JSON")
    a = ap.parse_args()
    if a.proposer_only:
        proposer_only()
    else:
        main(a.cells, a.threshold)
