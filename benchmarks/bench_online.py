"""Online-scheduler benchmark: expected-speedup priority + live intake.

Two arms over a 4-cell batch on a deterministic synthetic surface
(fixed per-trial latency; the cost surface is independent of the
latency, so every arm's tuning decisions are comparable bit-for-bit):

  * **time-to-first-improvement** — the batch has two "dud" train cells
    (no knob moves the cost — nothing to find) and two serving cells
    with large wins.  A primed trial history records exactly that
    structure for *neighbour* cells (same shape kind, different arch),
    so ``prioritize="history"`` schedules the win cells first while the
    historical ``arch`` order grinds through the duds.  With one cell
    slot (``max_active_cells=1``, the fabric's per-worker shape) the
    wall-clock until the first accepted improvement is the headline:
    history-priority must reach it strictly sooner on the same batch,
    with per-cell decisions bit-identical across both arms;
  * **mid-run admission latency** — a campaign over one cell; a second
    cell is submitted to the intake directory while the first trial is
    in flight.  Measured: submission → the admitted cell's first
    evaluated trial, and that the admitted cell completes in the same
    run (no restart).

Results land in results/benchmarks/BENCH_online.json and a copy at the
repo root (BENCH_online.json) for CI tracking.

Run:  PYTHONPATH=src python -m benchmarks.bench_online
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import json
import pathlib
import shutil
import threading
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent

TRIAL_LATENCY_S = 0.05
THRESHOLD = 0.05


def _baseline(spec=None):
    from repro.core.params import default_config
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def online_surface(wl, rt):
    """Duds and wins: train cells are flat (no knob helps — the cost of
    scheduling them first is pure wasted budget), serving cells carry
    the big wins the paper's serializer/rdd.compress stages find."""
    from repro.core.trial import TrialResult
    kind = wl.shp.kind
    c = 100.0 * (1.0 + 0.01 * (len(wl.arch) % 7))
    if kind != "train":
        if rt.compute_dtype == "bfloat16":
            c *= 0.72
        if rt.kv_cache_dtype == "int8":
            c *= 0.85
        if rt.attn_block_q == 256:
            c *= 0.92
    return TrialResult(cost_s=round(c, 6))


class TimedSurface:
    """online_surface + fixed latency + an evaluation ledger of
    (monotonic time, cell, cost)."""

    def __init__(self, sleep_s=TRIAL_LATENCY_S):
        self.sleep_s = sleep_s
        self.ledger = []
        self.lock = threading.Lock()

    def __call__(self, wl, rt):
        res = online_surface(wl, rt)
        if self.sleep_s:
            time.sleep(self.sleep_s)
        with self.lock:
            self.ledger.append((time.monotonic(), wl.key(), res.cost_s))
        return res


def prime_history(path, entries):
    """Write neighbour-cell (baseline, best) pairs demonstrating the
    given speedups — what an earlier campaign would have left behind."""
    from repro.core.history import TrialHistory
    from repro.core.params import default_config
    from repro.core.trial import Workload
    hist = TrialHistory(path)
    ts = 1.0
    for arch, shape, speedup in entries:
        wl = Workload(arch, shape)
        for name, cost in (("baseline", 100.0),
                           ("best", 100.0 / speedup)):
            hist.append({
                "v": 1, "ts": ts, "cell": wl.key(), "arch": arch,
                "shape": shape, "multi_pod": False, "strategy": "tree",
                "name": name, "delta": {},
                "config": default_config().as_dict(), "cost_s": cost,
                "crashed": False, "compiles": 0, "compile_s": 0.0,
                "cached": False})
            ts += 1.0
    return hist


PRIMED = [
    # train neighbours demonstrate "nothing to gain" ...
    ("olmoe-1b-7b", "train_4k", 1.0),
    ("deepseek-coder-33b", "train_4k", 1.0),
    # ... serving neighbours demonstrate the big wins
    ("zamba2-7b", "prefill_32k", 1.75),
    ("zamba2-7b", "decode_32k", 1.80),
]


def first_improvement_s(ledger, t0, threshold=THRESHOLD):
    """Wall seconds from t0 until some cell's trial first beats that
    cell's own baseline (its first evaluated trial) by > threshold."""
    baselines = {}
    for t, cell, cost in ledger:
        if cell not in baselines:
            baselines[cell] = cost
            continue
        if cost < baselines[cell] * (1.0 - threshold):
            return round(t - t0, 3)
    return None


def run_priority_arm(cells, mode, scratch):
    from repro.core.campaign import Campaign
    d = scratch / f"prio-{mode}"
    prime_history(d / "history.jsonl", PRIMED)
    surface = TimedSurface()
    camp = Campaign(cells, evaluator=surface,
                    baseline_factory=_baseline, checkpoint_dir=d,
                    threshold=THRESHOLD, prioritize=mode,
                    max_active_cells=1, max_workers=1)
    t0 = time.monotonic()
    reports = camp.run()
    wall = time.monotonic() - t0
    order = list(dict.fromkeys(cell for _, cell, _ in surface.ledger))
    return {
        "cell_order": order,
        "first_improvement_s": first_improvement_s(surface.ledger, t0),
        "wall_s": round(wall, 2),
        "trials": len(surface.ledger),
    }, reports


def run_admission_arm(seed_cell, late_cell, scratch):
    from repro.core.campaign import Campaign
    from repro.core.schedule import submit_cells
    d = scratch / "admission"
    surface = TimedSurface()
    submitted = {}

    real_call = surface.__call__

    def gated(wl, rt):
        # submit the late cell while the first trial is in flight —
        # the running campaign must admit it between batches
        if "t" not in submitted:
            submit_cells(d, [late_cell])
            submitted["t"] = time.monotonic()
        return real_call(wl, rt)

    camp = Campaign([seed_cell], evaluator=gated,
                    baseline_factory=_baseline, checkpoint_dir=d,
                    threshold=THRESHOLD, intake=True, max_workers=1)
    reports = camp.run()
    late_key = late_cell.key()
    first_late = next(t for t, cell, _ in surface.ledger
                      if cell == late_key)
    return {
        "seed_cell": seed_cell.key(),
        "admitted_cell": late_key,
        "submit_to_first_trial_s": round(first_late - submitted["t"], 3),
        "admitted_completed": late_key in reports
        and reports[late_key] is not None,
        "cells_reported": sorted(reports),
        "from_intake": camp.last_stats["queue"]["from_intake"],
    }


def main():
    from repro.core.campaign import Campaign, parse_cells, \
        tuning_fingerprint
    cells = parse_cells("smollm-135m:train_4k,glm4-9b:train_4k,"
                        "xlstm-1.3b:prefill_32k,xlstm-1.3b:decode_32k")
    print(f"batch: {len(cells)} cells "
          f"({', '.join(c.key() for c in cells)})")
    scratch = ROOT / "results" / "bench_online_scratch"
    shutil.rmtree(scratch, ignore_errors=True)

    # decision oracle: the plain batch campaign on the same surface
    ref = Campaign(cells, evaluator=online_surface,
                   baseline_factory=_baseline, threshold=THRESHOLD,
                   checkpoint_dir=None).run()

    arms, identical = {}, True
    for mode in ("arch", "history"):
        stats, reports = run_priority_arm(cells, mode, scratch)
        identical &= all(
            tuning_fingerprint(reports[k]) == tuning_fingerprint(ref[k])
            for k in ref)
        arms[mode] = stats
        print(f"{mode}: first improvement at "
              f"{stats['first_improvement_s']}s of {stats['wall_s']}s "
              f"(order: {' -> '.join(stats['cell_order'])})")
    gain = round(arms["arch"]["first_improvement_s"]
                 / max(arms["history"]["first_improvement_s"], 1e-9), 2)
    print(f"history-priority reaches first improvement x{gain} sooner, "
          f"decisions identical={identical}")

    admission = run_admission_arm(cells[2], cells[3], scratch)
    print(f"admission: {admission['admitted_cell']} submitted mid-run, "
          f"first trial {admission['submit_to_first_trial_s']}s after "
          f"submit, completed={admission['admitted_completed']}")

    out = {
        "cells": [c.key() for c in cells],
        "trial_latency_s": TRIAL_LATENCY_S,
        "threshold": THRESHOLD,
        "primed_history": [{"arch": a, "shape": s, "speedup": sp}
                           for a, s, sp in PRIMED],
        "prioritize": arms,
        "first_improvement_speedup": gain,
        "identical_to_static_campaign": identical,
        "admission": admission,
    }
    res_dir = ROOT / "results" / "benchmarks"
    res_dir.mkdir(parents=True, exist_ok=True)
    (res_dir / "BENCH_online.json").write_text(json.dumps(out, indent=1))
    (ROOT / "BENCH_online.json").write_text(json.dumps(out, indent=1))
    shutil.rmtree(scratch, ignore_errors=True)
    print(json.dumps(out, indent=1))
    assert identical, "priority mode changed tuning decisions!"
    assert arms["history"]["first_improvement_s"] \
        < arms["arch"]["first_improvement_s"], \
        "history-priority did not reach the first improvement sooner"
    assert admission["admitted_completed"], \
        "mid-run admitted cell did not complete"
    return out


if __name__ == "__main__":
    main()
