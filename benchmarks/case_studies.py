"""Sec.-5 case studies = the three hillclimb cells.

Cell selection is computed from the dry-run baseline table
(results/dryrun/*.json), per the assignment's criteria:
  1. worst roofline fraction  (model-FLOPs time / roofline step time)
  2. most collective-bound    (largest collective_s / total_s)
  3. most representative of the paper's technique — the MoE all-to-all
     trainer (the paper's own "shuffling" stress benchmark analogue)
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, List, Tuple

from repro.core import costmodel
from repro.core.params import default_config

DRYRUN = pathlib.Path(__file__).resolve().parent.parent / "results" / "dryrun"
REPRESENTATIVE = ("olmoe-1b-7b", "train_4k")


def _records() -> List[Dict]:
    out = []
    for f in sorted(DRYRUN.glob("*.json")):
        d = json.loads(f.read_text())
        if d.get("status") == "ok" and "multipod" not in d["mesh"]:
            out.append(d)
    return out


def roofline_fraction(rec: Dict) -> float:
    """useful model-FLOPs time / roofline step time, per chip."""
    from repro.configs import get_config, get_shape
    rl = rec["roofline"]
    mf = costmodel.model_flops(get_config(rec["arch"]),
                               get_shape(rec["shape"]))
    model_s = (mf / 256) / costmodel.HW["flops_bf16"]
    return model_s / max(rl["total_s"], 1e-12)


def select_cells() -> List[Tuple[str, str, str]]:
    recs = _records()
    if not recs:
        raise RuntimeError("run repro.launch.dryrun first")
    worst = min(recs, key=roofline_fraction)
    coll = max(recs, key=lambda r: (r["roofline"]["collective_s"]
                                    / max(r["roofline"]["total_s"], 1e-12)))
    cells = []
    seen = set()
    for why, rec in [("worst-roofline-fraction", worst),
                     ("most-collective-bound", coll)]:
        key = (rec["arch"], rec["shape"])
        if key in seen:   # fall to next-worst distinct cell
            pool = sorted(recs, key=roofline_fraction)
            rec = next(r for r in pool
                       if (r["arch"], r["shape"]) not in seen)
            key = (rec["arch"], rec["shape"])
        seen.add(key)
        cells.append((rec["arch"], rec["shape"], why))
    if REPRESENTATIVE not in seen:
        cells.append((*REPRESENTATIVE, "paper-technique-representative"))
    else:
        pool = [r for r in recs if (r["arch"], r["shape"]) not in seen]
        rec = max(pool, key=lambda r: r["roofline"]["collective_s"])
        cells.append((rec["arch"], rec["shape"], "next-collective-bound"))
    return cells


def run_case_studies(threshold: float = 0.05):
    """The three selected cells as one concurrent campaign: their tree
    walks interleave over one shared executor + compile cache, and each
    cell's report is bit-identical to the historical per-cell loop."""
    from benchmarks.common import save
    from repro.core import report
    from repro.core.campaign import Campaign, CellSpec
    selected = select_cells()
    camp = Campaign(
        [CellSpec(arch, shape) for arch, shape, _ in selected],
        threshold=threshold,
        baseline_factory=lambda spec: default_config(
            shard_strategy="fsdp_tp", attn_impl="pallas"),
        checkpoint_dir=None)        # benchmarks re-tune every run
    reports = camp.run()
    reps = []
    for (arch, shape, why), (key, rep) in zip(selected, reports.items()):
        md = (f"Selection criterion: **{why}**\n\n"
              + report.tuning_markdown(rep))
        save(f"case_study_{key}.md", md)
        reps.append(rep)
    save("case_study_campaign.md", report.campaign_markdown(reports))
    return reps


if __name__ == "__main__":
    for rep in run_case_studies():
        print(rep.workload, f"x{rep.speedup:.2f} in {rep.n_trials} trials")
