"""Synthetic model-vs-truth surfaces for the measured-tier benchmark.

The measured tier exists because the model's ranking can be wrong; this
surface pair makes it wrong *on purpose*, deterministically:

  * :func:`make_evaluator` — the model surface: exactly the fault-free
    :func:`benchmarks.fabric_surface.surface_cost` (so walk decisions
    stay bit-identical to every other benchmark on these cells);
  * :func:`make_measured_evaluator` — the "ground truth" a real run
    would measure: the same surface except the configs matching
    ``MEASURED_FLIP_DELTA`` (default ``attn_block_q=256`` — the
    model's favourite *last-stage* move, so a cell's top-2 candidates
    are guaranteed to disagree on it) are *slower* by
    ``MEASURED_FLIP_FACTOR`` (default 1.6).  Wherever the model's top
    choice matches the flip delta and the runner-up does not,
    measurement must overturn the ranking.

Environment variables (the ``launch/tune.py --measured-evaluator``
subprocess channel, mirroring benchmarks/chaos_surface.py):

  * ``MEASURED_FLIP_DELTA`` — ``knob=value[,knob=value...]``: configs
    matching every pair get the truth penalty;
  * ``MEASURED_FLIP_FACTOR`` — the penalty multiplier (default 1.6);
  * ``MEASURED_LEDGER`` — optional path; one ``{"cell", "config"}``
    JSON line is appended per *real* truth evaluation (cache hits do
    not append), so benchmarks count exactly how many measured
    evaluations a campaign paid;
  * ``MEASURED_CACHE_DIR`` — timing-cache directory for the returned
    :class:`~repro.core.measure.CachedMeasure` (default: a fresh
    in-memory-only cache, so bench arms control reuse explicitly).
"""
from __future__ import annotations

import json
import os
import pathlib

from benchmarks.chaos_surface import matches, parse_delta
from benchmarks.fabric_surface import surface_cost

FLIP_ENV = "MEASURED_FLIP_DELTA"
FACTOR_ENV = "MEASURED_FLIP_FACTOR"
LEDGER_ENV = "MEASURED_LEDGER"
CACHE_ENV = "MEASURED_CACHE_DIR"

DEFAULT_FLIP = "attn_block_q=256"


def make_evaluator():
    """The model surface (``--evaluator`` factory)."""
    return surface_cost


def truth_cost(wl, rt):
    """The measured-truth surface: the model surface with the flip
    configs penalized."""
    res = surface_cost(wl, rt)
    flip = parse_delta(os.environ.get(FLIP_ENV, DEFAULT_FLIP))
    if flip and matches(rt, flip):
        factor = float(os.environ.get(FACTOR_ENV, "1.6"))
        res.cost_s = round(res.cost_s * factor, 6)
    res.compiles, res.compile_s = 1, 0.01
    return res


def make_measured_evaluator():
    """The truth surface behind a timing cache (``--measured-evaluator``
    factory); ledger-counted so benchmarks can assert the k bound and
    cache-hit freeness."""
    from repro.core.measure import CachedMeasure, TimingCache

    def evaluate(wl, rt):
        ledger = os.environ.get(LEDGER_ENV)
        if ledger:
            with open(ledger, "a") as fh:
                fh.write(json.dumps({"cell": wl.key(),
                                     "config": rt.as_dict()}) + "\n")
        return truth_cost(wl, rt)

    cache_dir = os.environ.get(CACHE_ENV)
    cache = TimingCache(pathlib.Path(cache_dir)) if cache_dir \
        else TimingCache(use_disk=False)
    return CachedMeasure(evaluate, cache, repeats=3)
