"""Assemble EXPERIMENTS.md from results/ artifacts.

    PYTHONPATH=src python -m benchmarks.report_experiments

Sections: §Dry-run (every cell x mesh), §Roofline (three terms +
bottleneck + useful-FLOPs ratio, single-pod), §Perf (case-study tuning
logs + beyond-paper hillclimbs, merged from results/perf/*.md).
"""
from __future__ import annotations

import json
import pathlib

ROOT = pathlib.Path(__file__).resolve().parent.parent
DRYRUN = ROOT / "results" / "dryrun"
BENCH = ROOT / "results" / "benchmarks"
PERF = ROOT / "results" / "perf"


def _recs():
    out = []
    for f in sorted(DRYRUN.glob("*.json")):
        out.append(json.loads(f.read_text()))
    return out


def _fmt_bytes(b):
    return f"{b/1e9:.2f}"


def dryrun_section(recs) -> str:
    lines = [
        "## §Dry-run",
        "",
        "Every (architecture × input-shape × mesh) cell, lowered and",
        "compiled with `ShapeDtypeStruct` inputs (no allocation) on",
        "placeholder meshes: single-pod `(data=16, model=16)` = 256 chips,",
        "multi-pod `(pod=2, data=16, model=16)` = 512 chips.  `peak/chip` is",
        "`memory_analysis()` arguments+temps of the deployable (scanned)",
        "step; collective mix is parsed from the partitioned HLO.",
        "`fits` compares against 16 GB v5e HBM — baseline configs that",
        "exceed it are the paper's \"crash\" analogue and are exactly what",
        "the tuner's memoryFraction/serializer stages repair (§Perf).",
        "",
        "| arch | shape | mesh | status | peak/chip GB | fits | collectives (per-chip bytes) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"SKIP ({r['reason'][:48]}...) | – | – | – |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"FAIL {r.get('error','')[:40]} | – | – | – |")
            continue
        ma = r["memory_analysis"]
        coll = r["roofline"]["coll_summary"]
        cs = "; ".join(f"{k}×{int(v['count'])}:{v['bytes']/1e6:.0f}MB"
                       for k, v in sorted(coll.items()))
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{_fmt_bytes(ma['peak_bytes'])} | "
            f"{'Y' if r['fits_hbm'] else '**N**'} | {cs or '-'} |")
    return "\n".join(lines)


def roofline_section(recs) -> str:
    lines = [
        "## §Roofline",
        "",
        "Single-pod (256-chip) UNTUNED-ENGINE baseline: `fsdp_tp` cluster",
        "sharding with f32 \"Java-serializer\" compute, store-everything",
        "remat, unfused XLA attention, no compression (the exact config is",
        "recorded per cell in results/dryrun/*.json `tunable`).  The tuned",
        "configurations appear in §Perf.  Terms are calibrated per",
        "DESIGN.md §7 (XLA counts `while` bodies once; terms are",
        "extrapolated from two small unrolled compiles); peak memory is",
        "the exact `memory_analysis` of the full scanned compile.",
        "`useful` = MODEL_FLOPS / HLO_FLOPs; `frac` = model-FLOPs time /",
        "roofline step time (the roofline fraction that §Perf hillclimbs).",
        "",
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck | useful | frac | one-line diagnosis |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    from repro.configs import get_config, get_shape
    from repro.core import costmodel
    diags = {
        "memory": "unfused attention + f32 + remat=none residuals round-trip HBM",
        "collective": "f32 param all-gathers / grad reduce dominate ICI",
        "compute": "MXU-bound; push data-format + kernel fusion",
    }
    for r in recs:
        if r["status"] != "ok" or "multipod" in r["mesh"]:
            continue
        rl = r["roofline"]
        mf = costmodel.model_flops(get_config(r["arch"]),
                                   get_shape(r["shape"]))
        model_s = (mf / 256) / costmodel.HW["flops_bf16"]
        frac = model_s / max(rl["total_s"], 1e-12)
        useful = (mf / 256) / max(rl["flops_per_chip"], 1e-12)
        lines.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"**{rl['bottleneck']}** | {useful:.3f} | "
            f"{frac:.3f} | {diags[rl['bottleneck']]} |")
    return "\n".join(lines)


def perf_section() -> str:
    lines = ["## §Perf", ""]
    intro = PERF / "intro.md"
    if intro.exists():
        lines.append(intro.read_text())
    for f in sorted(BENCH.glob("case_study_*.md")):
        lines += ["", f.read_text()]
    for f in sorted(PERF.glob("hillclimb_*.md")):
        lines += ["", f.read_text()]
    tv = BENCH / "tree_variants.md"
    if tv.exists():
        lines += ["", tv.read_text()]
    t2 = BENCH / "table2_impact.md"
    if t2.exists():
        lines += ["", "### Sensitivity analysis (Table 2 analogue)", "",
                  "Mean |%Δ| of the calibrated roofline step time vs the",
                  "baseline, per knob per workload class:", "",
                  t2.read_text()]
    return "\n".join(lines)


def main():
    recs = _recs()
    doc = "\n\n".join([
        "# EXPERIMENTS",
        "",
        dryrun_section(recs),
        roofline_section(recs),
        perf_section(),
    ])
    (ROOT / "EXPERIMENTS.md").write_text(doc)
    print(f"wrote EXPERIMENTS.md ({len(doc)} chars, "
          f"{len([r for r in recs if r['status']=='ok'])} ok cells)")


if __name__ == "__main__":
    main()
