"""Telemetry benchmark: event overhead and trace/ledger consistency.

Two questions about the observability layer (core/telemetry.py), both
answered on the fabric surface — 2 subprocess workers over a shared
directory on the deterministic synthetic surface
(benchmarks/fabric_surface.py) with a fixed per-trial latency, the
exact setup of benchmarks/bench_fabric.py:

  * **overhead** — the same 2-worker campaign with tracing off vs on.
    Workers start behind a ready/go file barrier so wall covers fabric
    work, not interpreter cold start; each arm runs ``REPEATS`` times
    and the minimum wall is compared (the minimum is the
    least-noise-contaminated sample of a fixed workload).  Telemetry
    must cost **< 2% wall**, and decisions must stay bit-identical to
    the single-process campaign in *both* arms.
  * **consistency** — the traced arm also carries the evaluation
    ledger (``FABRIC_SURFACE_LEDGER``: one line per evaluation the
    surface actually ran).  The Chrome-trace export's ``trial``
    duration-slice count must equal the ledger's line count — every
    paid trial shows up on the timeline, no more, no fewer — and
    ``metrics.json`` must agree.

Results land in results/benchmarks/BENCH_telemetry.json and a copy at
the repo root (BENCH_telemetry.json) for CI tracking.

Run:  PYTHONPATH=src:. python -m benchmarks.bench_telemetry
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import shutil
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent

DEFAULT_CELLS = ("smollm-135m:train_4k,smollm-135m:prefill_32k,"
                 "xlstm-1.3b:prefill_32k,xlstm-1.3b:decode_32k")
TRIAL_LATENCY_S = 0.5
N_WORKERS = 2
REPEATS = 2
EVALUATOR_SPEC = "benchmarks.fabric_surface:make_evaluator"
MAX_OVERHEAD_PCT = 2.0


def _baseline(spec=None):
    from repro.core.params import default_config
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def _env(sleep_s=0.0, ledger=None):
    from benchmarks.fabric_surface import LEDGER_ENV, SLEEP_ENV
    env = os.environ.copy()
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT / "src"), str(ROOT)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env[SLEEP_ENV] = str(sleep_s)
    if ledger:
        env[LEDGER_ENV] = str(ledger)
    else:
        env.pop(LEDGER_ENV, None)
    return env


def _wait_files(paths, timeout_s=120.0):
    deadline = time.time() + timeout_s
    while time.time() < deadline:
        if all(p.exists() for p in paths):
            return
        time.sleep(0.05)
    missing = [str(p) for p in paths if not p.exists()]
    raise TimeoutError(f"barrier files never appeared: {missing}")


def _reference_reports(cells):
    """Single-process campaign on the same surface — the decision
    oracle both arms must reproduce bit-for-bit."""
    from benchmarks.fabric_surface import surface_cost
    from repro.core.campaign import Campaign
    return Campaign(cells, evaluator=surface_cost,
                    baseline_factory=_baseline,
                    checkpoint_dir=None).run()


def _fabric_reports(directory, cells):
    from repro.core.strategy import get_strategy
    spec = get_strategy("tree")
    out = {}
    for c in cells:
        d = json.loads((directory / f"{c.key()}.json").read_text())
        assert d.get("done"), f"{c.key()} incomplete"
        out[c.key()] = spec.load_report(d["report"])
    return out


def _identical(reports, ref):
    from repro.core.campaign import tuning_fingerprint
    return all(tuning_fingerprint(reports[k]) == tuning_fingerprint(ref[k])
               for k in ref)


def run_fleet(cells, d, trace, ledger=None):
    """One barrier-synchronized 2-worker run; returns measured wall."""
    from repro.core.fabric import LeaseBoard, spawn_worker
    barrier = d / "barrier"
    go = barrier / "go"
    procs, readies = [], []
    for i in range(N_WORKERS):
        ready = barrier / f"ready-{i}"
        readies.append(ready)
        procs.append(spawn_worker(
            cells, d, strategy="tree", evaluator_spec=EVALUATOR_SPEC,
            ttl_s=30.0, worker_id=f"w{i}", ready_file=ready, go_file=go,
            trace=trace, log_path=d / "logs" / f"worker-{i}.log",
            env=_env(sleep_s=TRIAL_LATENCY_S, ledger=ledger)))
    _wait_files(readies)
    t0 = time.time()
    go.parent.mkdir(parents=True, exist_ok=True)
    go.touch()
    rcs = [p.wait(timeout=300) for p in procs]
    wall = time.time() - t0
    assert not any(rcs), f"worker rcs {rcs}"
    assert LeaseBoard(d).held() == [], "lease left held"
    return wall


def run_overhead_arms(cells, scratch):
    """REPEATS runs per arm (off/on), minimum wall each; the first
    traced run keeps its evidence for the consistency arm."""
    walls = {"off": [], "on": []}
    for r in range(REPEATS):
        d = scratch / f"off-{r}"
        walls["off"].append(run_fleet(cells, d, trace=False))
        assert not (d / "events.jsonl").exists(), \
            "telemetry-off run wrote an event file"
        traced = scratch / f"on-{r}"
        walls["on"].append(run_fleet(cells, traced, trace=True,
                                     ledger=traced / "ledger.jsonl"))
    off, on = min(walls["off"]), min(walls["on"])
    return {
        "repeats": REPEATS,
        "wall_off_s": [round(w, 3) for w in walls["off"]],
        "wall_on_s": [round(w, 3) for w in walls["on"]],
        "min_wall_off_s": round(off, 3),
        "min_wall_on_s": round(on, 3),
        "overhead_pct": round((on - off) / off * 100.0, 2),
    }


def run_consistency_checks(cells, traced, ref):
    """Evidence checks on one traced run's directory."""
    from repro.core import telemetry
    records = telemetry.read_events(traced)
    assert records, "traced run recorded no events"
    trial_events = [r for r in records if r["kind"] == "trial"]
    ledger_lines = [line for line in
                    (traced / "ledger.jsonl").read_text().splitlines()
                    if line.strip()]
    trace_path = traced / "trace.json"
    n_exported = telemetry.export_chrome_trace(traced, trace_path)
    trace = json.loads(trace_path.read_text())
    slices = [e for e in trace["traceEvents"]
              if e.get("ph") == "X" and e.get("cat") == "trial"]
    # workers publish metrics.json at every cell boundary and at exit;
    # re-fold here so the assertion sees the complete stream, not
    # whichever worker's exit-time publish happened to land last
    assert (traced / telemetry.METRICS_NAME).exists(), \
        "traced run published no metrics.json"
    metrics = telemetry.publish_metrics(traced)
    reports = _fabric_reports(traced, cells)
    return {
        "events": len(records),
        "event_kinds": sorted({r["kind"] for r in records}),
        "trial_events": len(trial_events),
        "ledger_evaluations": len(ledger_lines),
        "trace_trial_slices": len(slices),
        "trace_events_exported": n_exported,
        "metrics_trials": metrics["counters"]["trials"],
        "workers_on_trace": metrics["gauges"]["workers"],
        "identical_to_single_process": _identical(reports, ref),
    }


def main(cells_spec: str):
    from repro.core.campaign import parse_cells
    cells = parse_cells(cells_spec)
    print(f"batch: {len(cells)} cells "
          f"({', '.join(c.key() for c in cells)})")
    scratch = ROOT / "results" / "bench_telemetry_scratch"
    shutil.rmtree(scratch, ignore_errors=True)

    ref = _reference_reports(cells)
    overhead = run_overhead_arms(cells, scratch)
    print(f"overhead: off {overhead['min_wall_off_s']}s, "
          f"on {overhead['min_wall_on_s']}s "
          f"-> {overhead['overhead_pct']}%")

    consistency = run_consistency_checks(cells, scratch / "on-0", ref)
    # the untraced arms decide identically too (they share the oracle)
    identical_off = _identical(_fabric_reports(scratch / "off-0", cells),
                               ref)
    print(f"consistency: {consistency['trial_events']} trial events, "
          f"{consistency['ledger_evaluations']} ledger evaluations, "
          f"{consistency['trace_trial_slices']} trace slices, "
          f"identical={consistency['identical_to_single_process']}")

    out = {
        "cells": [c.key() for c in cells],
        "workers": N_WORKERS,
        "trial_latency_s": TRIAL_LATENCY_S,
        "evaluator": EVALUATOR_SPEC,
        "max_overhead_pct": MAX_OVERHEAD_PCT,
        "overhead": overhead,
        "consistency": consistency,
        "identical_without_trace": identical_off,
    }
    res_dir = ROOT / "results" / "benchmarks"
    res_dir.mkdir(parents=True, exist_ok=True)
    (res_dir / "BENCH_telemetry.json").write_text(
        json.dumps(out, indent=1))
    (ROOT / "BENCH_telemetry.json").write_text(json.dumps(out, indent=1))
    shutil.rmtree(scratch, ignore_errors=True)
    print(json.dumps(out, indent=1))
    assert consistency["identical_to_single_process"] and identical_off, \
        "telemetry changed tuning decisions!"
    assert overhead["overhead_pct"] < MAX_OVERHEAD_PCT, \
        f"telemetry overhead {overhead['overhead_pct']}% >= " \
        f"{MAX_OVERHEAD_PCT}% wall"
    assert consistency["trace_trial_slices"] \
        == consistency["ledger_evaluations"], \
        "trace slice count != evaluation-ledger trial count"
    assert consistency["trial_events"] == consistency["metrics_trials"], \
        "metrics.json disagrees with the event stream"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=DEFAULT_CELLS,
                    help="comma-separated arch:shape[:pod|multipod]")
    a = ap.parse_args()
    main(a.cells)
