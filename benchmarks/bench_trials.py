"""Trial-throughput benchmark: structure-aware compile cache + parallel
sweep executor vs. the naive (compile-every-trial) evaluator.

A full SENSITIVITY_SWEEP pass — every (knob, value) pair the Sec.-4
protocol lists, plus the baseline — is evaluated on one cell twice,
cache-cold both times:

  * naive   — caching disabled: every trial pays its four calibration
    compiles, exactly the pre-engine evaluator;
  * engine  — cold CompileCache + SweepExecutor: trials that differ
    only in analytic knobs (or in knobs that provably never reach this
    cell's compiled HLO, core/params.compile_key) share compiles.

The engine must produce bit-identical cost_s for every swept point
(``identical_costs`` below) — the speedup is pure structure, no change
to any observed cost.  Results land in results/benchmarks/BENCH_trials.json
and a copy at the repo root (BENCH_trials.json) for CI tracking.

Run:  PYTHONPATH=src python -m benchmarks.bench_trials [--cell arch shape]
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import pathlib
import shutil
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent

# Default cell: ssm-family prefill — a serving cell of the paper's
# protocol where the largest share of the 12 knobs is analytic-only
# (no train machinery, no KV cache, no MoE wire), i.e. the best case
# the cache is designed around.  Any cell works; the equality check is
# what matters.
DEFAULT_CELL = ("xlstm-1.3b", "prefill_32k")


def sweep_points(baseline):
    """The full SENSITIVITY_SWEEP pass: baseline + every listed value.

    Values equal to the baseline's (each knob's default) are kept — a
    naive sweep driver pays full compiles for them; the engine gets
    them from the cache like any other repeated structure."""
    from repro.core.params import SENSITIVITY_SWEEP
    pts = [("baseline", {}, baseline)]
    for knob, values in SENSITIVITY_SWEEP.items():
        for v in values:
            pts.append((f"{knob}={v}", {knob: v},
                        baseline.replace(**{knob: v})))
    return pts


def run_pass(wl, points, evaluator, parallel: bool):
    from repro.core.executor import SweepExecutor
    t0 = time.time()
    if parallel:
        with SweepExecutor(evaluator) as ex:
            results = ex.map(wl, [rt for _, _, rt in points])
    else:
        results = [evaluator(wl, rt) for _, _, rt in points]
    wall = time.time() - t0
    return results, wall


def main(arch: str, shape: str, workers: int = None):
    from repro.core.params import default_config
    from repro.core.trial import CompileCache, RooflineEvaluator, Workload

    wl = Workload(arch, shape)
    baseline = default_config(shard_strategy="fsdp_tp")
    points = sweep_points(baseline)
    print(f"cell {wl.key()}: {len(points)} sweep points "
          f"(full SENSITIVITY_SWEEP pass incl. baseline)")

    # --- naive: no caching anywhere, sequential (the seed evaluator)
    naive = RooflineEvaluator(use_cache=False)
    naive_results, naive_wall = run_pass(wl, points, naive, parallel=False)
    naive_compiles = naive.total_compiles

    # --- engine: cold two-level cache + parallel executor
    cold_dir = ROOT / "results" / "bench_trials_cache"
    shutil.rmtree(cold_dir, ignore_errors=True)
    engine = RooflineEvaluator(
        compile_cache=CompileCache(directory=cold_dir))
    if workers:
        os.environ["REPRO_TRIAL_WORKERS"] = str(workers)
    engine_results, engine_wall = run_pass(wl, points, engine,
                                           parallel=True)
    engine_compiles = engine.total_compiles

    mismatches = [
        (name, rn.cost_s, re_.cost_s)
        for (name, _, _), rn, re_ in zip(points, naive_results,
                                         engine_results)
        if rn.cost_s != re_.cost_s or rn.crashed != re_.crashed]
    out = {
        "cell": wl.key(),
        "sweep_points": len(points),
        "naive": {"compiles": naive_compiles,
                  "wall_s": round(naive_wall, 1),
                  "compiles_per_trial": round(
                      naive_compiles / len(points), 2)},
        "engine": {"compiles": engine_compiles,
                   "wall_s": round(engine_wall, 1),
                   "compiles_per_trial": round(
                       engine_compiles / len(points), 2),
                   "cache": engine.compile_cache.stats()},
        "compile_reduction_x": round(naive_compiles
                                     / max(1, engine_compiles), 2),
        "wall_speedup_x": round(naive_wall / max(1e-9, engine_wall), 2),
        "identical_costs": not mismatches,
        "mismatches": mismatches[:10],
    }
    res_dir = ROOT / "results" / "benchmarks"
    res_dir.mkdir(parents=True, exist_ok=True)
    (res_dir / "BENCH_trials.json").write_text(json.dumps(out, indent=1))
    (ROOT / "BENCH_trials.json").write_text(json.dumps(out, indent=1))
    shutil.rmtree(cold_dir, ignore_errors=True)
    print(json.dumps(out, indent=1))
    assert not mismatches, "engine changed observed costs!"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", nargs=2, default=DEFAULT_CELL,
                    metavar=("ARCH", "SHAPE"))
    ap.add_argument("--workers", type=int, default=None)
    a = ap.parse_args()
    main(a.cell[0], a.cell[1], a.workers)
