"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (us_per_call = calibrated
roofline step time of the baseline config on the 256-chip mesh; derived =
per-figure summary).  Markdown/CSV artifacts land in results/benchmarks/.

MUST set the placeholder device count before ANY jax-touching import.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json
import sys
import time


def fig1_sortbykey():
    """Fig. 1 analogue: OFAT sensitivity, shuffle-intensive workload."""
    from benchmarks.common import WORKLOADS, save, sensitivity_for
    from repro.core import report
    rep = sensitivity_for(WORKLOADS["sortbykey~glm4-9b/train_4k"])
    save("fig1_sortbykey.csv", report.sensitivity_csv(rep))
    return rep


def fig2_shuffling():
    """Fig. 2 analogue: OFAT sensitivity, all-to-all-dominated MoE."""
    from benchmarks.common import WORKLOADS, save, sensitivity_for
    from repro.core import report
    rep = sensitivity_for(WORKLOADS["shuffling~olmoe-1b-7b/train_4k"])
    save("fig2_shuffling.csv", report.sensitivity_csv(rep))
    return rep


def fig3_kmeans():
    """Fig. 3 analogue: compute-bound workload at two input scales."""
    from benchmarks.common import WORKLOADS, save, sensitivity_for
    from repro.core import report
    rep_a = sensitivity_for(WORKLOADS["kmeans~smollm-135m/train_4k"])
    rep_b = sensitivity_for(WORKLOADS["kmeans2~smollm-135m/prefill_32k"])
    save("fig3_kmeans_scale1.csv", report.sensitivity_csv(rep_a))
    save("fig3_kmeans_scale2.csv", report.sensitivity_csv(rep_b))
    return rep_a, rep_b


def table2(reports):
    """Table 2: mean |%| impact per knob per workload + average."""
    from benchmarks.common import save
    from repro.core import report
    md = report.sensitivity_markdown(reports)
    save("table2_impact.md", md)
    return md


def case_studies():
    """Sec. 5: the tuning tree applied to the three hillclimb cells,
    run as one concurrent campaign (core/campaign.py)."""
    from benchmarks.case_studies import run_case_studies
    return run_case_studies()


def main() -> None:
    print("name,us_per_call,derived")
    t0 = time.time()
    r1 = fig1_sortbykey()
    print(f"fig1_sortbykey,{r1.baseline_cost*1e6:.0f},"
          f"top_knob={max(r1.impacts, key=lambda i: i.mean_abs_pct).knob}")
    r2 = fig2_shuffling()
    print(f"fig2_shuffling,{r2.baseline_cost*1e6:.0f},"
          f"top_knob={max(r2.impacts, key=lambda i: i.mean_abs_pct).knob}")
    r3a, r3b = fig3_kmeans()
    print(f"fig3_kmeans_scale1,{r3a.baseline_cost*1e6:.0f},"
          f"top={max(r3a.impacts, key=lambda i: i.mean_abs_pct).mean_abs_pct:.1f}%")
    print(f"fig3_kmeans_scale2,{r3b.baseline_cost*1e6:.0f},"
          f"top={max(r3b.impacts, key=lambda i: i.mean_abs_pct).mean_abs_pct:.1f}%")
    reports = {"sort-by-key": r1, "shuffling": r2, "k-means": r3a,
               "k-means-2x": r3b}
    table2(reports)
    avg = {}
    for rep in reports.values():
        for i in rep.impacts:
            avg.setdefault(i.knob, []).append(i.mean_abs_pct)
    top = max(avg, key=lambda k: sum(avg[k]) / len(avg[k]))
    print(f"table2_impact,0,avg_top_knob={top}")
    studies = case_studies()
    for rep in studies:
        print(f"case_study_{rep.workload},{rep.final_cost*1e6:.0f},"
              f"speedup=x{rep.speedup:.2f}_in_{rep.n_trials}_trials")
    finite = [r.speedup for r in studies
              if r.speedup == r.speedup and r.speedup != float("inf")]
    gmean = 1.0
    for s in finite:
        gmean *= s
    gmean **= 1.0 / max(1, len(finite))
    print(f"campaign_case_studies,0,cells={len(studies)}"
          f"_gmean_speedup=x{gmean:.2f}"
          f"_trials={sum(r.n_trials for r in studies)}")
    from benchmarks.tree_variants import run_variants
    for row in run_variants()[0]:
        print(f"tree_variant_{row['variant']},"
              f"{row['final_cost_s']*1e6:.0f},"
              f"speedup=x{row['speedup']}_accepted={row['accepted']}")
    print(f"# total wall time: {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
