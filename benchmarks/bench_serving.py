"""Serving-loop benchmark: SLO guardrail on/off, bounded bad-config
exposure, promotion, and repeat-campaign cache freeness.

Four arms over two real ``serve:<arch>:<trace>`` cells (the replay
evaluator actually runs — reduced model, CPU):

  * **bad_config** — the known-bad config (``wave_admission=full``
    starves the sparse Poisson trace) replayed once with the guard off
    (it finishes the whole trace and shows the tail queue delay a live
    stream would have eaten) and once with the guard armed (it must be
    aborted mid-trace, bounding worst-case exposure to a prefix of the
    stream);
  * **campaign_guard_on** — the full tuning tree per cell with
    ``slo_ttft=3.0``: the violator alternative is scored as a
    deterministic crash without finishing its trace, winners are
    promoted to a live board;
  * **campaign_guard_off** — the same tree with no guard: the violator
    burns a full replay but its (terrible) honest cost is rejected by
    the accept rule, so neither arm ever ships ``wave_admission=full``
    (the guard changes how fast a bad config is rejected, not whether
    it can win; marginal knobs may differ between arms — replay cost
    is a measured wall quantity with real noise);
  * **campaign_repeat** — fresh checkpoints, same disk timing cache:
    zero fresh successful replays (every surviving trial is a cache
    hit; only the never-memoized deterministic aborts re-run) and the
    re-promotion never regresses the live board.

Results land in results/benchmarks/BENCH_serving.json and a copy at
the repo root (BENCH_serving.json) for CI tracking.

Run:  PYTHONPATH=src python -m benchmarks.bench_serving
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import pathlib
import re
import shutil
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent

DEFAULT_CELLS = ("serve:smollm-135m:poisson_tiny,"
                 "serve:smollm-135m:bursty_tiny")
SLO_TTFT = 3.0
BAD_DELTA = {"wave_admission": "full"}


def _baseline(spec=None):
    from repro.core.params import default_config
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


def _evaluator(scratch, slo_ttft=None):
    """The dispatch stack over a bench-local timing cache (the shared
    results/trials cache must not leak arm-to-arm state in here)."""
    from repro.core.kernel_cell import DispatchEvaluator
    from repro.core.measure import TimingCache
    from repro.serving.evaluator import make_serve_evaluator
    serve = make_serve_evaluator(
        slo_ttft=slo_ttft, cache=TimingCache(scratch / "timings"))
    return DispatchEvaluator(serve=serve, slo_ttft=slo_ttft)


def run_bad_config(cells, scratch):
    """One replay of the known-bad config per guard setting."""
    from repro.serving.evaluator import ServeEvaluator
    wl = cells[0].workload()
    bad = _baseline().replace(**BAD_DELTA)
    off = ServeEvaluator()
    t0 = time.time()
    stats = off.replay(wl, bad)          # guard off: full trace
    wall_off = round(time.time() - t0, 3)
    on = ServeEvaluator(slo_ttft=SLO_TTFT)
    t0 = time.time()
    res = on(wl, bad)                    # guard on: must abort
    wall_on = round(time.time() - t0, 3)
    m = re.search(r"after (\d+)/(\d+) requests", res.error or "")
    served_at_abort, total = (int(m.group(1)), int(m.group(2))) \
        if m else (None, None)
    return {
        "bad_delta": BAD_DELTA,
        "guard_off": {"served": stats["served"],
                      "p95_qdelay_s": round(stats["p95_qdelay_s"], 3),
                      "mean_ttft_s": round(stats["mean_ttft_s"], 3),
                      "cost_s": round(ServeEvaluator.cost_of(stats), 4),
                      "wall_s": wall_off},
        "guard_on": {"aborted": bool(res.crashed),
                     "failure": res.failure,
                     "served_at_abort": served_at_abort,
                     "total": total,
                     "error": (res.error or "")[:160],
                     "wall_s": wall_on},
    }


def _campaign(cells, ckpt, evaluator):
    from repro.core.campaign import Campaign
    camp = Campaign(cells, strategy="tree", checkpoint_dir=ckpt,
                    evaluator=evaluator, baseline_factory=_baseline)
    t0 = time.time()
    reports = camp.run()
    return reports, round(time.time() - t0, 3)


def _arm_summary(cells, reports, wall):
    out = {"wall_s": wall, "cells": {}}
    for c in cells:
        rep = reports[c.key()]
        aborts = [e for e in rep.log if e["result"].get("crashed")
                  and "slo-violation" in e["result"].get("error", "")]
        fresh = [e for e in rep.log
                 if not e["result"].get("crashed")
                 and not e["result"].get("cached")]
        out["cells"][c.key()] = {
            "trials": rep.n_trials,
            "slo_aborts": len(aborts),
            "fresh_successful_replays": len(fresh),
            "baseline_cost_s": round(rep.baseline_cost, 4),
            "final_cost_s": round(rep.final_cost, 4),
            "final_config": rep.final_config,
        }
    return out


# ------------------------------------------------------------------ main
def main(cells_spec: str):
    from repro.core.campaign import parse_cells
    from repro.serving.canary import PromotionBoard, promote_winners
    cells = parse_cells(cells_spec)
    print(f"batch: {len(cells)} cells "
          f"({', '.join(c.key() for c in cells)})")
    scratch = ROOT / "results" / "bench_serving_scratch"
    shutil.rmtree(scratch, ignore_errors=True)
    scratch.mkdir(parents=True, exist_ok=True)

    bad = run_bad_config(cells, scratch)
    print(f"bad_config: guard off served {bad['guard_off']['served']} "
          f"(p95 qdelay {bad['guard_off']['p95_qdelay_s']}s); guard on "
          f"aborted after {bad['guard_on']['served_at_abort']}/"
          f"{bad['guard_on']['total']}")

    on_reports, on_wall = _campaign(
        cells, scratch / "guard_on", _evaluator(scratch, SLO_TTFT))
    guard_on = _arm_summary(cells, on_reports, on_wall)
    promote_winners(scratch, on_reports, source="bench:guard_on")
    board = PromotionBoard(scratch)
    live_first = {c.key(): board.live(c.key())["cost_s"] for c in cells}
    print(f"campaign_guard_on: {on_wall}s, aborts per cell "
          f"{[v['slo_aborts'] for v in guard_on['cells'].values()]}")

    off_reports, off_wall = _campaign(
        cells, scratch / "guard_off", _evaluator(scratch, None))
    guard_off = _arm_summary(cells, off_reports, off_wall)
    print(f"campaign_guard_off: {off_wall}s")

    rep_reports, rep_wall = _campaign(
        cells, scratch / "repeat", _evaluator(scratch, SLO_TTFT))
    repeat = _arm_summary(cells, rep_reports, rep_wall)
    promote_winners(scratch, rep_reports, source="bench:repeat")
    live_after = {c.key(): board.live(c.key())["cost_s"] for c in cells}
    fresh_repeat = sum(v["fresh_successful_replays"]
                       for v in repeat["cells"].values())
    print(f"campaign_repeat: {rep_wall}s, "
          f"{fresh_repeat} fresh successful replays")

    out = {
        "cells": [c.key() for c in cells],
        "slo_ttft": SLO_TTFT,
        "bad_config": bad,
        "campaign_guard_on": guard_on,
        "campaign_guard_off": guard_off,
        "campaign_repeat": repeat,
        "promotion": {"live_costs_first": live_first,
                      "live_costs_after_repeat": live_after,
                      "history_actions":
                          [r["action"] for r in board.history()]},
    }
    res_dir = ROOT / "results" / "benchmarks"
    res_dir.mkdir(parents=True, exist_ok=True)
    (res_dir / "BENCH_serving.json").write_text(json.dumps(out, indent=1))
    (ROOT / "BENCH_serving.json").write_text(json.dumps(out, indent=1))
    shutil.rmtree(scratch, ignore_errors=True)
    print(json.dumps(out, indent=1))

    g_on, g_off = bad["guard_on"], bad["guard_off"]
    assert g_on["aborted"] and g_on["failure"] == "deterministic", g_on
    assert g_on["served_at_abort"] < g_on["total"], \
        "the guard let the bad config finish its trace!"
    assert g_off["served"] == g_on["total"], \
        "guard-off replay did not serve the full trace!"
    for key, arm in guard_on["cells"].items():
        assert arm["slo_aborts"] >= 1, \
            f"{key}: guard-on campaign saw no SLO abort"
        # neither arm may ever ship the SLO-violating admission policy:
        # the guard aborts it, the honest replay cost rejects it
        for arm_name, summary in (("guard_on", guard_on),
                                  ("guard_off", guard_off)):
            final = summary["cells"][key]["final_config"]
            assert final.get("wave_admission", "greedy") != "full", \
                f"{key}: {arm_name} shipped the bad admission policy!"
    assert fresh_repeat == 0, \
        "repeat campaign re-paid successful replays despite the cache!"
    for key in live_first:
        assert live_after[key] <= live_first[key], \
            f"{key}: the live board regressed on re-promotion!"
    print("\nbench_serving: all invariants hold")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=DEFAULT_CELLS)
    args = ap.parse_args()
    main(args.cells)
