"""Fault-injecting synthetic evaluator for chaos benchmarks/tests.

Wraps the deterministic :func:`benchmarks.fabric_surface.surface_cost`
surface (so every non-faulted trial is bit-identical to the fault-free
fabric surface) and injects three fault classes on *chosen configs*,
selected by knob=value deltas — deterministic-by-config, like real
poison parameter values (the paper's crashing sort-by-key 0.1/0.7 run),
not random (modeled on the ft/ preemption/straggler surfaces: faults
you can aim).

Environment variables parameterize spawned workers (env is the only
channel a ``launch/tune.py --evaluator`` subprocess inherits):

  * ``CHAOS_KILL_DELTA`` — ``knob=value[,knob=value...]``: a config
    matching every pair SIGKILLs its own process (after
    ``CHAOS_KILL_DELAY_S``, default 0.05 s — long enough for the
    executor's quarantine intent record to land).  This is the poison
    config the quarantine must bound at K evaluations fleet-wide;
  * ``CHAOS_HANG_DELTA`` — matching configs sleep ``CHAOS_HANG_S``
    (default 3600 s): a wedged XLA compile.  Only a trial deadline
    (``--trial-timeout``) gets the sweep past it;
  * ``CHAOS_FLAKY_DELTA`` — matching configs raise ``OSError`` (a
    *transient* failure per the core/trial.py taxonomy) on their first
    ``CHAOS_FLAKY_FAILS`` (default 1) evaluations in each process,
    then succeed: retry/backoff must recover them with zero extra
    compiles;
  * ``CHAOS_SLEEP_S`` — per-trial sleep (evaluation latency), as in
    fabric_surface;
  * ``CHAOS_LEDGER`` — optional path; one ``{"cell", "config"}`` JSON
    line is appended per evaluation *before* any fault fires, so the
    ledger counts evaluations of the poison config even when the
    process dies mid-trial.
"""
from __future__ import annotations

import json
import os
import signal
import time

from benchmarks.fabric_surface import surface_cost

KILL_ENV = "CHAOS_KILL_DELTA"
KILL_DELAY_ENV = "CHAOS_KILL_DELAY_S"
HANG_ENV = "CHAOS_HANG_DELTA"
HANG_S_ENV = "CHAOS_HANG_S"
FLAKY_ENV = "CHAOS_FLAKY_DELTA"
FLAKY_FAILS_ENV = "CHAOS_FLAKY_FAILS"
SLEEP_ENV = "CHAOS_SLEEP_S"
LEDGER_ENV = "CHAOS_LEDGER"


def parse_delta(spec):
    """``knob=value[,knob=value...]`` -> list of (knob, value-string)."""
    out = []
    for item in (spec or "").split(","):
        item = item.strip()
        if not item:
            continue
        knob, sep, value = item.partition("=")
        if not sep:
            raise ValueError(f"bad chaos delta {item!r} (want knob=value)")
        out.append((knob.strip(), value.strip()))
    return out


def matches(rt, delta) -> bool:
    """A config triggers a fault iff every knob=value pair matches
    (string comparison, so booleans/ints match their CLI spelling)."""
    return bool(delta) and all(str(getattr(rt, k)) == v
                               for k, v in delta)


def make_evaluator():
    """Zero-arg factory (the ``--evaluator`` contract)."""
    kill = parse_delta(os.environ.get(KILL_ENV))
    kill_delay = float(os.environ.get(KILL_DELAY_ENV, "0.05") or "0.05")
    hang = parse_delta(os.environ.get(HANG_ENV))
    hang_s = float(os.environ.get(HANG_S_ENV, "3600") or "3600")
    flaky = parse_delta(os.environ.get(FLAKY_ENV))
    flaky_fails = int(os.environ.get(FLAKY_FAILS_ENV, "1") or "1")
    sleep_s = float(os.environ.get(SLEEP_ENV, "0") or "0")
    ledger = os.environ.get(LEDGER_ENV)
    flaky_count = {}                     # per-process: config blob -> n

    def evaluate(wl, rt):
        if ledger:
            # ledger first: the kill fault must still be counted
            line = json.dumps({"cell": wl.key(), "config": rt.as_dict()},
                              sort_keys=True) + "\n"
            fd = os.open(ledger, os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                         0o644)
            try:
                os.write(fd, line.encode())
            finally:
                os.close(fd)
        if matches(rt, kill):
            time.sleep(kill_delay)       # let the intent record land
            os.kill(os.getpid(), signal.SIGKILL)
        if matches(rt, hang):
            time.sleep(hang_s)           # a wedged compile
        if matches(rt, flaky):
            blob = json.dumps(rt.as_dict(), sort_keys=True, default=str)
            n = flaky_count.get(blob, 0)
            if n < flaky_fails:
                flaky_count[blob] = n + 1
                raise OSError("chaos: transient fault "
                              f"({n + 1}/{flaky_fails})")
        if sleep_s > 0:
            time.sleep(sleep_s)
        return surface_cost(wl, rt)

    return evaluate
