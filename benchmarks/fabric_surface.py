"""Deterministic synthetic cost surface for fabric benchmarks/tests.

Worker processes load it via ``launch/tune.py --evaluator
benchmarks.fabric_surface:make_evaluator`` (dotted-path spec, repo root
on PYTHONPATH).  Two environment variables parameterize the spawned
workers (env is the only channel a subprocess worker inherits):

  * ``FABRIC_SURFACE_SLEEP_S`` — per-trial sleep, emulating evaluation
    latency (a real trial pays XLA compiles; the sleep releases the
    GIL exactly like they do).  The *cost surface is independent of
    the sleep*, so decisions are comparable across arms;
  * ``FABRIC_SURFACE_LEDGER`` — optional path; every evaluation
    appends one ``{"cell", "config"}`` JSON line (O_APPEND, whole
    lines).  The kill-recovery arm diffs this ledger against the
    checkpoint state captured at kill time to prove that no absorbed
    trial is ever re-paid.

The surface is built so that cells of the same shape *kind* share one
best tree outcome (arch only scales the constant): that is the
structure warm-starting exploits, and exactly what the cell-signature
similarity (core/history.py) is supposed to detect.
"""
from __future__ import annotations

import json
import os
import time

from repro.core.trial import TrialResult

SLEEP_ENV = "FABRIC_SURFACE_SLEEP_S"
LEDGER_ENV = "FABRIC_SURFACE_LEDGER"


def surface_cost(wl, rt) -> TrialResult:
    """Deterministic cost of one (workload, config) trial."""
    kind = wl.shp.kind
    c = 100.0 * (1.0 + 0.01 * (len(wl.arch) % 7))
    if rt.compute_dtype == "bfloat16":
        c *= 0.72
    if rt.shard_strategy == "tp":
        c *= 1.15
    if rt.shard_strategy == "fsdp":
        c *= 1.10
    if kind == "train":
        if rt.remat_policy == "none":
            c *= 0.84
        if rt.remat_policy == "full":
            c *= 1.20
        if rt.microbatches == 2:
            c *= 0.93
        if rt.grad_comm_dtype == "bfloat16":
            c *= 0.99
    else:
        if rt.kv_cache_dtype == "int8":
            c *= 0.85
    if rt.attn_block_q == 256:
        c *= 0.92
    return TrialResult(cost_s=round(c, 6))


def make_evaluator():
    """Zero-arg factory (the ``--evaluator`` contract)."""
    sleep_s = float(os.environ.get(SLEEP_ENV, "0") or "0")
    ledger = os.environ.get(LEDGER_ENV)

    def evaluate(wl, rt) -> TrialResult:
        if ledger:
            line = json.dumps({"cell": wl.key(), "config": rt.as_dict()},
                              sort_keys=True) + "\n"
            fd = os.open(ledger, os.O_WRONLY | os.O_CREAT | os.O_APPEND,
                         0o644)
            try:
                os.write(fd, line.encode())
            finally:
                os.close(fd)
        if sleep_s > 0:
            time.sleep(sleep_s)
        return surface_cost(wl, rt)

    return evaluate
