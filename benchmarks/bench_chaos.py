"""Chaos benchmark: hardened trial execution under injected faults.

Four arms over the PR-2 4-cell batch on the fault-injecting synthetic
surface (benchmarks/chaos_surface.py, which wraps the deterministic
fabric surface — every non-faulted trial is bit-identical to the
fault-free run).  All faults target knobs whose tuning-tree stages are
train-only, so the single train cell (``smollm-135m:train_4k``) absorbs
every fault and the three other cells double as bit-identity controls.

  * **reference** — fault-free in-process campaign; the decision oracle
    and the evaluation-count baseline every chaos arm is diffed against;
  * **hang** — the ``microbatches=2`` config wedges (sleeps
    ``CHAOS_HANG_S`` = 300 s).  With ``--trial-timeout`` the sweep
    abandons it, records a ``timeout`` failure, and the campaign's wall
    stays bounded by the deadline, not the hang.  Non-hang cells must be
    bit-identical to reference;
  * **transient** — the ``grad_comm_dtype=bfloat16`` configs each fail
    once with ``OSError`` (transient class), then succeed.  With
    ``--max-retries`` every cell's decisions must be bit-identical to
    reference, extra evaluator invocations must equal the retry count
    exactly (each fault costs one re-evaluation, nothing cascades), and
    zero extra compiles are paid;
  * **poison** — the ``remat_policy=full`` config SIGKILLs whichever
    worker evaluates it.  A 2-worker fabric (strike threshold K=2) runs
    until both workers die; a third worker steals the expired lease,
    reaps the orphaned evaluation intents into strikes, quarantines the
    config fleet-wide and completes the cell (degraded).  The
    evaluation ledger must show the poison config evaluated exactly K
    times across the whole fleet — the crash-loop is broken.

Results land in results/benchmarks/BENCH_chaos.json and a copy at the
repo root (BENCH_chaos.json) for CI tracking.

Run:  PYTHONPATH=src python -m benchmarks.bench_chaos
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import contextlib
import json
import pathlib
import shutil
import time

ROOT = pathlib.Path(__file__).resolve().parent.parent

DEFAULT_CELLS = ("smollm-135m:train_4k,smollm-135m:prefill_32k,"
                 "xlstm-1.3b:prefill_32k,xlstm-1.3b:decode_32k")
FAULT_CELL = "smollm-135m__train_4k__pod"
KILL_DELTA = "remat_policy=full"
HANG_DELTA = "microbatches=2"
FLAKY_DELTA = "grad_comm_dtype=bfloat16"
HANG_S = 300.0
TRIAL_TIMEOUT_S = 1.0
STRIKE_K = 2
KILL_TTL_S = 2.0
EVALUATOR_SPEC = "benchmarks.chaos_surface:make_evaluator"


def _baseline(spec=None):
    from repro.core.params import default_config
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas")


@contextlib.contextmanager
def _chaos_env(**pairs):
    """Set CHAOS_* env vars for the duration (make_evaluator reads env
    at factory time, so in-process arms scope their faults here)."""
    old = {k: os.environ.get(k) for k in pairs}
    os.environ.update({k: str(v) for k, v in pairs.items()})
    try:
        yield
    finally:
        for k, v in old.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v


def _subprocess_env(ledger, **chaos):
    env = os.environ.copy()
    env["PYTHONPATH"] = os.pathsep.join(
        [str(ROOT / "src"), str(ROOT)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    env["CHAOS_LEDGER"] = str(ledger)
    env.update({k: str(v) for k, v in chaos.items()})
    return env


def _identical(reports, ref, keys):
    from repro.core.campaign import tuning_fingerprint
    return all(tuning_fingerprint(reports[k]) == tuning_fingerprint(ref[k])
               for k in keys)


def _compiles(reports):
    return sum(int(e["result"].get("compiles") or 0)
               for rep in reports.values() for e in rep.log)


def _ledger_lines(path):
    try:
        return [json.loads(line)
                for line in path.read_text().splitlines() if line]
    except OSError:
        return []


def _fabric_reports(directory, cells):
    from repro.core.strategy import get_strategy
    spec = get_strategy("tree")
    out = {}
    for c in cells:
        d = json.loads((directory / f"{c.key()}.json").read_text())
        assert d.get("done"), f"{c.key()} incomplete"
        out[c.key()] = spec.load_report(d["report"])
    return out


# ---------------------------------------------------------- reference
def run_reference_arm(cells, scratch):
    """Fault-free chaos surface (no deltas set): same decisions as the
    plain fabric surface, plus a ledger for invocation accounting."""
    from benchmarks.chaos_surface import make_evaluator
    from repro.core.campaign import Campaign
    ledger = scratch / "ledger-reference.jsonl"
    with _chaos_env(CHAOS_LEDGER=ledger):
        reports = Campaign(cells, evaluator=make_evaluator(),
                           baseline_factory=_baseline,
                           checkpoint_dir=None).run()
    return reports, len(_ledger_lines(ledger))


# --------------------------------------------------------------- hang
def run_hang_arm(cells, scratch, ref):
    from benchmarks.chaos_surface import make_evaluator
    from repro.core.campaign import Campaign
    d = scratch / "hang"
    with _chaos_env(CHAOS_HANG_DELTA=HANG_DELTA, CHAOS_HANG_S=HANG_S):
        camp = Campaign(cells, evaluator=make_evaluator(),
                        baseline_factory=_baseline, checkpoint_dir=d,
                        trial_timeout_s=TRIAL_TIMEOUT_S)
        t0 = time.time()
        reports = camp.run()
        wall = time.time() - t0
    health = (camp.last_stats.get("health") or {}).get(FAULT_CELL, {})
    timeouts = int((health.get("failures") or {}).get("timeout", 0))
    controls = [k for k in ref if k != FAULT_CELL]
    return {
        "hang_s": HANG_S,
        "trial_timeout_s": TRIAL_TIMEOUT_S,
        "wall_s": round(wall, 2),
        "wall_bounded_by_timeout": wall < HANG_S / 2,
        "timeouts_recorded": timeouts,
        "fault_cell_degraded": bool(health.get("degraded")),
        "controls_identical": _identical(reports, ref, controls),
    }


# ---------------------------------------------------------- transient
def run_transient_arm(cells, scratch, ref, ref_evals):
    from benchmarks.chaos_surface import make_evaluator
    from repro.core.campaign import Campaign
    d = scratch / "transient"
    ledger = scratch / "ledger-transient.jsonl"
    with _chaos_env(CHAOS_FLAKY_DELTA=FLAKY_DELTA, CHAOS_FLAKY_FAILS=1,
                    CHAOS_LEDGER=ledger):
        camp = Campaign(cells, evaluator=make_evaluator(),
                        baseline_factory=_baseline, checkpoint_dir=d,
                        max_retries=2)
        reports = camp.run()
    retries = int((camp.last_stats.get("hardening") or {})
                  .get("retries", 0))
    evals = len(_ledger_lines(ledger))
    return {
        "max_retries": 2,
        "retries": retries,
        "evaluations": evals,
        "reference_evaluations": ref_evals,
        "extra_evaluations": evals - ref_evals,
        "extra_compiles": _compiles(reports) - _compiles(ref),
        "all_cells_identical": _identical(reports, ref, list(ref)),
    }


# ------------------------------------------------------------- poison
def run_poison_arm(cells, scratch, ref):
    """2-worker fabric vs a worker-killing config.  Workers are managed
    directly (not run_coordinator — SIGKILL'd workers exit -9 and the
    coordinator treats any nonzero rc as failure, which is exactly the
    behavior under test here)."""
    from repro.core.fabric import LeaseBoard, spawn_worker
    from repro.core.quarantine import Quarantine
    d = scratch / "poison"
    ledger = d / "ledger.jsonl"
    d.mkdir(parents=True, exist_ok=True)
    env = _subprocess_env(ledger, CHAOS_KILL_DELTA=KILL_DELTA)

    def worker(i):
        return spawn_worker(cells, d, strategy="tree",
                            evaluator_spec=EVALUATOR_SPEC,
                            ttl_s=KILL_TTL_S, worker_id=f"w{i}",
                            strike_threshold=STRIKE_K,
                            log_path=d / "logs" / f"worker-{i}.log",
                            env=env)

    t0 = time.time()
    rcs = [p.wait(timeout=300) for p in [worker(0), worker(1)]]
    # both workers evaluated the poison config once each and died; the
    # survivor-less board still holds the poison cell's expired lease
    finisher = worker(2)
    rc2 = finisher.wait(timeout=300)
    wall = time.time() - t0
    assert rc2 == 0, f"finisher worker rc {rc2}"
    assert LeaseBoard(d).held() == [], "lease left held"

    poison_evals = sum(
        1 for rec in _ledger_lines(ledger)
        if str(rec["config"].get("remat_policy")) == "full")
    summary = Quarantine(d, strike_threshold=STRIKE_K).summary()
    state = json.loads((d / f"{FAULT_CELL}.json").read_text())
    health = state.get("health") or {}
    reports = _fabric_reports(d, cells)
    controls = [k for k in ref if k != FAULT_CELL]
    return {
        "strike_threshold": STRIKE_K,
        "worker_rcs": rcs + [rc2],
        "wall_s": round(wall, 2),
        "poison_evaluations_fleet_wide": poison_evals,
        "crash_loop_broken": poison_evals <= STRIKE_K,
        "quarantined_configs": summary["quarantined"],
        "quarantine_records": summary["records"],
        "fault_cell_done": bool(state.get("done")),
        "fault_cell_degraded": bool(health.get("degraded")),
        "fault_cell_quarantined_skips": int(health.get("quarantined", 0)),
        "controls_identical": _identical(reports, ref, controls),
    }


# ------------------------------------------------------------------ main
def main(cells_spec: str):
    from repro.core.campaign import parse_cells
    cells = parse_cells(cells_spec)
    print(f"batch: {len(cells)} cells "
          f"({', '.join(c.key() for c in cells)})")
    scratch = ROOT / "results" / "bench_chaos_scratch"
    shutil.rmtree(scratch, ignore_errors=True)
    scratch.mkdir(parents=True, exist_ok=True)

    ref, ref_evals = run_reference_arm(cells, scratch)
    print(f"reference: {ref_evals} evaluations, fault-free")

    hang = run_hang_arm(cells, scratch, ref)
    print(f"hang: wall {hang['wall_s']}s vs {HANG_S}s hang "
          f"({hang['timeouts_recorded']} timeouts, "
          f"controls identical={hang['controls_identical']})")

    transient = run_transient_arm(cells, scratch, ref, ref_evals)
    print(f"transient: {transient['retries']} retries, "
          f"{transient['extra_evaluations']} extra evaluations, "
          f"{transient['extra_compiles']} extra compiles, "
          f"identical={transient['all_cells_identical']}")

    poison = run_poison_arm(cells, scratch, ref)
    print(f"poison: evaluated {poison['poison_evaluations_fleet_wide']} "
          f"times fleet-wide (K={STRIKE_K}), worker rcs "
          f"{poison['worker_rcs']}, quarantined "
          f"{poison['quarantined_configs']}")

    out = {
        "cells": [c.key() for c in cells],
        "fault_cell": FAULT_CELL,
        "evaluator": EVALUATOR_SPEC,
        "deltas": {"kill": KILL_DELTA, "hang": HANG_DELTA,
                   "flaky": FLAKY_DELTA},
        "reference_evaluations": ref_evals,
        "hang": hang,
        "transient": transient,
        "poison": poison,
    }
    res_dir = ROOT / "results" / "benchmarks"
    res_dir.mkdir(parents=True, exist_ok=True)
    (res_dir / "BENCH_chaos.json").write_text(json.dumps(out, indent=1))
    (ROOT / "BENCH_chaos.json").write_text(json.dumps(out, indent=1))
    shutil.rmtree(scratch, ignore_errors=True)
    print(json.dumps(out, indent=1))
    assert hang["wall_bounded_by_timeout"], \
        "hang arm wall not bounded by the trial deadline!"
    assert hang["timeouts_recorded"] >= 1 and hang["fault_cell_degraded"]
    assert hang["controls_identical"], "hang arm changed control cells!"
    assert transient["all_cells_identical"], \
        "transient faults changed tuning decisions!"
    assert transient["extra_compiles"] == 0, \
        "transient recovery paid extra compiles!"
    assert transient["retries"] >= 1 \
        and transient["extra_evaluations"] == transient["retries"], \
        "transient recovery cost != one re-evaluation per fault"
    assert poison["crash_loop_broken"], \
        (f"poison config evaluated {poison['poison_evaluations_fleet_wide']}"
         f" times — quarantine failed to break the crash-loop at K")
    assert poison["fault_cell_done"] and poison["fault_cell_degraded"]
    assert poison["quarantined_configs"], "quarantine ledger empty!"
    assert poison["controls_identical"], "poison arm changed control cells!"
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cells", default=DEFAULT_CELLS,
                    help="comma-separated arch:shape[:pod|multipod]")
    a = ap.parse_args()
    main(a.cells)
