"""Shared benchmark plumbing.

The paper's three Sec.-4 workload classes map to (DESIGN.md §3.1):
  sort-by-key  (shuffle-intensive)  -> TP-dense LM train   (glm4-9b)
  shuffling    (shuffle-dominated)  -> MoE all-to-all train (olmoe-1b-7b)
  k-means      (compute-bound)      -> small dense LM train (smollm-135m)

Every benchmark "run" is one calibrated-roofline trial on the single-pod
production mesh (256 chips); results are cached under results/trials so
re-runs are instant.
"""
from __future__ import annotations

import json
import pathlib

from repro.core.params import default_config
from repro.core.sensitivity import run_sensitivity
from repro.core.trial import RooflineEvaluator, TrialRunner, Workload

RESULTS = pathlib.Path(__file__).resolve().parent.parent / "results" / "benchmarks"

WORKLOADS = {
    "sortbykey~glm4-9b/train_4k": Workload("glm4-9b", "train_4k"),
    "shuffling~olmoe-1b-7b/train_4k": Workload("olmoe-1b-7b", "train_4k"),
    "kmeans~smollm-135m/train_4k": Workload("smollm-135m", "train_4k"),
    "kmeans2~smollm-135m/prefill_32k": Workload("smollm-135m", "prefill_32k"),
}


def baseline_rt():
    """Cluster-level config fixed per [8]; knobs at Spark-like defaults,
    except the serializer (paper: all Sec.-4 runs use Kryo as baseline).
    The flash-attention kernel is part of the execution engine
    (infrastructure, like Spark's internals), not a tunable — its VMEM
    tile size IS the file.buffer tunable."""
    return default_config(shard_strategy="fsdp_tp",
                          compute_dtype="bfloat16",
                          attn_impl="pallas")


def sensitivity_for(wl: Workload):
    from repro.core.executor import SweepExecutor
    with SweepExecutor(RooflineEvaluator()) as executor:
        runner = TrialRunner(wl, executor.evaluator)
        return run_sensitivity(runner, baseline_rt(), executor=executor)


def save(name: str, text: str):
    RESULTS.mkdir(parents=True, exist_ok=True)
    (RESULTS / name).write_text(text)
    return RESULTS / name
