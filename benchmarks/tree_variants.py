"""Sec.-5 variants the paper sketches: acceptance-threshold sensitivity
("less restrictive manner ... 5% or 10%") and the shorter tree that
omits file.buffer ("two required runs less").

Runs against the trial cache, so invoke after benchmarks/run.py.
    PYTHONPATH=src python -m benchmarks.tree_variants
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import json


def run_variants(arch: str = "olmoe-1b-7b", shape: str = "train_4k"):
    from benchmarks.common import baseline_rt, save
    from repro.core.tree import default_tree, run_tuning, short_tree
    from repro.core.trial import RooflineEvaluator, TrialRunner, Workload

    wl = Workload(arch, shape)
    rows = []
    for label, stages, threshold in [
            ("tree@0%", None, 0.0),
            ("tree@5%", None, 0.05),
            ("tree@10%", None, 0.10),
            ("short-tree@5%", short_tree(wl.shp.kind), 0.05)]:
        runner = TrialRunner(wl, RooflineEvaluator())
        rep = run_tuning(runner, baseline_rt(), threshold=threshold,
                         stages=stages)
        rows.append({"variant": label, "trials": rep.n_trials,
                     "accepted": len(rep.accepted),
                     "final_cost_s": rep.final_cost,
                     "speedup": round(rep.speedup, 3)})
    md = ["### Tree variants (threshold + shorter tree), cell "
          f"`{wl.key()}`", "",
          "| variant | trials | accepted | final cost | speedup |",
          "|---|---|---|---|---|"]
    for r in rows:
        md.append(f"| {r['variant']} | {r['trials']} | {r['accepted']} | "
                  f"{r['final_cost_s']*1e3:.1f} ms | x{r['speedup']} |")
    text = "\n".join(md)
    save("tree_variants.md", text)
    return rows, text


if __name__ == "__main__":
    rows, text = run_variants()
    print(text)
