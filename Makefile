.PHONY: verify verify-fast bench-trials bench-campaign bench-fabric \
	bench-online bench-chaos bench-measured bench-serving \
	bench-telemetry

# tier-1: full suite, fail-fast (ROADMAP.md)
verify:
	./scripts/verify.sh

# skip the multi-minute subprocess end-to-end tests
verify-fast:
	./scripts/verify.sh -m 'not slow'

# trial-throughput benchmark -> BENCH_trials.json
bench-trials:
	PYTHONPATH=src python -m benchmarks.bench_trials

# campaign-throughput benchmark -> BENCH_campaign.json
bench-campaign:
	PYTHONPATH=src python -m benchmarks.bench_campaign

# fabric benchmark (worker scaling / kill-recovery / warm-start)
# -> BENCH_fabric.json
bench-fabric:
	PYTHONPATH=src python -m benchmarks.bench_fabric

# online-scheduler benchmark (priority time-to-first-improvement /
# mid-run admission latency) -> BENCH_online.json
bench-online:
	PYTHONPATH=src python -m benchmarks.bench_online

# chaos benchmark (poison quarantine / hang deadline / transient
# retry, with bit-identity controls) -> BENCH_chaos.json
bench-chaos:
	PYTHONPATH=src python -m benchmarks.bench_chaos

# measured-tier benchmark (roofline-only vs top-k re-rank, timing-cache
# repeat freeness, kernel tile autotuning) -> BENCH_measured.json
bench-measured:
	PYTHONPATH=src python -m benchmarks.bench_measured

# serving-loop benchmark (SLO guardrail on/off, bounded bad-config
# exposure, promotion, repeat-campaign cache freeness)
# -> BENCH_serving.json
bench-serving:
	PYTHONPATH=src python -m benchmarks.bench_serving

# telemetry benchmark (event overhead < 2% wall, trace/ledger
# consistency, bit-identity with tracing off) -> BENCH_telemetry.json
bench-telemetry:
	PYTHONPATH=src:. python -m benchmarks.bench_telemetry
