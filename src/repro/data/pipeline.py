"""Deterministic sharded synthetic-token pipeline.

The batch at step *t* is a pure function of (seed, t) — no iterator
state — so checkpoint/restart resumes the exact data order by saving
only the step counter (ft/ relies on this), and elastic remeshing is
trivial (any device layout draws the same global batch).  Each device
materializes only its addressable shard (``make_array_from_callback``).

Tokens are Zipf-distributed (text-like marginals) with a deterministic
per-(step, position) stream; labels are next-token shifted.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.params import TunableConfig
from repro.models.model import input_specs


def _tokens_for(seed: int, step: int, lo: int, hi: int, seq: int,
                vocab: int, zipf_a: float = 1.3) -> np.ndarray:
    """Rows [lo, hi) of the global (B, seq+1) token matrix at ``step``."""
    out = np.empty((hi - lo, seq + 1), np.int32)
    for r in range(lo, hi):
        rng = np.random.RandomState(
            (seed * 1_000_003 + step * 8_191 + r) % (2**31 - 1))
        z = rng.zipf(zipf_a, size=seq + 1).astype(np.int64)
        out[r - lo] = (z % vocab).astype(np.int32)
    return out


@dataclasses.dataclass
class SyntheticLM:
    """batch_at(step) -> sharded {tokens, labels, extras} matching
    ``input_specs``."""
    cfg: ArchConfig
    shape: ShapeConfig
    rt: TunableConfig
    mesh: jax.sharding.Mesh
    seed: int = 0

    def __post_init__(self):
        self.specs = input_specs(self.cfg, self.shape, self.rt)
        batch_axes = tuple(a for a in ("pod", "data")
                           if a in self.mesh.shape)
        self._shardings: Dict[str, NamedSharding] = {}
        for name, s in self.specs.items():
            spec = [None] * len(s.shape)
            if s.shape[0] % max(
                    1, int(np.prod([self.mesh.shape[a]
                                    for a in batch_axes]))) == 0:
                spec[0] = batch_axes
            self._shardings[name] = NamedSharding(self.mesh, P(*spec))

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        out = {}
        seq = self.specs["tokens"].shape[1]
        B = self.specs["tokens"].shape[0]

        def tok_cb(idx):
            lo, hi = idx[0].start or 0, idx[0].stop or B
            toks = _tokens_for(self.seed, step, lo, hi, seq, self.cfg.vocab)
            return toks[:, :-1]

        def lab_cb(idx):
            lo, hi = idx[0].start or 0, idx[0].stop or B
            toks = _tokens_for(self.seed, step, lo, hi, seq, self.cfg.vocab)
            return toks[:, 1:]

        out["tokens"] = jax.make_array_from_callback(
            (B, seq), self._shardings["tokens"], tok_cb)
        if "labels" in self.specs:
            out["labels"] = jax.make_array_from_callback(
                (B, seq), self._shardings["labels"], lab_cb)
        for extra in ("frontend_embeds", "frames"):
            if extra in self.specs:
                s = self.specs[extra]

                def emb_cb(idx, s=s):
                    shp = tuple((dim.stop or full) - (dim.start or 0)
                                for dim, full in zip(idx, s.shape))
                    rng = np.random.RandomState(
                        (self.seed * 31 + step * 7 + 13) % (2**31 - 1))
                    return rng.standard_normal(shp).astype(s.dtype) * 0.02

                out[extra] = jax.make_array_from_callback(
                    s.shape, self._shardings[extra], emb_cb)
        return out


class Prefetcher:
    """Background-thread prefetch queue over ``batch_at`` (depth N)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self._source = source
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, self._source.batch_at(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def stop(self):
        self._stop.set()
        self._thread.join(timeout=5)
