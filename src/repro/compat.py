"""Version compatibility shims for the installed JAX.

``jax.sharding.AxisType`` (explicit-sharding mesh axis kinds) only exists
in newer JAX releases; on older ones every mesh axis is implicitly
"auto", which is exactly what this codebase asks for.  All mesh
construction goes through :func:`axis_types_kw` so the same call sites
work on both sides of the API change.
"""
from __future__ import annotations

from typing import Dict, Tuple

try:                                     # JAX >= 0.5-era API
    from jax.sharding import AxisType  # type: ignore

    HAS_AXIS_TYPE = True
except ImportError:                      # older JAX: all axes are auto
    class AxisType:                      # type: ignore
        """Stand-in enum: only ``Auto`` is ever referenced here."""
        Auto = "auto"

    HAS_AXIS_TYPE = False


def axis_types_kw(n_axes: int) -> Dict[str, Tuple]:
    """kwargs dict for Mesh/make_mesh: axis_types only when supported."""
    if HAS_AXIS_TYPE:
        return {"axis_types": (AxisType.Auto,) * n_axes}
    return {}


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` across the API move: newer JAX exposes it at the
    top level with ``check_vma``; older releases have
    ``jax.experimental.shard_map.shard_map`` with the same semantics
    under ``check_rep``."""
    import jax

    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=check_vma)
