# The paper's primary contribution: black-box trial-and-error tuning of
# the 12-knob execution configuration — params (Sec. 3), sensitivity
# (Sec. 4 / Table 2), tree (Fig. 4), trial (the experimental-run
# protocol), costmodel (the CPU-container roofline evaluator).
from repro.core.params import TunableConfig, default_config  # noqa: F401
