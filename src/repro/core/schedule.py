"""Online campaign scheduler — live cell admission + priority queue.

The campaign engine (core/campaign.py) and fabric (core/fabric.py) were
batch systems: the set of (arch, shape, mesh) cells was frozen when the
process started, and cells ran in first-seen-arch order.  A production
tuning service meets workloads as they *arrive* and should spend its
trial budget where the expected gain is highest (online tuning à la
2309.01901).  This module owns both halves of that:

  * **intake** — a campaign directory gains an ``intake/`` subdirectory;
    anyone (``launch/tune.py --add-cells``, another process, another
    host on a shared mount) submits a cell by atomically renaming a
    ``<cell-key>.cell`` JSON file into it (:func:`submit_cells`).  A
    running campaign or fabric worker re-scans the intake between
    batches / when idle (:meth:`CellQueue.scan_intake`) and admits the
    new cells without restarting.  An ``intake/STOP`` sentinel
    (:func:`request_stop`) tells ``--watch`` workers to exit once the
    board is drained;
  * **priority** — a pluggable :class:`CellPrioritizer` scores every
    pending cell; the :class:`CellQueue` hands cells out
    highest-expected-speedup first.  ``arch`` reproduces the historical
    first-seen-arch order bit-for-bit; ``history`` estimates each
    cell's expected speedup from the accumulated trial history
    (:meth:`~repro.core.history.TrialHistory.expected_speedup` —
    best-of-nearest-cells via the history-fit similarity weights,
    falling back to the static registry-derived weights while the
    history is too thin to fit).  Cells
    the history knows nothing about sort *first* (explore-first: an
    unknown cell is where information is cheapest).  The first-seen-arch
    order survives as the tie-break, so same-arch calibration compiles
    still land adjacently in the shared compile cache.

Priority changes *scheduling order only*: each cell's search cursor is
a deterministic state machine, so a cold cell's decisions are
bit-identical to the static arch-ordered campaign whatever the
admission time or priority mode (regression-tested in
tests/test_schedule.py).  The one order-sensitive feature is
warm-start: seeds are resolved when a cell is handed out, so a
late-scheduled cell may be seeded by trials the same run already
appended — deliberate, and replay-exact via the checkpointed seeds.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pathlib
import time
from typing import (Any, Callable, Dict, List, Optional, Protocol,
                    Sequence, runtime_checkable)

from repro.core.campaign import CellSpec, parse_cells
from repro.core.fsutil import atomic_publish

INTAKE_DIR = "intake"
INTAKE_SUFFIX = ".cell"
STOP_FILENAME = "STOP"
INTAKE_VERSION = 1

_UNSET = object()


# ---------------------------------------------------------------- intake
def intake_dir(directory: pathlib.Path) -> pathlib.Path:
    """The intake subdirectory of a campaign/fabric directory."""
    return pathlib.Path(directory) / INTAKE_DIR


def submit_cells(directory: pathlib.Path,
                 cells: Sequence[CellSpec]) -> List[pathlib.Path]:
    """Submit cells to a (possibly running) campaign directory.

    One ``intake/<cell-key>.cell`` JSON file per cell, published with a
    unique tempfile + atomic ``os.replace`` so a concurrent scanner
    never reads a torn submission.  Re-submitting a cell overwrites its
    file (idempotent — admission dedups by cell key anyway).  Returns
    the published paths.
    """
    inbox = intake_dir(directory)
    inbox.mkdir(parents=True, exist_ok=True)
    out = []
    base = time.time()
    for i, spec in enumerate(cells):
        # strictly increasing timestamps keep one call's cells in list
        # order under the scanner's (submitted_at, key) sort
        payload = {"v": INTAKE_VERSION, "cell": spec.spec(),
                   "submitted_at": round(base + i * 1e-4, 6)}
        path = inbox / f"{spec.key()}{INTAKE_SUFFIX}"
        atomic_publish(path, json.dumps(payload))
        out.append(path)
    return out


def scan_intake(directory: pathlib.Path) -> List[CellSpec]:
    """Parse every submission in the intake directory, oldest first
    (submission timestamp, then cell key — deterministic across
    processes scanning the same mount).  Torn/invalid files are skipped,
    never fatal: the submitter's atomic rename makes them either a
    foreign leftover or garbage.  Submissions stay on disk — they are
    the durable admission record every fabric worker must see — until
    ``--fresh`` clears them.
    """
    inbox = intake_dir(directory)
    if not inbox.is_dir():
        return []
    found = []
    for path in inbox.glob(f"*{INTAKE_SUFFIX}"):
        try:
            d = json.loads(path.read_text())
            spec = parse_cells(d["cell"])[0]
            ts = float(d.get("submitted_at") or 0.0)
        except (OSError, ValueError, KeyError, TypeError,
                AttributeError):         # e.g. a non-string "cell"
            continue                     # torn or foreign file: skip
        found.append((ts, spec.key(), spec))
    found.sort(key=lambda t: (t[0], t[1]))
    return [spec for _, _, spec in found]


def clear_intake(directory: pathlib.Path,
                 cells: Optional[Sequence[CellSpec]] = None) -> None:
    """Remove intake submissions and any STOP sentinel — the
    ``--fresh`` companion to :func:`submit_cells`.  With ``cells=None``
    *every* submission goes (``--fresh`` must not let a stale
    ``--add-cells`` file silently re-admit a foreign cell into the
    supposedly fresh campaign); with an explicit list only those
    cells' files are removed."""
    inbox = intake_dir(directory)
    if cells is None:
        paths = list(inbox.glob(f"*{INTAKE_SUFFIX}")) \
            if inbox.is_dir() else []
    else:
        paths = [inbox / f"{spec.key()}{INTAKE_SUFFIX}"
                 for spec in cells]
    for path in paths:
        try:
            os.unlink(path)
        except OSError:
            pass
    try:
        os.unlink(inbox / STOP_FILENAME)
    except OSError:
        pass


def request_stop(directory: pathlib.Path) -> pathlib.Path:
    """Drop the STOP sentinel: ``--watch`` workers exit once every
    admitted cell is done (they finish the board first).

    A stop request is aimed at the workers watching *now*: each watch
    worker compares the sentinel's request time against its own
    process start (:func:`stop_requested_since`) and simply *ignores*
    an older one — nobody ever deletes the shared file on startup, so
    a new worker joining mid-drain can never cancel a live stop for
    the rest of the fabric.  The request time is stored in the payload
    (like intake submissions), so the comparison does not depend on
    filesystem mtime resolution; a stale sentinel is inert and is
    removed by ``--fresh`` or overwritten by the next stop."""
    inbox = intake_dir(directory)
    inbox.mkdir(parents=True, exist_ok=True)
    path = inbox / STOP_FILENAME
    # durable: a STOP that evaporates in a host crash leaves watch
    # workers draining a fabric the operator believes is stopping
    atomic_publish(path, json.dumps(
        {"v": 1, "requested_at": round(time.time(), 6)}),
        durable=True)
    return path


def _stop_requested_at(path: pathlib.Path) -> Optional[float]:
    """When the sentinel was dropped: the payload's own timestamp,
    falling back to mtime for a foreign/empty file; None if absent."""
    try:
        return float(json.loads(path.read_text())["requested_at"])
    except (OSError, ValueError, KeyError, TypeError):
        pass
    try:
        return path.stat().st_mtime
    except OSError:
        return None


def stop_requested(directory: pathlib.Path) -> bool:
    return (intake_dir(directory) / STOP_FILENAME).exists()


def stop_requested_since(directory: pathlib.Path,
                         since: float) -> bool:
    """True iff a STOP was requested at or after ``since`` (a watch
    worker passes its process start time): an older sentinel targets a
    *previous* session and is ignored — never deleted, so one worker's
    notion of stale can't cancel a stop that is live for the rest of
    the fabric.

    The comparison uses wall-clock timestamps from (possibly) two
    hosts, so multi-host watch fabrics need loosely synchronized
    clocks (NTP-level; skew larger than a worker's uptime makes a live
    stop read as stale — the remedy is re-issuing ``--stop``).  The
    same assumption already underpins the lease heartbeat TTLs
    (core/fabric.py)."""
    ts = _stop_requested_at(intake_dir(directory) / STOP_FILENAME)
    return ts is not None and ts >= since


# ----------------------------------------------------------- prioritizers
@runtime_checkable
class CellPrioritizer(Protocol):
    """Scores a pending cell's expected speedup.

    ``score`` returns the estimated speedup still to be had from tuning
    this cell (higher = schedule sooner), or ``None`` when the cell is
    unknown — unknown cells sort *first* (explore-first).  Scoring must
    be deterministic for a given history state: fabric workers on
    different hosts rank the same board identically.
    """

    name: str

    def score(self, spec: CellSpec) -> Optional[float]: ...


class ArchPrioritizer:
    """The historical order: no per-cell signal, every cell ties, and
    the queue's first-seen-arch + admission-order tie-break reproduces
    the static campaign's kickoff order bit-for-bit."""

    name = "arch"

    def score(self, spec: CellSpec) -> Optional[float]:
        return None


class HistoryPrioritizer:
    """Expected speedup from the accumulated trial history: the best
    observed speedup among the ``k_cells`` nearest already-tuned cells
    (signature similarity with weights *fit from the history itself*
    once it holds enough comparable cell pairs, else the static
    registry-derived weights — core/history.py).  A cell with no
    usable neighbours scores ``None`` → explore-first."""

    name = "history"

    def __init__(self, history, k_cells: int = 2):
        if history is None:
            raise ValueError("history prioritizer needs a trial history")
        self.history = history
        self.k_cells = k_cells

    def score(self, spec: CellSpec) -> Optional[float]:
        return self.history.expected_speedup(
            spec.arch, spec.shape, spec.multi_pod, k_cells=self.k_cells)


PRIORITIZERS: Dict[str, Callable[..., CellPrioritizer]] = {
    "arch": lambda history=None: ArchPrioritizer(),
    "history": lambda history=None: HistoryPrioritizer(history),
}


def get_prioritizer(name_or_instance, history=None) -> CellPrioritizer:
    """Resolve a prioritizer name (``arch`` / ``history``) or pass an
    instance through (custom prioritizers plug in like strategies)."""
    if not isinstance(name_or_instance, str):
        return name_or_instance
    if name_or_instance not in PRIORITIZERS:
        raise KeyError(f"unknown prioritizer {name_or_instance!r} "
                       f"(registered: {', '.join(sorted(PRIORITIZERS))})")
    return PRIORITIZERS[name_or_instance](history=history)


# ------------------------------------------------------------- the queue
@dataclasses.dataclass
class QueueEntry:
    """One admitted cell's scheduling state."""
    spec: CellSpec
    source: str                       # "seed" | "intake"
    admit_index: int
    admitted_at: float
    state: str = "pending"            # pending | active | done
    score: Optional[float] = None     # last priority query

    def as_dict(self) -> Dict[str, Any]:
        return {"cell": self.spec.key(), "source": self.source,
                "state": self.state, "score": self.score,
                "admitted_at": self.admitted_at}


class CellQueue:
    """Admission, ordering and completion tracking for an online
    campaign.

    Cells enter as construction-time *seeds* or through the intake
    directory (:meth:`scan_intake`), deduplicated by cell key.  Pending
    cells are handed out in priority order: unknown-first (explore),
    then expected speedup descending, with first-seen-arch grouping +
    admission order as the deterministic tie-break (compile-cache
    locality).  The queue is in-process state — in a fabric, every
    worker builds its own queue over the same directory and the lease
    board stays the sole claim arbiter; the queue only decides *which
    cell to try to claim next*.
    """

    def __init__(self, cells: Sequence[CellSpec] = (), *,
                 prioritizer="arch", history=None,
                 directory: Optional[pathlib.Path] = None):
        """``directory`` is the campaign/fabric directory whose
        ``intake/`` subdirectory this queue watches (None: no intake —
        a closed-world batch queue)."""
        self.prioritizer = get_prioritizer(prioritizer, history=history)
        self.directory = pathlib.Path(directory) \
            if directory is not None else None
        self._entries: Dict[str, QueueEntry] = {}
        self._arch_rank: Dict[str, int] = {}
        self.admit(cells, source="seed")

    # -------------------------------------------------------- admission
    def admit(self, cells: Sequence[CellSpec],
              source: str = "seed") -> List[CellSpec]:
        """Admit new cells (already-admitted keys are no-ops); returns
        the genuinely new ones in admission order."""
        fresh = []
        for spec in cells:
            key = spec.key()
            if key in self._entries:
                continue
            self._arch_rank.setdefault(spec.arch, len(self._arch_rank))
            self._entries[key] = QueueEntry(
                spec=spec, source=source, admit_index=len(self._entries),
                admitted_at=time.time())
            fresh.append(spec)
        return fresh

    def scan_intake(self) -> List[CellSpec]:
        """Admit every new submission in the directory's intake; returns
        the newly admitted cells (no directory → no-op)."""
        if self.directory is None:
            return []
        return self.admit(scan_intake(self.directory), source="intake")

    # --------------------------------------------------------- ordering
    def rank_key(self, key: str, gain=_UNSET) -> tuple:
        """The sort key of one admitted cell.  With ``gain`` (a live
        cursor-reported ``expected_gain``), that estimate replaces the
        prioritizer's static score — the campaign re-ranks in-flight
        cells between batches with it.  ``None`` (either source) sorts
        first: an unscored cell is an explore-first cell."""
        e = self._entries[key]
        if gain is _UNSET:
            e.score = self.prioritizer.score(e.spec)
            val = e.score
        else:
            val = gain
        return (0 if val is None else 1,
                -(val if val is not None else 0.0),
                self._arch_rank[e.spec.arch],
                e.admit_index)

    def order(self, states: Sequence[str] = ("pending",)
              ) -> List[CellSpec]:
        """Admitted cells in the given states, priority order
        (re-queries the prioritizer — history may have grown)."""
        keys = [k for k, e in self._entries.items() if e.state in states]
        keys.sort(key=self.rank_key)
        return [self._entries[k].spec for k in keys]

    def pop_next(self) -> Optional[CellSpec]:
        """Highest-priority pending cell, marked active; None if no
        cell is pending."""
        nxt = self.order()
        if not nxt:
            return None
        self.mark_active(nxt[0].key())
        return nxt[0]

    # ------------------------------------------------------ completion
    def _set_state(self, key: str, state: str) -> None:
        self._entries[key].state = state

    def mark_active(self, key: str) -> None:
        self._set_state(key, "active")

    def mark_done(self, key: str) -> None:
        self._set_state(key, "done")

    def state(self, key: str) -> str:
        return self._entries[key].state

    # --------------------------------------------------------- queries
    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    def cells(self) -> List[CellSpec]:
        """Every admitted cell, admission order."""
        return [e.spec for e in self._entries.values()]

    def entries(self) -> List[QueueEntry]:
        return list(self._entries.values())

    def depth(self) -> Dict[str, int]:
        """Queue depth per state (the ``--status`` headline)."""
        out = {"pending": 0, "active": 0, "done": 0}
        for e in self._entries.values():
            out[e.state] += 1
        return out

    def snapshot(self) -> Dict[str, Any]:
        """JSON-ready view for stats / reporting (re-scores pending
        cells so the recorded priorities are current)."""
        for key, e in self._entries.items():
            if e.state != "done":
                self.rank_key(key)       # refresh e.score
        return {
            "prioritize": self.prioritizer.name,
            "depth": self.depth(),
            "admitted": len(self._entries),
            "from_intake": sum(1 for e in self._entries.values()
                               if e.source == "intake"),
            "cells": [e.as_dict() for e in self._entries.values()],
        }


# --------------------------------------------------------------- status
def queue_status(directory: pathlib.Path, strategy: str = "tree",
                 cells: Optional[Sequence[CellSpec]] = None
                 ) -> Dict[str, Any]:
    """The operator's queue view (``launch/tune.py --status``): every
    cell known to a campaign directory — explicit ``cells``, checkpoint
    files and intake submissions — with its checkpoint state, plus the
    live lease board (:meth:`~repro.core.fabric.LeaseBoard.held`) so
    claimed/expired cells are visible without reading lease files by
    hand.  Read-only: never claims, never evaluates."""
    from repro.core.fabric import LeaseBoard, checkpoint_done
    directory = pathlib.Path(directory)
    known: Dict[str, Dict[str, Any]] = {}

    def note(key: str, **kw) -> Dict[str, Any]:
        d = known.setdefault(key, {"cell": key, "source": "checkpoint",
                                   "done": False})
        d.update(kw)
        return d

    for spec in (cells or []):
        note(spec.key(), source="seed")
    for spec in scan_intake(directory):
        entry = note(spec.key())
        if entry["source"] != "seed":
            entry["source"] = "intake"
    for path in sorted(directory.glob("*.json")):
        try:
            d = json.loads(path.read_text())
        except (OSError, ValueError):
            continue
        if isinstance(d, dict) and "cell" in d and "strategy" in d:
            # discovery only — done-ness is judged below by the one
            # shared criterion (checkpoint_done), so --status can never
            # call a cell done that a worker would re-tune
            entry = note(d["cell"])
            if isinstance(d.get("health"), dict):
                # per-cell failure/retry/quarantine counts so a
                # degrading campaign is visible before it finishes
                entry["health"] = d["health"]
    board = LeaseBoard(directory)
    leases, now = [], time.time()
    for st in board.held():
        leases.append({"cell": st.cell, "worker": st.worker,
                       "host": st.host,
                       "age_s": round(now - st.heartbeat_at, 1),
                       "ttl_s": st.ttl_s,
                       "expired": st.expired(now)})
        if st.cell not in known:
            note(st.cell, source="lease")
        if not st.expired(now):
            known[st.cell]["claimed_by"] = st.worker
    for key in known:
        known[key]["done"] = known[key]["done"] \
            or checkpoint_done(directory, key, strategy)
    pending = [k for k, d in known.items()
               if not d["done"] and "claimed_by" not in d]
    claimed = [k for k, d in known.items()
               if not d["done"] and "claimed_by" in d]
    # report the stop's request time, not just existence: the sentinel
    # is deliberately never deleted, so without the age an operator
    # can't tell a live drain from a stale leftover a newer watch
    # session is (correctly) ignoring
    stop_ts = _stop_requested_at(intake_dir(directory) / STOP_FILENAME)
    out = {
        "dir": str(directory),
        "strategy": strategy,
        "depth": {"pending": len(pending), "claimed": len(claimed),
                  "done": sum(d["done"] for d in known.values())},
        "stop_requested": stop_ts is not None,
        "stop_requested_at": stop_ts,
        "cells": sorted(known.values(), key=lambda d: d["cell"]),
        "leases": leases,
    }
    from repro.core.quarantine import Quarantine
    q = Quarantine(directory)
    if q.path.exists():
        out["quarantine"] = q.summary()
    return out
