"""Fleet-wide poison-config quarantine (evaluation-intent ledger).

The failure mode this closes: a config that SIGKILLs its worker leaves
no trace — the fabric's lease-steal recovers the *cell*, the stealer
replays the checkpoint, the cursor re-proposes the same config, and the
fleet crash-loops on it forever.  The quarantine ledger makes the
evaluation itself observable across process death:

  * before evaluating, a worker appends an **intent** record (cell,
    config key, attempt id, worker, pid) to ``quarantine.jsonl``;
  * after the evaluation returns — crashed or not — it appends a
    **completion** for the same attempt;
  * a worker that claims a cell (fresh or stolen lease) first *reaps
    orphans*: any intent on that cell with neither a completion nor a
    strike marks an evaluation that died mid-flight, and earns the
    in-flight config a **strike**;
  * a config whose effective strikes reach ``strike_threshold`` (K) is
    quarantined fleet-wide: every executor path skips it, scoring it as
    a deterministic crash.  A worker-killing config is therefore
    evaluated at most K times across the whole fabric.

Effective strikes use a *completion-reset* rule: only strikes recorded
after the config's last **successful** completion count.  This absolves
benign batch-mates — when a poison config kills a worker mid-batch, the
other in-flight configs are orphaned too and struck on reap, but they
succeed on re-evaluation and their count resets to zero; the poison
config never completes, so its strikes only accumulate.

The ledger is append-only JSONL via the torn-tolerant O_APPEND idiom
(core/fsutil.append_jsonl) with per-record fsync (``durable=True``):
records are correctness signals across worker processes, so they must
survive the very crash they are recording.  Readers skip unparseable
lines; records are idempotent and dedup by attempt id, so two stealers
racing to strike the same orphan converge on one effective strike.
"""
from __future__ import annotations

import hashlib
import json
import os
import pathlib
import time
import uuid
from typing import Dict, List, Optional, Set

from repro.core import telemetry as _telemetry
from repro.core.fsutil import append_jsonl
from repro.core.params import TunableConfig

QUARANTINE_FILENAME = "quarantine.jsonl"
DEFAULT_STRIKE_THRESHOLD = 3


def config_key(rt: TunableConfig) -> str:
    """Stable fleet-wide identity of a full config (all 12 knobs)."""
    blob = json.dumps(rt.as_dict(), sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:16]


class Quarantine:
    """One shared evaluation-intent ledger over a campaign directory.

    Thread-safe for the executor's use (appends are single O_APPEND
    writes; reads re-parse on (size, mtime) change) and multi-process
    safe by construction of the ledger format.
    """

    def __init__(self, directory: pathlib.Path,
                 strike_threshold: int = DEFAULT_STRIKE_THRESHOLD,
                 worker: str = "", durable: bool = True):
        self.dir = pathlib.Path(directory)
        self.path = self.dir / QUARANTINE_FILENAME
        self.strike_threshold = strike_threshold
        self.worker = worker
        self.durable = durable
        self._cache_stat = None
        self._cache_records: List[Dict] = []

    # ------------------------------------------------------------ ledger
    def _append(self, rec: Dict) -> None:
        rec = dict(rec)
        rec.setdefault("v", 1)
        rec.setdefault("ts", round(time.time(), 3))
        rec.setdefault("worker", self.worker)
        rec.setdefault("pid", os.getpid())
        append_jsonl(self.path, rec, durable=self.durable)

    def records(self) -> List[Dict]:
        """All parseable ledger records, in append order.  Cached on
        (size, mtime_ns) so repeated guards during a sweep cost one
        stat; unparseable lines (torn tails) are skipped."""
        try:
            st = self.path.stat()
        except OSError:
            return []
        stat_key = (st.st_size, st.st_mtime_ns)
        if stat_key == self._cache_stat:
            return self._cache_records
        recs = []
        try:
            text = self.path.read_text()
        except OSError:
            return []
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if isinstance(rec, dict) and rec.get("type"):
                recs.append(rec)
        self._cache_stat = stat_key
        self._cache_records = recs
        return recs

    # ------------------------------------------------------- protocol
    def begin(self, cell: str, rt: TunableConfig) -> Dict:
        """Record the intent to evaluate ``rt`` on ``cell``.  Returns
        the token to pass to :meth:`complete`."""
        token = {"attempt": uuid.uuid4().hex[:12],
                 "key": config_key(rt), "cell": cell}
        self._append({"type": "intent", "config": rt.as_dict(), **token})
        return token

    def complete(self, token: Dict, crashed: bool,
                 note: str = "") -> None:
        """Record that the attempt returned (however it went)."""
        self._append({"type": "complete", "crashed": bool(crashed),
                      "note": note, **token})

    def strike(self, attempt: str, key: str, cell: str = "",
               reason: str = "") -> None:
        """Assign one strike to ``key`` for a died/hung ``attempt``
        (idempotent per attempt: effective counting dedups by id)."""
        for rec in self.records():
            if rec.get("type") == "strike" and rec.get("attempt") == attempt:
                return
        self._append({"type": "strike", "attempt": attempt, "key": key,
                      "cell": cell, "reason": reason})
        tel = _telemetry.current()
        if tel.enabled:
            tel.emit("quarantine.strike", config=key, cell=cell,
                     reason=reason,
                     strikes=self.effective_strikes(key),
                     threshold=self.strike_threshold)

    def reap_orphans(self, cell: Optional[str] = None) -> List[str]:
        """Strike every orphaned intent (no completion, no strike) —
        call after claiming a cell's lease, when no other worker can be
        legitimately mid-evaluation on it.  ``cell=None`` reaps across
        all cells (single-process campaign resume).  Returns the config
        keys struck."""
        recs = self.records()
        completed = {r.get("attempt") for r in recs
                     if r.get("type") == "complete"}
        struck = {r.get("attempt") for r in recs
                  if r.get("type") == "strike"}
        reaped = []
        for rec in recs:
            if rec.get("type") != "intent":
                continue
            if cell is not None and rec.get("cell") != cell:
                continue
            att = rec.get("attempt")
            if att in completed or att in struck:
                continue
            self.strike(att, rec.get("key", ""), rec.get("cell", ""),
                        reason="orphaned intent (worker died mid-trial)")
            struck.add(att)
            reaped.append(rec.get("key", ""))
        return reaped

    # ------------------------------------------------------- judgment
    def effective_strikes(self, key: str) -> int:
        """Distinct struck attempts for ``key`` recorded after its last
        *successful* completion (the completion-reset rule)."""
        last_success = -1
        strikes = {}                      # attempt -> ledger position
        for i, rec in enumerate(self.records()):
            if rec.get("key") != key:
                continue
            t = rec.get("type")
            if t == "complete" and not rec.get("crashed"):
                last_success = i
            elif t == "strike":
                strikes.setdefault(rec.get("attempt"), i)
        return sum(1 for pos in strikes.values() if pos > last_success)

    def is_quarantined(self, key: str) -> bool:
        return self.effective_strikes(key) >= self.strike_threshold

    def quarantined_keys(self) -> Set[str]:
        keys = {r.get("key") for r in self.records()
                if r.get("type") == "strike"}
        return {k for k in keys if k and self.is_quarantined(k)}

    def summary(self) -> Dict:
        """Operator-facing rollup for ``tune.py --status``."""
        recs = self.records()
        strikes: Dict[str, int] = {}
        for rec in recs:
            if rec.get("type") == "strike":
                k = rec.get("key", "")
                strikes[k] = self.effective_strikes(k)
        return {
            "records": len(recs),
            "intents": sum(r.get("type") == "intent" for r in recs),
            "completions": sum(r.get("type") == "complete" for r in recs),
            "strikes": {k: n for k, n in sorted(strikes.items()) if n},
            "quarantined": sorted(self.quarantined_keys()),
            "strike_threshold": self.strike_threshold,
        }
