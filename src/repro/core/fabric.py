"""Campaign fabric — lease-based multi-process (multi-host-ready) cell
distribution.

The campaign engine (core/campaign.py) interleaves many cells inside
one process; the fabric shards those cells *across* processes — and,
because coordination happens entirely through files in one shared
directory, across hosts that mount it.  Nothing about the unit of work
changes: a cell's per-strategy JSON checkpoint is still the resumable
state, the disk :class:`~repro.core.trial.CompileCache` is still the
shared compile memo, and the ``history.jsonl`` trial store
(core/history.py) still accumulates every trial.  The fabric adds only
the *claiming* layer:

  * **leases** — a worker claims a cell by atomically creating
    ``leases/<cell>.lease`` (``O_CREAT | O_EXCL``) in the shared
    directory.  The lease records worker id, pid, host and a heartbeat
    timestamp with a TTL;
  * **heartbeats** — while a worker runs a cell's campaign, a daemon
    thread refreshes the lease (atomic tempfile + ``os.replace``) every
    ``ttl / 3`` seconds;
  * **recovery** — a lease whose heartbeat is older than its TTL is
    *expired*: any worker may steal it.  Stealing is race-free — the
    stealer ``os.rename``\\ s the lease file to a unique tombstone name
    (exactly one concurrent stealer wins the rename), unlinks it, and
    re-creates the lease via ``O_EXCL``.  Because the dead worker
    checkpointed after every absorbed batch, the stealer's campaign
    replays everything already absorbed and re-pays nothing;
  * **liveness caveat** — a worker paused longer than its TTL (not
    dead, just slow) can lose its lease and race the stealer on one
    cell.  Both then run the same deterministic cursor and publish
    whole checkpoints atomically, so the race costs duplicated trial
    evaluation, never a torn or wrong checkpoint.  The owner notices on
    its next heartbeat (:class:`LeaseLost`) and stops claiming credit.

Topologies:

  * ``FabricWorker`` — one process working a shared directory; start
    any number, on any host, at any time (``launch/tune.py --worker``);
  * ``run_coordinator`` — convenience: spawn N local workers over the
    same directory and wait (``launch/tune.py --workers N`` /
    ``--coordinate``).

Since the online scheduler (core/schedule.py), the shared directory is
also the *admission* channel: workers re-scan its ``intake/`` for new
cell submissions on every pass, claim cells in queue-priority order
(``--prioritize history``: highest expected speedup first), and with
``--watch`` idle instead of exiting once the board is drained — a
running fabric is a tuning service new workloads can join at any time.

**Filesystem requirements** — the protocol leans on three POSIX
semantics of the shared directory: atomic ``O_CREAT | O_EXCL`` create
(lease claims and steal locks — needs NFSv4+ if the mount is NFS; v2/v3
O_EXCL is not atomic), atomic same-directory ``rename`` (checkpoints,
compile-cache entries, heartbeats), and single-``write`` ``O_APPEND``
appends (the trial history, the quarantine ledger and the telemetry
event stream ``events.jsonl`` — local filesystems only; NFS may
interleave bytes across hosts, which the torn-tolerant readers survive
by *dropping* the damaged lines — acceptable for the history, where a
lost line only weakens warm-start retrieval, and for ``events.jsonl``,
where telemetry is observability and a dropped event only thins the
timeline/metrics, but NOT for ``quarantine.jsonl``, where a dropped
intent gives a worker-killing config a free extra evaluation — the
events file is accordingly written *non*-durable, no per-line fsync,
since its lines are never correctness signals).  Durability is
a fourth, quarantine-specific need: intent records must survive the
very worker crash they are recording, so the ledger (and the lease
heartbeats + STOP sentinels) is written with ``durable=True``
(``fsync`` before publish + parent-directory fsync,
core/fsutil.py).  Local disks and single-host multi-process use get
all four; for multi-host NFS campaigns the leases and checkpoints are
sound on v4+, and an object-store/rsync-backed history + quarantine
ledger is the roadmap item.

The coordinator passes workers an ``--evaluator module:factory``
dotted-path spec, so benchmarks and tests can swap the real
:class:`~repro.core.trial.RooflineEvaluator` for synthetic surfaces.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import os
import pathlib
import socket
import subprocess
import sys
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.core import telemetry as _telemetry
from repro.core.campaign import (CHECKPOINT_VERSION, Campaign, CellSpec)
from repro.core.executor import SweepExecutor
from repro.core.fsutil import atomic_publish
from repro.core.history import HISTORY_FILENAME, TrialHistory
from repro.core.strategy import get_strategy

LEASE_DIR = "leases"
DEFAULT_TTL_S = 30.0


class LeaseLost(RuntimeError):
    """The lease was stolen (our heartbeat went stale) or vanished."""


# ---------------------------------------------------------------- leases
@dataclasses.dataclass
class LeaseState:
    """The JSON payload of one lease file."""
    cell: str
    worker: str
    pid: int
    host: str
    acquired_at: float
    heartbeat_at: float
    ttl_s: float

    def expired(self, now: Optional[float] = None) -> bool:
        return (now or time.time()) - self.heartbeat_at > self.ttl_s

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class Lease:
    """A held lease: refresh to keep it, release when the cell is done."""

    def __init__(self, board: "LeaseBoard", state: LeaseState):
        self.board = board
        self.state = state

    @property
    def cell(self) -> str:
        return self.state.cell

    def refresh(self) -> bool:
        """True if the heartbeat was written; False on lock contention
        (retry next beat); raises LeaseLost if no longer ours."""
        return self.board._refresh(self)

    def release(self) -> None:
        self.board._release(self)


class LeaseBoard:
    """Atomic file leases over the cells of one shared directory."""

    def __init__(self, directory: pathlib.Path,
                 worker_id: Optional[str] = None,
                 ttl_s: float = DEFAULT_TTL_S):
        self.dir = pathlib.Path(directory) / LEASE_DIR
        self.worker_id = worker_id or \
            f"{socket.gethostname()}-{os.getpid()}-{uuid.uuid4().hex[:6]}"
        self.ttl_s = ttl_s
        # observability only — the FabricWorker points this at its bus;
        # claim/steal/lost decisions never read it
        self.telemetry = _telemetry.NULL

    def _path(self, cell: str) -> pathlib.Path:
        return self.dir / f"{cell}.lease"

    def read(self, cell: str) -> Optional[LeaseState]:
        """Parse a lease file; None if absent.  A torn/corrupt file is
        reported as an already-expired lease (stealable): lease writes
        are atomic, so torn content means a crashed foreign writer."""
        try:
            d = json.loads(self._path(cell).read_text())
            return LeaseState(**d)
        except FileNotFoundError:
            return None
        except (OSError, ValueError, TypeError):
            return LeaseState(cell=cell, worker="?", pid=0, host="?",
                              acquired_at=0.0, heartbeat_at=0.0,
                              ttl_s=self.ttl_s)

    def _write_new(self, path: pathlib.Path, state: LeaseState) -> bool:
        """O_CREAT|O_EXCL create — the atomic claim; False if held."""
        self.dir.mkdir(parents=True, exist_ok=True)
        try:
            fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_EXCL,
                         0o644)
        except FileExistsError:
            return False
        try:
            os.write(fd, json.dumps(state.as_dict()).encode())
        finally:
            os.close(fd)
        return True

    def _lock_path(self, cell: str) -> pathlib.Path:
        return self.dir / f"{cell}.lease.steal"

    def _try_lock(self, cell: str) -> bool:
        """The per-cell arbitration lock (``O_CREAT | O_EXCL``) both
        stealers and the owner's heartbeat serialize on, so neither can
        clobber a lease the other just (re)wrote.  A lock older than
        the TTL is a crashed holder's leftover and is cleared."""
        lock = self._lock_path(cell)
        try:
            os.close(os.open(lock, os.O_WRONLY | os.O_CREAT | os.O_EXCL,
                             0o644))
            return True
        except FileExistsError:
            try:
                if time.time() - lock.stat().st_mtime \
                        > max(5.0, self.ttl_s):
                    os.unlink(lock)      # crashed holder's leftover
            except OSError:
                pass
            return False                 # lost the arbitration: retry
        except FileNotFoundError:
            self.dir.mkdir(parents=True, exist_ok=True)
            return False

    def _unlock(self, cell: str) -> None:
        try:
            os.unlink(self._lock_path(cell))
        except OSError:
            pass

    def _bury_expired(self, cell: str) -> bool:
        """Remove the cell's lease iff it is (still) expired — the lock
        holder re-reads the lease *under the lock* before unlinking, so
        a fresh lease created between a stealer's first read and its
        steal can never be clobbered (that race loses live leases)."""
        path = self._path(cell)
        if not self._try_lock(cell):
            return False
        try:
            held = self.read(cell)
            if held is None:
                return True              # vanished: claimable
            if not held.expired():
                return False             # revived under us: keep it
            try:
                os.unlink(path)
            except FileNotFoundError:
                pass
            return True
        finally:
            self._unlock(cell)

    def try_acquire(self, cell: str) -> Optional[Lease]:
        """Claim a cell; None if a live worker holds it.  Expired
        leases (crashed workers) are stolen."""
        path = self._path(cell)
        stole = False
        for _ in range(4):               # bounded retries under races
            now = time.time()
            state = LeaseState(cell=cell, worker=self.worker_id,
                               pid=os.getpid(),
                               host=socket.gethostname(),
                               acquired_at=now, heartbeat_at=now,
                               ttl_s=self.ttl_s)
            if self._write_new(path, state):
                tel = self.telemetry
                if tel.enabled:
                    tel.emit("lease.steal" if stole else "lease.claim",
                             cell=cell, ttl_s=self.ttl_s)
                return Lease(self, state)
            held = self.read(cell)
            if held is not None and not held.expired():
                return None              # a live worker owns the cell
            if self._bury_expired(cell):  # steal: verified, then retry
                stole = True
        return None

    def _refresh(self, lease: Lease) -> bool:
        """Bump the heartbeat (atomic replace under the per-cell
        arbitration lock, so a stealer's freshly-created lease can
        never be clobbered by a stale owner's write).  Returns False
        when the lock is contended — skip this beat, the heartbeat
        retries next interval.  Raises :class:`LeaseLost` if the lease
        on disk is no longer ours *or already expired* (we cannot know
        whether a stealer is about to take it — stop claiming it)."""
        cell = lease.state.cell
        if not self._try_lock(cell):
            return False
        try:
            held = self.read(cell)
            if held is None or held.worker != self.worker_id \
                    or held.expired():
                tel = self.telemetry
                if tel.enabled:
                    tel.emit("lease.lost", cell=cell,
                             holder=held.worker if held else None)
                raise LeaseLost(
                    f"lease for {cell}: "
                    + ("expired before refresh" if held is not None
                       and held.worker == self.worker_id else
                       f"now held by "
                       f"{held.worker if held else 'nobody'}"))
            lease.state.heartbeat_at = time.time()
            # durable: a heartbeat that evaporates in a host crash reads
            # as a stale lease and triggers a false steal
            atomic_publish(self._path(cell),
                           json.dumps(lease.state.as_dict()),
                           prefix=".hb.", durable=True)
            return True
        finally:
            self._unlock(cell)

    def _release(self, lease: Lease) -> None:
        held = self.read(lease.state.cell)
        if held is not None and held.worker == self.worker_id:
            try:
                os.unlink(self._path(lease.state.cell))
            except FileNotFoundError:
                pass
            tel = self.telemetry
            if tel.enabled:
                tel.emit("lease.release", cell=lease.state.cell)

    def held(self) -> List[LeaseState]:
        """Every lease currently on the board (including expired ones)."""
        if not self.dir.exists():
            return []
        out = []
        for p in sorted(self.dir.glob("*.lease")):
            st = self.read(p.name[:-len(".lease")])
            if st is not None:
                out.append(st)
        return out

    def reap_expired(self) -> List[str]:
        """Bury every expired lease (e.g. leftovers of crashed workers
        on already-done cells); returns the buried cell keys."""
        out = []
        for st in self.held():
            if st.expired() and self._bury_expired(st.cell):
                out.append(st.cell)
        return out

    def clear(self, cells: Sequence[str]) -> None:
        """Unconditionally remove these cells' leases and any steal
        locks (``--fresh`` on a quiescent board)."""
        for cell in cells:
            for suffix in ("", ".steal"):
                try:
                    os.unlink(self._path(cell).with_name(
                        f"{cell}.lease{suffix}"))
                except OSError:
                    pass


class Heartbeat:
    """Context manager: refresh a lease from a daemon thread while the
    worker runs the cell's campaign.  If the lease is lost (stolen
    after a too-long pause), ``lost`` flips and refreshing stops — the
    campaign itself keeps running safely (see module docstring)."""

    def __init__(self, lease: Lease, interval: Optional[float] = None):
        self.lease = lease
        self.interval = interval or max(0.05, lease.state.ttl_s / 3.0)
        self.lost = False
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def _loop(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                self.lease.refresh()
            except LeaseLost:
                self.lost = True
                return
            except OSError:
                pass                     # transient fs hiccup: retry

    def __enter__(self) -> "Heartbeat":
        self._thread = threading.Thread(target=self._loop,
                                        name="lease-heartbeat",
                                        daemon=True)
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=5.0)


# --------------------------------------------------------------- worker
def load_evaluator(spec: Optional[str]) -> Callable:
    """Resolve an ``--evaluator module:factory`` dotted-path spec (the
    factory is called with no arguments); default: the kernel-aware
    :class:`~repro.core.kernel_cell.DispatchEvaluator` (bit-identical
    to a bare RooflineEvaluator on step cells)."""
    if not spec:
        from repro.core.kernel_cell import DispatchEvaluator
        return DispatchEvaluator()
    mod, sep, attr = spec.partition(":")
    if not sep or not attr:
        raise ValueError(f"evaluator spec {spec!r}: want module:factory")
    return getattr(importlib.import_module(mod), attr)()


def checkpoint_done(directory: pathlib.Path, cell: str,
                    strategy: str) -> bool:
    """Cheap completion check: the cell's checkpoint says done under
    this strategy.  This is the *weak* form (no signature validation) —
    the worker and coordinator use :meth:`Campaign.cell_done`, which
    additionally validates the threshold/baseline/walk/warm-start
    signature, so a done checkpoint from different parameters is
    re-claimed and re-tuned exactly as the single-process campaign
    would."""
    path = pathlib.Path(directory) / f"{cell}.json"
    try:
        d = json.loads(path.read_text())
    except (OSError, ValueError):
        return False
    return (isinstance(d, dict)
            and d.get("version") == CHECKPOINT_VERSION
            and d.get("strategy") == strategy
            and bool(d.get("done")))


class FabricWorker:
    """One process of the fabric: claim cells via leases, run each
    claimed cell's (checkpointed, resumable) single-cell campaign to
    completion, repeat until every target cell is done.

    Start any number of workers over the same ``directory`` — locally
    via :func:`run_coordinator`, or independently on other hosts
    against a shared mount.  ``evaluator`` defaults to the
    kernel-aware :class:`~repro.core.kernel_cell.DispatchEvaluator`
    (a RooflineEvaluator on step cells, the timing-cached kernel bench
    on kernel cells) whose disk caches are shared with every other
    worker.

    **Online mode** (core/schedule.py) — target cells are not frozen at
    startup: every scheduling pass re-scans the shared directory's
    ``intake/`` and admits new submissions, and the claim order follows
    the cell queue's priority (``prioritize="history"``: highest
    expected speedup first, unknown cells explore-first; ``"arch"``:
    the historical arch-grouped order).  With ``watch=True`` a worker
    that has drained the board *idles and keeps re-scanning* instead of
    exiting, so cells submitted hours later are claimed by the same
    process; the ``intake/STOP`` sentinel (``launch/tune.py --stop``)
    ends the watch once everything admitted is done.

    ``ready_file`` / ``go_file`` implement an optional start barrier
    for benchmarks: the worker touches ``ready_file`` once initialized,
    then blocks until ``go_file`` exists — so measured wall-clock
    covers fabric work, not interpreter/JAX cold start.
    """

    def __init__(self, cells: Sequence[CellSpec],
                 directory: pathlib.Path, *,
                 strategy: str = "tree",
                 strategy_options: Optional[Dict[str, Any]] = None,
                 threshold: float = 0.05,
                 evaluator: Optional[Callable] = None,
                 baseline_factory: Optional[Callable] = None,
                 worker_id: Optional[str] = None,
                 ttl_s: float = DEFAULT_TTL_S,
                 poll_s: float = 0.5,
                 warm_start: bool = False,
                 warm_start_cells: int = 2,
                 warm_start_per_cell: int = 1,
                 max_workers: Optional[int] = None,
                 prioritize: Any = "arch",
                 watch: bool = False,
                 started_at: Optional[float] = None,
                 ready_file: Optional[pathlib.Path] = None,
                 go_file: Optional[pathlib.Path] = None,
                 trial_timeout_s: Optional[float] = None,
                 max_retries: int = 0,
                 strike_threshold: Optional[int] = None,
                 measure_top_k: int = 0,
                 measured_evaluator: Optional[Callable] = None,
                 promote: bool = False,
                 trace: bool = False):
        if not cells and not watch:
            raise ValueError("fabric worker needs at least one cell "
                             "(or watch mode: claim intake submissions)")
        self.cells = list(cells)
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.strategy = get_strategy(strategy)
        self.strategy_options = dict(strategy_options or {})
        self.threshold = threshold
        if evaluator is None:
            # kernel-aware default, like Campaign's: step decisions
            # stay bit-identical to a bare RooflineEvaluator
            from repro.core.kernel_cell import DispatchEvaluator
            evaluator = DispatchEvaluator()
        self.evaluator = evaluator
        self.baseline_factory = baseline_factory
        self.board = LeaseBoard(self.dir, worker_id=worker_id,
                                ttl_s=ttl_s)
        # telemetry (core/telemetry.py): with trace=True this worker
        # appends span events to the shared <dir>/events.jsonl — and
        # installs the bus process-globally so the deep layers
        # (CompileCache, TimingCache, SLOGuard, Quarantine) emit too.
        # Observability only: decisions are bit-identical either way.
        if trace:
            self.telemetry = _telemetry.install(_telemetry.Telemetry(
                self.dir, worker=self.board.worker_id))
        else:
            self.telemetry = _telemetry.current()
        self.board.telemetry = self.telemetry
        self.log = _telemetry.get_logger(self.board.worker_id)
        self.poll_s = poll_s
        self.warm_start = warm_start
        self.warm_start_cells = warm_start_cells
        self.warm_start_per_cell = warm_start_per_cell
        self.max_workers = max_workers
        self.history = TrialHistory(self.dir / HISTORY_FILENAME)
        self.prioritize = prioritize
        self.watch = bool(watch)
        # the reference instant for stale-STOP clearing: callers that
        # pay long imports before constructing the worker (the CLI)
        # pass their process start; a STOP dropped after it is live
        self.started_at = started_at if started_at is not None \
            else time.time()
        self.ready_file = ready_file
        self.go_file = go_file
        self.trial_timeout_s = trial_timeout_s
        self.max_retries = int(max_retries)
        # measured tier: the disk TimingCache inside the default
        # measured evaluator is shared fleet-wide exactly like the
        # compile cache, so a re-claimed cell's re-rank re-pays nothing
        self.measure_top_k = int(measure_top_k)
        self.measured_evaluator = measured_evaluator
        # serving promotion (serving/canary.py): after each completed
        # cell, publish its surviving winner to the shared directory's
        # per-cell live-config board (the board itself enforces the
        # never-regress rule, so concurrent workers stay safe)
        self.promote = bool(promote)
        # one fleet-shared evaluation-intent ledger (core/quarantine.py)
        # over the campaign directory: every worker brackets trials with
        # intent/completion records and skips quarantined configs
        from repro.core.quarantine import Quarantine
        self.quarantine = Quarantine(
            self.dir, worker=self.board.worker_id,
            **({"strike_threshold": strike_threshold}
               if strike_threshold is not None else {}))
        # the completion probe: a Campaign that never runs, only asks
        # cell_done() — full signature validation (threshold, baseline,
        # walk, warm-start seeds), so a done checkpoint from different
        # parameters is re-claimed and re-tuned
        self._probe = Campaign(
            self.cells, strategy=self.strategy.name,
            strategy_options=self.strategy_options,
            threshold=self.threshold, evaluator=self.evaluator,
            baseline_factory=self.baseline_factory,
            checkpoint_dir=self.dir, history=self.history,
            warm_start=self.warm_start,
            warm_start_cells=self.warm_start_cells,
            warm_start_per_cell=self.warm_start_per_cell,
            measure_top_k=self.measure_top_k,  # cell_done gates on it
            quarantine=False,            # probe never evaluates
            intake=True)    # probe only; also admits the no-seed case

    # ------------------------------------------------------------ cells
    def _done(self, spec: CellSpec) -> bool:
        return self._probe.cell_done(spec)

    def _run_cell(self, spec: CellSpec, lease: Lease) -> Dict:
        camp = Campaign(
            [spec], strategy=self.strategy.name,
            strategy_options=self.strategy_options,
            threshold=self.threshold, evaluator=self.evaluator,
            baseline_factory=self.baseline_factory,
            checkpoint_dir=self.dir, history=self.history,
            warm_start=self.warm_start,
            warm_start_cells=self.warm_start_cells,
            warm_start_per_cell=self.warm_start_per_cell,
            max_workers=self.max_workers,
            trial_timeout_s=self.trial_timeout_s,
            max_retries=self.max_retries,
            measure_top_k=self.measure_top_k,
            measured_evaluator=self.measured_evaluator,
            quarantine=self.quarantine,
            telemetry=self.telemetry)
        with Heartbeat(lease) as hb:
            reports = camp.run()
        if self.promote and reports:
            from repro.serving.canary import promote_winners
            promote_winners(self.dir, reports,
                            source=self.board.worker_id)
        stats = dict(camp.last_stats)
        stats["lease_lost"] = hb.lost
        return stats

    # -------------------------------------------------------------- run
    def run(self) -> Dict[str, Any]:
        """Work the board until every admitted cell is done (or, with
        ``watch``, until the STOP sentinel lands); returns per-worker
        stats (cells completed here, trials, waits, admissions).

        Every pass re-scans the intake directory (live admission) and
        claims in cell-queue priority order.  The lease board stays the
        sole claim arbiter — the queue only decides which cell this
        worker *tries* next, so two workers ranking the board
        identically still split it cleanly."""
        from repro.core.schedule import CellQueue, stop_requested_since
        if self.ready_file is not None:
            self.ready_file.parent.mkdir(parents=True, exist_ok=True)
            self.ready_file.touch()
        if self.go_file is not None:
            while not self.go_file.exists():
                time.sleep(0.05)
        t0 = time.time()
        if self.telemetry.enabled:
            self.telemetry.emit("worker.start", watch=self.watch,
                                cells=len(self.cells))
        self.log.info(f"worker up: {len(self.cells)} target cell(s)"
                      f"{', watch' if self.watch else ''}")
        queue = CellQueue(self.cells, prioritizer=self.prioritize,
                          history=self.history, directory=self.dir)
        completed: List[str] = []
        evaluated = replayed = 0
        lease_losses = 0
        waited_s = 0.0
        while True:
            queue.scan_intake()
            for spec in queue.cells():
                if queue.state(spec.key()) != "done" \
                        and self._done(spec):
                    queue.mark_done(spec.key())
            remaining = queue.order()    # pending, priority order
            if not remaining:
                # board drained: exit — unless watching for late
                # submissions and no STOP has landed *for this
                # session* (a sentinel predating this worker targets a
                # previous session and is ignored, never deleted — see
                # core/schedule.request_stop)
                if not self.watch or stop_requested_since(
                        self.dir, self.started_at):
                    break
                time.sleep(self.poll_s)
                waited_s += self.poll_s
                continue
            progress = False
            for spec in remaining:
                lease = self.board.try_acquire(spec.key())
                if lease is None:
                    continue
                try:
                    if self._done(spec):
                        continue         # raced: finished by another worker
                    self.log.info(f"claimed {spec.key()}")
                    stats = self._run_cell(spec, lease)
                    completed.append(spec.key())
                    evaluated += stats.get("evaluated_trials", 0)
                    replayed += stats.get("replayed_trials", 0)
                    lease_losses += bool(stats.get("lease_lost"))
                    if stats.get("lease_lost"):
                        self.log.warn(f"lease lost on {spec.key()} "
                                      "(heartbeat went stale)")
                    self.log.info(
                        f"completed {spec.key()}: "
                        f"{stats.get('evaluated_trials', 0)} evaluated, "
                        f"{stats.get('replayed_trials', 0)} replayed")
                    progress = True
                finally:
                    lease.release()
                queue.mark_done(spec.key())
                if self.telemetry.enabled:
                    # refresh the live metrics snapshot after each cell
                    # (atomic last-writer-wins over the shared events)
                    _telemetry.publish_metrics(self.dir)
                break                    # re-rank: priority may have moved
            if not progress:
                # every remaining cell is leased by a live worker — wait
                # for them (or for their leases to expire) and re-scan
                self.log.debug("board contended/drained: waiting "
                               f"{self.poll_s}s")
                time.sleep(self.poll_s)
                waited_s += self.poll_s
        snap = queue.snapshot()
        if self.telemetry.enabled:
            self.telemetry.emit("worker.stop", cells=len(completed),
                                evaluated=evaluated, replayed=replayed,
                                wall_s=round(time.time() - t0, 2))
            _telemetry.publish_metrics(self.dir)
        self.log.info(f"worker done: {len(completed)} cell(s), "
                      f"{evaluated} trials evaluated")
        return {
            "worker": self.board.worker_id,
            "cells_completed": completed,
            "evaluated_trials": evaluated,
            "replayed_trials": replayed,
            "lease_losses": lease_losses,
            "cells_admitted": snap["admitted"],
            "intake_admitted": snap["from_intake"],
            "prioritize": snap["prioritize"],
            "waited_s": round(waited_s, 2),
            "wall_s": round(time.time() - t0, 2),
        }


# ---------------------------------------------------------- coordinator
def worker_argv(cells: Sequence[CellSpec], directory: pathlib.Path, *,
                strategy: str = "tree",
                evaluator_spec: Optional[str] = None,
                ttl_s: float = DEFAULT_TTL_S,
                threshold: float = 0.05,
                warm_start: bool = False,
                prioritize: str = "arch",
                watch: bool = False,
                worker_id: Optional[str] = None,
                ready_file: Optional[pathlib.Path] = None,
                go_file: Optional[pathlib.Path] = None,
                trial_timeout_s: Optional[float] = None,
                max_retries: int = 0,
                strike_threshold: Optional[int] = None,
                measure_top_k: int = 0,
                measured_evaluator_spec: Optional[str] = None,
                slo_ttft: Optional[float] = None,
                promote: bool = False,
                trace: bool = False,
                extra: Sequence[str] = ()) -> List[str]:
    """The ``launch/tune.py --worker`` command line for one worker."""
    argv = [sys.executable, "-m", "repro.launch.tune", "--worker",
            "--dir", str(directory),
            "--strategy", strategy,
            "--threshold", str(threshold),
            "--worker-ttl", str(ttl_s)]
    if cells:
        argv += ["--cells", ",".join(c.spec() for c in cells)]
    if evaluator_spec:
        argv += ["--evaluator", evaluator_spec]
    if warm_start:
        argv += ["--warm-start"]
    if trial_timeout_s is not None:
        argv += ["--trial-timeout", str(trial_timeout_s)]
    if max_retries:
        argv += ["--max-retries", str(max_retries)]
    if strike_threshold is not None:
        argv += ["--strike-threshold", str(strike_threshold)]
    if measure_top_k:
        argv += ["--measure-top-k", str(measure_top_k)]
    if measured_evaluator_spec:
        argv += ["--measured-evaluator", measured_evaluator_spec]
    if slo_ttft is not None:
        argv += ["--slo-ttft", str(slo_ttft)]
    if promote:
        argv += ["--promote"]
    if trace:
        argv += ["--trace"]
    if prioritize != "arch":
        argv += ["--prioritize", prioritize]
    if watch:
        argv += ["--watch"]
    if worker_id:
        argv += ["--worker-id", worker_id]
    if ready_file is not None:
        argv += ["--ready-file", str(ready_file)]
    if go_file is not None:
        argv += ["--go-file", str(go_file)]
    argv += list(extra)
    return argv


def spawn_worker(cells: Sequence[CellSpec], directory: pathlib.Path, *,
                 log_path: Optional[pathlib.Path] = None,
                 env: Optional[Dict[str, str]] = None,
                 **kw) -> subprocess.Popen:
    """Spawn one detached local worker process (see :func:`worker_argv`
    for the keyword options)."""
    argv = worker_argv(cells, directory, **kw)
    if log_path is not None:
        log_path.parent.mkdir(parents=True, exist_ok=True)
        out = open(log_path, "ab")
    else:
        out = subprocess.DEVNULL
    try:
        return subprocess.Popen(argv, stdout=out, stderr=subprocess.STDOUT,
                                env=env or os.environ.copy())
    finally:
        if out is not subprocess.DEVNULL:
            out.close()


def run_coordinator(cells: Sequence[CellSpec],
                    directory: pathlib.Path, *,
                    workers: int = 2,
                    strategy: str = "tree",
                    strategy_options: Optional[Dict[str, Any]] = None,
                    evaluator_spec: Optional[str] = None,
                    ttl_s: float = DEFAULT_TTL_S,
                    threshold: float = 0.05,
                    warm_start: bool = False,
                    prioritize: str = "arch",
                    watch: bool = False,
                    trial_timeout_s: Optional[float] = None,
                    max_retries: int = 0,
                    strike_threshold: Optional[int] = None,
                    measure_top_k: int = 0,
                    measured_evaluator_spec: Optional[str] = None,
                    slo_ttft: Optional[float] = None,
                    promote: bool = False,
                    trace: bool = False,
                    extra_args: Sequence[str] = (),
                    log_dir: Optional[pathlib.Path] = None,
                    timeout_s: Optional[float] = None) -> Dict[str, Any]:
    """Spawn N local workers over one shared directory, wait for them,
    verify completion and collect the per-cell reports.

    With ``watch=True`` the workers stay alive after draining the board
    and keep claiming intake submissions; the coordinator then blocks
    until someone requests a stop (``launch/tune.py --stop`` /
    :func:`~repro.core.schedule.request_stop`) and the workers drain
    out.  Cells admitted through the intake directory while the fabric
    ran are verified and reported exactly like the seed cells.

    Completion is verified with the same full-signature probe the
    workers use (:meth:`Campaign.cell_done` with ``strategy_options`` /
    ``threshold`` / ``warm_start`` and the default baseline the worker
    CLI tunes with), so a stale-parameter checkpoint counts as
    incomplete rather than being silently published.  Returns
    ``{"reports": {cell: report}, "stats": {...}}``; raises
    ``RuntimeError`` if any cell is incomplete or a lease is left held
    after the workers exit (expired leftovers are reaped first).
    """
    from repro.core.schedule import scan_intake
    directory = pathlib.Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    t0 = time.time()
    procs = []
    for i in range(workers):
        log = (pathlib.Path(log_dir) / f"worker-{i}.log") \
            if log_dir else None
        procs.append(spawn_worker(
            cells, directory, strategy=strategy,
            evaluator_spec=evaluator_spec, ttl_s=ttl_s,
            threshold=threshold, warm_start=warm_start,
            prioritize=prioritize, watch=watch,
            worker_id=f"w{i}-{uuid.uuid4().hex[:6]}",
            trial_timeout_s=trial_timeout_s, max_retries=max_retries,
            strike_threshold=strike_threshold,
            measure_top_k=measure_top_k,
            measured_evaluator_spec=measured_evaluator_spec,
            slo_ttft=slo_ttft, promote=promote, trace=trace,
            extra=extra_args, log_path=log))
    rcs = [p.wait(timeout=timeout_s) for p in procs]
    wall = time.time() - t0

    # the worker-side queue admits intake submissions live; fold them
    # into the verification set so an admitted cell is held to the same
    # completion bar as a seed cell
    all_cells = list(cells)
    known = {c.key() for c in all_cells}
    for admitted in scan_intake(directory):
        if admitted.key() not in known:
            known.add(admitted.key())
            all_cells.append(admitted)

    board = LeaseBoard(directory, ttl_s=ttl_s)
    reaped = board.reap_expired()
    leftover = board.held()
    spec = get_strategy(strategy)
    probe = Campaign(all_cells, strategy=strategy,
                     strategy_options=strategy_options,
                     threshold=threshold,
                     evaluator=lambda wl, rt: None,  # probe never runs
                     checkpoint_dir=directory, warm_start=warm_start,
                     measure_top_k=measure_top_k,
                     quarantine=False, intake=True)
    reports: Dict[str, Any] = {}
    incomplete = []
    for cell in all_cells:
        path = directory / f"{cell.key()}.json"
        if not probe.cell_done(cell):
            incomplete.append(cell.key())
            continue
        d = json.loads(path.read_text())
        reports[cell.key()] = spec.load_report(d["report"])
    stats = {
        "workers": workers,
        "strategy": spec.name,
        "cells": len(all_cells),
        "seed_cells": len(cells),
        "intake_cells": len(all_cells) - len(cells),
        "prioritize": prioritize,
        "watch": watch,
        "wall_s": round(wall, 2),
        "cells_per_hour": round(len(all_cells) / max(wall, 1e-9)
                                * 3600.0, 1),
        "worker_rcs": rcs,
        "reaped_leases": reaped,
        "leases_left": [st.cell for st in leftover],
        "incomplete_cells": incomplete,
    }
    if incomplete or leftover or any(rcs):
        raise RuntimeError(f"fabric run incomplete: {stats}")
    return {"reports": reports, "stats": stats}
