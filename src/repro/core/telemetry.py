"""Structured campaign telemetry: span events, trace export, metrics.

The tuner generates far more evidence per campaign than the decision
path consumes — where wall-clock went (compile vs eval vs idle), how
the fleet behaved (claims, steals, strikes, SLO aborts), which cell
improved first.  This module captures that evidence as a stream of
structured events without ever being *part* of the decision path.

Three layers:

* **Event bus** (`Telemetry`) — every trial, compile, cache hit/miss,
  lease claim/steal, retry, timeout, quarantine strike, measured
  re-rank, and SLO abort appends one JSON line to a shared
  ``events.jsonl`` in the campaign directory, via the same
  torn-tolerant O_APPEND idiom as ``history.jsonl`` (fsutil.
  ``append_jsonl``): one write per line, self-healing tail, readers
  skip bad lines.  Multi-process safe on the fabric dir.  Span events
  carry a start timestamp plus duration and a per-process span id;
  nested spans link to their parent via a thread-local stack, so a
  compile event emitted inside a trial attempt records the trial as
  its parent.
* **Chrome-trace export** (`chrome_trace`) — folds the event stream
  into Chrome/Perfetto ``traceEvents`` JSON: one process track per
  worker, one thread track per pool thread, trials and compiles as
  duration slices, steals / strikes / SLO aborts / retries as instant
  events.  Load via ``chrome://tracing`` or https://ui.perfetto.dev.
* **Metrics** (`fold_metrics` / `publish_metrics`) — counters, gauges
  and histograms folded from the same stream (trials/s, compile-cache
  hit rate, retry/timeout/quarantine rates, per-worker utilization,
  time-to-first-improvement per cell, wall-clock attribution),
  published atomically as ``metrics.json`` (fsutil.atomic_publish).

**Hard invariant:** telemetry observes, never decides.  Nothing here
may feed tuning decisions, and a campaign with telemetry enabled must
be bit-identical (fingerprints, logs, budgets) to one without — the
regression tests in tests/test_telemetry.py enforce this.  When
disabled (the default), every hook is a no-op behind a plain
attribute check (``t.enabled``), and ``emit`` never lets an OSError
escape into the trial path.

A process-global *current* telemetry (``install`` / ``current``) lets
deep layers that predate this module (CompileCache, the timing cache,
the SLO guard) emit without threading a handle through every
constructor; components that do take a ``telemetry=`` kwarg
(SweepExecutor, Campaign, FabricWorker) default to ``current()``.

Also here: the leveled fleet `Logger` (``REPRO_LOG=debug|info|warn``),
worker-id-prefixed so interleaved multi-worker output is attributable.
"""
from __future__ import annotations

import itertools
import json
import os
import socket
import sys
import threading
import time
from typing import Any, Dict, List, Optional

from .fsutil import append_jsonl, atomic_publish

EVENTS_NAME = "events.jsonl"
METRICS_NAME = "metrics.json"
SCHEMA_VERSION = 1

LOG_ENV = "REPRO_LOG"
_LOG_LEVELS = {"debug": 10, "info": 20, "warn": 30}


# --------------------------------------------------------------- logger
class Logger:
    """Tiny leveled logger for fleet processes.

    Level comes from ``REPRO_LOG`` (debug|info|warn, default info);
    every line is prefixed with the worker id so interleaved output
    from a multi-worker fabric stays attributable.  Writes to stderr —
    stdout is reserved for machine-readable CLI output (``--status
    --json``, report markdown).
    """

    def __init__(self, prefix: str = "", level: Optional[str] = None,
                 stream=None):
        if level is None:
            level = os.environ.get(LOG_ENV, "info")
        self.level = _LOG_LEVELS.get(str(level).lower(), 20)
        self.prefix = prefix
        self.stream = stream

    def _emit(self, level: str, msg: str) -> None:
        if _LOG_LEVELS[level] < self.level:
            return
        tag = f"[{self.prefix}] " if self.prefix else ""
        out = self.stream if self.stream is not None else sys.stderr
        try:
            print(f"[{level}] {tag}{msg}", file=out, flush=True)
        except (OSError, ValueError):
            pass                      # a dead log pipe never kills work

    def debug(self, msg: str) -> None:
        self._emit("debug", msg)

    def info(self, msg: str) -> None:
        self._emit("info", msg)

    def warn(self, msg: str) -> None:
        self._emit("warn", msg)


def get_logger(prefix: str = "", level: Optional[str] = None) -> Logger:
    return Logger(prefix=prefix, level=level)


# ------------------------------------------------------------ event bus
class _NullSpan:
    """No-op span: returned by a disabled Telemetry so hot paths pay a
    single attribute check and no allocation."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def note(self, **fields):
        return self


_NULL_SPAN = _NullSpan()


class Span:
    """Context manager emitting one complete event on exit.

    The event's ``ts`` is the span start and ``dur_s`` its length, so
    a crash mid-span loses only that event — acceptable for telemetry,
    which is observability, not a correctness ledger.  ``note()``
    attaches fields learned during the span (cost, crash, cache
    state).  Entering pushes the span id on a thread-local stack so
    events emitted underneath record it as ``parent``.
    """

    __slots__ = ("_t", "kind", "fields", "id", "parent", "t0")

    def __init__(self, telemetry: "Telemetry", kind: str,
                 fields: Dict[str, Any]):
        self._t = telemetry
        self.kind = kind
        self.fields = fields
        self.id = telemetry._next_span()
        self.parent: Optional[str] = None
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = time.time()
        stack = self._t._stack()
        self.parent = stack[-1] if stack else None
        stack.append(self.id)
        return self

    def note(self, **fields):
        self.fields.update(fields)
        return self

    def __exit__(self, exc_type, exc, tb):
        stack = self._t._stack()
        if stack and stack[-1] == self.id:
            stack.pop()
        self._t.emit(self.kind, ts=self.t0,
                     dur_s=time.time() - self.t0,
                     span=self.id, parent=self.parent, **self.fields)
        return False


class Telemetry:
    """Append-only structured event bus over a campaign directory.

    Every record is one JSON line in ``<directory>/events.jsonl``:

        {"v": 1, "kind": "trial", "ts": <epoch s>, "worker": "...",
         "pid": 1234, "thread": "sweep-0", "span": "4d2.7",
         "parent": "4d2.3", "dur_s": 0.81, ...kind-specific fields}

    ``worker`` is the fabric worker id (or ``host-pid`` for
    single-process campaigns) and, with ``thread``, becomes the track
    in the Chrome-trace export.  Span ids are ``<pid hex>.<seq>`` —
    unique per process, cheap, and deliberately *not* random so
    telemetry shares no entropy source with the search.
    """

    def __init__(self, directory=None, worker: str = "",
                 enabled: bool = True):
        self.enabled = bool(enabled) and directory is not None
        self.path = (os.path.join(str(directory), EVENTS_NAME)
                     if directory is not None else None)
        self.directory = str(directory) if directory is not None else None
        if not worker:
            try:
                host = socket.gethostname().split(".")[0]
            except OSError:
                host = "host"
            worker = f"{host}-{os.getpid()}"
        self.worker = worker
        self._pid = os.getpid()
        self._seq = itertools.count(1)
        self._local = threading.local()

    # -- internals
    def _stack(self) -> List[str]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def _next_span(self) -> str:
        return f"{self._pid:x}.{next(self._seq)}"

    # -- emission
    def emit(self, kind: str, *, ts: Optional[float] = None,
             dur_s: Optional[float] = None, span: Optional[str] = None,
             parent: Optional[str] = None, **fields) -> None:
        """Append one event.  Never raises into the caller: a full or
        vanished disk costs telemetry lines, not trials."""
        if not self.enabled:
            return
        rec: Dict[str, Any] = {
            "v": SCHEMA_VERSION,
            "kind": kind,
            "ts": time.time() if ts is None else ts,
            "worker": self.worker,
            "pid": self._pid,
            "thread": threading.current_thread().name,
        }
        if dur_s is not None:
            rec["dur_s"] = round(float(dur_s), 6)
        if span is not None:
            rec["span"] = span
        if parent is None:
            stack = self._stack()
            if stack:
                rec["parent"] = stack[-1]
        else:
            rec["parent"] = parent
        rec.update(fields)
        try:
            append_jsonl(self.path, rec)
        except (OSError, TypeError, ValueError):
            pass

    def span(self, kind: str, **fields):
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, kind, fields)


NULL = Telemetry(None, enabled=False)

_current: Telemetry = NULL
_current_lock = threading.Lock()


def install(t: Telemetry) -> Telemetry:
    """Make *t* the process-global telemetry returned by current()."""
    global _current
    with _current_lock:
        _current = t
    return t


def uninstall() -> None:
    install(NULL)


def current() -> Telemetry:
    return _current


# --------------------------------------------------------------- reader
def read_events(directory) -> List[Dict[str, Any]]:
    """All parseable events from <directory>/events.jsonl.

    Same tolerance contract as the history/quarantine readers: a torn
    or corrupt line (worker died mid-write on a non-atomic mount) is
    skipped, never fatal.
    """
    path = os.path.join(str(directory), EVENTS_NAME)
    out: List[Dict[str, Any]] = []
    try:
        with open(path, "r") as f:
            raw = f.read()
    except OSError:
        return out
    for line in raw.splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except ValueError:
            continue
        if isinstance(rec, dict) and "kind" in rec:
            out.append(rec)
    return out


# -------------------------------------------------------------- metrics
_HIST_EDGES = ((0.001, "le_1ms"), (0.01, "le_10ms"), (0.1, "le_100ms"),
               (1.0, "le_1s"), (10.0, "le_10s"), (100.0, "le_100s"),
               (float("inf"), "gt_100s"))

_COUNTER_KINDS = {
    "trial": "trials",
    "compile": "compiles",
    "cache.hit": "cache_hits",
    "cache.miss": "cache_misses",
    "timing_cache.hit": "timing_cache_hits",
    "timing_cache.miss": "timing_cache_misses",
    "retry": "retries",
    "timeout": "timeouts",
    "quarantine.skip": "quarantine_skips",
    "quarantine.strike": "quarantine_strikes",
    "lease.claim": "lease_claims",
    "lease.steal": "lease_steals",
    "lease.lost": "lease_lost",
    "slo.abort": "slo_aborts",
    "measure.rerank": "measure_reranks",
    "cell.activate": "cells_activated",
    "cell.done": "cells_done",
}


def _bucket(dur: float) -> str:
    for edge, label in _HIST_EDGES:
        if dur <= edge:
            return label
    return _HIST_EDGES[-1][1]


def fold_metrics(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Fold an event stream into counters / gauges / histograms.

    Pure function of the records — callers decide freshness (live fold
    for ``--status``, atomic ``metrics.json`` publish at checkpoints).
    """
    counters = {name: 0 for name in _COUNTER_KINDS.values()}
    counters["crashes"] = 0
    per_worker: Dict[str, Dict[str, Any]] = {}
    per_cell: Dict[str, Dict[str, Any]] = {}
    hist: Dict[str, int] = {label: 0 for _, label in _HIST_EDGES}
    t0 = t1 = None
    eval_s = compile_s = measure_s = 0.0

    for rec in records:
        ts = rec.get("ts")
        if not isinstance(ts, (int, float)):
            continue
        dur = rec.get("dur_s") or 0.0
        t0 = ts if t0 is None else min(t0, ts)
        t1 = ts + dur if t1 is None else max(t1, ts + dur)
        kind = rec.get("kind")
        name = _COUNTER_KINDS.get(kind)
        if name:
            counters[name] += 1
        w = per_worker.setdefault(rec.get("worker") or "?",
                                  {"trials": 0, "busy_s": 0.0})
        if kind == "trial":
            w["trials"] += 1
            w["busy_s"] += dur
            eval_s += dur
            hist[_bucket(dur)] += 1
            if rec.get("crashed"):
                counters["crashes"] += 1
            cell = rec.get("cell")
            if cell:
                c = per_cell.setdefault(cell, {
                    "trials": 0, "best_cost_s": None,
                    "t_first": ts, "first_improvement_s": None,
                    "baseline_cost_s": None})
                c["trials"] += 1
                c["t_first"] = min(c["t_first"], ts)
                cost = rec.get("cost_s")
                if isinstance(cost, (int, float)):
                    if c["baseline_cost_s"] is None:
                        c["baseline_cost_s"] = cost
                    if c["best_cost_s"] is None or cost < c["best_cost_s"]:
                        c["best_cost_s"] = cost
                        if (cost < c["baseline_cost_s"]
                                and c["first_improvement_s"] is None):
                            c["first_improvement_s"] = round(
                                ts + dur - c["t_first"], 3)
        elif kind == "compile":
            compile_s += dur
        elif kind == "measure":
            measure_s += dur

    wall = max((t1 - t0), 0.0) if (t0 is not None and t1 is not None) else 0.0
    workers = sorted(per_worker)
    for w in per_worker.values():
        w["busy_s"] = round(w["busy_s"], 3)
        w["utilization"] = round(w["busy_s"] / wall, 3) if wall > 0 else 0.0
    for c in per_cell.values():
        c.pop("t_first", None)
        if c["best_cost_s"] is not None:
            c["best_cost_s"] = round(c["best_cost_s"], 6)
        if c["baseline_cost_s"] is not None:
            c["baseline_cost_s"] = round(c["baseline_cost_s"], 6)

    trials = counters["trials"]
    lookups = counters["cache_hits"] + counters["cache_misses"]
    rate = lambda n: round(n / trials, 4) if trials else 0.0  # noqa: E731
    gauges = {
        "trials_per_s": round(trials / wall, 3) if wall > 0 else 0.0,
        "cache_hit_rate": (round(counters["cache_hits"] / lookups, 4)
                           if lookups else None),
        "retry_rate": rate(counters["retries"]),
        "timeout_rate": rate(counters["timeouts"]),
        "quarantine_rate": rate(counters["quarantine_skips"]),
        "crash_rate": rate(counters["crashes"]),
        "workers": len(workers),
    }
    # wall-clock attribution: compile time is nested inside trial spans
    # when the cache compiles in-line, so "eval" here is trial time net
    # of compile; idle is whatever the busiest-track wall doesn't cover.
    busy = eval_s
    attribution = {
        "wall_s": round(wall, 3),
        "trial_s": round(eval_s, 3),
        "compile_s": round(compile_s, 3),
        "eval_s": round(max(eval_s - compile_s, 0.0), 3),
        "measure_s": round(measure_s, 3),
        "idle_s": round(max(wall * max(len(workers), 1) - busy, 0.0), 3),
    }
    return {
        "v": SCHEMA_VERSION,
        "window": {"t0": t0, "t1": t1, "wall_s": round(wall, 3)},
        "events": len(records),
        "counters": counters,
        "gauges": gauges,
        "attribution": attribution,
        "per_worker": {k: per_worker[k] for k in workers},
        "per_cell": {k: per_cell[k] for k in sorted(per_cell)},
        "histograms": {"trial_dur_s": hist},
    }


def publish_metrics(directory) -> Optional[Dict[str, Any]]:
    """Fold <dir>/events.jsonl and atomically publish metrics.json.

    Multi-process safe: each worker folds the *shared* event file, so
    last-writer-wins is convergent (the latest fold sees the most
    events).  Returns the metrics dict, or None when there are no
    events to fold.
    """
    records = read_events(directory)
    if not records:
        return None
    metrics = fold_metrics(records)
    try:
        atomic_publish(os.path.join(str(directory), METRICS_NAME),
                       json.dumps(metrics, indent=1), prefix=".metrics-")
    except OSError:
        return metrics
    return metrics


def load_metrics(directory) -> Optional[Dict[str, Any]]:
    try:
        with open(os.path.join(str(directory), METRICS_NAME), "r") as f:
            return json.load(f)
    except (OSError, ValueError):
        return None


# --------------------------------------------------------- chrome trace
_TRACK_FIELDS = ("v", "kind", "ts", "worker", "pid", "thread", "dur_s",
                 "span", "parent")


def chrome_trace(records: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Fold events into Chrome-trace / Perfetto ``traceEvents`` JSON.

    Workers become process tracks (pid), their pool threads thread
    tracks (tid).  Events with a duration (trial, compile, cell,
    measure spans) become complete slices (``ph: "X"``); everything
    else (steals, strikes, retries, SLO aborts…) becomes an instant
    event (``ph: "i"``).  Timestamps are microseconds relative to the
    earliest event, which keeps the JSON small and Perfetto happy.
    """
    stamped = [r for r in records
               if isinstance(r.get("ts"), (int, float))]
    stamped.sort(key=lambda r: r["ts"])
    t0 = stamped[0]["ts"] if stamped else 0.0
    pids: Dict[str, int] = {}
    tids: Dict[tuple, int] = {}
    events: List[Dict[str, Any]] = []
    for rec in stamped:
        worker = rec.get("worker") or "?"
        thread = rec.get("thread") or "main"
        if worker not in pids:
            pids[worker] = len(pids) + 1
            events.append({"name": "process_name", "ph": "M",
                           "pid": pids[worker], "tid": 0,
                           "args": {"name": worker}})
        track = (worker, thread)
        if track not in tids:
            tids[track] = sum(1 for t in tids if t[0] == worker) + 1
            events.append({"name": "thread_name", "ph": "M",
                           "pid": pids[worker], "tid": tids[track],
                           "args": {"name": thread}})
        kind = rec.get("kind", "?")
        args = {k: v for k, v in rec.items()
                if k not in _TRACK_FIELDS and v is not None}
        name = kind
        if rec.get("cell"):
            name = f"{kind} {rec['cell']}"
        ev: Dict[str, Any] = {
            "name": name, "cat": kind,
            "pid": pids[worker], "tid": tids[track],
            "ts": round((rec["ts"] - t0) * 1e6, 1),
            "args": args,
        }
        if rec.get("span") is not None:
            ev["args"]["span"] = rec["span"]
            if rec.get("parent") is not None:
                ev["args"]["parent"] = rec["parent"]
        if rec.get("dur_s") is not None:
            ev["ph"] = "X"
            ev["dur"] = round(rec["dur_s"] * 1e6, 1)
        else:
            ev["ph"] = "i"
            ev["s"] = "t"
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def export_chrome_trace(directory, out_path) -> int:
    """Write the Chrome-trace JSON for <dir>/events.jsonl to out_path.
    Returns the number of trace events written (excluding metadata)."""
    trace = chrome_trace(read_events(directory))
    payload = json.dumps(trace)
    out_path = str(out_path)
    parent = os.path.dirname(out_path) or "."
    os.makedirs(parent, exist_ok=True)
    atomic_publish(out_path, payload, prefix=".trace-")
    return sum(1 for e in trace["traceEvents"] if e.get("ph") != "M")
