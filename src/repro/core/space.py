"""The declarative knob space — single source of truth per parameter.

Before the Strategy API, the knob space was encoded four separate times
(``DOMAINS``, ``SENSITIVITY_SWEEP``, ``PARAM_DOCS`` and the
``COMPILE_KNOBS``/``ANALYTIC_KNOBS`` partition in ``core/params.py``,
plus the tree's stage deltas in ``core/tree.py``) and the encodings
could silently drift.  Now each knob is declared exactly once as a
:class:`Knob` in the :data:`SPACE` registry, and every historical name
is *derived* from it (``core/params.py`` keeps the old names as thin
re-exports so imports keep working).

Adding a knob = adding one :class:`Knob` entry here plus the matching
``TunableConfig`` field; the drift tests (tests/test_space.py) enforce
that the two stay in sync.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Iterator, Sequence, Tuple

REACH_CLASSES = ("compile", "analytic")


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable (or infrastructure) parameter of the step function.

    ``domain`` lists the legal values, first entry = the Spark-like
    default.  ``reach`` declares whether the knob can change the
    lowered/compiled HLO ("compile") or only ever enters the analytic
    roofline terms ("analytic") — the trial-throughput engine's compile
    projection (``TunableConfig.compile_key``) is derived from it.
    ``reach_evidence`` records where a conditionally-relevant compile
    knob actually reaches the step function (the per-knob evidence for
    the compile_key canonicalizations).  ``sweep`` lists the values the
    Sec.-4 sensitivity analysis tests (chosen by the paper's rules:
    binary -> non-default, categorical -> all, numeric -> neighbours).
    ``spark`` is the bare Spark-parameter analogue (used as the tree
    stage's spark_name); ``doc`` the annotated PARAM_DOCS line.
    Infrastructure knobs (``tunable=False``) are never swept or listed
    in DOMAINS/PARAM_DOCS but still carry a domain and a reach class.
    ``seq_tile=True`` marks a Pallas sequence-tile knob: its effective
    block is ``min(value, seq_len)`` and must divide the cell's
    sequence length (``ParamSpace.validate(cfg, seq_len=...)`` turns a
    non-dividing tile into a clean ``ValueError`` instead of a deep
    Pallas grid assertion).
    """
    name: str
    domain: Tuple[Any, ...]
    reach: str
    spark: str = ""
    doc: str = ""
    sweep: Tuple[Any, ...] = ()
    reach_evidence: str = ""
    tunable: bool = True
    seq_tile: bool = False

    def __post_init__(self):
        if self.reach not in REACH_CLASSES:
            raise ValueError(f"{self.name}: reach {self.reach!r} not in "
                             f"{REACH_CLASSES}")
        if not self.domain:
            raise ValueError(f"{self.name}: empty domain")
        bad = [v for v in self.sweep if v not in self.domain]
        if bad:
            raise ValueError(f"{self.name}: sweep values {bad} not in "
                             f"domain {self.domain}")

    @property
    def default(self) -> Any:
        return self.domain[0]

    def validate(self, value: Any) -> None:
        if value not in self.domain:
            raise ValueError(f"{self.name}={value!r} not in domain "
                             f"{self.domain}")

    def validate_tile(self, value: Any, seq_len: int) -> None:
        """Check a sequence-tile value against a concrete sequence
        length (kernels clamp the block to ``min(value, seq_len)``
        before asserting divisibility — mirror that here so the error
        is raised once, with the knob's name, before any Pallas call)."""
        if not self.seq_tile:
            return
        eff = min(int(value), int(seq_len))
        if eff <= 0 or seq_len % eff != 0:
            raise ValueError(
                f"{self.name}={value}: effective tile {eff} does not "
                f"divide sequence length {seq_len} — pick a tile that "
                f"divides the cell's sequence")


class ParamSpace:
    """Ordered registry of :class:`Knob` s; every projection the rest of
    the codebase consumes (domains, sweep, docs, compile partition,
    reach evidence, grid size) is computed from it."""

    def __init__(self, knobs: Sequence[Knob]):
        self._knobs: Dict[str, Knob] = {}
        for k in knobs:
            if k.name in self._knobs:
                raise ValueError(f"duplicate knob {k.name!r}")
            self._knobs[k.name] = k

    # ----------------------------------------------------------- access
    def __getitem__(self, name: str) -> Knob:
        return self._knobs[name]

    def __contains__(self, name: str) -> bool:
        return name in self._knobs

    def __iter__(self) -> Iterator[Knob]:
        return iter(self._knobs.values())

    def __len__(self) -> int:
        return len(self._knobs)

    def names(self) -> Tuple[str, ...]:
        return tuple(self._knobs)

    # ------------------------------------------------------ projections
    def domains(self) -> Dict[str, Tuple[Any, ...]]:
        """Legal values per *tunable* knob (the historical DOMAINS)."""
        return {k.name: k.domain for k in self if k.tunable}

    def sweep(self) -> Dict[str, Tuple[Any, ...]]:
        """Sensitivity sweep values per swept knob (SENSITIVITY_SWEEP)."""
        return {k.name: k.sweep for k in self if k.sweep}

    def docs(self) -> Dict[str, str]:
        """Spark-analogue documentation per tunable knob (PARAM_DOCS)."""
        return {k.name: (k.doc or k.spark) for k in self if k.tunable}

    def compile_knobs(self) -> Tuple[str, ...]:
        """Knobs that can reach the compiled HLO, in registration order
        (the order is load-bearing: it fixes the compile_key tuple
        layout, hence the disk compile-cache keys)."""
        return tuple(k.name for k in self if k.reach == "compile")

    def analytic_knobs(self) -> Tuple[str, ...]:
        return tuple(k.name for k in self if k.reach == "analytic")

    def reach_evidence(self) -> Dict[str, str]:
        """Where each conditionally-relevant compile knob reaches the
        step function (the historical KNOB_REACH)."""
        return {k.name: k.reach_evidence for k in self if k.reach_evidence}

    def defaults(self) -> Dict[str, Any]:
        return {k.name: k.default for k in self}

    def seq_tile_knobs(self) -> Tuple[str, ...]:
        """Pallas sequence-tile knobs (validated against the cell's
        sequence lengths by evaluators that actually run kernels)."""
        return tuple(k.name for k in self if k.seq_tile)

    # ------------------------------------------------------- validation
    def validate(self, cfg: Any, seq_len: int = None) -> None:
        """Check every tunable field of a TunableConfig-like object.

        With ``seq_len`` the sequence-tile knobs are additionally
        checked for divisibility against that concrete sequence length
        (a non-dividing tile is a deterministic crash trial, not a deep
        Pallas error).  Callers that never execute a kernel — the
        roofline evaluator in particular — pass no ``seq_len`` and keep
        their historical behaviour bit-identical."""
        for k in self:
            if k.tunable:
                k.validate(getattr(cfg, k.name))
            if seq_len is not None and k.seq_tile:
                k.validate_tile(getattr(cfg, k.name), seq_len)

    def validate_delta(self, delta: Dict[str, Any]) -> None:
        """Check a partial assignment (e.g. a tree stage alternative)."""
        for name, value in delta.items():
            if name not in self._knobs:
                raise KeyError(f"unknown knob {name!r} "
                               f"(known: {', '.join(self.names())})")
            self._knobs[name].validate(value)

    def exhaustive_size(self) -> int:
        """Size of the exhaustive grid over the tunable knobs, computed
        arithmetically (never materialize the cross-product)."""
        return math.prod(len(k.domain) for k in self if k.tunable)


# ---------------------------------------------------------------- SPACE
# Registration order = TunableConfig field order (load-bearing: the
# compile_knobs() projection fixes the compile_key tuple layout).
SPACE = ParamSpace([
    # 1. spark.serializer (Java -> Kryo)
    Knob("compute_dtype", ("float32", "bfloat16"), "compile",
         spark="spark.serializer",
         doc="spark.serializer (Java -> Kryo)",
         sweep=("float32", "bfloat16"),
         reach_evidence="structural: every matmul/activation dtype in "
                        "every step function"),
    # 2. spark.shuffle.manager (sort | hash | tungsten-sort)
    Knob("shard_strategy", ("dp", "fsdp", "tp", "fsdp_tp"), "compile",
         spark="spark.shuffle.manager",
         doc="spark.shuffle.manager (sort/hash/tungsten-sort)",
         # sweep order: baseline (fsdp_tp) first, then the alternatives
         sweep=("fsdp_tp", "dp", "fsdp", "tp"),
         reach_evidence="structural: param/activation sharding in every "
                        "step function (runtime/sharding.py)"),
    # 3. spark.shuffle.compress — the error-feedback int8 path joined
    # the sweep once the trial-throughput engine made the extra point
    # ~free (it shares the explicit-gradsync compile projection)
    Knob("grad_comm_dtype", ("float32", "bfloat16", "int8_ef"), "compile",
         spark="spark.shuffle.compress",
         sweep=("float32", "bfloat16", "int8_ef"),
         reach_evidence="train only; explicit path (gradsync) only"),
    # 4. spark.io.compression.codec (snappy | lzf | lz4; float32 = off)
    Knob("comm_codec", ("bfloat16", "float16", "int8", "float32"),
         "compile",
         spark="spark.io.compression.codec",
         doc="spark.io.compression.codec (snappy/lzf/lz4)",
         sweep=("bfloat16", "float16", "int8"),
         reach_evidence="moe family only (moe._encode_wire)"),
    # 5+6. spark.shuffle/storage.memoryFraction (one joint knob, exactly
    # as the paper tunes them).  default 'dots' = balanced (0.2/0.6);
    # 'none' = storage-heavy (store everything, 0.1/0.7); 'full' =
    # shuffle-heavy (recompute everything)
    Knob("remat_policy", ("dots", "none", "full"), "compile",
         spark="spark.shuffle/storage.memoryFraction",
         doc="spark.shuffle.memoryFraction + spark.storage.memoryFraction",
         sweep=("dots", "none", "full"),
         reach_evidence="train; prefill via remat.to_carry dtype"),
    # 7. spark.reducer.maxSizeInFlight
    Knob("microbatches", (1, 2, 4), "compile",
         spark="spark.reducer.maxSizeInFlight",
         sweep=(1, 2, 4),
         reach_evidence="train only (stepfn.build_train_step)"),
    # 8. spark.shuffle.file.buffer (Pallas VMEM tile)
    Knob("attn_block_q", (128, 256, 512), "analytic",
         spark="spark.shuffle.file.buffer",
         doc="spark.shuffle.file.buffer (q tile)",
         sweep=(128, 256, 512),
         reach_evidence="Pallas kernel tile only; never in the "
                        "calibration compiles (attn_impl forced to xla)",
         seq_tile=True),
    # the kv tile joined the sweep alongside the q tile: both are
    # analytic-only, so the whole sweep reuses one compile
    Knob("attn_block_kv", (128, 256, 512), "analytic",
         spark="spark.shuffle.file.buffer",
         doc="spark.shuffle.file.buffer (kv tile)",
         sweep=(128, 256, 512),
         reach_evidence="Pallas kernel tile only; never in the "
                        "calibration compiles (attn_impl forced to xla)",
         seq_tile=True),
    # 9. spark.shuffle.consolidateFiles
    Knob("fuse_grad_collectives", (False, True), "compile",
         spark="spark.shuffle.consolidateFiles",
         sweep=(False, True),
         reach_evidence="train only; explicit path (gradsync) only"),
    # 10. spark.rdd.compress — float32 (compression off) joined the
    # sweep so the matrix shows the cost of *disabling* the default,
    # like the paper's compress-off rows
    Knob("kv_cache_dtype", ("bfloat16", "int8", "float32"), "compile",
         spark="spark.rdd.compress",
         sweep=("bfloat16", "int8", "float32"),
         reach_evidence="prefill/decode cache ops; not ssm family"),
    # 11. spark.shuffle.spill.compress
    Knob("remat_save_dtype", ("float32", "bfloat16"), "compile",
         spark="spark.shuffle.spill.compress",
         sweep=("float32", "bfloat16"),
         reach_evidence="train; prefill via remat.to_carry dtype"),
    # 12. spark.shuffle.io.preferDirectBufs
    Knob("donate_buffers", (True, False), "compile",
         spark="spark.shuffle.io.preferDirectBufs",
         sweep=(True, False),
         reach_evidence="train/decode donate_argnums; not prefill"),
    # beyond-paper knob (see DESIGN.md): how attention is distributed
    # when head counts don't divide the model axis
    Knob("attn_tp_fallback", ("replicate", "batch_shard"), "compile",
         doc="(beyond-paper) attention TP fallback",
         reach_evidence="attention sharding when heads % model axis != 0"),
    # infrastructure (not tuned): the execution engine's attention
    # kernel; pallas on TPU, xla on dry-run.  Its VMEM tile size IS the
    # file.buffer tunable.
    Knob("attn_impl", ("xla", "pallas"), "analytic", tunable=False,
         reach_evidence="calibration compiles force attn_impl=xla; the "
                        "pallas/xla split enters analytically"),
    # infrastructure (not tuned): shard residual seq dim over model axis
    Knob("seq_parallel", (False, True), "compile", tunable=False,
         reach_evidence="residual sharding in stepfn (all kinds)"),
    # infrastructure (not tuned): unrolled layer stack for cost
    # calibration / cross-layer fusion experiments
    Knob("unroll_layers", (False, True), "compile", tunable=False,
         reach_evidence="calibration-compile variant selector"),
    # serving knobs (not tuned by step/kernel campaigns — only serve
    # cells propose deltas on them via their own stage tree, so the
    # classic DOMAINS/sweep/compile-key projections stay byte-identical).
    # Wave size of the batched serving scheduler: how many requests one
    # prefill+decode wave carries.
    Knob("max_wave_size", (4, 2, 8), "analytic", tunable=False,
         spark="spark.default.parallelism",
         doc="spark.default.parallelism (serving wave size)",
         reach_evidence="serving wave scheduler only "
                        "(serving/scheduler.py BatchScheduler); never "
                        "enters a step compile"),
    # Wave admission policy: "greedy" serves whatever has arrived,
    # "full" holds the wave until max_wave_size requests are queued
    # (higher batch efficiency, unbounded queue delay on sparse traffic).
    Knob("wave_admission", ("greedy", "full"), "analytic", tunable=False,
         spark="spark.locality.wait",
         doc="spark.locality.wait (serving wave admission)",
         reach_evidence="serving wave admission only "
                        "(serving/evaluator.py replay loop); never "
                        "enters a step compile"),
])
