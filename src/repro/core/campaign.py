"""Campaign engine — run any search strategy over many cells at once.

The paper's deliverable is a *methodology* applied across a whole
workload matrix (its Table 2 grid and three case studies), not one tuned
application.  A :class:`Campaign` generalizes ``launch/tune.py`` from
one (arch, shape, mesh) cell per process to the full assignment, and —
since the Strategy API — from one hardcoded procedure to any registered
:class:`~repro.core.strategy.SearchCursor` strategy (``tree``,
``short``, ``sensitivity``, ``random``, …):

  * **cell enumeration** — :func:`enumerate_cells` walks
    ``configs.list_archs() x SHAPES x meshes`` and keeps the applicable
    cells (same ``shape_applicable`` rule ``launch/dryrun.py`` uses);
  * **interleaved cursors** — every cell gets a cursor from the
    strategy registry; the scheduler keeps one proposed batch per cell
    in flight on a single shared
    :class:`~repro.core.executor.SweepExecutor`, so the pool stays busy
    across cells while each cell's walk stays sequential.  Cells are
    kicked off grouped by arch, so same-arch calibration compiles land
    adjacently and hit the shared
    :class:`~repro.core.trial.CompileCache` while it is warm;
  * **checkpoint / resume** — after every absorbed batch the cell's
    trial log is persisted as JSON under ``results/campaign/``; an
    interrupted campaign replays the stored results through the cursor
    (no re-evaluation, bit-identical decisions) and only evaluates the
    remainder.  Checkpoints carry the strategy name + version; a
    stale-strategy checkpoint is discarded with a warning, and
    PR-2-era (version-1) tree checkpoints are migrated in place;
  * **reporting** — per-cell reports identical to what the blocking
    per-cell driver (``run_tuning`` / ``run_sensitivity``) produces,
    plus the cross-cell matrix (``report.strategy_markdown``);
  * **history / warm-start** — every evaluated trial is appended to the
    shared ``history.jsonl`` trial store (core/history.py) by default,
    and with ``warm_start=True`` each cell's cursor is seeded with the
    best configs of the nearest already-tuned cells, so campaigns are
    cumulative: each run makes the next one cheaper.

The campaign fabric (core/fabric.py) runs one single-cell campaign per
leased cell, sharing this module's checkpoint, history and compile-cache
formats across worker processes.

Per-cell results are bit-identical to the sequential loop by
construction: the cursor is the same state machine the blocking driver
uses, and batches are recorded in proposal order.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import time
import warnings
from concurrent.futures import FIRST_COMPLETED, wait
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.configs import SHAPES, get_config, get_shape, list_archs, \
    shape_applicable
from repro.core import telemetry as _telemetry
from repro.core.executor import SweepExecutor
from repro.core.fsutil import atomic_publish
from repro.core.history import (HISTORY_FILENAME, TrialHistory,
                                config_from_dict)
from repro.core.params import TunableConfig, default_config
from repro.core.strategy import SearchCursor, StrategySpec, get_strategy
from repro.core.tree import Stage, TuningReport
from repro.core.trial import TrialResult, TrialRunner, Workload

CAMPAIGN_DIR = pathlib.Path(__file__).resolve().parents[3] \
    / "results" / "campaign"

CHECKPOINT_VERSION = 2


# ---------------------------------------------------------------- cells
@dataclasses.dataclass(frozen=True)
class CellSpec:
    """One (arch, shape, mesh) cell of the assignment matrix."""
    arch: str
    shape: str
    multi_pod: bool = False

    def workload(self) -> Workload:
        return Workload(self.arch, self.shape, self.multi_pod)

    def key(self) -> str:
        return self.workload().key()

    def spec(self) -> str:
        """The ``arch:shape:mesh`` string :func:`parse_cells` accepts
        (the fabric coordinator rebuilds worker command lines from it)."""
        return f"{self.arch}:{self.shape}:" \
            + ("multipod" if self.multi_pod else "pod")


def enumerate_cells(archs: Optional[Sequence[str]] = None,
                    shapes: Optional[Sequence[str]] = None,
                    meshes: Sequence[bool] = (False,)) -> List[CellSpec]:
    """Every applicable cell of the assignment (dryrun's skip rule)."""
    out = []
    for arch in (archs or list_archs()):
        cfg = get_config(arch)
        for shape in (shapes or list(SHAPES)):
            ok, _ = shape_applicable(cfg, get_shape(shape))
            if not ok:
                continue
            for mp in meshes:
                out.append(CellSpec(arch, shape, mp))
    return out


def parse_cells(text: str,
                default_multi_pod: bool = False) -> List[CellSpec]:
    """Parse ``arch:shape[:pod|multipod]`` comma-separated cell specs;
    specs without an explicit mesh suffix use ``default_multi_pod``.
    ``kernel:<name>:<shape>`` specs become
    :class:`~repro.core.kernel_cell.KernelCell` s (Pallas tile-sweep
    cells), so every cell entry point — ``--cells``, ``--add-cells``
    intake, fabric worker command lines — accepts them."""
    cells = []
    for item in text.split(","):
        item = item.strip()
        if not item:
            continue
        parts = item.split(":")
        if parts[0] == "kernel":
            from repro.core.kernel_cell import parse_kernel_cell
            cells.append(parse_kernel_cell(item))
            continue
        if parts[0] == "serve":
            from repro.serving.evaluator import parse_serve_cell
            cells.append(parse_serve_cell(item))
            continue
        if len(parts) not in (2, 3):
            raise ValueError(f"bad cell spec {item!r} "
                             "(want arch:shape[:pod|multipod])")
        arch, shape = parts[0], parts[1]
        mp = default_multi_pod
        if len(parts) == 3:
            if parts[2] not in ("pod", "multipod"):
                raise ValueError(f"bad mesh {parts[2]!r} in {item!r}")
            mp = parts[2] == "multipod"
        cfg = get_config(arch)            # raises on unknown arch
        shp = get_shape(shape)            # raises on unknown shape
        ok, reason = shape_applicable(cfg, shp)
        if not ok:
            raise ValueError(f"cell {item!r} not applicable: {reason}")
        cells.append(CellSpec(arch, shape, mp))
    if not cells:
        raise ValueError("no cells in spec")
    return cells


def tuning_fingerprint(rep: TuningReport) -> Dict:
    """The deterministic projection of a report used for equality checks
    across runs with different cache states: everything except the
    wall-clock compile accounting fields of each log entry."""
    volatile = ("compile_s", "compiles", "cached", "retries")
    return {
        "workload": rep.workload,
        "baseline_cost": rep.baseline_cost,
        "final_cost": rep.final_cost,
        "final_config": rep.final_config,
        "n_trials": rep.n_trials,
        "accepted": list(rep.accepted),
        "log": [{**e, "result": {k: v for k, v in e["result"].items()
                                 if k not in volatile}}
                for e in rep.log],
    }


def cell_health(log) -> Dict:
    """Failure/retry accounting over a trial log (TrialLogEntry objects
    or their checkpointed dicts).  Empty for a fault-free cell.

    A cell is ``degraded`` when environment faults touched its walk —
    timeouts, worker deaths (incl. quarantine skips) or unrecovered
    transient failures.  Deterministic crashes are *not* degradation:
    a config that legitimately overflows HBM is normal tuning signal.
    Recovered transients leave only a ``retries`` count (the final
    result, and thus every decision, is the fault-free one)."""
    from repro.core.trial import (FAILURE_TIMEOUT, FAILURE_TRANSIENT,
                                  FAILURE_WORKER_DEATH)
    failures: Dict[str, int] = {}
    retries = 0
    quarantined = 0
    for e in log:
        res = e.get("result", {}) if isinstance(e, dict) else e.result
        f = res.get("failure") or ""
        if res.get("crashed") and f:
            failures[f] = failures.get(f, 0) + 1
        retries += int(res.get("retries") or 0)
        if (res.get("error") or "").startswith("quarantined"):
            quarantined += 1
    out: Dict[str, Any] = {}
    if failures:
        out["failures"] = dict(sorted(failures.items()))
    if retries:
        out["retries"] = retries
    if quarantined:
        out["quarantined"] = quarantined
    if quarantined or any(k in failures for k in
                          (FAILURE_TRANSIENT, FAILURE_TIMEOUT,
                           FAILURE_WORKER_DEATH)):
        out["degraded"] = True
    return out


def _default_stages(spec: CellSpec) -> Optional[List[Stage]]:
    """The campaign's default stages factory: kernel cells walk their
    tile-sweep stage (core/kernel_cell.py), serve cells their serving
    tree (serving/evaluator.py); step cells return None so the strategy
    keeps its own default tree — bit-identical to the historical
    ``lambda spec: None``."""
    if str(spec.arch).startswith("kernel-"):
        from repro.core.kernel_cell import kernel_stages
        return kernel_stages(spec)
    if str(spec.arch).startswith("serve-"):
        from repro.serving.evaluator import serve_stages
        return serve_stages(spec)
    return None


# ------------------------------------------------------------- campaign
class _CellRun:
    """One cell's in-progress walk: runner + cursor + replay ledger."""

    def __init__(self, spec: CellSpec, runner: TrialRunner,
                 cursor: SearchCursor, signature: str):
        self.spec = spec
        self.runner = runner
        self.cursor = cursor
        self.signature = signature
        self.replay: List[Dict] = []     # checkpointed log entries
        self.replayed = 0                # trials served from checkpoint
        self.report: Optional[Any] = None
        self.warmstart: List[Dict] = []  # seed configs offered the cursor
        self.primer: Optional[Dict] = None   # learned-proposer fit state


class Campaign:
    """Run one strategy over a batch of cells concurrently on a shared
    executor.

    ``strategy`` names a registered search strategy (core/strategy.py);
    ``strategy_options`` are passed to its cursor factory (e.g.
    ``{"knobs": ...}`` for sensitivity, ``{"budget": ..., "seed": ...}``
    for random).  ``evaluator`` defaults to a fresh
    :class:`~repro.core.trial.RooflineEvaluator` (shared compile cache
    across every cell); pass a synthetic evaluator for tests.  With
    ``checkpoint_dir=None`` nothing is persisted.

    **Trial history / warm-start** — with the default ``history=None``
    every evaluated trial is appended to ``history.jsonl`` next to the
    checkpoints (campaigns are cumulative by default; pass
    ``history=False`` to opt out, or a :class:`~repro.core.history
    .TrialHistory` to use a specific store).  With ``warm_start=True``
    each cell's cursor is additionally seeded (via the
    ``SearchCursor.warm_start`` hook) with the best configs of the
    ``warm_start_cells`` nearest already-tuned cells in the history.
    The seeds a cell actually used are persisted in its checkpoint and
    replayed on resume, so an interrupted warm-started campaign is
    immune to the history growing underneath it.

    **Online scheduling** (core/schedule.py) — ``prioritize`` names the
    cell prioritizer (``"arch"``: the historical first-seen-arch order;
    ``"history"``: expected speedup from the trial history, unknown
    cells explore-first; or a custom :class:`~repro.core.schedule
    .CellPrioritizer` instance).  ``intake=True`` re-scans
    ``<checkpoint_dir>/intake/`` between batches so cells submitted
    while the campaign runs (``launch/tune.py --add-cells``) are
    admitted live.  ``max_active_cells`` bounds concurrent cells
    (None: all).  None of the three changes a cold cell's decisions —
    only scheduling order.  The one interaction: ``warm_start`` seeds
    are resolved when a cell is *handed out*, so in a bounded or
    intake campaign a late cell may be seeded by trials this same run
    appended — deliberate (the cumulative-history contract),
    deterministic given the history at activation, and replay-exact on
    resume because the checkpoint stores the seeds actually used.

    **Measured tier** (core/measure.py) — with ``measure_top_k=k > 0``
    each cell's finished walk is followed by a re-rank pass: its top-k
    surviving configs (by model cost) are re-evaluated with
    median-of-N *real* jitted timings on a dedicated single-worker
    executor (same deadline/retry/quarantine hardening), and the
    measured winner is published into the report's ``measured``
    section, the checkpoint and the trial history
    (``<strategy>+measured``).  The default ``0`` is a true no-op: no
    measured evaluator is ever constructed and the walk's
    logs/budgets/decisions are bit-identical to a model-only campaign
    — the pass only *re-ranks after* the walk, it never feeds back
    into it.  ``measured_evaluator`` overrides the measured tier's
    default (kernel bench / reduced wall-clock proxy behind the disk
    timing cache) — on real hardware pass a
    :class:`~repro.core.trial.WallClockEvaluator` over the production
    mesh.
    """

    def __init__(self, cells: Sequence[CellSpec], *,
                 strategy: str = "tree",
                 strategy_options: Optional[Dict[str, Any]] = None,
                 threshold: float = 0.05,
                 evaluator: Optional[Callable] = None,
                 baseline_factory: Optional[
                     Callable[[CellSpec], TunableConfig]] = None,
                 stages_factory: Optional[
                     Callable[[CellSpec], Optional[List[Stage]]]] = None,
                 checkpoint_dir: Optional[pathlib.Path] = CAMPAIGN_DIR,
                 executor: Optional[SweepExecutor] = None,
                 max_workers: Optional[int] = None,
                 history: Any = None,
                 warm_start: bool = False,
                 warm_start_cells: int = 2,
                 warm_start_per_cell: int = 1,
                 prioritize: Any = "arch",
                 intake: bool = False,
                 max_active_cells: Optional[int] = None,
                 trial_timeout_s: Optional[float] = None,
                 max_retries: int = 0,
                 quarantine: Any = None,
                 strike_threshold: Optional[int] = None,
                 measure_top_k: int = 0,
                 measured_evaluator: Optional[Callable] = None,
                 telemetry: Any = None):
        if not cells and not intake:
            raise ValueError("campaign needs at least one cell "
                             "(or intake admission)")
        if len(set(c.key() for c in cells)) != len(cells):
            raise ValueError("duplicate cells in campaign")
        self.cells = list(cells)
        self.strategy: StrategySpec = get_strategy(strategy)
        self.strategy_options = dict(strategy_options or {})
        self.threshold = threshold
        if executor is not None and evaluator is not None \
                and executor.evaluator is not evaluator:
            raise ValueError("executor wraps a different evaluator")
        if executor is not None:
            evaluator = executor.evaluator
        elif evaluator is None:
            # kernel-aware default: kernel cells time their jitted
            # kernel, everything else passes through to the same
            # RooflineEvaluator as before (bit-identical step decisions)
            from repro.core.kernel_cell import DispatchEvaluator
            evaluator = DispatchEvaluator()
        self.evaluator = evaluator
        self.executor = executor
        self.max_workers = max_workers
        self.baseline_factory = baseline_factory or (
            lambda spec: default_config(shard_strategy="fsdp_tp",
                                        attn_impl="pallas"))
        self.stages_factory = stages_factory or _default_stages
        self.checkpoint_dir = pathlib.Path(checkpoint_dir) \
            if checkpoint_dir else None
        if history is None:              # default: cumulative campaigns
            self.history = TrialHistory(
                self.checkpoint_dir / HISTORY_FILENAME) \
                if self.checkpoint_dir else None
        elif history is False:
            self.history = None
        else:
            self.history = history
        self.warm_start = bool(warm_start)
        self.warm_start_cells = warm_start_cells
        self.warm_start_per_cell = warm_start_per_cell
        if self.warm_start and self.history is None:
            raise ValueError("warm_start needs a trial history "
                             "(checkpoint_dir or history=)")
        self.prioritize = prioritize
        if prioritize == "history" and self.history is None:
            raise ValueError("prioritize='history' needs a trial "
                             "history (checkpoint_dir or history=)")
        self.intake = bool(intake)
        if self.intake and self.checkpoint_dir is None:
            raise ValueError("intake admission needs a checkpoint_dir")
        if max_active_cells is not None and max_active_cells < 1:
            raise ValueError("max_active_cells must be >= 1")
        self.max_active_cells = max_active_cells
        # ------------------------------------------- trial hardening
        hardened = (trial_timeout_s is not None or max_retries
                    or quarantine not in (None, False)
                    or strike_threshold is not None)
        if executor is not None and hardened:
            raise ValueError("trial hardening (timeout/retries/"
                             "quarantine) configures the campaign's own "
                             "executor — configure the external "
                             "SweepExecutor directly instead")
        self.trial_timeout_s = trial_timeout_s
        self.max_retries = int(max_retries)
        if quarantine is False or (quarantine is None
                                   and self.checkpoint_dir is None):
            self.quarantine = None       # opted out / nowhere to persist
        elif quarantine is None:
            from repro.core.quarantine import Quarantine
            self.quarantine = Quarantine(
                self.checkpoint_dir,
                **({"strike_threshold": strike_threshold}
                   if strike_threshold is not None else {}))
        else:
            self.quarantine = quarantine
            if strike_threshold is not None:
                self.quarantine.strike_threshold = strike_threshold
        # --------------------------------------------- measured tier
        self.measure_top_k = int(measure_top_k)
        if self.measure_top_k < 0:
            raise ValueError("measure_top_k must be >= 0")
        self.measured_evaluator = measured_evaluator
        self._measured_eval: Optional[Callable] = None
        # ------------------------------------------------- telemetry
        # Observability only (core/telemetry.py): the bus is handed to
        # the executors and fed cell lifecycle events, but nothing it
        # records feeds back into decisions — campaigns are
        # bit-identical with telemetry on or off (tests/test_telemetry).
        self.telemetry = (telemetry if telemetry is not None
                          else _telemetry.current())
        self.last_stats: Dict = {}

    # --------------------------------------------------------- per cell
    def _make_cursor(self, spec: CellSpec, runner: TrialRunner,
                     baseline: TunableConfig) -> SearchCursor:
        options = dict(self.strategy_options)
        stages = self.stages_factory(spec)
        if stages is not None:
            options["stages"] = stages
        return self.strategy.factory(runner, baseline, self.threshold,
                                     options)

    # ------------------------------------------------------ checkpoints
    def _ckpt_path(self, spec: CellSpec) -> pathlib.Path:
        return self.checkpoint_dir / f"{spec.key()}.json"

    def discard_checkpoints(self) -> None:
        """Forget persisted state for this campaign's cells (re-tune)."""
        if self.checkpoint_dir is None:
            return
        for spec in self.cells:
            path = self._ckpt_path(spec)
            if path.exists():
                path.unlink()

    def _signature(self, spec: CellSpec, baseline: TunableConfig,
                   cursor: SearchCursor) -> str:
        """Everything the cell's decisions depend on.  For the tree
        strategy the blob layout is byte-identical to the PR-2-era
        checkpoint signature, so v1 checkpoints stay resumable."""
        blob = json.dumps(
            [spec.key(), self.threshold, baseline.as_dict(),
             cursor.signature_parts()],
            sort_keys=True, default=str)
        return hashlib.sha1(blob.encode()).hexdigest()

    def _read_checkpoint(self, spec: CellSpec) -> Optional[Dict]:
        """Read + version/strategy-validate a cell's checkpoint (stale
        strategies are discarded with a warning); signature validation
        happens later in :meth:`_apply_checkpoint`, once warm-start
        seeds are resolved."""
        if self.checkpoint_dir is None:
            return None
        path = self._ckpt_path(spec)
        if not path.exists():
            return None
        try:
            d = json.loads(path.read_text())
        except (OSError, ValueError):
            return None                  # unreadable: start fresh
        if not isinstance(d, dict):
            return None
        # migration shim: PR-2-era (v1) checkpoints predate the strategy
        # field but were always tree walks with today's signature blob
        if d.get("version") == 1 and "strategy" not in d:
            d["version"] = CHECKPOINT_VERSION
            d["strategy"] = "tree"
            d["strategy_version"] = 1
        if (d.get("version") != CHECKPOINT_VERSION
                or d.get("strategy") != self.strategy.name
                or d.get("strategy_version") != self.strategy.version):
            warnings.warn(
                f"discarding stale checkpoint {path.name}: "
                f"strategy {d.get('strategy')!r} "
                f"v{d.get('strategy_version')} (ckpt v{d.get('version')}) "
                f"!= {self.strategy.name!r} v{self.strategy.version}")
            return None
        return d

    def _apply_checkpoint(self, cr: _CellRun, d: Optional[Dict]) -> None:
        if d is None or d.get("signature") != cr.signature:
            return                       # stale walk/baseline: start fresh
        if d.get("done") and d.get("report"):
            cr.report = self.strategy.load_report(d["report"])
            cr.replayed = cr.report.n_trials
            return
        cr.replay = list(d.get("log") or [])

    def _resolve_warmstart(self, spec: CellSpec, baseline: TunableConfig,
                           cursor: SearchCursor,
                           ckpt: Optional[Dict]) -> List[Dict]:
        """Seed the cursor; returns the seed config dicts used.

        A valid checkpoint's stored seed list wins over a fresh history
        query (the history may have grown since the interrupted run —
        replay must see the walk the checkpoint recorded); the stored
        list is trusted only if re-seeding the cursor with it
        reproduces the checkpoint's signature."""
        if not self.warm_start:
            return []
        stored = (ckpt or {}).get("warmstart")
        if stored is not None:           # [] is a stored decision too
            try:
                cursor.warm_start([config_from_dict(d) for d in stored])
            except (ValueError, TypeError):
                pass                     # seeds from an older knob space
            else:
                if self._signature(spec, baseline, cursor) \
                        == ckpt.get("signature"):
                    return list(stored)
        ws = self.history.warmstart_configs(
            spec.arch, spec.shape, spec.multi_pod,
            k_cells=self.warm_start_cells,
            per_cell=self.warm_start_per_cell)
        cursor.warm_start([config_from_dict(d) for d in ws])
        return ws

    def _resolve_primer(self, cursor: SearchCursor,
                        ckpt: Optional[Dict]) -> Optional[Dict]:
        """Prime a history-fit cursor (core/proposer.py) with its
        checkpointable fit state; returns the state used (None for
        strategies without the prime/build_primer hooks).

        A checkpoint's stored state wins over a fresh fit — the history
        may have grown since the interrupted run, and replay must see
        the fit the checkpoint's walk was proposed from.  The stored
        state is self-validating: ``prime`` re-fits from the
        append-only history *prefix* it names and raises if the bytes
        no longer match (rewritten store), in which case a fresh fit is
        built and the stale checkpoint is discarded downstream by the
        signature check."""
        prime = getattr(cursor, "prime", None)
        build = getattr(cursor, "build_primer", None)
        if not callable(prime) or not callable(build):
            return None
        stored = (ckpt or {}).get("primer")
        if stored is not None:
            try:
                prime(stored, self.history)
            except (ValueError, TypeError, KeyError):
                pass                     # stale/foreign state: refit
            else:
                return dict(stored)
        state = build(self.history)
        prime(state, self.history)
        return state

    def cell_done(self, spec: CellSpec) -> bool:
        """Full-validation completion probe: True iff the cell's
        checkpoint is done under this campaign's *exact* parameters —
        strategy, version, threshold/baseline/walk signature and
        warm-start seeds all included.  Never evaluates a trial; the
        fabric's pre-claim check (a done checkpoint from different
        parameters reads as not-done, so the cell is claimed and
        re-tuned exactly as the single-process campaign would)."""
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # probe loops must not spam
            ckpt = self._read_checkpoint(spec)
        if not ckpt or not ckpt.get("done") or not ckpt.get("report"):
            return False
        if self.measure_top_k and self.strategy.measurable:
            # a finished walk that still owes its measured re-rank
            # reads as not-done, so the fabric claims it and runs just
            # the measure pass (the walk itself replays for free)
            md = (ckpt.get("report") or {}).get("measured")
            if not (isinstance(md, dict)
                    and md.get("k") == self.measure_top_k):
                return False
        baseline = self.baseline_factory(spec)
        runner = TrialRunner(spec.workload(), self.evaluator)
        cursor = self._make_cursor(spec, runner, baseline)
        self._resolve_primer(cursor, ckpt)
        self._resolve_warmstart(spec, baseline, cursor, ckpt)
        return ckpt.get("signature") \
            == self._signature(spec, baseline, cursor)

    def _save_checkpoint(self, cr: _CellRun) -> None:
        if self.checkpoint_dir is None:
            return
        self.checkpoint_dir.mkdir(parents=True, exist_ok=True)
        state = {
            "version": CHECKPOINT_VERSION,
            "strategy": self.strategy.name,
            "strategy_version": self.strategy.version,
            "cell": cr.spec.key(),
            "signature": cr.signature,
            "threshold": self.threshold,
            "done": cr.report is not None,
            "log": [dataclasses.asdict(e) for e in cr.runner.log],
            "report": dataclasses.asdict(cr.report)
            if cr.report is not None else None,
        }
        if self.warm_start:
            state["warmstart"] = cr.warmstart
        if cr.primer is not None:
            state["primer"] = cr.primer
        health = cell_health(cr.runner.log)
        if health:                       # fault-free checkpoints unchanged
            state["health"] = health
        # atomic publish: concurrent fabric workers racing on one cell
        # (a stolen-but-alive lease) each land a complete checkpoint,
        # never a torn one
        atomic_publish(self._ckpt_path(cr.spec),
                       json.dumps(state, indent=1, default=str))

    # -------------------------------------------------------- advancing
    def _advance(self, cr: _CellRun):
        """Drive the cursor forward, replaying checkpointed batches;
        returns the next batch that needs live evaluation, or None when
        the cell's walk is complete."""
        while True:
            batch = cr.cursor.propose()
            if not batch:
                cr.report = cr.cursor.report()
                self._save_checkpoint(cr)
                return None
            start = cr.runner.n_trials
            stored = cr.replay[start:start + len(batch)]
            if len(stored) == len(batch) and all(
                    s.get("config") == c.config.as_dict()
                    and s.get("name") == c.name
                    for s, c in zip(stored, batch)):
                # replay: record the stored results without evaluating
                results, indices = [], []
                for s, c in zip(stored, batch):
                    res = TrialResult(**s["result"])
                    cr.runner.record(c.config, c.name, res, c.delta,
                                     replayed=True)
                    results.append(res)
                    indices.append(cr.runner.n_trials - 1)
                cr.cursor.absorb(results, indices)
                cr.replayed += len(batch)
                continue
            cr.replay = cr.replay[:start]    # drop any stale tail
            return batch

    def _absorb(self, cr: _CellRun, batch, results) -> None:
        indices = []
        for c, res in zip(batch, results):
            cr.runner.record(c.config, c.name, res, c.delta)
            indices.append(cr.runner.n_trials - 1)
        cr.cursor.absorb(results, indices)
        self._save_checkpoint(cr)

    # ----------------------------------------------------- measured tier
    def _resolve_measured_evaluator(self) -> Callable:
        """The evaluator the re-rank pass times configs with: the
        injected one, else the measured tier's default (kernel bench /
        reduced wall-clock proxy behind the disk timing cache)."""
        if self._measured_eval is None:
            if self.measured_evaluator is not None:
                self._measured_eval = self.measured_evaluator
            else:
                from repro.core.measure import default_measured_evaluator
                self._measured_eval = default_measured_evaluator()
        return self._measured_eval

    def _measured_pending(self, report: Any) -> bool:
        """Whether a finished walk still owes its measured re-rank."""
        if not self.measure_top_k or not self.strategy.measurable:
            return False
        md = getattr(report, "measured", None)
        return not (isinstance(md, dict)
                    and md.get("k") == self.measure_top_k)

    def _measure_batch(self, cr: _CellRun) -> Optional[List[Dict]]:
        """The cell's measured-tier candidates (top-k surviving configs
        of the finished walk, by model cost), or None when the pass is
        off / already published / has nothing to measure — in the last
        case an empty ``measured`` stamp is published so completion
        probes (``cell_done``) converge."""
        if not self._measured_pending(cr.report):
            return None
        from repro.core.measure import select_top_k
        cands = select_top_k(getattr(cr.report, "log", None) or [],
                             self.measure_top_k)
        if not cands:
            cr.report.measured = {
                "k": self.measure_top_k, "evaluations": 0,
                "candidates": [], "winner": None,
                "note": "no surviving configs to measure"}
            self._save_checkpoint(cr)
            return None
        return cands

    def _absorb_measured(self, cr: _CellRun, cands: List[Dict],
                         results: List[TrialResult]) -> None:
        """Publish the measured re-rank: per-candidate model-vs-measured
        costs, the measured winner, and whether measurement overturned
        the model's own ranking choice (``candidates[0]``).  Every
        measured evaluation is also emitted to the trial history under
        ``<strategy>+measured``."""
        sink = self.history.sink(f"{self.strategy.name}+measured") \
            if self.history is not None else None
        rows: List[Dict] = []
        best: Optional[int] = None
        for rank, (c, res) in enumerate(zip(cands, results)):
            row = {"rank": rank, "name": c["name"],
                   "config": c["config"].as_dict(),
                   "model_cost_s": c["model_cost_s"],
                   "cost_s": res.cost_s, "crashed": bool(res.crashed)}
            if res.crashed:
                row["failure"] = res.failure
                row["error"] = res.error
            if res.cached:
                row["cached"] = True
            if res.compiles:
                row["compiles"] = res.compiles
            if res.retries:
                row["retries"] = int(res.retries)
            rows.append(row)
            if not res.crashed and (best is None
                                    or res.cost_s
                                    < results[best].cost_s):
                best = rank
            if sink is not None:
                sink(cr.runner.workload, c["config"],
                     f"measured:{c['name'] or rank}", res, {})
        md: Dict[str, Any] = {
            "k": self.measure_top_k,
            "evaluations": len(rows),
            "candidates": rows,
            "model_choice": rows[0]["config"],
        }
        if best is not None:
            md["winner"] = rows[best]["config"]
            md["winner_name"] = rows[best]["name"]
            md["winner_cost_s"] = rows[best]["cost_s"]
            md["overturned"] = best != 0
        else:
            md["winner"] = None
            md["note"] = ("every measured candidate crashed; "
                          "the model ranking stands")
        cr.report.measured = md
        self._save_checkpoint(cr)
        if self.telemetry.enabled:
            self.telemetry.emit(
                "measure.rerank", cell=cr.spec.key(),
                evaluations=len(rows),
                overturned=bool(md.get("overturned")),
                winner_cost_s=md.get("winner_cost_s"))

    # -------------------------------------------------------- activation
    def _activate(self, spec: CellSpec) -> _CellRun:
        """Build one cell's run state (cursor, checkpoint, warm-start)
        the moment the queue hands the cell out."""
        if self.quarantine is not None:
            # we own this cell now (queue hand-out / fabric lease), so
            # any intent on it without a completion is an evaluation
            # that died with its worker: strike the in-flight config
            self.quarantine.reap_orphans(spec.workload().key())
        baseline = self.baseline_factory(spec)
        runner = TrialRunner(
            spec.workload(), self.evaluator,
            history=self.history.sink(self.strategy.name)
            if self.history is not None else None)
        cursor = self._make_cursor(spec, runner, baseline)
        ckpt = self._read_checkpoint(spec)
        primer = self._resolve_primer(cursor, ckpt)
        warmstart = self._resolve_warmstart(spec, baseline, cursor, ckpt)
        cr = _CellRun(spec, runner, cursor,
                      self._signature(spec, baseline, cursor))
        cr.warmstart = warmstart
        cr.primer = primer
        self._apply_checkpoint(cr, ckpt)
        if self.telemetry.enabled:
            self.telemetry.emit("cell.activate", cell=spec.key(),
                                strategy=self.strategy.name,
                                warmstart=len(cr.warmstart),
                                replayed=cr.replayed or len(cr.replay))
        return cr

    # -------------------------------------------------------------- run
    def run(self) -> Dict[str, Any]:
        """Drain the cell queue: run the strategy on every admitted
        cell; returns ``{cell_key: report}`` in admission order.

        Cells start in queue-priority order (core/schedule.py — the
        default ``arch`` prioritizer reproduces the historical
        first-seen-arch kickoff, so same-arch trials stay adjacent in
        the executor queue; ``history`` starts the highest
        expected-speedup cells first).  With ``intake=True`` the
        checkpoint directory's ``intake/`` is re-scanned between
        batches, so cells submitted while the campaign runs are
        admitted, tuned and reported without a restart; priority is
        re-queried at every hand-out, and in-flight cells are re-ranked
        between batches by their cursor-reported ``expected_gain``.
        ``max_active_cells`` bounds how many cells are in flight at
        once (None: all — the batch behaviour); a bounded campaign is
        where priority shapes wall-clock-to-first-improvement most.
        Scheduling order never changes per-cell decisions: each cursor
        is a deterministic state machine.
        """
        from repro.core.schedule import CellQueue
        t0 = time.time()
        queue = CellQueue(
            self.cells, prioritizer=self.prioritize,
            history=self.history,
            directory=self.checkpoint_dir if self.intake else None)
        runs: Dict[str, _CellRun] = {}
        own_executor = self.executor is None
        executor = self.executor or SweepExecutor(
            self.evaluator, self.max_workers,
            trial_timeout_s=self.trial_timeout_s,
            max_retries=self.max_retries,
            quarantine=self.quarantine,
            telemetry=self.telemetry)
        # key -> ("walk" | "measure", batch, futs)
        pending: Dict[str, Tuple[str, list, list]] = {}
        m_exec: Optional[SweepExecutor] = None

        def measured_executor() -> SweepExecutor:
            """Lazy single-worker executor for measured trials: real
            wall clocks must not time-share the host with each other
            (or with a batch of concurrent model trials racing CPU),
            and serializing bounds the extra cost at k evaluations per
            cell.  Same deadline/retry/quarantine hardening as the
            model executor."""
            nonlocal m_exec
            if m_exec is None:
                m_exec = SweepExecutor(
                    self._resolve_measured_evaluator(), max_workers=1,
                    trial_timeout_s=self.trial_timeout_s,
                    max_retries=self.max_retries,
                    quarantine=self.quarantine,
                    telemetry=self.telemetry)
            return m_exec

        try:
            def kick(cr: _CellRun) -> None:
                """Advance one cell: next walk batch if the walk is
                live, else the measured re-rank batch, else done."""
                if cr.report is None:
                    batch = self._advance(cr)
                    if batch is not None:
                        futs = [executor.submit(cr.runner.workload,
                                                c.config)
                                for c in batch]
                        pending[cr.spec.key()] = ("walk", batch, futs)
                        return
                cands = self._measure_batch(cr)
                if cands is None:
                    queue.mark_done(cr.spec.key())
                    if self.telemetry.enabled:
                        self.telemetry.emit(
                            "cell.done", cell=cr.spec.key(),
                            trials=cr.runner.n_trials,
                            replayed=cr.replayed)
                    return
                futs = [measured_executor().submit(cr.runner.workload,
                                                   c["config"])
                        for c in cands]
                pending[cr.spec.key()] = ("measure", cands, futs)

            def fill() -> None:
                """Admit live submissions, then start queued cells
                while cell slots are free (priority re-queried at
                every hand-out)."""
                queue.scan_intake()
                while (self.max_active_cells is None
                       or len(pending) < self.max_active_cells):
                    spec = queue.pop_next()
                    if spec is None:
                        return
                    cr = self._activate(spec)
                    runs[spec.key()] = cr
                    # a checkpoint-done cell may still owe its measured
                    # re-rank; kick() resolves both cases
                    kick(cr)

            def live_rank(key: str):
                """Re-rank ready cells by the cursor's own live gain
                estimate — the highest-expected-gain cell's next batch
                enters the executor queue first."""
                gain_fn = getattr(runs[key].cursor, "expected_gain",
                                  None)
                return queue.rank_key(
                    key, gain=gain_fn() if callable(gain_fn) else None)

            fill()
            while pending:
                outstanding = {f for _, _, fs in pending.values()
                               for f in fs if not f.done()}
                if outstanding:
                    wait(outstanding, return_when=FIRST_COMPLETED)
                ready = [k for k, (_, _, fs) in pending.items()
                         if all(f.done() for f in fs)]
                ready.sort(key=live_rank)
                for key in ready:
                    tag, batch, futs = pending.pop(key)
                    results = [f.result() for f in futs]
                    if tag == "measure":
                        self._absorb_measured(runs[key], batch, results)
                    else:
                        self._absorb(runs[key], batch, results)
                    kick(runs[key])
                fill()
        finally:
            if own_executor:
                executor.shutdown()
            if m_exec is not None:
                m_exec.shutdown()

        reports = {spec.key(): runs[spec.key()].report
                   for spec in queue.cells()}
        n_trials = sum(r.n_trials for r in reports.values())
        replayed = sum(cr.replayed for cr in runs.values())
        wall = time.time() - t0
        self.last_stats = {
            "strategy": self.strategy.name,
            "cells": len(queue),
            "trials": n_trials,
            "replayed_trials": replayed,
            "evaluated_trials": n_trials - replayed,
            "wall_s": round(wall, 1),
            "cells_per_hour": round(len(queue) / max(wall, 1e-9)
                                    * 3600.0, 1),
            "queue": queue.snapshot(),
        }
        if self.warm_start:
            self.last_stats["warmstarted_cells"] = sum(
                1 for cr in runs.values() if cr.warmstart)
        if self.measure_top_k:
            meas = {k: getattr(cr.report, "measured", None)
                    for k, cr in runs.items()}
            meas = {k: m for k, m in meas.items()
                    if isinstance(m, dict)}
            self.last_stats["measured"] = {
                "k": self.measure_top_k,
                "cells": len(meas),
                "evaluations": sum(m.get("evaluations", 0)
                                   for m in meas.values()),
                "cached": sum(1 for m in meas.values()
                              for c in m.get("candidates", [])
                              if c.get("cached")),
                "overturned": sorted(
                    k for k, m in meas.items() if m.get("overturned")),
            }
        health = {k: cell_health(cr.runner.log) for k, cr in runs.items()}
        health = {k: h for k, h in health.items() if h}
        if health:                       # fault-free stats unchanged
            self.last_stats["health"] = health
            for cd in self.last_stats["queue"].get("cells", []):
                if cd.get("cell") in health:
                    cd["health"] = health[cd["cell"]]
            self.last_stats["degraded_cells"] = sorted(
                k for k, h in health.items() if h.get("degraded"))
            ex_stats = executor.stats()
            self.last_stats["hardening"] = {
                k: ex_stats[k] for k in ("retries", "timeouts",
                                         "quarantined")}
        return reports
