"""The measured wall-clock tier (ISSUE 7).

The paper's whole method is trial-and-error over a *small number of
real experimental runs*; this module is where those few real runs live.
The roofline strategy screens the knob space for ~free, then the
campaign's two-tier re-rank pass (``Campaign(measure_top_k=k)``)
re-evaluates the top-k surviving configs of each cell with median-of-N
real jitted step timings and publishes the measured winner — the
headline number is a measured step time, not a model prediction.

Three pieces:

  * :class:`TimingCache` — the disk-backed timing memo, an instance of
    the two-level :class:`~repro.core.trial.CompileCache` (same atomic
    publish, same in-flight dedup, same memoization-by-failure-class
    policy: successes persist, deterministic crashes stay in-memory
    only, transient faults are never remembered) under
    ``results/trials/timings``.  Keys cover the *full* config dict —
    unlike the compile cache's compile-projection keys, a measured wall
    clock depends on every knob.
  * :class:`CachedMeasure` — wraps any measured evaluator with the
    timing cache, so repeated measured trials re-pay nothing: a cache
    hit returns the stored cost with ``cached=True, compiles=0``; a
    memoized deterministic crash is re-raised with its stored failure
    class (pre-tagged, so :func:`~repro.core.trial.classify_exception`
    keeps it).
  * :func:`select_top_k` / :func:`default_measured_evaluator` — the
    re-rank candidate selection over a cell's trial log, and the
    measured tier's default evaluator: kernel cells time their jitted
    kernel (core/kernel_cell.py, interpret mode on CPU), step cells
    time the *reduced runnable proxy* of their step on a single-device
    host mesh (this container is CPU-only; on real hardware pass a
    :class:`~repro.core.trial.WallClockEvaluator` over the production
    mesh as ``Campaign(measured_evaluator=...)`` instead).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Any, Callable, Dict, List, Optional

from repro.core.params import TunableConfig
from repro.core.trial import (CACHE_DIR, CompileCache, TrialResult,
                              WallClockEvaluator, Workload)

#: bump when the measured protocol changes (invalidates stored timings)
MEASURE_VERSION = "measure-v1"

TIMING_DIR = CACHE_DIR / "timings"


# ------------------------------------------------------------ the cache
class TimingCache(CompileCache):
    """Disk-backed measured-timing memo, keyed like the compile cache
    (opaque per-cell strings, JSON values, atomic publish) but over the
    full-config measure key."""

    CACHE_KIND = "timing_cache"      # separate hit-rate in metrics.json

    def __init__(self, directory: Optional[pathlib.Path] = None,
                 mem_entries: int = 512, use_disk: bool = True):
        super().__init__(directory or TIMING_DIR, mem_entries, use_disk)


def measure_key(wl: Workload, rt: TunableConfig, repeats: int,
                tag: str = MEASURE_VERSION) -> str:
    """Cache key of one measured evaluation: the cell, the *full*
    config (every knob can move a wall clock), the repeat count and the
    protocol version tag."""
    blob = json.dumps([tag, wl.key(), int(repeats), rt.as_dict()],
                      sort_keys=True, default=str)
    h = hashlib.sha1(blob.encode()).hexdigest()[:16]
    return f"{wl.key()}__measured__{h}"


class CachedMeasure:
    """Wrap a measured evaluator with the two-level timing cache.

    The wrapped callable keeps the evaluator contract ``(workload,
    config) -> TrialResult``, so it drops into a
    :class:`~repro.core.executor.SweepExecutor` (deadline / retry /
    quarantine) unchanged.  Fresh evaluations pass through with their
    own accounting; cache hits cost nothing (``cached=True``,
    ``compiles=0``); memoized deterministic crashes are replayed with
    their stored failure class.
    """

    def __init__(self, evaluator: Callable, cache: Optional[TimingCache]
                 = None, repeats: Optional[int] = None,
                 tag: str = MEASURE_VERSION):
        self.evaluator = evaluator
        self.cache = cache if cache is not None else TimingCache()
        self.repeats = repeats if repeats is not None \
            else int(getattr(evaluator, "repeats", 0))
        self.tag = tag

    def _key(self, wl: Workload, rt: TunableConfig) -> str:
        """Cache-key hook: subclasses fold extra identity into the key
        (the serve tier adds the trace's content hash)."""
        return measure_key(wl, rt, self.repeats, self.tag)

    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        key = self._key(wl, rt)
        fresh: List[TrialResult] = []

        def build() -> Dict:
            from repro.core import telemetry as _telemetry
            with _telemetry.current().span("measure", cell=wl.key()):
                res = self.evaluator(wl, rt)
            fresh.append(res)
            if res.crashed:
                return {"error": res.error, "failure": res.failure,
                        "compile_s": res.compile_s}
            return {"cost_s": res.cost_s, "compile_s": res.compile_s,
                    "compiles": res.compiles}

        entry = self.cache.get_or_build(key, build)
        if fresh:                        # this call ran the evaluator
            return fresh[0]
        if "error" in entry:             # memoized deterministic crash
            return TrialResult(
                cost_s=float("inf"), crashed=True,
                error=entry["error"],
                failure=entry.get("failure", ""), cached=True)
        return TrialResult(cost_s=float(entry["cost_s"]), cached=True,
                           compiles=0, compile_s=0.0)


# ------------------------------------------------- re-rank candidates
def select_top_k(log: List[Any], k: int) -> List[Dict]:
    """The measured tier's candidate list: the k cheapest *distinct,
    surviving* (non-crashed) configs of a cell's trial log, by model
    cost, ties broken by log order.  Each entry is
    ``{"name", "config": TunableConfig, "model_cost_s"}`` —
    ``candidates[0]`` is the model's own ranking choice, which the
    measured winner may overturn."""
    from repro.core.history import config_from_dict
    seen = set()
    entries: List[Dict] = []
    for e in log:
        d = e if isinstance(e, dict) else dataclasses.asdict(e)
        res = d.get("result") or {}
        if res.get("crashed"):
            continue
        ck = json.dumps(d.get("config"), sort_keys=True, default=str)
        if ck in seen:
            continue
        seen.add(ck)
        entries.append(d)
    entries.sort(key=lambda d: d["result"].get("cost_s", float("inf")))
    out = []
    for d in entries[:max(0, int(k))]:
        try:
            cfg = config_from_dict(d["config"])
        except (ValueError, TypeError, KeyError):
            continue                     # older knob space: skip cleanly
        out.append({"name": d.get("name", ""), "config": cfg,
                    "model_cost_s": d["result"].get("cost_s")})
    return out


# --------------------------------------- default measured evaluation
def _measure_mesh(multi_pod: bool = False):
    """A single-device host mesh: always valid on this CPU container
    (the CI environment forces 512 placeholder devices, under which the
    factored host mesh's data axis would not divide a tiny proxy
    batch)."""
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


@dataclasses.dataclass
class _ProxyWorkload(Workload):
    """Same cell identity, reduced config + capped shape (runnable on
    one CPU device — the calibration-point idea applied to execution:
    measure the runnable proxy, rank by its real wall clock)."""
    seq_cap: int = 128
    batch_cap: int = 8

    @property
    def cfg(self):
        from repro.configs import get_reduced
        return get_reduced(self.arch)

    @property
    def shp(self):
        from repro.configs import get_shape
        from repro.configs.base import ShapeConfig
        base = get_shape(self.shape)
        return ShapeConfig(f"measure_{base.name}",
                           min(base.seq_len, self.seq_cap),
                           min(base.global_batch, self.batch_cap),
                           base.kind)


class ReducedWallClock:
    """Hardened :class:`WallClockEvaluator` over each cell's reduced
    runnable proxy (CPU infrastructure).  Keeps the cell's identity for
    keys/history; only the executed program is reduced."""

    def __init__(self, repeats: int = 3, seq_cap: int = 128,
                 batch_cap: int = 8):
        self.repeats = repeats
        self.seq_cap = seq_cap
        self.batch_cap = batch_cap
        self._ev = WallClockEvaluator(
            lambda multi_pod=False: _measure_mesh(), None, repeats)

    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        proxy = _ProxyWorkload(wl.arch, wl.shape, wl.multi_pod,
                               seq_cap=self.seq_cap,
                               batch_cap=self.batch_cap)
        if proxy.shp.kind == "train" and rt.attn_impl == "pallas":
            # the flash kernel is forward-only (no VJP): executed train
            # steps take attention through the XLA path, exactly like
            # the roofline calibration compiles (core/trial.py); the
            # forward-only prefill/decode kinds keep the real kernel
            rt = rt.replace(attn_impl="xla")
        return self._ev(proxy, rt)


def default_measured_evaluator(cache_dir: Optional[pathlib.Path] = None,
                               repeats: int = 3) -> CachedMeasure:
    """The campaign's measured tier when none is injected: dispatch
    kernel cells to the kernel bench, step cells to the reduced
    wall-clock proxy; wrap everything in the disk-backed timing cache
    (``cache_dir`` defaults to the shared ``results/trials/timings``)."""
    from repro.core.kernel_cell import (KernelBenchEvaluator,
                                        is_kernel_workload)
    step = ReducedWallClock(repeats=repeats)
    kern = KernelBenchEvaluator(repeats=repeats)

    def dispatch(wl: Workload, rt: TunableConfig) -> TrialResult:
        if is_kernel_workload(wl):
            return kern(wl, rt)
        if str(getattr(wl, "arch", "")).startswith("serve-"):
            # serve cells are *already* measured (the trial cost is a
            # trace replay): the re-rank pass replays the same trace,
            # guard off, through its own lazily-built evaluator
            from repro.serving.evaluator import ServeEvaluator
            if not hasattr(dispatch, "_serve"):
                dispatch._serve = ServeEvaluator()
            return dispatch._serve(wl, rt)
        return step(wl, rt)

    return CachedMeasure(dispatch, cache=TimingCache(cache_dir),
                         repeats=repeats)
