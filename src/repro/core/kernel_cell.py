"""KernelCell — Pallas tile sweeps as first-class campaign cells.

2403.00995 argues tuning at *stage* granularity beats one global config;
this module opens that surface here: each Pallas kernel's tile knobs
(``block_q`` / ``block_kv`` / scan-chunk / row-block) become a tuned
cell per (kernel, shape), driven through the existing
campaign/strategy/fabric/quarantine machinery unchanged.  Only measured
timing can adjudicate tiles — the roofline model treats them
analytically — so kernel cells are evaluated by
:class:`KernelBenchEvaluator`, which times the jitted kernel itself
(interpret mode on CPU, Mosaic on TPU), wrapped in the measured tier's
disk-backed :class:`~repro.core.measure.TimingCache`.

Design decisions:

  * every kernel's tile knobs are a **projection of the existing
    ``SPACE``** onto :class:`~repro.core.params.TunableConfig` fields
    (``attn_block_q``/``attn_block_kv`` for flash_attention; the q-tile
    field doubles as flash_decode's kv block, ssm_scan's chunk and
    rmsnorm's row block), so quarantine config keys, history records
    and every strategy work without a second config type;
  * a kernel cell is a :class:`~repro.core.campaign.CellSpec` whose
    ``arch`` is ``kernel-<name>`` and whose shapes come from the
    :data:`KERNELS` registry — cell keys stay three ``__``-separated
    parts, checkpoints/leases/reports all behave identically;
  * a tile that does not divide the shape's sequence length is a
    **clean deterministic-crash trial** (validated up front via
    ``Knob.validate_tile``), exactly like the paper's failed 0.1/0.7
    run — even though the public kernel wrappers themselves self-fit
    ragged shapes for correctness, the tuner never silently aliases
    one tile value to another.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs.base import ShapeConfig
from repro.core.campaign import CellSpec
from repro.core.params import TunableConfig
from repro.core.space import SPACE
from repro.core.tree import Stage
from repro.core.trial import (TrialError, TrialResult, Workload,
                              classify_exception)

KERNEL_ARCH_PREFIX = "kernel-"


def is_kernel_workload(wl: Any) -> bool:
    return str(getattr(wl, "arch", "")).startswith(KERNEL_ARCH_PREFIX)


# ------------------------------------------------------------- registry
@dataclasses.dataclass(frozen=True)
class KernelShape:
    """One benchmarked shape of a kernel.  ``seq_len`` is the dimension
    the tile knobs must divide; ``dims`` the full argument geometry."""
    name: str
    seq_len: int
    dims: Tuple[Tuple[str, int], ...]

    def dim(self, name: str) -> int:
        return dict(self.dims)[name]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One tunable kernel: its SPACE tile projection, its shapes, and
    a builder returning ``(fn, args)`` where ``fn`` applies the kernel
    with the config's tiles (jitted by the evaluator)."""
    name: str
    knobs: Tuple[str, ...]
    shapes: Dict[str, KernelShape]
    build: Callable[[KernelShape, TunableConfig], Tuple[Callable, Tuple]]


def _shape(name: str, seq_len: int, **dims: int) -> KernelShape:
    return KernelShape(name, seq_len, tuple(sorted(dims.items())))


def _build_flash_attention(shape: KernelShape, rt: TunableConfig):
    from repro.kernels.flash_attention.ops import flash_attention
    B, H, S, hd = (shape.dim(n) for n in ("B", "H", "S", "hd"))
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (B, S, H, hd), jnp_dtype())
    k = jax.random.normal(kk, (B, S, H, hd), jnp_dtype())
    v = jax.random.normal(kv, (B, S, H, hd), jnp_dtype())

    def fn(q, k, v):
        return flash_attention(q, k, v, causal=True,
                               block_q=rt.attn_block_q,
                               block_kv=rt.attn_block_kv)
    return fn, (q, k, v)


def _build_flash_decode(shape: KernelShape, rt: TunableConfig):
    import jax.numpy as jnp
    from repro.kernels.flash_decode.ops import flash_decode
    B, H, Hkv, S, hd = (shape.dim(n)
                        for n in ("B", "H", "Hkv", "S", "hd"))
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(kq, (B, 1, H, hd), jnp_dtype())
    kc = jax.random.normal(kk, (B, S, Hkv, hd), jnp_dtype())
    vc = jax.random.normal(kv, (B, S, Hkv, hd), jnp_dtype())
    length = jnp.int32(shape.dim("length"))

    def fn(q, kc, vc, length):
        return flash_decode(q, kc, vc, length,
                            block_kv=rt.attn_block_kv)
    return fn, (q, kc, vc, length)


def _build_ssm_scan(shape: KernelShape, rt: TunableConfig):
    from repro.kernels.ssm_scan.ops import ssm_scan
    B, S, H, P, N = (shape.dim(n) for n in ("B", "S", "H", "P", "N"))
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    X = jax.random.normal(ks[0], (B, S, H, P), jnp_dtype())
    Bm = jax.random.normal(ks[1], (B, S, N), jnp_dtype())
    Cm = jax.random.normal(ks[2], (B, S, N), jnp_dtype())
    dt = jax.nn.softplus(jax.random.normal(ks[3], (B, S, H),
                                           jnp_dtype()))
    la = -jax.nn.softplus(jax.random.normal(ks[4], (B, S, H),
                                            jnp_dtype()))

    def fn(X, Bm, Cm, dt, la):
        return ssm_scan(X, Bm, Cm, dt, la, chunk=rt.attn_block_q)
    return fn, (X, Bm, Cm, dt, la)


def _build_rmsnorm(shape: KernelShape, rt: TunableConfig):
    from repro.kernels.rmsnorm.ops import rmsnorm
    rows, d = shape.dim("rows"), shape.dim("d")
    kx, _ = jax.random.split(jax.random.PRNGKey(3))
    x = jax.random.normal(kx, (rows, d), jnp_dtype())
    scale = jax.numpy.ones((d,), jnp_dtype())

    def fn(x, scale):
        return rmsnorm(x, scale, block_rows=rt.attn_block_q)
    return fn, (x, scale)


def jnp_dtype():
    import jax.numpy as jnp
    return jnp.float32


#: the tunable kernels.  Shapes are deliberately tiny: the evaluator
#: runs them in interpret mode on CPU (CI), where grid-step dispatch
#: dominates — exactly the overhead the tile knobs trade against VMEM.
#: "ragged" shapes make some tiles *invalid* (non-dividing), producing
#: the paper's deterministic-crash trials inside an otherwise normal
#: sweep.
KERNELS: Dict[str, KernelSpec] = {
    "flash_attention": KernelSpec(
        "flash_attention", ("attn_block_q", "attn_block_kv"),
        {"tiny": _shape("tiny", 256, B=1, H=2, S=256, hd=64),
         "ragged": _shape("ragged", 384, B=1, H=2, S=384, hd=64)},
        _build_flash_attention),
    "flash_decode": KernelSpec(
        "flash_decode", ("attn_block_kv",),
        {"tiny": _shape("tiny", 512, B=1, H=4, Hkv=2, S=512, hd=64,
                        length=384)},
        _build_flash_decode),
    "ssm_scan": KernelSpec(
        "ssm_scan", ("attn_block_q",),
        {"tiny": _shape("tiny", 512, B=1, S=512, H=2, P=8, N=8)},
        _build_ssm_scan),
    "rmsnorm": KernelSpec(
        "rmsnorm", ("attn_block_q",),
        {"tiny": _shape("tiny", 4096, rows=4096, d=512)},
        _build_rmsnorm),
}


# ---------------------------------------------------------------- cells
@dataclasses.dataclass
class KernelWorkload(Workload):
    """A kernel cell's workload: same key/identity contract as a step
    workload, but ``cfg``/``shp`` come from the kernel registry (the
    step-builder path is never taken — kernel cells are evaluated by
    :class:`KernelBenchEvaluator`)."""

    @property
    def kernel(self) -> str:
        return self.arch[len(KERNEL_ARCH_PREFIX):]

    @property
    def cfg(self):
        raise TrialError(f"kernel workload {self.key()} has no arch "
                         "config — route it to KernelBenchEvaluator")

    @property
    def shp(self) -> ShapeConfig:
        ks = KERNELS[self.kernel].shapes[self.shape]
        return ShapeConfig(self.shape, ks.seq_len, 1, "kernel")


@dataclasses.dataclass(frozen=True)
class KernelCell(CellSpec):
    """One (kernel, shape) tile-sweep cell.  ``arch`` is
    ``kernel-<name>`` so cell keys/checkpoints/leases keep the
    three-part ``arch__shape__mesh`` layout everywhere."""

    @property
    def kernel(self) -> str:
        return self.arch[len(KERNEL_ARCH_PREFIX):]

    def workload(self) -> KernelWorkload:
        return KernelWorkload(self.arch, self.shape, self.multi_pod)

    def spec(self) -> str:
        return f"kernel:{self.kernel}:{self.shape}"


def kernel_cell(kernel: str, shape: str) -> KernelCell:
    if kernel not in KERNELS:
        raise ValueError(f"unknown kernel {kernel!r} "
                         f"(known: {', '.join(sorted(KERNELS))})")
    if shape not in KERNELS[kernel].shapes:
        raise ValueError(
            f"unknown shape {shape!r} for kernel {kernel!r} "
            f"(known: {', '.join(sorted(KERNELS[kernel].shapes))})")
    return KernelCell(KERNEL_ARCH_PREFIX + kernel, shape, False)


def parse_kernel_cell(item: str) -> KernelCell:
    """Parse one ``kernel:<name>:<shape>`` cell spec (the string
    :meth:`KernelCell.spec` emits and the fabric round-trips)."""
    parts = item.strip().split(":")
    if len(parts) != 3 or parts[0] != "kernel":
        raise ValueError(f"bad kernel cell spec {item!r} "
                         "(want kernel:<name>:<shape>)")
    return kernel_cell(parts[1], parts[2])


def kernel_cells(kernels: Optional[List[str]] = None) -> List[KernelCell]:
    """Every registered (kernel, shape) cell."""
    return [kernel_cell(k, s)
            for k in (kernels or sorted(KERNELS))
            for s in sorted(KERNELS[k].shapes)]


def kernel_signature(arch: str, shape: str, multi_pod: bool = False
                     ) -> Dict:
    """Warm-start similarity features for a kernel cell (the kernel-side
    counterpart of :func:`repro.core.history.cell_signature`)."""
    name = arch[len(KERNEL_ARCH_PREFIX):]
    ks = KERNELS.get(name)
    return {
        "arch": arch,
        "shape": shape,
        "kind": "kernel",
        "family": arch,
        "multi_pod": bool(multi_pod),
        "active_knobs": list(ks.knobs) if ks else [],
    }


# --------------------------------------------------------------- stages
def kernel_stages(spec: Any) -> List[Stage]:
    """The tile-sweep tree for one kernel cell: a single joint stage
    whose alternatives are every non-default combination of the
    kernel's tile projection (≤ 8 + baseline — inside the paper's
    ≤ 10-trial budget)."""
    import itertools
    ks = KERNELS[spec.arch[len(KERNEL_ARCH_PREFIX):]]
    defaults = {n: SPACE[n].default for n in ks.knobs}
    alts = []
    for combo in itertools.product(*(SPACE[n].domain for n in ks.knobs)):
        delta = {n: v for n, v in zip(ks.knobs, combo)
                 if v != defaults[n]}
        if delta:
            alts.append(delta)
    return [Stage("tiles", SPACE[ks.knobs[0]].spark, alts,
                  kinds=("kernel",))]


# ------------------------------------------------------------ evaluator
class KernelBenchEvaluator:
    """Time the jitted kernel itself: median of N repeats after one
    warm-up (= the compile).  Interpret mode on CPU (the ops wrappers
    select it from the backend) keeps this CI-runnable; the same code
    path compiles to Mosaic on TPU.  Hardened exactly like the other
    evaluators: tile-divisibility is validated up front (clean
    deterministic crash), everything else goes through
    :func:`classify_exception`."""

    def __init__(self, repeats: int = 3):
        self.repeats = repeats

    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        t0 = time.time()
        try:
            if not is_kernel_workload(wl):
                raise TrialError(f"{wl.key()} is not a kernel cell")
            name = wl.arch[len(KERNEL_ARCH_PREFIX):]
            ks = KERNELS.get(name)
            if ks is None or wl.shape not in ks.shapes:
                raise TrialError(f"unknown kernel cell {wl.key()}")
            shape = ks.shapes[wl.shape]
            SPACE.validate(rt)
            for knob in ks.knobs:
                SPACE[knob].validate_tile(getattr(rt, knob),
                                          shape.seq_len)
            fn, args = ks.build(shape, rt)
            jitted = jax.jit(fn)
            c0 = time.time()
            jax.block_until_ready(jitted(*args))
            compile_s = round(time.time() - c0, 2)
            ts = []
            for _ in range(self.repeats):
                t1 = time.time()
                jax.block_until_ready(jitted(*args))
                ts.append(time.time() - t1)
            return TrialResult(cost_s=float(np.median(ts)), compiles=1,
                               compile_s=compile_s)
        except Exception as e:
            err = str(e) if isinstance(e, TrialError) \
                else f"{type(e).__name__}: {e}"
            return TrialResult(cost_s=float("inf"), crashed=True,
                               error=err[:500],
                               failure=classify_exception(e),
                               compile_s=round(time.time() - t0, 2))


class DispatchEvaluator:
    """The campaign's cell-kind-aware default evaluator: kernel
    workloads go to the (timing-cached) kernel bench, serve workloads
    to the (timing-cached) traffic-replay evaluator
    (serving/evaluator.py, lazily built so pure-step campaigns never
    import the serving stack), every other workload passes through to
    the step evaluator unchanged — a pure-step campaign's decisions
    are bit-identical to a bare RooflineEvaluator's."""

    def __init__(self, step: Optional[Callable] = None,
                 kernel: Optional[Callable] = None,
                 serve: Optional[Callable] = None,
                 slo_ttft: Optional[float] = None):
        if step is None:
            from repro.core.trial import RooflineEvaluator
            step = RooflineEvaluator()
        if kernel is None:
            from repro.core.measure import CachedMeasure
            kernel = CachedMeasure(KernelBenchEvaluator())
        self.step = step
        self.kernel = kernel
        self.serve = serve
        self.slo_ttft = slo_ttft

    def _serve_eval(self) -> Callable:
        if self.serve is None:
            from repro.serving.evaluator import make_serve_evaluator
            self.serve = make_serve_evaluator(slo_ttft=self.slo_ttft)
        return self.serve

    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        if is_kernel_workload(wl):
            return self.kernel(wl, rt)
        if str(getattr(wl, "arch", "")).startswith("serve-"):
            return self._serve_eval()(wl, rt)
        return self.step(wl, rt)


def make_evaluator() -> DispatchEvaluator:
    """Zero-arg factory (``--evaluator repro.core.kernel_cell:
    make_evaluator`` — also what the campaign builds by default)."""
    return DispatchEvaluator()
