"""Markdown / CSV emission for EXPERIMENTS.md artifacts."""
from __future__ import annotations

import json
from typing import Dict, List

from repro.core.sensitivity import SensitivityReport
from repro.core.tree import TuningReport


def sensitivity_markdown(reports: Dict[str, SensitivityReport]) -> str:
    """Table-2 analogue: rows = knobs, cols = workloads + average."""
    knobs = [i.knob for i in next(iter(reports.values())).impacts]
    lines = ["| knob (Spark analogue) | " +
             " | ".join(reports) + " | Average |",
             "|---" * (len(reports) + 2) + "|"]
    for k in knobs:
        row = [k]
        vals = []
        for rep in reports.values():
            imp = next(i for i in rep.impacts if i.knob == k)
            cell = f"{imp.mean_abs_pct:.1f}%"
            if imp.crashes:
                cell += f" ({imp.crashes} crash)"
            row.append(cell)
            vals.append(imp.mean_abs_pct)
        row.append(f"{sum(vals)/len(vals):.1f}%")
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


def sensitivity_csv(rep: SensitivityReport) -> str:
    lines = ["knob,value,deviation_pct,crashed"]
    for imp in rep.impacts:
        for v, d in zip(imp.values, imp.deviations_pct):
            lines.append(f"{imp.knob},{v},"
                         f"{'' if d != d else round(d, 2)},{d != d}")
    return "\n".join(lines)


def tuning_markdown(rep: TuningReport) -> str:
    out = [f"### Case study: `{rep.workload}`",
           "",
           f"* baseline cost: **{_fmt_s(rep.baseline_cost)}**",
           f"* final cost:    **{_fmt_s(rep.final_cost)}** "
           f"(speedup x{rep.speedup:.2f})",
           f"* trials used:   {rep.n_trials} (cap 10)",
           f"* accepted: {'; '.join(rep.accepted) or '(none)'}",
           "",
           "| # | stage | change | cost | vs incumbent | verdict |",
           "|---|---|---|---|---|---|"]
    prev = None
    for i, e in enumerate(rep.log):
        cost = e["result"].get("cost_s", float("nan"))
        crashed = e["result"].get("crashed")
        verdict = ("CRASH" if crashed else
                   "accept" if e.get("accepted") else "reject")
        if i == 0:
            verdict = "baseline"
        delta = ", ".join(f"{k}={v}" for k, v in e["delta"].items()) or "-"
        out.append(f"| {i} | {e['name']} | {delta} | {_fmt_s(cost)} | "
                   f"{e.get('note','')} | {verdict} |")
    return "\n".join(out)


def _fmt_s(x: float) -> str:
    if x != x or x == float("inf") or x >= 1e29:
        return "crash"
    if x >= 1.0:
        return f"{x:.3f} s"
    return f"{x*1e3:.2f} ms"
