"""Markdown / CSV emission for EXPERIMENTS.md artifacts."""
from __future__ import annotations

import json
import math
from typing import Dict, List, Optional

from repro.core.sensitivity import SensitivityReport
from repro.core.tree import MAX_TRIALS, TuningReport


def sensitivity_markdown(reports: Dict[str, SensitivityReport]) -> str:
    """Table-2 analogue: rows = knobs, cols = workloads + average."""
    knobs = [i.knob for i in next(iter(reports.values())).impacts]
    lines = ["| knob (Spark analogue) | " +
             " | ".join(reports) + " | Average |",
             "|---" * (len(reports) + 2) + "|"]
    for k in knobs:
        row = [k]
        vals = []
        for rep in reports.values():
            imp = next(i for i in rep.impacts if i.knob == k)
            cell = f"{imp.mean_abs_pct:.1f}%"
            if imp.crashes:
                cell += f" ({imp.crashes} crash)"
            row.append(cell)
            vals.append(imp.mean_abs_pct)
        row.append(f"{sum(vals)/len(vals):.1f}%")
        lines.append("| " + " | ".join(row) + " |")
    return "\n".join(lines)


def sensitivity_cell_markdown(rep: SensitivityReport) -> str:
    """One cell's OFAT matrix: rows = knobs, per-value deviations."""
    out = [f"### Sensitivity: `{rep.workload}`",
           "",
           f"* baseline cost: **{_fmt_s(rep.baseline_cost)}**",
           f"* trials used:   {rep.n_trials}",
           "",
           "| knob (Spark analogue) | values | deviation % | "
           "mean abs % | crashes |",
           "|---|---|---|---|---|"]
    for imp in rep.impacts:
        devs = ", ".join("crash" if d != d else f"{d:+.1f}"
                         for d in imp.deviations_pct)
        vals = ", ".join(str(v) for v in imp.values)
        out.append(f"| {imp.knob} ({imp.spark_name}) | {vals} | {devs} | "
                   f"{imp.mean_abs_pct:.1f}% | {imp.crashes} |")
    return "\n".join(out)


def sensitivity_csv(rep: SensitivityReport) -> str:
    lines = ["knob,value,deviation_pct,crashed"]
    for imp in rep.impacts:
        for v, d in zip(imp.values, imp.deviations_pct):
            lines.append(f"{imp.knob},{v},"
                         f"{'' if d != d else round(d, 2)},{d != d}")
    return "\n".join(lines)


def tuning_markdown(rep: TuningReport) -> str:
    out = [f"### Case study: `{rep.workload}`",
           "",
           f"* baseline cost: **{_fmt_s(rep.baseline_cost)}**",
           f"* final cost:    **{_fmt_s(rep.final_cost)}** "
           f"(speedup x{rep.speedup:.2f})",
           f"* trials used:   {rep.n_trials} (cap 10)",
           f"* accepted: {'; '.join(rep.accepted) or '(none)'}",
           "",
           "| # | stage | change | cost | vs incumbent | verdict |",
           "|---|---|---|---|---|---|"]
    prev = None
    for i, e in enumerate(rep.log):
        cost = e["result"].get("cost_s", float("nan"))
        crashed = e["result"].get("crashed")
        verdict = ("CRASH" if crashed else
                   "accept" if e.get("accepted") else "reject")
        if i == 0:
            verdict = "baseline"
        delta = ", ".join(f"{k}={v}" for k, v in e["delta"].items()) or "-"
        out.append(f"| {i} | {e['name']} | {delta} | {_fmt_s(cost)} | "
                   f"{e.get('note','')} | {verdict} |")
    md = getattr(rep, "measured", None)
    if isinstance(md, dict):             # model-only reports unchanged
        out += ["", measured_markdown(md)]
    pd = getattr(rep, "proposer", None)
    if isinstance(pd, dict):             # learned-proposer walks only
        out += ["", proposer_markdown(pd)]
    return "\n".join(out)


def measured_markdown(md: Dict) -> str:
    """The measured tier's re-rank table (``TuningReport.measured``,
    core/measure.py): the model's top-K surviving configs, each with
    its model-predicted and real median wall-clock cost, the measured
    winner, and whether measurement overturned the model ranking."""
    head = (f"**Measured re-rank** (top-{md.get('k')}, "
            f"{md.get('evaluations', 0)} evaluation(s))")
    rows = md.get("candidates") or []
    if not rows:
        return head + f": {md.get('note', 'no candidates')}"
    out = [head, "",
           "| rank (model) | candidate | model cost | measured | "
           "verdict |",
           "|---|---|---|---|---|"]
    winner = md.get("winner")
    for r in rows:
        if r.get("crashed"):
            verdict = f"CRASH ({r.get('failure', '?')})"
        elif winner is not None and r.get("config") == winner:
            verdict = "**winner**"
            if md.get("overturned"):
                verdict += " (overturned model choice)"
        else:
            verdict = "reject"
        cached = " (cached)" if r.get("cached") else ""
        out.append(
            f"| {r.get('rank')} | {r.get('name') or '—'} | "
            f"{_fmt_s(r.get('model_cost_s', float('nan')))} | "
            f"{_fmt_s(r.get('cost_s', float('nan')))}{cached} | "
            f"{verdict} |")
    if md.get("note"):
        out += ["", f"_{md['note']}_"]
    return "\n".join(out)


def proposer_markdown(pd: Dict) -> str:
    """The learned proposer's predicted-vs-actual table
    (``TuningReport.proposer``, core/proposer.py): the fit it rode
    (record counts + digest prefix) and, per proposed trial, the
    ridge model's predicted cost next to the evaluated one — the
    inspection surface for "is the model earning its trials"."""
    head = (f"**Learned proposer** (fit on {pd.get('records', 0)} of "
            f"{pd.get('raw', 0)} history records, "
            f"digest `{str(pd.get('digest', ''))[:12]}`)")
    rows = pd.get("rows") or []
    if not rows:
        return head + ": no model-proposed trials"
    out = [head, "",
           "| trial | predicted | actual | error |",
           "|---|---|---|---|"]
    for r in rows:
        pred = r.get("predicted_s", float("nan"))
        if r.get("crashed"):
            actual, err = "CRASH", "—"
        else:
            cost = r.get("cost_s", float("nan"))
            actual = _fmt_s(cost)
            err = (f"{(pred - cost) / cost * 100.0:+.1f}%"
                   if cost == cost and cost > 0 else "—")
        out.append(f"| {r.get('name') or '—'} | {_fmt_s(pred)} | "
                   f"{actual} | {err} |")
    return "\n".join(out)


def _health_cell(h: Optional[Dict]) -> str:
    """Compact per-cell failure/retry/quarantine summary (one table
    cell of :func:`queue_markdown`)."""
    if not h:
        return "—"
    parts = [f"{n} {kind}"
             for kind, n in sorted((h.get("failures") or {}).items())]
    if h.get("retries"):
        parts.append(f"{h['retries']} retried")
    if h.get("quarantined"):
        parts.append(f"{h['quarantined']} quarantined")
    if h.get("degraded"):
        parts.append("DEGRADED")
    return "; ".join(parts) or "—"


def queue_markdown(queue: Dict) -> str:
    """Admission / priority view of an online campaign (the
    ``Campaign.last_stats["queue"]`` snapshot, core/schedule.py, or a
    ``queue_status`` dict): one row per admitted cell — how it entered
    (seed vs intake), the priority score it was scheduled under (``—``
    = unknown → explore-first) and its final queue state.  When any
    cell carries failure accounting (``health``, core/campaign.py), a
    health column is added so an operator sees a degrading campaign
    before it finishes."""
    cells = queue.get("cells", [])
    with_health = any(d.get("health") for d in cells)
    lines = [f"### Queue: {queue.get('admitted', 0)} cells admitted "
             f"({queue.get('from_intake', 0)} via intake), "
             f"prioritize={queue.get('prioritize', 'arch')}",
             "",
             "| cell | admitted | priority | state |"
             + (" health |" if with_health else ""),
             "|---|---|---|---|" + ("---|" if with_health else "")]
    for d in cells:
        score = d.get("score")
        row = (f"| {d['cell']} | {d.get('source', '?')} | "
               f"{'—' if score is None else f'{score:.2f}'} | "
               f"{d.get('state', '?')} |")
        if with_health:
            row += f" {_health_cell(d.get('health'))} |"
        lines.append(row)
    return "\n".join(lines)


def campaign_markdown(reports: Dict[str, TuningReport],
                      queue: Optional[Dict] = None) -> str:
    """Cross-cell speedup matrix: rows = archs, cols = shape__mesh cells
    (the paper's case-study summary generalized to the full assignment).
    With ``queue`` (an online campaign's admission snapshot) the
    admission/priority table is appended."""
    parsed = []
    for key, rep in reports.items():
        arch, shape, mesh = key.split("__")
        parsed.append((arch, f"{shape}__{mesh}", rep))
    archs = list(dict.fromkeys(a for a, _, _ in parsed))
    cols = list(dict.fromkeys(c for _, c, _ in parsed))
    by_cell = {(a, c): r for a, c, r in parsed}
    lines = ["### Campaign: tuning-tree speedup per cell",
             "",
             "| arch | " + " | ".join(cols) + " |",
             "|---" * (len(cols) + 1) + "|"]
    for a in archs:
        row = [a]
        for c in cols:
            rep = by_cell.get((a, c))
            if rep is None:
                row.append("—")
            elif rep.final_cost != rep.final_cost \
                    or rep.final_cost == float("inf"):
                row.append("crash")
            elif rep.baseline_cost == float("inf"):
                # crashed baseline, viable candidate found: the ratio is
                # meaningless, the recovery is the result
                row.append(f"recovered ({rep.n_trials})")
            else:
                row.append(f"x{rep.speedup:.2f} ({rep.n_trials})")
        lines.append("| " + " | ".join(row) + " |")
    # the gmean covers cells with a finite, nonzero ratio: a crashed
    # final (speedup 0) or crashed baseline (speedup inf/nan) is
    # reported in its cell, not averaged into the headline number
    speedups = [r.speedup for r in reports.values()
                if r.speedup == r.speedup and r.speedup != float("inf")
                and r.speedup > 0]
    gmean = (float(math.prod(speedups)) ** (1.0 / len(speedups))) \
        if speedups else float("nan")
    lines += ["",
              f"* cells tuned: {len(reports)}",
              f"* total trials: {sum(r.n_trials for r in reports.values())}"
              f" (cap {MAX_TRIALS * len(reports)})",
              f"* accepted changes: "
              f"{sum(len(r.accepted) for r in reports.values())}",
              f"* geometric-mean speedup: x{gmean:.2f}",
              "",
              "Each cell: `x<speedup> (<trials used>)`."]
    measured = {k: r.measured for k, r in reports.items()
                if isinstance(getattr(r, "measured", None), dict)}
    if measured:                         # model-only output unchanged
        overturned = sorted(k for k, m in measured.items()
                            if m.get("overturned"))
        line = (f"* measured re-rank: {len(measured)} cell(s), "
                f"{sum(m.get('evaluations', 0) for m in measured.values())}"
                f" real evaluation(s), {len(overturned)} overturned")
        if overturned:
            line += " — " + ", ".join(f"`{c}`" for c in overturned)
        lines.insert(-2, line)
    fitted = {k: r.proposer for k, r in reports.items()
              if isinstance(getattr(r, "proposer", None), dict)}
    if fitted:                           # tree-only output unchanged
        lines.insert(-2, (
            f"* learned proposer: {len(fitted)} cell(s) fit "
            f"(on {sum(p.get('records', 0) for p in fitted.values())} "
            f"history records), "
            f"{sum(len(p.get('rows') or []) for p in fitted.values())} "
            f"model-proposed trial(s)"))
    degraded = sorted(d["cell"] for d in (queue or {}).get("cells", [])
                      if (d.get("health") or {}).get("degraded"))
    if degraded:                         # fault-free output unchanged
        lines.insert(-2, f"* degraded cells (partial results under "
                         f"faults): {len(degraded)} — "
                         + ", ".join(f"`{c}`" for c in degraded))
    if queue is not None:
        lines += ["", queue_markdown(queue)]
    return "\n".join(lines)


def serving_markdown(live: Dict[str, Optional[Dict]],
                     history: List[Dict]) -> str:
    """The serving promotion board (serving/canary.PromotionBoard):
    one row per serve cell's live config, plus the promotion/demotion
    history tail.  ``live`` maps cell key -> live-file dict (None =
    nothing promoted yet)."""
    lines = ["### Serving: promoted live configs",
             "",
             "| cell | live cost | promoted knobs | source |",
             "|---|---|---|---|"]
    for key in sorted(live):
        rec = live[key]
        if not rec:
            lines.append(f"| {key} | — (nothing promoted) | — | — |")
            continue
        cfg = rec.get("config") or {}
        knobs = ", ".join(
            f"{k}={cfg[k]}" for k in ("max_wave_size", "wave_admission",
                                      "kv_cache_dtype", "donate_buffers",
                                      "compute_dtype") if k in cfg)
        lines.append(f"| {key} | {_fmt_s(rec.get('cost_s', float('nan')))}"
                     f" | {knobs or '—'} | {rec.get('source') or '—'} |")
    promoted = sum(r.get("action") == "promoted" for r in history)
    kept = sum(r.get("action") == "kept-incumbent" for r in history)
    lines += ["",
              f"* promotion events: {promoted} promoted, {kept} kept "
              "the incumbent (the live file never regresses)"]
    demoted = [r for r in history
               if r.get("action") == "promoted" and r.get("demoted")]
    if demoted:
        lines += ["", "| demoted at | cell | old cost | new cost |",
                  "|---|---|---|---|"]
        for r in demoted[-10:]:
            lines.append(
                f"| {r.get('ts')} | {r.get('cell')} | "
                f"{_fmt_s((r['demoted'] or {}).get('cost_s', float('nan')))}"
                f" | {_fmt_s(r.get('cost_s', float('nan')))} |")
    return "\n".join(lines)


def telemetry_markdown(metrics: Dict) -> str:
    """The campaign's "where the time went" section, rendered from the
    telemetry aggregator's published ``metrics.json``
    (core/telemetry.fold_metrics).  Appended to the campaign summary
    only when the directory carries telemetry — untraced output is
    unchanged."""
    counters = metrics.get("counters") or {}
    gauges = metrics.get("gauges") or {}
    attr = metrics.get("attribution") or {}
    hit = gauges.get("cache_hit_rate")
    lines = [
        "### Telemetry: where the time went",
        "",
        f"* events: {metrics.get('events', 0)} over "
        f"{attr.get('wall_s', 0.0)}s wall, "
        f"{gauges.get('workers', 0)} worker(s), "
        f"{gauges.get('trials_per_s', 0.0)} trials/s",
        f"* compile-cache hit rate: "
        f"{'—' if hit is None else format(hit, '.0%')}; per-trial "
        f"rates: {gauges.get('retry_rate', 0)} retry, "
        f"{gauges.get('timeout_rate', 0)} timeout, "
        f"{gauges.get('quarantine_rate', 0)} quarantine, "
        f"{gauges.get('crash_rate', 0)} crash",
        f"* fleet: {counters.get('lease_claims', 0)} lease claim(s), "
        f"{counters.get('lease_steals', 0)} steal(s), "
        f"{counters.get('quarantine_strikes', 0)} strike(s), "
        f"{counters.get('slo_aborts', 0)} SLO abort(s)",
        "",
        "| where | seconds |",
        "|---|---|",
        f"| trials (total) | {attr.get('trial_s', 0.0)} |",
        f"| — compiles | {attr.get('compile_s', 0.0)} |",
        f"| — evaluation (net of compile) | {attr.get('eval_s', 0.0)} |",
        f"| measured tier | {attr.get('measure_s', 0.0)} |",
        f"| idle (worker-seconds) | {attr.get('idle_s', 0.0)} |",
    ]
    per_worker = metrics.get("per_worker") or {}
    if per_worker:
        lines += ["", "| worker | trials | busy | utilization |",
                  "|---|---|---|---|"]
        for w in sorted(per_worker):
            d = per_worker[w]
            lines.append(f"| {w} | {d.get('trials', 0)} | "
                         f"{d.get('busy_s', 0.0)}s | "
                         f"{format(d.get('utilization', 0.0), '.0%')} |")
    per_cell = metrics.get("per_cell") or {}
    if per_cell:
        lines += ["", "| cell | trials | best cost | "
                      "first improvement after |",
                  "|---|---|---|---|"]
        for c in sorted(per_cell):
            d = per_cell[c]
            best = d.get("best_cost_s")
            fi = d.get("first_improvement_s")
            lines.append(
                f"| {c} | {d.get('trials', 0)} | "
                f"{'—' if best is None else _fmt_s(best)} | "
                f"{'—' if fi is None else format(fi) + 's'} |")
    return "\n".join(lines)


def cell_markdown(rep) -> str:
    """Render one cell's report, whatever strategy produced it."""
    if isinstance(rep, SensitivityReport):
        return sensitivity_cell_markdown(rep)
    return tuning_markdown(rep)


def strategy_markdown(reports: Dict, queue: Optional[Dict] = None) -> str:
    """Render a campaign's cross-cell summary, whatever strategy
    produced it: tuning-style reports get the speedup matrix,
    sensitivity reports get the Table-2 impact matrix.  ``queue``
    (an online campaign's admission snapshot) appends the
    admission/priority table."""
    if all(isinstance(r, SensitivityReport) for r in reports.values()):
        md = ("### Campaign: sensitivity impact per cell (Table 2)\n\n"
              + sensitivity_markdown(reports))
        if queue is not None:
            md += "\n\n" + queue_markdown(queue)
        return md
    if all(isinstance(r, TuningReport) for r in reports.values()):
        return campaign_markdown(reports, queue=queue)
    raise TypeError("mixed report types in one campaign: "
                    + ", ".join(sorted({type(r).__name__
                                        for r in reports.values()})))


def _fmt_s(x: float) -> str:
    if x != x or x == float("inf") or x >= 1e29:
        return "crash"
    if x >= 1.0:
        return f"{x:.3f} s"
    return f"{x*1e3:.2f} ms"
