"""Strategy API v1 — pluggable search cursors over one knob space.

The paper's methodology is two search procedures over the same knob
space: the Sec.-4 one-factor-at-a-time sensitivity sweep (Table 2) and
the Fig.-4 ≤10-trial tuning tree.  Both — plus any future procedure
(online cell prioritization à la 2309.01901, multi-granularity tuning à
la 2403.00995) — share one shape:

    propose() -> [Candidate]      # next batch of independent trials
    absorb(results, indices)      # apply outcomes, advance
    done                          # walk complete?
    report()                      # strategy-specific summary

That shape is the :class:`SearchCursor` protocol.  A strategy is a
named, versioned cursor factory in the :data:`STRATEGIES` registry; the
campaign engine (core/campaign.py) drives *any* registered strategy —
interleaved over the shared executor/compile cache, checkpointed and
resumable — without knowing which one it is.

Registered strategies:

  * ``tree``  — the Fig.-4 tuning tree (:class:`~repro.core.tree
    .TreeCursor`), bit-identical logs/budget/decisions to the
    historical blocking walk;
  * ``short`` (alias ``short-tree``) — the paper's two-runs-shorter
    variant (omits the file.buffer stage);
  * ``sensitivity`` — the Table-2 OFAT matrix
    (:class:`~repro.core.sensitivity.SensitivityCursor`), so the
    campaign schedules sensitivity cells concurrently;
  * ``random`` — a budget-matched random-search baseline
    (:class:`RandomCursor`): same ≤10-trial budget as the tree, purely
    random candidates, seeded per cell for determinism;
  * ``model`` — the learned cost-model proposer
    (:class:`~repro.core.proposer.ModelCursor`): a ridge fit on the
    trial history proposes the top-k predicted configs per batch and
    refits online; thin histories fall back bit-identically to the
    ``tree`` walk (1808.06008, 2503.03826).

Adding a strategy = one cursor class + one ``register_strategy`` call.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import (Any, Callable, Dict, List, Optional, Protocol,
                    Sequence, runtime_checkable)

import numpy as np

from repro.core.executor import SweepExecutor, run_trials
from repro.core.params import DOMAINS, TunableConfig
from repro.core.proposer import (MIN_RECORDS, POOL_SIZE, RIDGE_LAMBDA,
                                 TOP_K, ModelCursor)
from repro.core.sensitivity import (KnobImpact, SensitivityCursor,
                                    SensitivityReport)
from repro.core.tree import (MAX_TRIALS, Candidate, TreeCursor,
                             TuningReport, absorb_baseline,
                             apply_accept_rule, short_tree)
from repro.core.trial import TrialResult, TrialRunner


# ------------------------------------------------------------- protocol
@runtime_checkable
class SearchCursor(Protocol):
    """The propose → absorb → done → report shape every strategy obeys.

    Invariants the campaign engine relies on:

      * calls alternate — every proposed batch is absorbed before the
        next ``propose()``; ``propose()`` returns ``[]`` iff the walk
        is complete;
      * a batch's candidates are mutually independent (safe to evaluate
        concurrently);
      * the cursor keeps no hidden result state — replaying recorded
        results through propose/absorb reconstructs the walk
        bit-identically (this is how checkpoint resume works);
      * ``strategy_version`` (class attribute) gates checkpoint
        compatibility, and ``signature_parts()`` returns a
        JSON-serializable description of everything that shapes the
        walk's decisions — including any warm-start seeds;
      * ``warm_start(configs)`` (called before the first proposal, if
        at all) offers the cursor full candidate configurations
        retrieved from the trial history (core/history.py) — the best
        configs of the nearest already-tuned cells.  A strategy is free
        to ignore them (the default no-op); one that uses them must
        fold them into ``signature_parts()`` so checkpoints stay
        replay-exact;
      * ``expected_gain()`` is a *live* estimate of the improvement
        still ahead of the walk (higher = more expected gain; ``None``
        = unknown, which the online scheduler treats as explore-first).
        It feeds the campaign's cell prioritizer (core/schedule.py)
        when in-flight cells are re-ranked between batches; it must
        never influence the cursor's own decisions, so reporting any
        estimate keeps walks bit-identical.
    """

    runner: TrialRunner
    strategy_version: int

    @property
    def done(self) -> bool: ...

    def propose(self) -> List[Candidate]: ...

    def absorb(self, results: Sequence[TrialResult],
               indices: Sequence[int]) -> None: ...

    def report(self) -> Any: ...

    def expected_gain(self) -> Optional[float]: ...

    def signature_parts(self) -> list: ...

    def warm_start(self, configs: Sequence[TunableConfig]) -> None: ...


# ------------------------------------------------------ random baseline
class RandomCursor:
    """Budget-matched random search — the control arm for the tree.

    Evaluates the baseline, then ``budget - 1`` uniformly random
    configurations over the tunable domains in one batch (random search
    is non-adaptive, so the whole budget exposes maximal parallelism).
    The accept rule mirrors the tree's: the cheapest viable candidate
    wins iff it clears the relative-improvement threshold.  Sampling is
    seeded per (seed, workload) so a cell's walk is deterministic and
    checkpoint-resumable.
    """

    strategy_version = 1

    def __init__(self, runner: TrialRunner, baseline: TunableConfig,
                 threshold: float = 0.05, budget: int = MAX_TRIALS,
                 seed: int = 0):
        if budget < 1:
            raise ValueError("random strategy needs budget >= 1")
        self.runner = runner
        self.baseline = baseline
        self.threshold = threshold
        self.budget = budget
        self.seed = seed
        self.incumbent = baseline
        self.baseline_cost = float("nan")
        self.best_cost = float("nan")
        self.accepted: List[str] = []
        self._phase = 0                  # 0: baseline, 1: sweep, 2: done
        self._pending: Optional[List[Candidate]] = None

    def _rng(self) -> np.random.RandomState:
        blob = f"{self.seed}:{self.runner.workload.key()}".encode()
        return np.random.RandomState(
            int.from_bytes(hashlib.sha1(blob).digest()[:4], "big"))

    def _sample(self, n: int) -> List[Candidate]:
        rng = self._rng()
        out = []
        base = self.baseline.as_dict()
        for i in range(n):
            draw = {k: dom[rng.randint(len(dom))]
                    for k, dom in DOMAINS.items()}
            delta = {k: v for k, v in draw.items() if base[k] != v}
            out.append(Candidate(self.baseline.replace(**draw),
                                 f"random:{i + 1}", delta))
        return out

    @property
    def done(self) -> bool:
        return self._phase >= 2

    def propose(self) -> List[Candidate]:
        if self._pending is not None:
            raise RuntimeError("previous batch not absorbed yet")
        if self._phase == 0:
            self._pending = [Candidate(self.baseline, "baseline", {})]
        elif self._phase == 1:
            n = self.budget - self.runner.n_trials
            if n <= 0:
                self._phase = 2
                return []
            self._pending = self._sample(n)
        else:
            return []
        return list(self._pending)

    def absorb(self, results: Sequence[TrialResult],
               indices: Sequence[int]) -> None:
        if self._pending is None:
            raise RuntimeError("no batch proposed")
        if len(results) != len(self._pending) \
                or len(indices) != len(self._pending):
            raise ValueError("results/indices do not match proposed batch")
        cands, self._pending = self._pending, None
        if self._phase == 0:
            self.best_cost = absorb_baseline(self.runner, results[0],
                                             indices[0])
            self.baseline_cost = self.best_cost
            self._phase = 1
            return
        won = apply_accept_rule(self.runner,
                                list(zip(cands, results, indices)),
                                self.best_cost, self.threshold)
        if won is not None:
            cand, cost = won
            self.incumbent = cand.config
            self.best_cost = cost
            self.accepted.append(f"random: {cand.delta}")
        self._phase = 2

    def report(self) -> TuningReport:
        return TuningReport(
            workload=self.runner.workload.key(),
            baseline_cost=self.baseline_cost,
            final_cost=self.best_cost,
            final_config=self.incumbent.as_dict(),
            n_trials=self.runner.n_trials,
            accepted=self.accepted,
            log=[dataclasses.asdict(e) for e in self.runner.log],
        )

    def expected_gain(self) -> Optional[float]:
        """Unknown before the baseline; the whole (non-adaptive) budget
        while the sweep batch is pending; zero once absorbed."""
        if self._phase >= 2:
            return 0.0
        if self._phase == 0:
            return None
        return 1.0

    def signature_parts(self) -> list:
        return ["random", self.seed, self.budget]

    def warm_start(self, configs: Sequence[TunableConfig]) -> None:
        """No-op: random search is the budget-matched *control* arm —
        seeding it with history would make it adaptive."""


# ------------------------------------------------------------- registry
@dataclasses.dataclass(frozen=True)
class StrategySpec:
    """One registered strategy: a versioned cursor factory plus the
    report (de)serializer the campaign's checkpoints need."""
    name: str
    version: int
    factory: Callable[..., "SearchCursor"]   # (runner, baseline,
    #                                          threshold, options) -> cursor
    load_report: Callable[[Dict], Any]       # checkpointed dict -> report
    description: str = ""
    #: whether the measured tier (core/measure.py) can re-rank this
    #: strategy's reports — requires a TuningReport-shaped report (a
    #: trial log of candidate configs plus a ``measured`` slot).  The
    #: sensitivity matrix reports knob impacts, not candidates, so the
    #: campaign's ``measure_top_k`` pass skips it.
    measurable: bool = True


STRATEGIES: Dict[str, StrategySpec] = {}
_ALIASES = {"short-tree": "short"}


def register_strategy(spec: StrategySpec) -> StrategySpec:
    if spec.name in STRATEGIES:
        raise ValueError(f"strategy {spec.name!r} already registered")
    STRATEGIES[spec.name] = spec
    return spec


def get_strategy(name: str) -> StrategySpec:
    key = _ALIASES.get(name, name)
    if key not in STRATEGIES:
        raise KeyError(f"unknown strategy {name!r} "
                       f"(registered: {', '.join(list_strategies())})")
    return STRATEGIES[key]


def list_strategies() -> List[str]:
    return sorted(STRATEGIES)


def make_cursor(name: str, runner: TrialRunner, baseline: TunableConfig,
                *, threshold: float = 0.05,
                options: Optional[Dict[str, Any]] = None) -> SearchCursor:
    """Instantiate a registered strategy's cursor for one cell."""
    return get_strategy(name).factory(runner, baseline, threshold,
                                      dict(options or {}))


def drive(cursor: SearchCursor,
          executor: Optional[SweepExecutor] = None) -> Any:
    """Blocking driver: propose/evaluate/absorb until done, return the
    report.  ``run_tuning`` and ``run_sensitivity`` are this loop
    specialized to their cursor."""
    runner = cursor.runner
    while True:
        batch = cursor.propose()
        if not batch:
            break
        pairs = run_trials(runner, [c.as_trial() for c in batch], executor)
        cursor.absorb([r for _, r in pairs], [i for i, _ in pairs])
    return cursor.report()


# -------------------------------------------------------- registrations
def _load_tuning_report(d: Dict) -> TuningReport:
    return TuningReport(**d)


def _load_sensitivity_report(d: Dict) -> SensitivityReport:
    return SensitivityReport(
        workload=d["workload"], baseline_cost=d["baseline_cost"],
        impacts=[KnobImpact(**i) for i in d["impacts"]],
        n_trials=d["n_trials"])


def _tree_factory(runner, baseline, threshold, options):
    return TreeCursor(runner, baseline, threshold=threshold,
                      stages=options.get("stages"))


def _short_factory(runner, baseline, threshold, options):
    stages = options.get("stages")
    if stages is None:
        stages = short_tree(runner.workload.shp.kind)
    return TreeCursor(runner, baseline, threshold=threshold, stages=stages)


def _sensitivity_factory(runner, baseline, threshold, options):
    return SensitivityCursor(runner, baseline, knobs=options.get("knobs"))


def _random_factory(runner, baseline, threshold, options):
    return RandomCursor(runner, baseline, threshold=threshold,
                        budget=options.get("budget", MAX_TRIALS),
                        seed=options.get("seed", 0))


def _model_factory(runner, baseline, threshold, options):
    return ModelCursor(
        runner, baseline, threshold=threshold,
        budget=options.get("budget", MAX_TRIALS),
        seed=options.get("seed", 0),
        top_k=options.get("top_k", TOP_K),
        min_records=options.get("min_records", MIN_RECORDS),
        pool_size=options.get("pool_size", POOL_SIZE),
        ridge_lambda=options.get("ridge_lambda", RIDGE_LAMBDA),
        stages=options.get("stages"),
        history=options.get("history"))


register_strategy(StrategySpec(
    "tree", TreeCursor.strategy_version, _tree_factory,
    _load_tuning_report,
    "the paper's Fig.-4 ≤10-trial tuning tree"))
register_strategy(StrategySpec(
    "short", TreeCursor.strategy_version, _short_factory,
    _load_tuning_report,
    "the paper's two-runs-shorter tree (omits file.buffer)"))
register_strategy(StrategySpec(
    "sensitivity", SensitivityCursor.strategy_version,
    _sensitivity_factory, _load_sensitivity_report,
    "the Sec.-4 OFAT sensitivity matrix (Table 2)",
    measurable=False))
register_strategy(StrategySpec(
    "random", RandomCursor.strategy_version, _random_factory,
    _load_tuning_report,
    "budget-matched random-search baseline"))


register_strategy(StrategySpec(
    "model", ModelCursor.strategy_version, _model_factory,
    _load_tuning_report,
    "history-fit ridge cost model proposing top-k predicted configs; "
    "falls back to the tree walk on thin history"))
