"""Sec.-4 sensitivity analysis: one-factor-at-a-time sweeps + Table 2.

For each of the 12 knobs, every non-default value is evaluated against
the workload's baseline (values chosen by the paper's rules: binary ->
non-default, categorical -> all values, numeric -> neighbours).  The
impact statistic is the paper's: mean |% deviation| from the baseline
runtime, regardless of sign.  Crashes are recorded (sort-by-key 0.1/0.7
analogue) and excluded from the mean, as in the paper.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

from repro.core.executor import SweepExecutor, run_trials
from repro.core.params import (PARAM_DOCS, SENSITIVITY_SWEEP, TunableConfig)
from repro.core.trial import TrialRunner, Workload


@dataclasses.dataclass
class KnobImpact:
    knob: str
    spark_name: str
    values: List[Any]
    deviations_pct: List[float]        # one per tested value (nan = crash)
    crashes: int

    @property
    def mean_abs_pct(self) -> float:
        vals = [abs(d) for d in self.deviations_pct if d == d]
        return sum(vals) / len(vals) if vals else 0.0


@dataclasses.dataclass
class SensitivityReport:
    workload: str
    baseline_cost: float
    impacts: List[KnobImpact]
    n_trials: int

    def table(self) -> List[Dict]:
        return [{"knob": i.knob, "spark": i.spark_name,
                 "mean_abs_pct": round(i.mean_abs_pct, 1),
                 "crashes": i.crashes} for i in self.impacts]


def run_sensitivity(runner: TrialRunner, baseline: TunableConfig,
                    knobs: Optional[Dict[str, tuple]] = None,
                    executor: Optional[SweepExecutor] = None
                    ) -> SensitivityReport:
    """OFAT sweep.  With an ``executor`` the (mutually independent)
    candidate evaluations overlap; the report, trial log and run count
    are identical to the sequential path."""
    knobs = knobs or SENSITIVITY_SWEEP
    base_res = runner.run(baseline, "baseline", {})
    base_cost = base_res.cost_s
    candidates, spans = [], []
    for knob, values in knobs.items():
        default = getattr(baseline, knob)
        tested = [v for v in values if v != default]
        spans.append((knob, tested))
        candidates.extend(
            (baseline.replace(**{knob: v}), f"ofat:{knob}", {knob: v})
            for v in tested)
    pairs = run_trials(runner, candidates, executor)
    impacts: List[KnobImpact] = []
    it = iter((res, runner.log[idx]) for idx, res in pairs)
    for knob, tested in spans:
        devs, crashes = [], 0
        for _ in tested:
            res, entry = next(it)
            if res.crashed:
                crashes += 1
                devs.append(float("nan"))
                entry.note = "crashed"
            else:
                devs.append(100.0 * (res.cost_s - base_cost) / base_cost)
        impacts.append(KnobImpact(knob, PARAM_DOCS.get(knob, ""), tested,
                                  devs, crashes))
    return SensitivityReport(runner.workload.key(), base_cost, impacts,
                             runner.n_trials)
