"""Sec.-4 sensitivity analysis: one-factor-at-a-time sweeps + Table 2.

For each of the 12 knobs, every non-default value is evaluated against
the workload's baseline (values chosen by the paper's rules: binary ->
non-default, categorical -> all values, numeric -> neighbours).  The
impact statistic is the paper's: mean |% deviation| from the baseline
runtime, regardless of sign.  Crashes are recorded (sort-by-key 0.1/0.7
analogue) and excluded from the mean, as in the paper.

Since the Strategy API, the sweep is a :class:`SensitivityCursor` —
the same propose/absorb/done/report protocol the tuning tree uses
(core/strategy.SearchCursor) — so a :class:`~repro.core.campaign
.Campaign` can schedule whole Table-2 matrices concurrently over the
shared executor/compile cache, with checkpoint/resume for free.
``run_sensitivity`` remains as a thin blocking driver over the cursor.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.core.executor import SweepExecutor
from repro.core.params import (PARAM_DOCS, SENSITIVITY_SWEEP, TunableConfig)
from repro.core.tree import Candidate
from repro.core.trial import TrialResult, TrialRunner, Workload


@dataclasses.dataclass
class KnobImpact:
    knob: str
    spark_name: str
    values: List[Any]
    deviations_pct: List[float]        # one per tested value (nan = crash)
    crashes: int

    @property
    def mean_abs_pct(self) -> float:
        vals = [abs(d) for d in self.deviations_pct if d == d]
        return sum(vals) / len(vals) if vals else 0.0


@dataclasses.dataclass
class SensitivityReport:
    workload: str
    baseline_cost: float
    impacts: List[KnobImpact]
    n_trials: int

    def table(self) -> List[Dict]:
        return [{"knob": i.knob, "spark": i.spark_name,
                 "mean_abs_pct": round(i.mean_abs_pct, 1),
                 "crashes": i.crashes} for i in self.impacts]


class SensitivityCursor:
    """The Table-2 OFAT matrix as a :class:`SearchCursor` strategy.

    Two batches: the baseline, then every (knob, non-default value)
    candidate at once — the candidates are mutually independent, so one
    proposal exposes maximal parallelism to the campaign's shared
    executor.  The trial log, run count and KnobImpact table are
    identical to the historical blocking ``run_sensitivity`` loop.
    """

    strategy_version = 1

    def __init__(self, runner: TrialRunner, baseline: TunableConfig,
                 knobs: Optional[Dict[str, tuple]] = None):
        self.runner = runner
        self.baseline = baseline
        self.knobs = dict(knobs) if knobs is not None \
            else dict(SENSITIVITY_SWEEP)
        self.baseline_cost = float("nan")
        self.impacts: List[KnobImpact] = []
        self._spans: List[tuple] = []    # (knob, tested values)
        self._phase = 0                  # 0: baseline, 1: sweep, 2: done
        self._pending: Optional[List[Candidate]] = None

    @property
    def done(self) -> bool:
        return self._phase >= 2

    def propose(self) -> List[Candidate]:
        if self._pending is not None:
            raise RuntimeError("previous batch not absorbed yet")
        if self._phase == 0:
            self._pending = [Candidate(self.baseline, "baseline", {})]
        elif self._phase == 1:
            cands = []
            self._spans = []
            for knob, values in self.knobs.items():
                default = getattr(self.baseline, knob)
                tested = [v for v in values if v != default]
                self._spans.append((knob, tested))
                cands.extend(
                    Candidate(self.baseline.replace(**{knob: v}),
                              f"ofat:{knob}", {knob: v})
                    for v in tested)
            self._pending = cands
        else:
            return []
        return list(self._pending)

    def absorb(self, results: Sequence[TrialResult],
               indices: Sequence[int]) -> None:
        if self._pending is None:
            raise RuntimeError("no batch proposed")
        if len(results) != len(self._pending) \
                or len(indices) != len(self._pending):
            raise ValueError("results/indices do not match proposed batch")
        self._pending = None
        if self._phase == 0:
            self.baseline_cost = results[0].cost_s
            self._phase = 1
            return
        it = iter(zip(results, indices))
        base_cost = self.baseline_cost
        for knob, tested in self._spans:
            devs, crashes = [], 0
            for _ in tested:
                res, idx = next(it)
                if res.crashed:
                    crashes += 1
                    devs.append(float("nan"))
                    self.runner.log[idx].note = "crashed"
                else:
                    devs.append(100.0 * (res.cost_s - base_cost)
                                / base_cost)
            self.impacts.append(KnobImpact(knob, PARAM_DOCS.get(knob, ""),
                                           tested, devs, crashes))
        self._phase = 2

    def report(self) -> SensitivityReport:
        return SensitivityReport(self.runner.workload.key(),
                                 self.baseline_cost, self.impacts,
                                 self.runner.n_trials)

    def expected_gain(self) -> Optional[float]:
        """The OFAT matrix is a fixed design: the whole sweep is one
        batch, so the gain estimate is all-or-nothing — unknown before
        the baseline, the full sweep while it is pending, zero after."""
        if self._phase >= 2:
            return 0.0
        if self._phase == 0:
            return None
        return 1.0

    def signature_parts(self) -> list:
        return [[k, list(v)] for k, v in self.knobs.items()]

    def warm_start(self, configs: Sequence[TunableConfig]) -> None:
        """No-op: the OFAT matrix is a fixed design — every (knob,
        value) deviation from the baseline is measured regardless of
        what other cells learned."""


def run_sensitivity(runner: TrialRunner, baseline: TunableConfig,
                    knobs: Optional[Dict[str, tuple]] = None,
                    executor: Optional[SweepExecutor] = None
                    ) -> SensitivityReport:
    """OFAT sweep.  With an ``executor`` the (mutually independent)
    candidate evaluations overlap; the report, trial log and run count
    are identical to the sequential path.  This is a thin blocking
    driver over :class:`SensitivityCursor`."""
    from repro.core.strategy import drive       # import cycle: call-time
    return drive(SensitivityCursor(runner, baseline, knobs=knobs),
                 executor)
