"""Roofline cost model: compiled HLO -> {compute, memory, collective} seconds.

This is the trial evaluator on CPU-only infrastructure (DESIGN.md §2.2):
the paper measures wall-clock medians; we derive the three roofline terms
of the *compiled* step on the production mesh from
``compiled.cost_analysis()`` (FLOPs, HBM bytes) and the collective ops
parsed out of the partitioned HLO text.

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16 (98.5 f32),
819 GB/s HBM, ~50 GB/s/link ICI per mesh axis, 25 GB/s DCN (pod axis).

NOTE on normalization: XLA's post-SPMD ``cost_analysis()`` reports the
per-partition program, so FLOPs/bytes are *per chip*; the roofline terms
divide by per-chip peaks directly.  (Empirically verified in
tests/test_costmodel.py.)
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

HW = {
    "flops_bf16": 197e12,
    "flops_f32": 98.5e12,
    "hbm_bw": 819e9,
    "ici_bw": 50e9,
    "dcn_bw": 25e9,
    "hbm_per_chip": 16e9,          # v5e 16 GB
    "ici_latency": 1e-6,           # per collective op fixed cost (s)
}

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[^\]]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.I)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{([^}]*)\}")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    bytes_out: int       # per-partition output bytes
    group_size: int
    dtype: str = ""


@dataclasses.dataclass
class CollectiveStats:
    ops: List[CollectiveOp]

    def summary(self) -> Dict[str, Dict[str, float]]:
        out: Dict[str, Dict[str, float]] = {}
        for op in self.ops:
            d = out.setdefault(op.kind, {"count": 0, "bytes": 0.0})
            d["count"] += 1
            d["bytes"] += op.bytes_out
        return out

    def total_bytes(self) -> float:
        return sum(op.bytes_out for op in self.ops)


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Parse per-partition collective ops out of (S)PMD-partitioned HLO."""
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2).lower()
        nbytes = _shape_bytes(shape_str)
        dts = _SHAPE_RE.findall(shape_str)
        dtype = dts[0][0] if dts else ""
        gs = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            gs = int(gm.group(2))
        else:
            gl = _GROUPS_LIST_RE.search(line)
            if gl:
                first = gl.group(1).split("}")[0].split("{")[-1]
                gs = max(1, len([x for x in first.split(",") if x.strip()]))
        ops.append(CollectiveOp(kind, nbytes, gs, dtype))
    return CollectiveStats(ops)


def collective_seconds(stats: CollectiveStats, pod_size: int = 256,
                       ici_bw: float = None, dcn_bw: float = None,
                       compute_dtype: str = "float32") -> float:
    """Ring-model time: per op, (g-1)/g x bytes / bw (x2 for all-reduce).

    Groups larger than a pod (or equal to the pod count on a multi-pod
    mesh, i.e. size<=4 here) crossing DCN use the DCN bandwidth.

    XLA-CPU's AllReducePromotion pass rewrites every small-dtype
    reduction to f32 (bf16 reductions crash the backend otherwise), so
    under bf16 compute the parsed f32 reduction payloads are halved back
    to the dtype a TPU would put on the wire (documented §7)."""
    ici = ici_bw or HW["ici_bw"]
    dcn = dcn_bw or HW["dcn_bw"]
    promoted = compute_dtype != "float32"
    t = 0.0
    for op in stats.ops:
        g = max(op.group_size, 1)
        if g == 1:
            continue
        nbytes = op.bytes_out
        if (promoted and op.dtype == "f32"
                and op.kind in ("all-reduce", "reduce-scatter")):
            nbytes *= 0.5
        bw = dcn if (g <= 4 or g > pod_size) else ici
        ring = (g - 1) / g
        factor = 2.0 * ring if op.kind == "all-reduce" else ring
        if op.kind == "collective-permute":
            factor = 1.0
        t += factor * nbytes / bw + HW["ici_latency"]
    return t


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_chip: float
    bytes_per_chip: float
    collective_bytes: float
    coll_summary: Dict[str, Dict[str, float]]
    peak_mem_bytes: Optional[float] = None

    @property
    def total_s(self) -> float:
        # terms overlap on real hardware; the roofline step time is the max
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def bottleneck(self) -> str:
        vals = {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}
        return max(vals, key=vals.get)

    def as_dict(self) -> Dict:
        return {
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "total_s": self.total_s,
            "bottleneck": self.bottleneck,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "collective_bytes": self.collective_bytes,
            "coll_summary": self.coll_summary,
            "peak_mem_bytes": self.peak_mem_bytes,
        }


def cost_analysis_dict(compiled) -> Dict:
    """compiled.cost_analysis() as one flat dict across JAX versions
    (older releases return a singleton list of dicts)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):      # older JAX: one dict per program
        ca = ca[0] if ca else {}
    return ca


def roofline_from_dict(d: Dict) -> "Roofline":
    """Inverse of Roofline.as_dict (drops the derived total/bottleneck);
    used by the compile cache to rehydrate memoized measurements."""
    fields = {f.name for f in dataclasses.fields(Roofline)}
    return Roofline(**{k: v for k, v in d.items() if k in fields})


def analyze(compiled, compute_dtype: str = "bfloat16",
            pod_size: int = 256, flash_attention_correction: float = 0.0
            ) -> Roofline:
    """Roofline terms from a compiled executable (per-chip program)."""
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    peak = HW["flops_bf16"] if compute_dtype != "float32" else HW["flops_f32"]
    try:
        txt = compiled.as_text()
    except Exception:
        txt = ""
    stats = parse_collectives(txt)
    mem = None
    try:
        ma = compiled.memory_analysis()
        # peak = live arguments + temporaries (donated outputs alias args)
        mem = (getattr(ma, "argument_size_in_bytes", 0)
               + getattr(ma, "temp_size_in_bytes", 0))
    except Exception:
        pass
    mem_bytes = max(0.0, byts - flash_attention_correction)
    return Roofline(
        compute_s=flops / peak,
        memory_s=mem_bytes / HW["hbm_bw"],
        collective_s=collective_seconds(stats, pod_size=pod_size,
                                        compute_dtype=compute_dtype),
        flops_per_chip=flops,
        bytes_per_chip=byts,
        collective_bytes=stats.total_bytes(),
        coll_summary=stats.summary(),
        peak_mem_bytes=mem,
    )


# ----------------------------------------------------- flash correction
def attention_applications(cfg, shape):
    """[(count, S_q, S_kv)] softmax-attention applications per step."""
    S = shape.seq_len
    if shape.kind == "decode":
        return []                       # one-token scores are negligible
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return [(cfg.n_layers, S, S)]
    if fam == "hybrid":
        return [(cfg.n_layers // cfg.attn_every, S, S)]
    if fam == "ssm":
        return []
    if fam == "encdec":
        S_enc = S // cfg.enc_seq_ratio
        return [(cfg.enc_layers, S_enc, S_enc),   # encoder self
                (cfg.n_layers, S, S),             # decoder self
                (cfg.n_layers, S, S_enc)]         # cross
    raise ValueError(fam)


def attention_shards(cfg, rt, data_size: int, model_size: int) -> int:
    """How many ways the (B,H,Sq,Skv) attention tensors are sharded:
    batch over the data axes always; heads over the model axis only when
    divisible (otherwise replicated — the attn_tp_fallback situation)."""
    heads_sharded = (cfg.n_heads % max(1, model_size) == 0
                     or rt.attn_tp_fallback == "batch_shard")
    return data_size * (model_size if heads_sharded else 1)


def flash_refetch_bytes(cfg, shape, rt, data_size: int,
                        model_size: int) -> float:
    """Per-chip HBM bytes the flash kernel itself moves for the S x S
    part: K/V tiles re-fetched once per Q-tile (file.buffer knob)."""
    if rt.attn_impl != "pallas":
        return 0.0
    B, H, hd = shape.global_batch, cfg.n_heads, cfg.hd
    shards = attention_shards(cfg, rt, data_size, model_size)
    kvb = 2 if rt.compute_dtype != "float32" else 4
    mult = 3.0 if shape.kind == "train" else 1.0   # fwd + 2 bwd passes
    total = 0.0
    for count, sq, skv in attention_applications(cfg, shape):
        n_qtiles = max(1, sq // max(1, rt.attn_block_q))
        total += count * mult * n_qtiles * 2.0 * B * H * skv * hd * kvb
    return total / shards


def flash_memory_correction_bytes(cfg, shape, rt, data_size: int,
                                  model_size: int) -> float:
    """Per-chip HBM bytes REMOVED from the memory term when the Pallas
    flash kernel replaces the XLA reference attention (DESIGN.md §7.3).

    XLA materializes the (B,H,Sq,Skv) f32 score/softmax tensors in HBM
    (~4 round-trip passes for train incl. backward, 2 for prefill); the
    kernel keeps them in VMEM, at the cost of re-fetching the K/V tiles
    once per Q-tile (the spark.shuffle.file.buffer knob).  Reported as a
    separate correction, never silently folded into raw HLO numbers.
    """
    if rt.attn_impl != "pallas":
        return 0.0
    B, H, hd = shape.global_batch, cfg.n_heads, cfg.hd
    shards = attention_shards(cfg, rt, data_size, model_size)
    kvb = 2 if rt.compute_dtype != "float32" else 4
    passes = 4.0 if shape.kind == "train" else 2.0
    total = 0.0
    for count, sq, skv in attention_applications(cfg, shape):
        xla = passes * B * H * sq * skv * 4.0
        n_qtiles = max(1, sq // max(1, rt.attn_block_q))
        refetch = max(0, n_qtiles - 1) * 2.0 * B * H * skv * hd * kvb
        total += count * max(0.0, xla - refetch)
    return total / shards


def flash_peak_correction_bytes(cfg, shape, rt, data_size: int,
                                model_size: int) -> float:
    """Per-chip PEAK bytes removed by the flash kernel: the stored
    (B,H,Sq,Skv) softmax tensors (x2: pre-softmax scores + probabilities).
    With remat 'none'/'dots' (dots_saveable keeps dot outputs) every
    layer's scores are live for the backward; with 'full' (or
    forward-only steps) only ~2 transient layers are."""
    if rt.attn_impl != "pallas":
        return 0.0
    B, H = shape.global_batch, cfg.n_heads
    shards = attention_shards(cfg, rt, data_size, model_size)
    stored_all = shape.kind == "train" and rt.remat_policy in ("none",
                                                               "dots")
    # ~3 (B,H,Sq,Skv) f32 tensors live per layer on the XLA path (raw
    # scores, masked scores, softmax out — measured per-layer delta on
    # the scanned compile is ~2.7 of them)
    total = 0.0
    for count, sq, skv in attention_applications(cfg, shape):
        live = count if stored_all else min(count, 2)
        total += live * 3.0 * B * H * sq * skv * 4.0
    return total / shards


# ------------------------------------------------- analytic memory model
# XLA-CPU "bytes accessed" proved unreliable for HBM-traffic purposes
# (unfused elementwise chains count full round-trips per op and differ
# wildly by dtype; measured 2.2x inflation for bf16 vs f32 on identical
# math).  The memory term is therefore derived from first principles —
# params / activations / attention / vocab / optimizer / KV traffic —
# which is exactly dtype- and knob-sensitive.  FLOPs and collective bytes
# stay HLO-derived (reliable).  Constants documented inline.

_ACT_RT_FWD = 8.0      # residual-stream round-trips per layer, forward
_ACT_RT_BWD = 16.0     # backward ~2x forward
_WIDE_RT_FWD = 3.0     # d_ff-wide tensors per layer, forward
_WIDE_RT_BWD = 6.0


def _layer_width(cfg) -> float:
    """Effective 'wide' dim per layer (d_ff; experts: top_k x d_ff;
    ssm: expanded inner dim)."""
    if cfg.family == "moe":
        return float(cfg.top_k * cfg.d_ff)
    if cfg.family in ("hybrid",):
        return float(cfg.ssm_expand * cfg.d_model * 2)
    if cfg.family == "ssm":
        return float(cfg.n_heads * cfg.hd * 3)
    return float(cfg.d_ff)


def analytic_memory_bytes(cfg, shape, rt, data_size: int,
                          model_size: int) -> float:
    """Per-chip HBM bytes of one step (the roofline memory term)."""
    chips = data_size * model_size
    comp_b = 4 if rt.compute_dtype == "float32" else 2
    p_b = 4 if cfg.param_dtype == "float32" else 2
    train = shape.kind == "train"
    B, S = shape.global_batch, shape.seq_len
    tokens = B * (1 if shape.kind == "decode" else S)
    L = max(1, cfg.n_layers)

    # ---- parameters: read once per forward; backward reads them again;
    # remat 'full' recomputes the forward (one more read); each extra
    # microbatch re-reads them; cast read(p_b)+write(comp_b) if casting
    n_params = cfg.param_count()
    fwd_passes = 1.0 + (1.0 if train and rt.remat_policy == "full" else 0.0)
    passes = (fwd_passes + 1.0) if train else fwd_passes
    passes *= max(1, rt.microbatches if train else 1)
    param_traffic = n_params * (p_b + comp_b) * passes / chips
    if train:
        # optimizer: read grads+params+2 moments, write params+2 moments
        state_b = 4.0 * (7.0 if cfg.optimizer == "adamw" else 3.0)
        param_traffic += n_params * state_b / chips

    # ---- activations (residual stream replicated over model axis
    # unless seq_parallel; wide tensors sharded over model)
    d = cfg.d_model
    res_shards = data_size * (model_size if rt.seq_parallel else 1)
    act_rt = _ACT_RT_FWD + (_ACT_RT_BWD if train else 0.0) \
        + (_ACT_RT_FWD if train and rt.remat_policy == "full" else 0.0)
    act = L * tokens * d * comp_b * act_rt / res_shards
    wide_rt = _WIDE_RT_FWD + (_WIDE_RT_BWD if train else 0.0) \
        + (_WIDE_RT_FWD if train and rt.remat_policy == "full" else 0.0)
    act += L * tokens * _layer_width(cfg) * comp_b * wide_rt / chips
    # remat-saved residuals are written once and read once in backward,
    # in remat_save_dtype
    if train and rt.remat_policy != "none":
        save_b = 2 if rt.remat_save_dtype == "bfloat16" else comp_b
        act += 2.0 * L * tokens * d * save_b / res_shards

    # ---- attention S x S traffic
    attn = 0.0
    shards = attention_shards(cfg, rt, data_size, model_size)
    H, hd = cfg.n_heads, cfg.hd
    for count, sq, skv in attention_applications(cfg, shape):
        if rt.attn_impl == "pallas":
            n_qtiles = max(1, sq // max(1, rt.attn_block_q))
            mult = 3.0 if train else 1.0
            attn += (count * mult * n_qtiles * 2.0
                     * B * H * skv * hd * comp_b) / shards
        else:
            passes_sq = (4.0 if train else 2.0)
            attn += count * passes_sq * B * H * sq * skv * 4.0 / shards

    # ---- vocab: logits written f32 + softmax read + backward
    V = cfg.vocab
    lg_passes = 3.0 if train else 1.0
    vocab = tokens * V * 4.0 * lg_passes / chips

    # ---- decode KV cache: read the whole live cache at stored dtype
    kv = 0.0
    if shape.kind == "decode":
        kv_b = {"int8": 1, "bfloat16": 2, "float32": 4}[rt.kv_cache_dtype]
        if cfg.family in ("dense", "vlm", "moe"):
            n_kv_layers, state = cfg.n_layers, 0
        elif cfg.family == "hybrid":
            n_kv_layers = cfg.n_layers // cfg.attn_every
            d_in = cfg.ssm_expand * cfg.d_model
            state = (cfg.n_layers * B * (d_in // cfg.ssm_head_dim)
                     * cfg.ssm_head_dim * cfg.ssm_state * 4.0)
        elif cfg.family == "ssm":
            n_kv_layers = 0
            state = cfg.n_layers * B * H * hd * hd * 4.0
        else:  # encdec: self cache + fixed cross cache
            n_kv_layers, state = cfg.n_layers * 2, 0
        kv = (n_kv_layers * 2.0 * B * S * cfg.n_kv_heads * hd * kv_b
              + 2.0 * state) / chips
        # donate=False forces a copy of the updated cache
        if not rt.donate_buffers:
            kv *= 2.0

    return param_traffic + act + attn + vocab + kv


# ------------------------------------------------------------ calibration
# XLA's cost_analysis counts a `while` body ONCE regardless of trip count
# (verified: tests/test_costmodel_calibration.py), so roofline terms for
# scanned layer stacks are recovered by compiling two small UNROLLED
# variants (1 unit and 3 units of layers) and extrapolating linearly:
#     term(U) = outside + U * per_unit.
# The unit is one scan iteration of the outermost stack (a layer; for
# hybrid/ssm families a GROUP of attn_every/slstm_every layers).
# Known residual undercounts (documented in DESIGN.md §7): inner
# chunk/time scans (Mamba2 cross-chunk state, sLSTM recurrence) remain
# body-once within a unit; their per-unit share is <1% FLOPs.

def calibration_points(cfg):
    """[(small_cfg, units), (mid_cfg, units)], true_units for ``cfg``."""
    fam = cfg.family
    if fam in ("dense", "vlm", "moe"):
        return ([(cfg.replace(n_layers=1), 1),
                 (cfg.replace(n_layers=3), 3)], float(cfg.n_layers))
    if fam == "encdec":
        # enc and dec stacks both scale with the unit count
        return ([(cfg.replace(n_layers=1, enc_layers=1), 1),
                 (cfg.replace(n_layers=3, enc_layers=3), 3)],
                float(cfg.n_layers))
    if fam == "hybrid":
        ae = cfg.attn_every
        # unit = one group (ae mamba blocks + shared attn); the remainder
        # mamba blocks count as rem/ae of a group (attn share is small)
        return ([(cfg.replace(n_layers=ae), 1),
                 (cfg.replace(n_layers=3 * ae), 3)],
                cfg.n_layers / ae)
    if fam == "ssm":
        se = cfg.slstm_every
        return ([(cfg.replace(n_layers=se), 1),
                 (cfg.replace(n_layers=3 * se), 3)],
                cfg.n_layers / se)
    raise ValueError(fam)


def extrapolate(v1: float, v3: float, units: float) -> float:
    """outside + units*per_unit from measurements at 1 and 3 units."""
    per_unit = max(0.0, (v3 - v1) / 2.0)
    outside = max(0.0, v1 - per_unit)
    return outside + units * per_unit


def extrapolate_roofline(r1: "Roofline", r3: "Roofline", units: float
                         ) -> "Roofline":
    ex = lambda a, b: extrapolate(a, b, units)
    coll = {}
    for kind in set(r1.coll_summary) | set(r3.coll_summary):
        a = r1.coll_summary.get(kind, {"count": 0, "bytes": 0.0})
        b = r3.coll_summary.get(kind, {"count": 0, "bytes": 0.0})
        coll[kind] = {"count": ex(a["count"], b["count"]),
                      "bytes": ex(a["bytes"], b["bytes"])}
    return Roofline(
        compute_s=ex(r1.compute_s, r3.compute_s),
        memory_s=ex(r1.memory_s, r3.memory_s),
        collective_s=ex(r1.collective_s, r3.collective_s),
        flops_per_chip=ex(r1.flops_per_chip, r3.flops_per_chip),
        bytes_per_chip=ex(r1.bytes_per_chip, r3.bytes_per_chip),
        collective_bytes=ex(r1.collective_bytes, r3.collective_bytes),
        coll_summary=coll,
        peak_mem_bytes=(ex(r1.peak_mem_bytes, r3.peak_mem_bytes)
                        if r1.peak_mem_bytes and r3.peak_mem_bytes
                        else None),
    )


def model_flops(cfg, shape, per_token_factor: float = 6.0) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful model FLOPs for the cell.

    train: 6ND; prefill: 2ND (forward only); decode: 2N per token.
    encdec: encoder params see only the (seq/ratio) frame tokens."""
    factor = 6.0 if shape.kind == "train" else 2.0
    B = shape.global_batch
    tokens = B * (1 if shape.kind == "decode" else shape.seq_len)
    if cfg.family == "encdec":
        enc, dec, embed = cfg.encdec_split()
        enc_tokens = (B * (shape.seq_len // cfg.enc_seq_ratio)
                      if shape.kind != "decode" else 0)
        return factor * (enc * enc_tokens + (dec + embed) * tokens)
    return factor * cfg.active_param_count() * tokens
