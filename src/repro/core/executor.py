"""Parallel trial execution for sweeps — the trial-throughput engine.

The tuner's outer loops (sensitivity sweeps, tree stage alternatives,
hillclimb lookahead, case-study batches) evaluate *independent*
candidate configurations; the expensive part of each evaluation is an
XLA lower+compile that releases the GIL, so a thread pool overlaps them
well on CPU-only infrastructure.  :class:`SweepExecutor` adds:

  * **in-flight deduplication** — two submissions of the same
    (cell, config) share one evaluation (on top of the evaluator's own
    compile-level dedup in core/trial.CompileCache);
  * **order-preserving gather** — ``map()`` returns results in
    submission order, so callers log trials deterministically and
    :class:`~repro.core.trial.TrialRunner` accounting (the paper's
    <=10-runs budget) is byte-identical to the sequential path;
  * **speculative prefetch** — fire-and-forget cache warming for
    candidates a sequential driver will probably evaluate next
    (hillclimb lookahead); results land in the evaluator's caches, so
    a wrong guess costs only idle worker time, never correctness.

Evaluation faults surface as crashed TrialResults (cost = inf), exactly
like the sequential evaluator's behaviour — classified per the failure
taxonomy in core/trial.py.  Three hardening layers (all off by default;
fault-free accounting is bit-identical to the unhardened executor):

  * **deadlines** (``trial_timeout_s``) — an evaluation that exceeds
    the deadline is recorded as a ``timeout`` failure; its wedged
    thread is abandoned to a side pool of zombies (reaped opportunistically,
    never joined with a wait), so one hanging XLA compile cannot wedge
    the sweep;
  * **retry/backoff** (``max_retries``) — ``transient`` failures are
    re-evaluated with exponential backoff + deterministic jitter,
    inside the original submission (finished futures leave the
    in-flight table, so a fresh submit of a previously-crashed config
    never dedups onto the crashed Future);
  * **quarantine** (``quarantine=``, a core/quarantine.Quarantine) —
    each evaluation is bracketed by intent/completion ledger records,
    and configs quarantined fleet-wide are skipped outright, scored as
    deterministic crashes.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import telemetry as _telemetry
from repro.core.params import TunableConfig
from repro.core.trial import (FAILURE_TIMEOUT, FAILURE_WORKER_DEATH,
                              TrialResult, Workload, classify_exception)


def default_workers() -> int:
    """Worker count: REPRO_TRIAL_WORKERS env var, else min(8, cores-1),
    floored at 2 — compiles release the GIL, so even small boxes overlap
    one compile with one analytic recompute."""
    env = os.environ.get("REPRO_TRIAL_WORKERS")
    if env:
        return max(1, int(env))
    return max(2, min(8, (os.cpu_count() or 2) - 1))


def _trial_key(wl: Workload, rt: TunableConfig) -> Tuple:
    return (wl.key(), tuple(sorted(rt.as_dict().items())))


def _safe_eval(evaluator, wl: Workload, rt: TunableConfig) -> TrialResult:
    """Evaluator contract: never raise — a fault is a crashed trial."""
    try:
        return evaluator(wl, rt)
    except Exception as e:
        return TrialResult(cost_s=float("inf"), crashed=True,
                           error=f"{type(e).__name__}: {e}"[:500],
                           failure=classify_exception(e))


class SweepExecutor:
    """Evaluate independent (workload, config) candidates concurrently."""

    def __init__(self, evaluator: Callable[[Workload, TunableConfig],
                                           TrialResult],
                 max_workers: Optional[int] = None, *,
                 trial_timeout_s: Optional[float] = None,
                 max_retries: int = 0,
                 retry_backoff_s: float = 0.05,
                 quarantine=None,
                 telemetry=None):
        self.evaluator = evaluator
        self.max_workers = max_workers or default_workers()
        self.trial_timeout_s = trial_timeout_s
        self.max_retries = max_retries
        self.retry_backoff_s = retry_backoff_s
        self.quarantine = quarantine
        self.telemetry = (telemetry if telemetry is not None
                          else _telemetry.current())
        self._pool = ThreadPoolExecutor(
            max_workers=self.max_workers,
            thread_name_prefix="sweep")
        self._lock = threading.Lock()
        self._inflight: Dict[Tuple, Future] = {}
        self._zombies: List[threading.Thread] = []
        # Counters are read by stats() as one consistent snapshot and
        # mutated from pool threads; every increment MUST go through
        # _count() (or an explicit `with self._lock` block) — the
        # concurrent-stress test in tests/test_executor.py enforces
        # exact totals under contention.
        self.n_evals = 0            # distinct evaluations actually run
        self.n_submitted = 0        # submissions incl. deduplicated ones
        self.n_retries = 0          # transient re-evaluations paid for
        self.n_timeouts = 0         # evaluations abandoned at the deadline
        self.n_quarantined = 0      # candidates skipped as quarantined

    def _count(self, name: str, n: int = 1) -> None:
        """Thread-safe counter increment (pool threads race on these)."""
        with self._lock:
            setattr(self, name, getattr(self, name) + n)

    # ------------------------------------------------------------ core
    def submit(self, wl: Workload, rt: TunableConfig) -> Future:
        """Schedule one evaluation; identical in-flight candidates are
        coalesced onto the same future."""
        self._reap_zombies()
        key = _trial_key(wl, rt)
        with self._lock:
            self.n_submitted += 1
            fut = self._inflight.get(key)
            if fut is not None:
                return fut
            fut = self._pool.submit(self._run, key, wl, rt)
            self._inflight[key] = fut
            self.n_evals += 1
            return fut

    def _run(self, key: Tuple, wl: Workload, rt: TunableConfig
             ) -> TrialResult:
        try:
            return self._evaluate(wl, rt)
        finally:
            with self._lock:
                self._inflight.pop(key, None)

    def _evaluate(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        """One candidate, wrapped in a telemetry trial span.  The span
        observes the result after the fact — decisions are made by
        _evaluate_raw alone, so runs are bit-identical with telemetry
        on or off."""
        t = self.telemetry
        if not t.enabled:
            return self._evaluate_raw(wl, rt)
        from repro.core.quarantine import config_key
        with t.span("trial", cell=wl.key(), config=config_key(rt)) as sp:
            res = self._evaluate_raw(wl, rt)
            note = {"crashed": res.crashed, "retries": res.retries}
            if res.cost_s == res.cost_s and res.cost_s != float("inf"):
                note["cost_s"] = round(res.cost_s, 6)
            if res.failure:
                note["failure"] = res.failure
            if res.cached:
                note["cached"] = True
            if res.compile_s:
                note["compile_s"] = round(res.compile_s, 6)
            sp.note(**note)
            return res

    def _evaluate_raw(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        """One candidate through the full hardening stack: quarantine
        guard, then attempt + bounded transient retries."""
        t = self.telemetry
        q = self.quarantine
        if q is not None:
            from repro.core.quarantine import config_key
            ck = config_key(rt)
            if q.is_quarantined(ck):
                self._count("n_quarantined")
                if t.enabled:
                    t.emit("quarantine.skip", cell=wl.key(), config=ck,
                           strikes=q.effective_strikes(ck))
                return TrialResult(
                    cost_s=float("inf"), crashed=True,
                    failure=FAILURE_WORKER_DEATH,
                    error=f"quarantined: config {ck} reached "
                          f"{q.effective_strikes(ck)} strikes "
                          f"(threshold {q.strike_threshold}) — "
                          "skipped fleet-wide, scored as a crash")
        res = self._attempt(wl, rt)
        attempt = 0
        while res.retryable and attempt < self.max_retries:
            attempt += 1
            self._count("n_retries")
            backoff = self._backoff(wl, rt, attempt)
            if t.enabled:
                t.emit("retry", cell=wl.key(), attempt=attempt,
                       backoff_s=round(backoff, 4), failure=res.failure)
            time.sleep(backoff)
            res = self._attempt(wl, rt)
        res.retries = attempt
        return res

    def _backoff(self, wl: Workload, rt: TunableConfig,
                 attempt: int) -> float:
        """Exponential backoff with *deterministic* jitter (hash of the
        candidate + attempt, not random): workers desynchronize without
        making campaign wall-time depend on RNG state."""
        blob = f"{_trial_key(wl, rt)}:{attempt}".encode()
        jitter = int(hashlib.sha1(blob).hexdigest()[:4], 16) / 0xffff
        return self.retry_backoff_s * (2 ** (attempt - 1)) * (1 + jitter)

    def _attempt(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        """One evaluation bracketed by quarantine intent/completion; the
        deadline (if any) is enforced here.  An interrupt (BaseException,
        e.g. KeyboardInterrupt unwinding the pool) still writes the
        completion — only true process death leaves an orphaned intent."""
        q = self.quarantine
        token = q.begin(wl.key(), rt) if q is not None else None
        try:
            if self.trial_timeout_s is None:
                res = _safe_eval(self.evaluator, wl, rt)
            else:
                res = self._attempt_with_deadline(wl, rt)
        except BaseException:
            if token is not None:
                q.complete(token, crashed=True, note="interrupted")
            raise
        if token is not None:
            q.complete(token, crashed=res.crashed, note=res.failure)
            if res.failure == FAILURE_TIMEOUT:
                # a hang is as poisonous as a kill, just slower: strike
                # it so K timeouts fleet-wide quarantine the config
                q.strike(token["attempt"], token["key"], token["cell"],
                         reason="deadline exceeded")
        return res

    def _attempt_with_deadline(self, wl: Workload,
                               rt: TunableConfig) -> TrialResult:
        done = threading.Event()
        box: Dict[str, TrialResult] = {}

        def work():
            box["res"] = _safe_eval(self.evaluator, wl, rt)
            done.set()

        t = threading.Thread(target=work, daemon=True,
                             name="sweep-trial")
        t.start()
        if done.wait(self.trial_timeout_s):
            return box["res"]
        # the evaluation is wedged: abandon its thread to the zombie
        # side pool (reaped without waiting) so the sweep moves on
        with self._lock:
            self._zombies.append(t)
            self.n_timeouts += 1
        tel = self.telemetry
        if tel.enabled:
            tel.emit("timeout", cell=wl.key(),
                     deadline_s=self.trial_timeout_s)
        return TrialResult(
            cost_s=float("inf"), crashed=True, failure=FAILURE_TIMEOUT,
            error=f"trial exceeded deadline of {self.trial_timeout_s}s "
                  "(evaluation abandoned)")

    def _reap_zombies(self) -> None:
        """Drop abandoned trial threads that have since finished.  Never
        blocks: a still-wedged zombie just stays in the pool (it is a
        daemon thread, so it cannot outlive the process)."""
        with self._lock:
            self._zombies = [t for t in self._zombies if t.is_alive()]

    def map(self, wl: Workload, configs: Sequence[TunableConfig]
            ) -> List[TrialResult]:
        """Evaluate candidates concurrently; results in input order."""
        futs = [self.submit(wl, rt) for rt in configs]
        return [f.result() for f in futs]

    def prefetch(self, wl: Workload, configs: Iterable[TunableConfig]
                 ) -> None:
        """Fire-and-forget warm-up of the evaluator caches (speculative
        lookahead); never blocks, never raises."""
        for rt in configs:
            self.submit(wl, rt)

    # ------------------------------------------------------- lifecycle
    def shutdown(self, wait: bool = True,
                 cancel_futures: bool = False) -> None:
        self._pool.shutdown(wait=wait, cancel_futures=cancel_futures)
        self._reap_zombies()

    def __enter__(self) -> "SweepExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"submitted": self.n_submitted, "evals": self.n_evals,
                    "deduped": self.n_submitted - self.n_evals,
                    "workers": self.max_workers,
                    "retries": self.n_retries,
                    "timeouts": self.n_timeouts,
                    "quarantined": self.n_quarantined,
                    "zombies": len(self._zombies)}


def run_trials(runner, candidates: Sequence[Tuple[TunableConfig, str,
                                                  Optional[dict]]],
               executor: Optional[SweepExecutor] = None
               ) -> List[Tuple[int, TrialResult]]:
    """Evaluate a batch of candidates for a TrialRunner.

    With an executor the evaluations overlap; the runner's log gains one
    entry per candidate *in input order* either way.  Both paths apply
    the same fault conversion (an evaluator exception = crashed trial),
    so run counting, log layout and results are identical regardless of
    how the batch was scheduled.

    Returns ``(log_index, result)`` per candidate: the exact position of
    the candidate's entry in ``runner.log``, so callers annotate entries
    directly instead of re-finding them by config equality (two identical
    configs from different stages would cross-annotate).
    """
    if executor is None:
        out = []
        for rt, name, delta in candidates:
            res = _safe_eval(runner.evaluator, runner.workload, rt)
            runner.record(rt, name, res, delta)
            out.append((len(runner.log) - 1, res))
        return out
    if executor.evaluator is not runner.evaluator:
        raise ValueError("executor wraps a different evaluator than the "
                         "runner — results would bypass the runner's "
                         "evaluator")
    futs = [executor.submit(runner.workload, rt)
            for rt, name, delta in candidates]
    results = [f.result() for f in futs]
    out = []
    for (rt, name, delta), res in zip(candidates, results):
        runner.record(rt, name, res, delta)
        out.append((len(runner.log) - 1, res))
    return out
