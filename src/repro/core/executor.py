"""Parallel trial execution for sweeps — the trial-throughput engine.

The tuner's outer loops (sensitivity sweeps, tree stage alternatives,
hillclimb lookahead, case-study batches) evaluate *independent*
candidate configurations; the expensive part of each evaluation is an
XLA lower+compile that releases the GIL, so a thread pool overlaps them
well on CPU-only infrastructure.  :class:`SweepExecutor` adds:

  * **in-flight deduplication** — two submissions of the same
    (cell, config) share one evaluation (on top of the evaluator's own
    compile-level dedup in core/trial.CompileCache);
  * **order-preserving gather** — ``map()`` returns results in
    submission order, so callers log trials deterministically and
    :class:`~repro.core.trial.TrialRunner` accounting (the paper's
    <=10-runs budget) is byte-identical to the sequential path;
  * **speculative prefetch** — fire-and-forget cache warming for
    candidates a sequential driver will probably evaluate next
    (hillclimb lookahead); results land in the evaluator's caches, so
    a wrong guess costs only idle worker time, never correctness.

Evaluation faults surface as crashed TrialResults (cost = inf), exactly
like the sequential evaluator's behaviour.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.params import TunableConfig
from repro.core.trial import TrialResult, Workload


def default_workers() -> int:
    """Worker count: REPRO_TRIAL_WORKERS env var, else min(8, cores-1),
    floored at 2 — compiles release the GIL, so even small boxes overlap
    one compile with one analytic recompute."""
    env = os.environ.get("REPRO_TRIAL_WORKERS")
    if env:
        return max(1, int(env))
    return max(2, min(8, (os.cpu_count() or 2) - 1))


def _trial_key(wl: Workload, rt: TunableConfig) -> Tuple:
    return (wl.key(), tuple(sorted(rt.as_dict().items())))


def _safe_eval(evaluator, wl: Workload, rt: TunableConfig) -> TrialResult:
    """Evaluator contract: never raise — a fault is a crashed trial."""
    try:
        return evaluator(wl, rt)
    except Exception as e:
        return TrialResult(cost_s=float("inf"), crashed=True,
                           error=f"{type(e).__name__}: {e}"[:500])


class SweepExecutor:
    """Evaluate independent (workload, config) candidates concurrently."""

    def __init__(self, evaluator: Callable[[Workload, TunableConfig],
                                           TrialResult],
                 max_workers: Optional[int] = None):
        self.evaluator = evaluator
        self.max_workers = max_workers or default_workers()
        self._pool = ThreadPoolExecutor(
            max_workers=self.max_workers,
            thread_name_prefix="sweep")
        self._lock = threading.Lock()
        self._inflight: Dict[Tuple, Future] = {}
        self.n_evals = 0            # distinct evaluations actually run
        self.n_submitted = 0        # submissions incl. deduplicated ones

    # ------------------------------------------------------------ core
    def submit(self, wl: Workload, rt: TunableConfig) -> Future:
        """Schedule one evaluation; identical in-flight candidates are
        coalesced onto the same future."""
        key = _trial_key(wl, rt)
        with self._lock:
            self.n_submitted += 1
            fut = self._inflight.get(key)
            if fut is not None:
                return fut
            fut = self._pool.submit(self._run, key, wl, rt)
            self._inflight[key] = fut
            self.n_evals += 1
            return fut

    def _run(self, key: Tuple, wl: Workload, rt: TunableConfig
             ) -> TrialResult:
        try:
            return _safe_eval(self.evaluator, wl, rt)
        finally:
            with self._lock:
                self._inflight.pop(key, None)

    def map(self, wl: Workload, configs: Sequence[TunableConfig]
            ) -> List[TrialResult]:
        """Evaluate candidates concurrently; results in input order."""
        futs = [self.submit(wl, rt) for rt in configs]
        return [f.result() for f in futs]

    def prefetch(self, wl: Workload, configs: Iterable[TunableConfig]
                 ) -> None:
        """Fire-and-forget warm-up of the evaluator caches (speculative
        lookahead); never blocks, never raises."""
        for rt in configs:
            self.submit(wl, rt)

    # ------------------------------------------------------- lifecycle
    def shutdown(self, wait: bool = True,
                 cancel_futures: bool = False) -> None:
        self._pool.shutdown(wait=wait, cancel_futures=cancel_futures)

    def __enter__(self) -> "SweepExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"submitted": self.n_submitted, "evals": self.n_evals,
                    "deduped": self.n_submitted - self.n_evals,
                    "workers": self.max_workers}


def run_trials(runner, candidates: Sequence[Tuple[TunableConfig, str,
                                                  Optional[dict]]],
               executor: Optional[SweepExecutor] = None
               ) -> List[Tuple[int, TrialResult]]:
    """Evaluate a batch of candidates for a TrialRunner.

    With an executor the evaluations overlap; the runner's log gains one
    entry per candidate *in input order* either way.  Both paths apply
    the same fault conversion (an evaluator exception = crashed trial),
    so run counting, log layout and results are identical regardless of
    how the batch was scheduled.

    Returns ``(log_index, result)`` per candidate: the exact position of
    the candidate's entry in ``runner.log``, so callers annotate entries
    directly instead of re-finding them by config equality (two identical
    configs from different stages would cross-annotate).
    """
    if executor is None:
        out = []
        for rt, name, delta in candidates:
            res = _safe_eval(runner.evaluator, runner.workload, rt)
            runner.record(rt, name, res, delta)
            out.append((len(runner.log) - 1, res))
        return out
    if executor.evaluator is not runner.evaluator:
        raise ValueError("executor wraps a different evaluator than the "
                         "runner — results would bypass the runner's "
                         "evaluator")
    futs = [executor.submit(runner.workload, rt)
            for rt, name, delta in candidates]
    results = [f.result() for f in futs]
    out = []
    for (rt, name, delta), res in zip(candidates, results):
        runner.record(rt, name, res, delta)
        out.append((len(runner.log) - 1, res))
    return out
