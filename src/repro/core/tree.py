"""The paper's Fig. 4 — the trial-and-error tuning tree.

A tree stage tests one (or two *alternative*, correlated-pair) parameter
changes against the incumbent configuration.  A change is accepted iff it
improves the observed cost by more than ``threshold`` (relative, the
paper's 5-10%); accepted values propagate to every later stage.  At most
10 trial configurations are evaluated per application — against the
exhaustive grid of |domains| combinations (core/params.exhaustive_size()).

Stage map (Spark parameter -> TPU knob, DESIGN.md §2.1):
  1. serializer          -> compute_dtype=bf16
  2. shuffle.manager     -> shard_strategy alternatives, each with its
     documented companion (tungsten+lzf -> tp+f16 codec;
     hash+consolidateFiles -> fsdp+fused grad collectives)
  3. shuffle.compress    -> grad_comm_dtype=bf16          (train only)
  4. memoryFraction pair -> remat_policy dots / full alternatives
  5. spill.compress      -> remat_save_dtype=bf16
  6. reducer.maxSizeInFlight -> microbatches 2 / 4        (train only)
  7. rdd.compress        -> kv_cache_dtype=int8           (serving only)
  8. file.buffer         -> attn tile 256 (pallas path)
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence

from repro.core.executor import SweepExecutor
from repro.core.params import TunableConfig
from repro.core.space import SPACE
from repro.core.trial import TrialRunner, TrialResult, Workload


@dataclasses.dataclass
class Stage:
    name: str
    spark_name: str
    alternatives: Sequence[Dict[str, Any]]   # each alt: knob deltas
    kinds: Sequence[str] = ("train", "prefill", "decode")


def _stage(name: str, knob: str, alternatives: Sequence[Dict[str, Any]],
           kinds: Sequence[str] = ("train", "prefill", "decode")) -> Stage:
    """Build a stage whose spark_name comes from the knob registry and
    whose alternative deltas are validated against it — a stage can no
    longer reference a knob or value the space doesn't declare."""
    for alt in alternatives:
        SPACE.validate_delta(alt)
    return Stage(name, SPACE[knob].spark, alternatives, kinds)


def default_tree(kind: str = "train") -> List[Stage]:
    stages = [
        _stage("serializer", "compute_dtype",
               [dict(compute_dtype="bfloat16")]),
        _stage("shuffle.manager", "shard_strategy",
               [dict(shard_strategy="tp", comm_codec="float16"),
                dict(shard_strategy="fsdp", fuse_grad_collectives=True)]),
        _stage("shuffle.compress", "grad_comm_dtype",
               [dict(grad_comm_dtype="bfloat16")], kinds=("train",)),
        _stage("memoryFraction", "remat_policy",
               [dict(remat_policy="none"), dict(remat_policy="full")],
               kinds=("train",)),
        _stage("spill.compress", "remat_save_dtype",
               [dict(remat_save_dtype="bfloat16")], kinds=("train",)),
        _stage("maxSizeInFlight", "microbatches",
               [dict(microbatches=2)], kinds=("train",)),
        _stage("rdd.compress", "kv_cache_dtype",
               [dict(kv_cache_dtype="int8")], kinds=("prefill", "decode")),
        _stage("file.buffer", "attn_block_q",
               [dict(attn_block_q=256, attn_block_kv=256)]),
    ]
    return [s for s in stages if kind in s.kinds]


def short_tree(kind: str = "train") -> List[Stage]:
    """The paper's shorter variant: "a shorter version of our methodology
    with two required runs less, would omit it [file.buffer]"."""
    return [s for s in default_tree(kind) if s.name != "file.buffer"]


MAX_TRIALS = 10


@dataclasses.dataclass
class TuningReport:
    workload: str
    baseline_cost: float
    final_cost: float
    final_config: Dict[str, Any]
    n_trials: int
    accepted: List[str]
    log: List[Dict]
    #: measured-tier re-rank summary (core/measure.py), attached by the
    #: campaign when ``measure_top_k > 0``; None for model-only walks —
    #: and deliberately excluded from ``tuning_fingerprint``, so
    #: model-tier decisions stay bit-identical with or without it
    measured: Optional[Dict] = None
    #: learned-proposer fit summary + per-trial predicted-vs-actual
    #: rows (core/proposer.py); None for every other strategy *and*
    #: for the model strategy's cold-start fallback (whose report is
    #: the tree's, verbatim).  Excluded from ``tuning_fingerprint``
    #: like ``measured``.
    proposer: Optional[Dict] = None

    @property
    def speedup(self) -> float:
        if self.final_cost <= 0:
            return float("nan")
        return self.baseline_cost / self.final_cost


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One proposed trial: the config to evaluate plus its log labels."""
    config: TunableConfig
    name: str
    delta: Dict[str, Any]

    def as_trial(self) -> tuple:
        """The (config, name, delta) triple core/executor.run_trials takes."""
        return (self.config, self.name, self.delta)


def absorb_baseline(runner: TrialRunner, result: TrialResult,
                    index: int) -> float:
    """Record the baseline trial's outcome (shared by every
    TuningReport-shaped strategy): the log entry is marked accepted and
    the returned incumbent cost is inf for a crashed baseline, so any
    later viable candidate clears the relative threshold."""
    entry = runner.log[index]
    entry.accepted = True
    entry.note = "baseline (defaults after cluster-level config)"
    return result.cost_s if not result.crashed else float("inf")


def apply_accept_rule(runner: TrialRunner, batch, best_cost: float,
                      threshold: float):
    """The paper's accept/reject rule over one batch of alternatives
    (``batch``: (candidate, result, log index) triples).  Crashes are
    annotated (the paper's 0.1/0.7 sort-by-key outcome), the cheapest
    viable candidate wins iff it beats ``best_cost`` by more than
    ``threshold`` (any finite cost beats a crashed incumbent), and
    every other entry is rejected.  Returns the accepted
    (candidate, cost) or None.  Shared by the tree and random
    strategies so the rule can never silently diverge between them."""
    for _, res, idx in batch:
        if res.crashed:
            runner.log[idx].note = "crashed (exceeds per-chip HBM)"
            runner.log[idx].accepted = False
    viable = [(c, r, i) for c, r, i in batch if not r.crashed]
    accepted = None
    if viable:
        cand, res, idx = min(viable, key=lambda t: t[1].cost_s)
        improves = (best_cost == float("inf")
                    or res.cost_s < best_cost * (1.0 - threshold))
        runner.log[idx].accepted = bool(improves)
        if improves:
            accepted = (cand, res.cost_s)
        # non-winning alternatives are rejected
        for _, _, i in batch:
            if runner.log[i].accepted is None:
                runner.log[i].accepted = False
    return accepted


class TreeCursor:
    """Resumable state machine over the Fig.-4 tuning tree.

    The blocking tree walk is split into two halves so a scheduler can
    interleave many walks over one trial executor (core/campaign.py):

      * :meth:`propose` returns the next batch of trial candidates —
        first the baseline, then each stage's runnable alternatives —
        or ``[]`` once the walk is complete;
      * :meth:`absorb` takes the batch's results plus the log indices
        the runner recorded them at, applies the paper's accept/reject
        rule, annotates the log *by index* (no config-equality rescans)
        and advances to the next stage.

    Calls must alternate (every propose'd batch absorbed before the
    next propose).  The trial log, ≤10-run budget accounting and
    accept/reject decisions are identical to the historical blocking
    loop; ``run_tuning`` below is now a thin driver over this cursor.
    The cursor holds no results of its own beyond the incumbent/cost
    scalars, so a walk can be reconstructed (checkpoint resume) by
    replaying recorded trial results through propose/absorb.

    :meth:`warm_start` (the ``SearchCursor`` warm-start hook) seeds the
    walk with full candidate configurations — the best configs of the
    nearest already-tuned cells, retrieved from the trial history
    (core/history.py).  They are evaluated as one batch right after the
    baseline under the same accept rule as a tree stage; an adopted
    warm-start config moves the incumbent, so later stages whose
    alternative is already satisfied are skipped (that is where the
    trials-to-convergence saving comes from).  Warm-start trials count
    against the ≤10-run budget, and the seeded configs enter
    :meth:`signature_parts` so checkpointed walks replay bit-identically
    (a cold walk's signature is byte-identical to the pre-warm-start
    layout).

    This propose/absorb/done/report shape is the
    :class:`~repro.core.strategy.SearchCursor` protocol — the campaign
    engine drives any registered strategy through it (the ``tree`` and
    ``short`` strategies are this class).
    """

    strategy_version = 1

    def __init__(self, runner: TrialRunner, baseline: TunableConfig,
                 threshold: float = 0.05,
                 stages: Optional[List[Stage]] = None):
        self.runner = runner
        self.baseline = baseline
        self.threshold = threshold
        kind = runner.workload.shp.kind
        self.stages = stages if stages is not None else default_tree(kind)
        self.incumbent = baseline
        self.baseline_cost = float("nan")
        self.best_cost = float("nan")
        self.accepted: List[str] = []
        self._stage_i = -1          # -1: baseline not yet evaluated
        self._pending: Optional[List[Candidate]] = None
        self._done = False
        self._warmstart: List[TunableConfig] = []
        self._warmstart_absorbed = False
        self._in_warmstart = False

    @property
    def done(self) -> bool:
        return self._done

    def warm_start(self, configs: Sequence[TunableConfig]) -> None:
        """Seed the walk with candidate configs evaluated right after
        the baseline (see class docstring).  Must be called before the
        first proposal; calling again before then replaces the seeds
        (the campaign retries with a re-queried list when a
        checkpoint's stored list turns out stale)."""
        if self._stage_i >= 0 or self._pending is not None:
            raise RuntimeError("warm_start must precede the first "
                               "proposal")
        seen, out = set(), []
        base = json.dumps(self.baseline.as_dict(), sort_keys=True,
                          default=str)
        for cfg in configs:
            fp = json.dumps(cfg.as_dict(), sort_keys=True, default=str)
            if fp == base or fp in seen:
                continue                 # no-op / duplicate seed
            seen.add(fp)
            out.append(cfg)
        self._warmstart = out

    def _warmstart_batch(self) -> List[Candidate]:
        base = self.baseline.as_dict()
        cands = [Candidate(cfg, "warmstart",
                           {k: v for k, v in cfg.as_dict().items()
                            if base[k] != v})
                 for cfg in self._warmstart]
        return cands[:max(0, MAX_TRIALS - self.runner.n_trials)]

    def propose(self) -> List[Candidate]:
        """Next batch of candidates to evaluate; [] when the walk is done."""
        if self._pending is not None:
            raise RuntimeError("previous batch not absorbed yet")
        if self._done:
            return []
        if self._stage_i < 0:
            self._pending = [Candidate(self.baseline, "baseline", {})]
            return list(self._pending)
        if self._warmstart and not self._warmstart_absorbed:
            batch = self._warmstart_batch()
            if batch:
                self._in_warmstart = True
                self._pending = batch
                return list(self._pending)
            self._warmstart_absorbed = True      # budget already spent
        while True:
            if (self._stage_i >= len(self.stages)
                    or self.runner.n_trials >= MAX_TRIALS):
                self._done = True
                return []
            stage = self.stages[self._stage_i]
            # skip alternatives that are no-ops on the incumbent; the run
            # budget admits only as many candidates as trials remain
            runnable = [alt for alt in stage.alternatives
                        if not all(getattr(self.incumbent, k) == v
                                   for k, v in alt.items())]
            runnable = runnable[:MAX_TRIALS - self.runner.n_trials]
            if not runnable:
                self._stage_i += 1
                continue
            self._pending = [Candidate(self.incumbent.replace(**alt),
                                       stage.name, alt)
                             for alt in runnable]
            return list(self._pending)

    def absorb(self, results: Sequence[TrialResult],
               indices: Sequence[int]) -> None:
        """Apply one batch's outcomes (results aligned with the proposed
        candidates; ``indices`` = their positions in ``runner.log``)."""
        if self._pending is None:
            raise RuntimeError("no batch proposed")
        if len(results) != len(self._pending) \
                or len(indices) != len(self._pending):
            raise ValueError("results/indices do not match proposed batch")
        cands, self._pending = self._pending, None
        if self._stage_i < 0:
            self.best_cost = absorb_baseline(self.runner, results[0],
                                             indices[0])
            self.baseline_cost = self.best_cost
            self._stage_i = 0
            return
        if self._in_warmstart:
            self._in_warmstart = False
            self._warmstart_absorbed = True
            won = apply_accept_rule(self.runner,
                                    list(zip(cands, results, indices)),
                                    self.best_cost, self.threshold)
            if won is not None:
                cand, cost = won
                self.incumbent = cand.config
                self.best_cost = cost
                self.accepted.append(f"warmstart: {cand.delta}")
            return
        stage = self.stages[self._stage_i]
        won = apply_accept_rule(self.runner,
                                list(zip(cands, results, indices)),
                                self.best_cost, self.threshold)
        if won is not None:
            cand, cost = won
            self.incumbent = cand.config
            self.best_cost = cost
            self.accepted.append(f"{stage.name}: {cand.delta}")
        self._stage_i += 1

    def report(self) -> TuningReport:
        return TuningReport(
            workload=self.runner.workload.key(),
            baseline_cost=self.baseline_cost,
            final_cost=self.best_cost,
            final_config=self.incumbent.as_dict(),
            n_trials=self.runner.n_trials,
            accepted=self.accepted,
            log=[dataclasses.asdict(e) for e in self.runner.log],
        )

    def expected_gain(self) -> Optional[float]:
        """Live estimate for the online scheduler (core/schedule.py):
        the share of the tree still ahead of the walk — each remaining
        stage is one more chance to accept an improvement.  ``None``
        before the baseline is absorbed (nothing observed yet:
        explore-first), ``0.0`` once the walk is done."""
        if self._done:
            return 0.0
        if self._stage_i < 0:
            return None
        total = max(1, len(self.stages))
        return max(0.0, (total - self._stage_i) / total)

    def signature_parts(self) -> list:
        """JSON-serializable description of everything that shapes this
        walk's decisions — part of the campaign checkpoint signature.
        The layout is byte-compatible with the PR-2-era (v1) checkpoint
        signature blob, so pre-Strategy-API tree checkpoints resume; a
        warm-started walk appends its seed configs (so cold checkpoints
        are never replayed into a differently-seeded walk)."""
        parts = [[s.name, s.spark_name, list(s.alternatives),
                  list(s.kinds)] for s in self.stages]
        if self._warmstart:
            parts.append(["warmstart",
                          [cfg.as_dict() for cfg in self._warmstart]])
        return parts


def run_tuning(runner: TrialRunner, baseline: TunableConfig,
               threshold: float = 0.05,
               stages: Optional[List[Stage]] = None,
               executor: Optional[SweepExecutor] = None) -> TuningReport:
    """Walk the tree: evaluate alternatives, keep what clears the threshold.

    A stage's alternatives are independent of each other (all derived
    from the same incumbent), so with an ``executor`` they evaluate
    concurrently; the trial log, run budget and accept/reject decisions
    are identical to the sequential walk.  This is a thin blocking
    driver over :class:`TreeCursor`."""
    from repro.core.strategy import drive       # import cycle: call-time
    return drive(TreeCursor(runner, baseline, threshold=threshold,
                            stages=stages), executor)
