"""The paper's Fig. 4 — the trial-and-error tuning tree.

A tree stage tests one (or two *alternative*, correlated-pair) parameter
changes against the incumbent configuration.  A change is accepted iff it
improves the observed cost by more than ``threshold`` (relative, the
paper's 5-10%); accepted values propagate to every later stage.  At most
10 trial configurations are evaluated per application — against the
exhaustive grid of |domains| combinations (core/params.exhaustive_size()).

Stage map (Spark parameter -> TPU knob, DESIGN.md §2.1):
  1. serializer          -> compute_dtype=bf16
  2. shuffle.manager     -> shard_strategy alternatives, each with its
     documented companion (tungsten+lzf -> tp+f16 codec;
     hash+consolidateFiles -> fsdp+fused grad collectives)
  3. shuffle.compress    -> grad_comm_dtype=bf16          (train only)
  4. memoryFraction pair -> remat_policy dots / full alternatives
  5. spill.compress      -> remat_save_dtype=bf16
  6. reducer.maxSizeInFlight -> microbatches 2 / 4        (train only)
  7. rdd.compress        -> kv_cache_dtype=int8           (serving only)
  8. file.buffer         -> attn tile 256 (pallas path)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.core.executor import SweepExecutor, run_trials
from repro.core.params import TunableConfig
from repro.core.trial import TrialRunner, TrialResult, Workload


@dataclasses.dataclass
class Stage:
    name: str
    spark_name: str
    alternatives: Sequence[Dict[str, Any]]   # each alt: knob deltas
    kinds: Sequence[str] = ("train", "prefill", "decode")


def default_tree(kind: str = "train") -> List[Stage]:
    stages = [
        Stage("serializer", "spark.serializer",
              [dict(compute_dtype="bfloat16")]),
        Stage("shuffle.manager", "spark.shuffle.manager",
              [dict(shard_strategy="tp", comm_codec="float16"),
               dict(shard_strategy="fsdp", fuse_grad_collectives=True)]),
        Stage("shuffle.compress", "spark.shuffle.compress",
              [dict(grad_comm_dtype="bfloat16")], kinds=("train",)),
        Stage("memoryFraction", "spark.shuffle/storage.memoryFraction",
              [dict(remat_policy="none"), dict(remat_policy="full")],
              kinds=("train",)),
        Stage("spill.compress", "spark.shuffle.spill.compress",
              [dict(remat_save_dtype="bfloat16")], kinds=("train",)),
        Stage("maxSizeInFlight", "spark.reducer.maxSizeInFlight",
              [dict(microbatches=2)], kinds=("train",)),
        Stage("rdd.compress", "spark.rdd.compress",
              [dict(kv_cache_dtype="int8")], kinds=("prefill", "decode")),
        Stage("file.buffer", "spark.shuffle.file.buffer",
              [dict(attn_block_q=256, attn_block_kv=256)]),
    ]
    return [s for s in stages if kind in s.kinds]


def short_tree(kind: str = "train") -> List[Stage]:
    """The paper's shorter variant: "a shorter version of our methodology
    with two required runs less, would omit it [file.buffer]"."""
    return [s for s in default_tree(kind) if s.name != "file.buffer"]


MAX_TRIALS = 10


@dataclasses.dataclass
class TuningReport:
    workload: str
    baseline_cost: float
    final_cost: float
    final_config: Dict[str, Any]
    n_trials: int
    accepted: List[str]
    log: List[Dict]

    @property
    def speedup(self) -> float:
        if self.final_cost <= 0:
            return float("nan")
        return self.baseline_cost / self.final_cost


def run_tuning(runner: TrialRunner, baseline: TunableConfig,
               threshold: float = 0.05,
               stages: Optional[List[Stage]] = None,
               executor: Optional[SweepExecutor] = None) -> TuningReport:
    """Walk the tree: evaluate alternatives, keep what clears the threshold.

    A stage's alternatives are independent of each other (all derived
    from the same incumbent), so with an ``executor`` they evaluate
    concurrently; the trial log, run budget and accept/reject decisions
    are identical to the sequential walk."""
    kind = runner.workload.shp.kind
    stages = stages if stages is not None else default_tree(kind)
    incumbent = baseline
    base_res = runner.run(baseline, "baseline", {})
    runner.log[-1].accepted = True
    runner.log[-1].note = "baseline (defaults after cluster-level config)"
    best_cost = base_res.cost_s if not base_res.crashed else float("inf")
    baseline_cost = best_cost
    accepted: List[str] = []

    for stage in stages:
        if runner.n_trials >= MAX_TRIALS:
            break
        # skip alternatives that are no-ops on the incumbent; the run
        # budget admits only as many candidates as trials remain
        runnable = [alt for alt in stage.alternatives
                    if not all(getattr(incumbent, k) == v
                               for k, v in alt.items())]
        runnable = runnable[:MAX_TRIALS - runner.n_trials]
        cands = [(incumbent.replace(**alt), stage.name, alt)
                 for alt in runnable]
        results = run_trials(runner, cands, executor)
        cand_results = [(alt, cand, res) for (cand, _, alt), res
                        in zip(cands, results)]
        if not cand_results:
            continue
        viable = [(a, c, r) for a, c, r in cand_results if not r.crashed]
        for a, c, r in cand_results:
            # annotate crashes (the paper's 0.1/0.7 sort-by-key outcome)
            if r.crashed:
                idx = [e for e in runner.log if e.config == c.as_dict()]
                if idx:
                    idx[-1].note = "crashed (exceeds per-chip HBM)"
                    idx[-1].accepted = False
        if not viable:
            continue
        alt, cand, res = min(viable, key=lambda t: t[2].cost_s)
        improves = (best_cost == float("inf")
                    or res.cost_s < best_cost * (1.0 - threshold))
        for e in runner.log:
            if e.accepted is None and e.config == cand.as_dict():
                e.accepted = bool(improves)
        if improves:
            incumbent = cand
            best_cost = res.cost_s
            accepted.append(f"{stage.name}: {alt}")
        # non-winning alternatives are rejected
        for e in runner.log:
            if e.accepted is None:
                e.accepted = False

    return TuningReport(
        workload=runner.workload.key(),
        baseline_cost=baseline_cost,
        final_cost=best_cost,
        final_config=incumbent.as_dict(),
        n_trials=runner.n_trials,
        accepted=accepted,
        log=[dataclasses.asdict(e) for e in runner.log],
    )
