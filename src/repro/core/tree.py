"""The paper's Fig. 4 — the trial-and-error tuning tree.

A tree stage tests one (or two *alternative*, correlated-pair) parameter
changes against the incumbent configuration.  A change is accepted iff it
improves the observed cost by more than ``threshold`` (relative, the
paper's 5-10%); accepted values propagate to every later stage.  At most
10 trial configurations are evaluated per application — against the
exhaustive grid of |domains| combinations (core/params.exhaustive_size()).

Stage map (Spark parameter -> TPU knob, DESIGN.md §2.1):
  1. serializer          -> compute_dtype=bf16
  2. shuffle.manager     -> shard_strategy alternatives, each with its
     documented companion (tungsten+lzf -> tp+f16 codec;
     hash+consolidateFiles -> fsdp+fused grad collectives)
  3. shuffle.compress    -> grad_comm_dtype=bf16          (train only)
  4. memoryFraction pair -> remat_policy dots / full alternatives
  5. spill.compress      -> remat_save_dtype=bf16
  6. reducer.maxSizeInFlight -> microbatches 2 / 4        (train only)
  7. rdd.compress        -> kv_cache_dtype=int8           (serving only)
  8. file.buffer         -> attn tile 256 (pallas path)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

from repro.core.executor import SweepExecutor, run_trials
from repro.core.params import TunableConfig
from repro.core.trial import TrialRunner, TrialResult, Workload


@dataclasses.dataclass
class Stage:
    name: str
    spark_name: str
    alternatives: Sequence[Dict[str, Any]]   # each alt: knob deltas
    kinds: Sequence[str] = ("train", "prefill", "decode")


def default_tree(kind: str = "train") -> List[Stage]:
    stages = [
        Stage("serializer", "spark.serializer",
              [dict(compute_dtype="bfloat16")]),
        Stage("shuffle.manager", "spark.shuffle.manager",
              [dict(shard_strategy="tp", comm_codec="float16"),
               dict(shard_strategy="fsdp", fuse_grad_collectives=True)]),
        Stage("shuffle.compress", "spark.shuffle.compress",
              [dict(grad_comm_dtype="bfloat16")], kinds=("train",)),
        Stage("memoryFraction", "spark.shuffle/storage.memoryFraction",
              [dict(remat_policy="none"), dict(remat_policy="full")],
              kinds=("train",)),
        Stage("spill.compress", "spark.shuffle.spill.compress",
              [dict(remat_save_dtype="bfloat16")], kinds=("train",)),
        Stage("maxSizeInFlight", "spark.reducer.maxSizeInFlight",
              [dict(microbatches=2)], kinds=("train",)),
        Stage("rdd.compress", "spark.rdd.compress",
              [dict(kv_cache_dtype="int8")], kinds=("prefill", "decode")),
        Stage("file.buffer", "spark.shuffle.file.buffer",
              [dict(attn_block_q=256, attn_block_kv=256)]),
    ]
    return [s for s in stages if kind in s.kinds]


def short_tree(kind: str = "train") -> List[Stage]:
    """The paper's shorter variant: "a shorter version of our methodology
    with two required runs less, would omit it [file.buffer]"."""
    return [s for s in default_tree(kind) if s.name != "file.buffer"]


MAX_TRIALS = 10


@dataclasses.dataclass
class TuningReport:
    workload: str
    baseline_cost: float
    final_cost: float
    final_config: Dict[str, Any]
    n_trials: int
    accepted: List[str]
    log: List[Dict]

    @property
    def speedup(self) -> float:
        if self.final_cost <= 0:
            return float("nan")
        return self.baseline_cost / self.final_cost


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One proposed trial: the config to evaluate plus its log labels."""
    config: TunableConfig
    name: str
    delta: Dict[str, Any]

    def as_trial(self) -> tuple:
        """The (config, name, delta) triple core/executor.run_trials takes."""
        return (self.config, self.name, self.delta)


class TreeCursor:
    """Resumable state machine over the Fig.-4 tuning tree.

    The blocking tree walk is split into two halves so a scheduler can
    interleave many walks over one trial executor (core/campaign.py):

      * :meth:`propose` returns the next batch of trial candidates —
        first the baseline, then each stage's runnable alternatives —
        or ``[]`` once the walk is complete;
      * :meth:`absorb` takes the batch's results plus the log indices
        the runner recorded them at, applies the paper's accept/reject
        rule, annotates the log *by index* (no config-equality rescans)
        and advances to the next stage.

    Calls must alternate (every propose'd batch absorbed before the
    next propose).  The trial log, ≤10-run budget accounting and
    accept/reject decisions are identical to the historical blocking
    loop; ``run_tuning`` below is now a thin driver over this cursor.
    The cursor holds no results of its own beyond the incumbent/cost
    scalars, so a walk can be reconstructed (checkpoint resume) by
    replaying recorded trial results through propose/absorb.
    """

    def __init__(self, runner: TrialRunner, baseline: TunableConfig,
                 threshold: float = 0.05,
                 stages: Optional[List[Stage]] = None):
        self.runner = runner
        self.baseline = baseline
        self.threshold = threshold
        kind = runner.workload.shp.kind
        self.stages = stages if stages is not None else default_tree(kind)
        self.incumbent = baseline
        self.baseline_cost = float("nan")
        self.best_cost = float("nan")
        self.accepted: List[str] = []
        self._stage_i = -1          # -1: baseline not yet evaluated
        self._pending: Optional[List[Candidate]] = None
        self._done = False

    @property
    def done(self) -> bool:
        return self._done

    def propose(self) -> List[Candidate]:
        """Next batch of candidates to evaluate; [] when the walk is done."""
        if self._pending is not None:
            raise RuntimeError("previous batch not absorbed yet")
        if self._done:
            return []
        if self._stage_i < 0:
            self._pending = [Candidate(self.baseline, "baseline", {})]
            return list(self._pending)
        while True:
            if (self._stage_i >= len(self.stages)
                    or self.runner.n_trials >= MAX_TRIALS):
                self._done = True
                return []
            stage = self.stages[self._stage_i]
            # skip alternatives that are no-ops on the incumbent; the run
            # budget admits only as many candidates as trials remain
            runnable = [alt for alt in stage.alternatives
                        if not all(getattr(self.incumbent, k) == v
                                   for k, v in alt.items())]
            runnable = runnable[:MAX_TRIALS - self.runner.n_trials]
            if not runnable:
                self._stage_i += 1
                continue
            self._pending = [Candidate(self.incumbent.replace(**alt),
                                       stage.name, alt)
                             for alt in runnable]
            return list(self._pending)

    def absorb(self, results: Sequence[TrialResult],
               indices: Sequence[int]) -> None:
        """Apply one batch's outcomes (results aligned with the proposed
        candidates; ``indices`` = their positions in ``runner.log``)."""
        if self._pending is None:
            raise RuntimeError("no batch proposed")
        if len(results) != len(self._pending) \
                or len(indices) != len(self._pending):
            raise ValueError("results/indices do not match proposed batch")
        cands, self._pending = self._pending, None
        if self._stage_i < 0:
            base_res = results[0]
            entry = self.runner.log[indices[0]]
            entry.accepted = True
            entry.note = "baseline (defaults after cluster-level config)"
            self.best_cost = base_res.cost_s if not base_res.crashed \
                else float("inf")
            self.baseline_cost = self.best_cost
            self._stage_i = 0
            return
        stage = self.stages[self._stage_i]
        batch = list(zip(cands, results, indices))
        for _, res, idx in batch:
            # annotate crashes (the paper's 0.1/0.7 sort-by-key outcome)
            if res.crashed:
                self.runner.log[idx].note = "crashed (exceeds per-chip HBM)"
                self.runner.log[idx].accepted = False
        viable = [(c, r, i) for c, r, i in batch if not r.crashed]
        if viable:
            cand, res, idx = min(viable, key=lambda t: t[1].cost_s)
            improves = (self.best_cost == float("inf")
                        or res.cost_s < self.best_cost
                        * (1.0 - self.threshold))
            self.runner.log[idx].accepted = bool(improves)
            if improves:
                self.incumbent = cand.config
                self.best_cost = res.cost_s
                self.accepted.append(f"{stage.name}: {cand.delta}")
            # non-winning alternatives are rejected
            for _, _, i in batch:
                if self.runner.log[i].accepted is None:
                    self.runner.log[i].accepted = False
        self._stage_i += 1

    def report(self) -> TuningReport:
        return TuningReport(
            workload=self.runner.workload.key(),
            baseline_cost=self.baseline_cost,
            final_cost=self.best_cost,
            final_config=self.incumbent.as_dict(),
            n_trials=self.runner.n_trials,
            accepted=self.accepted,
            log=[dataclasses.asdict(e) for e in self.runner.log],
        )


def run_tuning(runner: TrialRunner, baseline: TunableConfig,
               threshold: float = 0.05,
               stages: Optional[List[Stage]] = None,
               executor: Optional[SweepExecutor] = None) -> TuningReport:
    """Walk the tree: evaluate alternatives, keep what clears the threshold.

    A stage's alternatives are independent of each other (all derived
    from the same incumbent), so with an ``executor`` they evaluate
    concurrently; the trial log, run budget and accept/reject decisions
    are identical to the sequential walk.  This is a thin blocking
    driver over :class:`TreeCursor`."""
    cursor = TreeCursor(runner, baseline, threshold=threshold, stages=stages)
    while True:
        batch = cursor.propose()
        if not batch:
            break
        pairs = run_trials(runner, [c.as_trial() for c in batch], executor)
        cursor.absorb([r for _, r in pairs], [i for i, _ in pairs])
    return cursor.report()
