"""Learned cost-model proposer — the ``model`` search strategy.

The paper's premise is that a near-optimal config can be found from a
*very small number of experimental runs*; after the compile cache and
the campaign fabric, the residual cost of a campaign is the number of
trials the cursor evaluates before it lands on the winner.  The trial
history (core/history.py) now holds every evaluated trial across
campaigns — enough signal for a lightweight learned cost model in the
spirit of learning-based tuners (1808.06008) and retrieval-augmented
config tuning (2503.03826): fit on the past, propose the predicted
winners, spend live trials confirming instead of exploring.

:class:`ModelCursor` is that model as a first-class
:class:`~repro.core.strategy.SearchCursor`:

  * **fit** — a pure-numpy ridge regression of log-cost over the
    fixed feature layout of :func:`repro.core.history.featurize`
    (knob one-hots, active-knob indicators, hashed arch/family
    buckets), trained on the *same-shape-kind* viable records of the
    history (gains do not transfer across kinds — the same rule the
    scheduler's expected-speedup uses).  Log-cost makes the surface's
    multiplicative knob effects additive, exactly what a linear model
    can represent;
  * **propose** — each round proposes the top-k predicted configs
    over the *observed support* of the cell's active knobs (values
    with no fit row are exploration, which stays the tree's job);
    because the fit is additive over one-hots its global argmin is
    the per-knob argmin, so large grids need only the argmin plus the
    best single-knob swaps while small grids are scored exhaustively;
    already-evaluated configs are skipped, within the same
    ≤ ``budget`` trials as the tree;
  * **absorb** — live results are appended to the fit rows (crashes
    imputed a worse-than-anything-observed cost, so the model steers
    away from them) and the model refit before the next round (online
    refinement), under the shared
    :func:`~repro.core.tree.apply_accept_rule`;
  * **cold start** — with fewer than ``min_records`` usable same-kind
    records the cursor *delegates every decision* to an embedded
    :class:`~repro.core.tree.TreeCursor`, so a thin-history campaign
    is bit-identical to ``--strategy tree`` (regression-tested);
  * **checkpointable fit state** — the campaign primes the cursor via
    :meth:`build_primer`/:meth:`prime` with a tiny state blob (the raw
    record count and a digest of the rows actually fit) persisted in
    the cell checkpoint.  Because the history is append-only, re-fitting
    on the stored record *prefix* reproduces the original fit exactly,
    so a killed campaign resumes replay-exact even after the history
    has grown underneath it.  A digest mismatch (rewritten history)
    raises, and the campaign falls back to a fresh fit + fresh walk.

Everything is deterministic: same history bytes + same seed ⇒ same
fit ⇒ same proposals, in any process (no wall-clock, no unseeded RNG —
ties break on the canonical config JSON).
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import json
import math
import pathlib
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import telemetry as _telemetry
from repro.core.history import (FEATURES_VERSION, TrialHistory, _viable,
                                cell_signature, config_from_dict,
                                feature_names, featurize)
from repro.core.params import TunableConfig
from repro.core.space import SPACE
from repro.core.tree import (MAX_TRIALS, Candidate, Stage, TreeCursor,
                             TuningReport, absorb_baseline,
                             apply_accept_rule)
from repro.core.trial import TrialResult, TrialRunner

MODEL_VERSION = 1

#: cold-start rule: fewer usable same-kind history rows than this and
#: the cursor delegates to the tree walk.  Roughly two finished
#: same-kind walks plus change — below that a 60+-feature ridge fit is
#: noise dressed as knowledge.
MIN_RECORDS = 24
RIDGE_LAMBDA = 1e-2
TOP_K = 3
#: active-knob grids up to this size are scored exhaustively; larger
#: spaces use the additive argmin + single-swap frontier instead.
POOL_SIZE = 256


def _fp(d: Dict[str, Any]) -> str:
    return json.dumps(d, sort_keys=True, default=str)


def fit_rows(history: Optional[TrialHistory], target_sig: Dict,
             limit: Optional[int] = None
             ) -> Tuple[List[Tuple[np.ndarray, float]], int, str]:
    """The (features, log-cost) rows of ``history`` a fit for
    ``target_sig``'s cell may use: viable, positive-cost, same shape
    kind, featurizable (old-space records are skipped, never crash —
    regression-tested).  ``limit`` restricts the scan to the first N
    raw records: the append-only prefix a checkpointed fit was built
    on.  Returns (rows, raw record count scanned, digest) where the
    digest commits to the feature layout and every row actually used,
    so two processes that fit the same bytes provably fit the same
    model."""
    recs: List[Dict] = history.records() if history is not None else []
    if limit is not None:
        recs = recs[:max(0, int(limit))]
    rows: List[Tuple[np.ndarray, float]] = []
    h = hashlib.sha1(f"features:v{FEATURES_VERSION}".encode())
    for rec in recs:
        if not _viable(rec):
            continue
        cost = float(rec["cost_s"])
        if not cost > 0.0:
            continue
        try:
            sig = cell_signature(rec.get("arch"), rec.get("shape"),
                                 rec.get("multi_pod", False))
            if sig["kind"] != target_sig["kind"]:
                continue                 # gains don't transfer kinds
            cfg = config_from_dict(rec["config"]).as_dict()
            x = featurize(cfg, sig)
        except Exception:
            continue                     # older space / foreign cell
        h.update(_fp([rec.get("cell"), cfg, cost]).encode())
        rows.append((x, math.log(cost)))
    return rows, len(recs), h.hexdigest()


class ModelCursor:
    """History-fit ridge proposer over one cell (see module docstring).

    Obeys the :class:`~repro.core.strategy.SearchCursor` protocol; the
    campaign additionally primes it (``build_primer``/``prime``) with
    the checkpointable fit state.  An unprimed cursor primes itself
    from its ``history`` option on first use, so ``drive()`` and the
    single-cell CLI work without a campaign.
    """

    strategy_version = 1

    def __init__(self, runner: TrialRunner, baseline: TunableConfig,
                 threshold: float = 0.05, *, budget: int = MAX_TRIALS,
                 seed: int = 0, top_k: int = TOP_K,
                 min_records: int = MIN_RECORDS,
                 pool_size: int = POOL_SIZE,
                 ridge_lambda: float = RIDGE_LAMBDA,
                 stages: Optional[List[Stage]] = None,
                 history: Any = None):
        if budget < 1:
            raise ValueError("model strategy needs budget >= 1")
        if top_k < 1:
            raise ValueError("model strategy needs top_k >= 1")
        self.runner = runner
        self.baseline = baseline
        self.threshold = threshold
        self.budget = int(budget)
        self.seed = int(seed)
        self.top_k = int(top_k)
        self.min_records = int(min_records)
        self.pool_size = int(pool_size)
        self.ridge_lambda = float(ridge_lambda)
        wl = runner.workload
        self.cell_sig = cell_signature(wl.arch, wl.shape, wl.multi_pod)
        self._stages = stages
        self._history = (TrialHistory(pathlib.Path(history))
                         if isinstance(history, (str, pathlib.Path))
                         else history)
        # fit state (None until primed)
        self._state: Optional[Dict[str, Any]] = None
        self._tree: Optional[TreeCursor] = None   # cold-start delegate
        self._rows: List[Tuple[np.ndarray, float]] = []
        self._w: Optional[np.ndarray] = None
        # walk state (warm path)
        self.incumbent = baseline
        self.baseline_cost = float("nan")
        self.best_cost = float("nan")
        self.accepted: List[str] = []
        self._phase = 0                  # 0: baseline, 1: rounds, 2: done
        self._round = 0
        self._pending: Optional[List[Candidate]] = None
        self._pred_pending: List[float] = []
        self._seen: set = set()
        self._predictions: List[Dict[str, Any]] = []
        self._ws_seeds: Optional[List[TunableConfig]] = None

    # ------------------------------------------------------- fit state
    @property
    def cold(self) -> Optional[bool]:
        """Cold-start decision (None until primed)."""
        return None if self._state is None else bool(self._state["cold"])

    def build_primer(self, history: Any = None) -> Dict[str, Any]:
        """Snapshot the fit state for this cell from ``history``: the
        raw record count, the number of usable rows, and their digest.
        Tiny by construction — the checkpoint stores the *identity* of
        the fit, not the matrix; :meth:`prime` re-derives the fit from
        the history's record prefix, which the append-only store keeps
        stable."""
        rows, raw, digest = fit_rows(history if history is not None
                                     else self._history, self.cell_sig)
        return {"v": MODEL_VERSION, "cold": len(rows) < self.min_records,
                "records": len(rows), "raw": raw, "digest": digest}

    def prime(self, state: Dict[str, Any], history: Any = None) -> None:
        """Adopt a fit state (fresh from :meth:`build_primer` or stored
        in a checkpoint) and fit the model from the matching history
        prefix.  Raises ``ValueError`` when the stored state no longer
        matches the history bytes (rewritten/truncated store) — the
        campaign then rebuilds a fresh primer.  Must precede the first
        proposal; re-priming before it replaces the state."""
        if self._phase != 0 or self._pending is not None \
                or self.runner.n_trials:
            raise RuntimeError("prime must precede the first proposal")
        if not isinstance(state, dict) or state.get("v") != MODEL_VERSION:
            raise ValueError(f"unusable model state: {state!r}")
        hist = history if history is not None else self._history
        rows, raw, digest = fit_rows(hist, self.cell_sig,
                                     limit=state["raw"])
        if digest != state.get("digest") \
                or len(rows) != state.get("records"):
            raise ValueError("stored model state does not match the "
                             "history bytes")
        cold = len(rows) < self.min_records
        self._state = {"v": MODEL_VERSION, "cold": cold,
                       "records": len(rows), "raw": raw,
                       "digest": digest}
        self._tree = None
        if cold:
            self._tree = TreeCursor(self.runner, self.baseline,
                                    threshold=self.threshold,
                                    stages=self._stages)
            if self._ws_seeds is not None:
                self._tree.warm_start(self._ws_seeds)
        else:
            self._rows = rows
            self._refit()
        t = _telemetry.current()
        if t.enabled:
            t.emit("model.fit", cell=self.runner.workload.key(),
                   cold=cold, records=len(rows), raw=raw,
                   digest=digest)

    def _ensure_primed(self) -> None:
        if self._state is None:
            self.prime(self.build_primer(self._history), self._history)

    def _refit(self) -> None:
        x = np.stack([r[0] for r in self._rows])
        y = np.asarray([r[1] for r in self._rows], dtype=np.float64)
        a = x.T @ x + self.ridge_lambda * np.eye(x.shape[1])
        self._w = np.linalg.solve(a, x.T @ y)

    # ------------------------------------------------------- proposing
    def _active(self) -> List[str]:
        """The knobs the proposal space varies: the cell's active knobs
        that exist in today's registry with a non-trivial domain."""
        return [k for k in self.cell_sig.get("active_knobs") or []
                if k in SPACE.names() and len(SPACE[k].domain) > 1]

    def _predict(self, cfg: Dict[str, Any]) -> float:
        return float(featurize(cfg, self.cell_sig) @ self._w)

    def _observed(self, knob: str) -> List[Any]:
        """The values of ``knob`` with at least one fit row — the
        values the model has *evidence* about, in registry order."""
        names = feature_names()
        out = []
        for v in SPACE[knob].domain:
            ix = names.index(f"{knob}={v}")
            if any(r[0][ix] for r in self._rows):
                out.append(v)
        return out

    def _candidate_dicts(self) -> List[Dict[str, Any]]:
        """The configs one round may propose, deterministically.

        The proposal space is each active knob's *observed support*
        (plus the baseline's value): a never-observed value carries
        ridge weight 0, which an all-positive fit misreads as "best
        available" — proposing it is exploration, and exploration is
        the tree's job, not the model's.  Small support grids
        (≤ ``pool_size``) are enumerated outright.  Larger spaces
        exploit the fit's additivity: its global argmin is the
        per-knob argmin over the baseline, and the next-best
        predictions are that argmin's single-knob swaps — the exact
        top of the grid under an additive model, without materializing
        the grid."""
        base = self.baseline.as_dict()
        domains: Dict[str, List[Any]] = {}
        size = 1
        for k in self._active():
            allowed = set(self._observed(k)) | {base[k]}
            domains[k] = [v for v in SPACE[k].domain if v in allowed]
            size *= len(domains[k])
        active = list(domains)
        out: List[Dict[str, Any]] = []
        if size <= self.pool_size:
            for combo in itertools.product(
                    *(domains[k] for k in active)):
                d = dict(base)
                d.update({k: v for k, v in zip(active, combo)})
                out.append(d)
            return out
        argmin = dict(base)
        for k in active:
            best = min(domains[k],
                       key=lambda v: (self._knob_weight(k, v), str(v)))
            argmin[k] = best
        out.append(argmin)
        for k in active:
            for v in domains[k]:
                if v == argmin[k]:
                    continue
                d = dict(argmin)
                d[k] = v
                out.append(d)
        return out

    def _knob_weight(self, knob: str, value: Any) -> float:
        names = feature_names()
        return float(self._w[names.index(f"{knob}={value}")])

    def _topk(self, n: int) -> List[Candidate]:
        base = self.baseline.as_dict()
        scored: List[Tuple[float, str, Dict[str, Any]]] = []
        for d in self._candidate_dicts():
            fp = _fp(d)
            if fp in self._seen:
                continue
            scored.append((self._predict(d), fp, d))
        # deterministic: predicted cost asc, then canonical config json
        scored.sort(key=lambda t: (t[0], t[1]))
        cands: List[Candidate] = []
        self._pred_pending = []
        for pred, fp, d in scored[:n]:
            self._seen.add(fp)
            delta = {k: v for k, v in d.items() if base[k] != v}
            cands.append(Candidate(self.baseline.replace(**delta),
                                   f"model:{self._round + 1}."
                                   f"{len(cands) + 1}", delta))
            self._pred_pending.append(math.exp(pred))
        return cands

    # ------------------------------------------------------- protocol
    @property
    def done(self) -> bool:
        if self._state is None:
            return False
        if self._tree is not None:
            return self._tree.done
        return self._phase >= 2

    def warm_start(self, configs: Sequence[TunableConfig]) -> None:
        """Cold mode forwards the seeds to the embedded tree (keeping
        bit-identity with a warm-started ``tree`` walk); the warm path
        ignores them — the model already conditions on the *entire*
        history the seeds were retrieved from, so they are redundant
        and deliberately kept out of the signature."""
        self._ws_seeds = list(configs)
        if self._tree is not None:
            self._tree.warm_start(self._ws_seeds)

    def propose(self) -> List[Candidate]:
        self._ensure_primed()
        if self._tree is not None:
            return self._tree.propose()
        if self._pending is not None:
            raise RuntimeError("previous batch not absorbed yet")
        if self._phase == 0:
            self._pending = [Candidate(self.baseline, "baseline", {})]
            return list(self._pending)
        if self._phase != 1:
            return []
        n = min(self.top_k, self.budget - self.runner.n_trials)
        if n <= 0:
            self._phase = 2
            return []
        self._refit()                    # online: absorbed rows re-enter fit
        cands = self._topk(n)
        if not cands:
            self._phase = 2
            return []
        self._pending = cands
        t = _telemetry.current()
        if t.enabled:
            t.emit("model.propose", cell=self.runner.workload.key(),
                   round=self._round + 1, k=len(cands),
                   records=len(self._rows),
                   predicted_best_s=round(self._pred_pending[0], 6))
        return list(self._pending)

    def absorb(self, results: Sequence[TrialResult],
               indices: Sequence[int]) -> None:
        if self._tree is not None:
            self._tree.absorb(results, indices)
            return
        if self._pending is None:
            raise RuntimeError("no batch proposed")
        if len(results) != len(self._pending) \
                or len(indices) != len(self._pending):
            raise ValueError("results/indices do not match proposed batch")
        cands, self._pending = self._pending, None
        if self._phase == 0:
            self.best_cost = absorb_baseline(self.runner, results[0],
                                             indices[0])
            self.baseline_cost = self.best_cost
            self._seen.add(_fp(self.baseline.as_dict()))
            self._absorb_rows([cands[0]], [results[0]])
            self._phase = 1
            return
        won = apply_accept_rule(self.runner,
                                list(zip(cands, results, indices)),
                                self.best_cost, self.threshold)
        for cand, res, idx, pred in zip(cands, results, indices,
                                        self._pred_pending):
            self._predictions.append({
                "name": cand.name, "predicted_s": round(pred, 6),
                "cost_s": res.cost_s, "crashed": bool(res.crashed)})
            if not res.crashed and not self.runner.log[idx].note:
                self.runner.log[idx].note = \
                    f"model predicted {pred:.4f}s"
        self._absorb_rows(cands, results)
        self._pred_pending = []
        if won is not None:
            cand, cost = won
            self.incumbent = cand.config
            self.best_cost = cost
            self.accepted.append(f"model: {cand.delta}")
        self._round += 1

    def _absorb_rows(self, cands: Sequence[Candidate],
                     results: Sequence[TrialResult]) -> None:
        """Online refinement: every live result becomes a fit row for
        the next round's refit.  A crash is *information*, not a gap:
        an unseen knob value carries weight 0, which an all-positive
        fit reads as "best available", so a skipped crash would be
        re-proposed (with cosmetic swaps) every round.  Instead the
        crash is imputed a cost above everything observed, pushing its
        knob values out of the argmin deterministically."""
        for cand, res in zip(cands, results):
            if res.crashed or not res.cost_s > 0.0 \
                    or not math.isfinite(res.cost_s):
                if not self._rows:
                    continue
                y = max(r[1] for r in self._rows) + math.log(4.0)
            else:
                y = math.log(res.cost_s)
            x = featurize(cand.config.as_dict(), self.cell_sig)
            self._rows.append((x, y))

    def report(self) -> TuningReport:
        if self._tree is not None:
            # cold start: the tree's report, verbatim — bit-identical
            # decisions *and* bytes with --strategy tree
            return self._tree.report()
        return TuningReport(
            workload=self.runner.workload.key(),
            baseline_cost=self.baseline_cost,
            final_cost=self.best_cost,
            final_config=self.incumbent.as_dict(),
            n_trials=self.runner.n_trials,
            accepted=self.accepted,
            log=[dataclasses.asdict(e) for e in self.runner.log],
            proposer={
                "version": MODEL_VERSION,
                "cold": False,
                "records": self._state["records"],
                "raw": self._state["raw"],
                "digest": self._state["digest"],
                "rows": list(self._predictions),
            },
        )

    def expected_gain(self) -> Optional[float]:
        """Unknown before the baseline (explore-first); afterwards the
        share of the trial budget still unspent — each remaining trial
        is one more model-ranked chance to accept an improvement.
        Reported to the scheduler only; never feeds back into the
        cursor's own decisions."""
        if self._tree is not None:
            return self._tree.expected_gain()
        if self._phase >= 2:
            return 0.0
        if self._phase == 0:
            return None
        return max(0.0, (self.budget - self.runner.n_trials)
                   / max(1, self.budget))

    def signature_parts(self) -> list:
        parts: list = ["model", MODEL_VERSION, self.seed, self.budget,
                       self.top_k, self.min_records, self.pool_size,
                       self.ridge_lambda]
        if self._state is not None:
            parts.append({k: self._state[k]
                          for k in ("cold", "records", "raw", "digest")})
        if self._tree is not None:
            parts.append(self._tree.signature_parts())
        return parts
