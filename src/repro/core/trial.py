"""Black-box trial runner (the paper's "experimental run").

A trial = one configuration of the 12 knobs applied to one workload cell
(arch x shape x mesh).  The application is a black box: the runner only
observes a scalar cost.

Two evaluators:
  * RooflineEvaluator — lower+compile on the production mesh, cost =
    analytic roofline step time (CPU-only infrastructure, DESIGN.md §2.2).
    A config whose compiled peak memory exceeds per-chip HBM *crashes*,
    exactly like the paper's sort-by-key 0.1/0.7 run.
  * WallClockEvaluator — median of N real executions (the paper's
    protocol; used on real hardware and in the CPU examples/tests).

Trial-throughput engine: the expensive unit of the whole reproduction is
the calibration compile, and most knobs never reach the compiled HLO
(core/params.COMPILE_KNOBS / ANALYTIC_KNOBS).  The four calibration
compiles per trial are therefore memoized in a two-level
:class:`CompileCache` — an in-memory LRU in front of a disk cache —
keyed by ``TunableConfig.compile_key()`` (the compile projection), not
the full config hash.  A sweep over ``attn_block_q/kv``, ``comm_codec``
or ``kv_cache_dtype`` reuses one compile and recomputes only the
analytic roofline terms; the observed cost of every trial is bit-equal
to what the naive (compile-every-time) evaluator produces.  The cache is
thread-safe with in-flight deduplication so the parallel sweep executor
(core/executor.py) never compiles the same program twice concurrently.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import json
import os
import pathlib
import threading
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

from repro.configs import get_config, get_shape
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import costmodel
from repro.core import telemetry as _telemetry
from repro.core.params import TunableConfig

CACHE_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "trials"

# ------------------------------------------------------- failure taxonomy
# Every crashed trial is classified so the layers above can react
# differently (ISSUE 6): deterministic failures stay memoized and scored
# (the config is genuinely bad), transient ones are retryable, timeouts
# come from the executor deadline, and worker-death is assigned post hoc
# by the quarantine ledger (the evaluation never returned at all).
FAILURE_DETERMINISTIC = "deterministic"
FAILURE_TRANSIENT = "transient"
FAILURE_TIMEOUT = "timeout"
FAILURE_WORKER_DEATH = "worker-death"

#: Environment faults that may succeed on retry.  TimeoutError and
#: ConnectionError are OSError subclasses, so disk/NFS hiccups, host
#: OOM and socket drops all land here; everything else (shape errors,
#: HBM overflow, XLA lowering failures) is deterministic per program.
_TRANSIENT_TYPES = (OSError, MemoryError)


def classify_exception(e: BaseException) -> str:
    """Map an evaluator exception to a failure class.  An exception that
    already carries a ``.failure`` attribute (e.g. :class:`TrialError`
    re-raised from a memoized entry) keeps its class."""
    tagged = getattr(e, "failure", "")
    if tagged:
        return tagged
    if isinstance(e, _TRANSIENT_TYPES):
        return FAILURE_TRANSIENT
    return FAILURE_DETERMINISTIC


class TrialError(RuntimeError):
    """An evaluator failure that carries its classification."""

    def __init__(self, message: str, failure: str = FAILURE_DETERMINISTIC):
        super().__init__(message)
        self.failure = failure


@dataclasses.dataclass
class TrialResult:
    cost_s: float                  # observed "runtime" (black-box metric)
    crashed: bool = False
    error: str = ""
    roofline: Optional[Dict] = None
    peak_bytes: Optional[float] = None
    fits_hbm: bool = True
    compile_s: float = 0.0
    cached: bool = False
    compiles: int = 0              # fresh XLA compiles this trial paid for
    failure: str = ""              # taxonomy class when crashed ("" if not)
    retries: int = 0               # transient retries this result absorbed

    @property
    def retryable(self) -> bool:
        return self.crashed and self.failure == FAILURE_TRANSIENT

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Workload:
    """One tunable application instance (cell)."""
    arch: str
    shape: str
    multi_pod: bool = False

    @property
    def cfg(self) -> ArchConfig:
        return get_config(self.arch)

    @property
    def shp(self) -> ShapeConfig:
        return get_shape(self.shape)

    def key(self) -> str:
        return f"{self.arch}__{self.shape}__" + \
            ("multipod" if self.multi_pod else "pod")


class CompileCache:
    """Two-level memo of calibration-compile measurements.

    Level 1 is an in-memory LRU (per process); level 2 is the disk cache
    under ``results/trials/compiles``.  Keys are opaque strings built
    from (cell, calibration point, scan/unroll variant, compile
    projection).  Values are small JSON dicts — either a serialized
    :class:`costmodel.Roofline` or ``{"error": ..., "failure": ...}``
    for a program that failed to build/compile.  Only *deterministic*
    failures are memoized like successes; a transient fault (classified
    via :func:`classify_exception` — e.g. an ``OSError`` from the disk
    cache or a host OOM under a parallel sweep) is returned to its
    waiters but never remembered, so the next lookup rebuilds.

    ``get_or_build`` is thread-safe with in-flight deduplication: when N
    executor threads ask for the same key, one runs the builder and the
    rest block on its result.

    The disk level is also multi-*process* safe (the campaign fabric,
    core/fabric.py, shares one cache directory across workers): every
    write goes to a uniquely-named tempfile in the cache directory and
    is published with an atomic ``os.replace``, so two workers building
    the same key concurrently each publish a complete entry (last
    writer wins — the values are deterministic per key, so both wrote
    the same bytes); a reader that still encounters a torn/corrupt
    entry (e.g. left behind by a pre-fabric writer that crashed
    mid-write) treats it as a miss and rebuilds, repairing the entry.
    """

    #: telemetry event prefix — subclasses with their own semantics
    #: (the measured tier's TimingCache) override, so the metrics
    #: aggregator can report compile-cache and timing-cache hit rates
    #: separately
    CACHE_KIND = "cache"

    def __init__(self, directory: Optional[pathlib.Path] = None,
                 mem_entries: int = 512, use_disk: bool = True):
        self.dir = pathlib.Path(directory) if directory else \
            CACHE_DIR / "compiles"
        self.mem_entries = mem_entries
        self.use_disk = use_disk
        self._mem: "collections.OrderedDict[str, Dict]" = \
            collections.OrderedDict()
        self._lock = threading.Lock()
        self._inflight: Dict[str, threading.Event] = {}
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> pathlib.Path:
        return self.dir / f"{key}.json"

    def _mem_put(self, key: str, val: Dict) -> None:
        self._mem[key] = val
        self._mem.move_to_end(key)
        while len(self._mem) > self.mem_entries:
            self._mem.popitem(last=False)

    def _lookup(self, key: str) -> Optional[Dict]:
        """One locked probe of memory then disk (caller holds no lock)."""
        with self._lock:
            if key in self._mem:
                self._mem.move_to_end(key)
                return self._mem[key]
        if self.use_disk:
            p = self._path(key)
            try:
                val = json.loads(p.read_text())
            except (OSError, ValueError):
                # missing, or torn by a crashed writer / a concurrent
                # non-atomic producer: treat as a miss and rebuild
                return None
            if not isinstance(val, dict):
                return None
            with self._lock:
                self._mem_put(key, val)
            return val
        return None

    def _disk_put(self, key: str, val: Dict) -> None:
        """Publish one entry atomically (core/fsutil.atomic_publish),
        safe against concurrent writers in other processes."""
        from repro.core.fsutil import atomic_publish
        self.dir.mkdir(parents=True, exist_ok=True)
        atomic_publish(self._path(key), json.dumps(val),
                       prefix=f".{key}.")

    def get_or_build(self, key: str, builder: Callable[[], Dict]) -> Dict:
        tel = _telemetry.current()
        while True:
            val = self._lookup(key)
            if val is not None:
                with self._lock:
                    self.hits += 1
                if tel.enabled:
                    tel.emit(f"{self.CACHE_KIND}.hit", key=key)
                return val
            with self._lock:
                ev = self._inflight.get(key)
                if ev is None:
                    self._inflight[key] = threading.Event()
                    self.misses += 1
                    break
            ev.wait()       # another thread is compiling this program
        if tel.enabled:
            tel.emit(f"{self.CACHE_KIND}.miss", key=key)
        try:
            val = builder()
            # memoization policy by failure class: successes go to both
            # levels; deterministic build errors are memoized in-memory
            # only (persisting them would outlive the run that observed
            # them); transient faults are memoized NOWHERE — the caller
            # sees this one failure, and the next lookup of the same key
            # rebuilds instead of replaying a stale environment hiccup
            if self.use_disk and "error" not in val:
                self._disk_put(key, val)
            if val.get("failure") != FAILURE_TRANSIENT:
                with self._lock:
                    self._mem_put(key, val)
            return val
        finally:
            with self._lock:
                self._inflight.pop(key).set()

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {"hits": self.hits, "misses": self.misses,
                    "mem_entries": len(self._mem)}


class RooflineEvaluator:
    """cost = calibrated analytic roofline seconds of the compiled step.

    XLA counts `while` bodies once, so instead of one full compile the
    evaluator compiles two small UNROLLED variants (1 and 3 layer-units)
    and extrapolates every term to the true depth
    (core/costmodel.calibration_points) — which also makes a trial ~10x
    cheaper than compiling the full stack.

    The four calibration compiles are memoized in a :class:`CompileCache`
    keyed by ``TunableConfig.compile_key()`` — configs that differ only
    in analytic knobs share one set of compiles (see module docstring).
    """

    def __init__(self, mesh_factory: Callable = None, use_cache: bool = True,
                 hbm_limit: float = None,
                 compile_cache: Optional[CompileCache] = None):
        from repro.launch.mesh import make_production_mesh
        self._mesh_factory = mesh_factory or make_production_mesh
        self.use_cache = use_cache
        self.hbm_limit = hbm_limit or costmodel.HW["hbm_per_chip"]
        self.compile_cache = compile_cache or \
            (CompileCache() if use_cache else
             CompileCache(use_disk=False, mem_entries=0))
        # per-trial accounting shared across threads
        self._acct = threading.local()
        self.total_compiles = 0
        self._count_lock = threading.Lock()

    # ------------------------------------------------------------- keys
    def _compile_id(self, wl: Workload, mesh, point_units: int,
                    rt_variant: TunableConfig) -> str:
        ck = rt_variant.compile_key(kind=wl.shp.kind, family=wl.cfg.family)
        # mesh axis ORDER matters for sharding — keep it in the key
        blob = json.dumps([wl.key(), point_units, list(mesh.shape.items()),
                           ck], sort_keys=True, default=str)
        h = hashlib.sha1(blob.encode()).hexdigest()[:16]
        return f"{wl.key()}__u{point_units}__{h}"

    # --------------------------------------------------------- compiles
    def _roofline_at(self, cfg, shape, rt: TunableConfig, mesh,
                     multi_pod: bool):
        from repro.runtime.stepfn import build_step
        bundle = build_step(cfg, shape, rt, mesh)
        with mesh:
            compiled = bundle.lower().compile()
        return costmodel.analyze(
            compiled, compute_dtype=rt.compute_dtype,
            pod_size=256 if multi_pod else 10**9)

    def _measured(self, wl: Workload, mesh, point_cfg, units_tag: int,
                  rt_variant: TunableConfig) -> costmodel.Roofline:
        """One memoized calibration compile -> Roofline (raises the
        memoized error if this program deterministically fails)."""
        key = self._compile_id(wl, mesh, units_tag, rt_variant)
        built = []

        def build() -> Dict:
            built.append(True)
            with _telemetry.current().span("compile", cell=wl.key(),
                                           key=key) as sp:
                t0 = time.time()
                try:
                    rl = self._roofline_at(point_cfg, wl.shp, rt_variant,
                                           mesh, wl.multi_pod)
                    return {"roofline": rl.as_dict(),
                            "compile_s": round(time.time() - t0, 2)}
                except Exception as e:
                    # classify BEFORE memoizing: only deterministic program
                    # failures may be remembered (the cache skips transient
                    # entries), so an OSError from the disk cache is not
                    # permanently recorded as a crashed program
                    sp.note(error=True)
                    return {"error": f"{type(e).__name__}: {e}"[:500],
                            "failure": classify_exception(e),
                            "compile_s": round(time.time() - t0, 2)}

        entry = self.compile_cache.get_or_build(key, build)
        acct = self._trial_acct()
        acct["cache_reads"] += 1
        if built:
            acct["compiles"] += 1
            acct["compile_s"] += entry.get("compile_s", 0.0)
            with self._count_lock:
                self.total_compiles += 1
        if "error" in entry:
            raise TrialError(entry["error"],
                             failure=entry.get("failure",
                                               FAILURE_DETERMINISTIC))
        return costmodel.roofline_from_dict(entry["roofline"])

    def _trial_acct(self) -> Dict[str, Any]:
        if not hasattr(self._acct, "d"):
            self._acct.d = {"compiles": 0, "compile_s": 0.0,
                            "cache_reads": 0}
        return self._acct.d

    # ------------------------------------------------------------ trial
    def calibrated_roofline(self, wl: Workload, rt: TunableConfig):
        """Compute + collective terms from two small UNROLLED compiles
        (while bodies count once, §7.1); PEAK memory from two small
        SCANNED compiles (buffer reuse only shows up scanned); the
        MEMORY term from the first-principles analytic traffic model
        (§7.3 — XLA-CPU 'bytes accessed' is unreliable for HBM traffic).
        The pallas-vs-xla attention distinction and every knob
        (remat/microbatch/dtypes/tiles/donation) enter analytically."""
        mesh = self._mesh_factory(multi_pod=wl.multi_pod)
        points, units = costmodel.calibration_points(wl.cfg)
        rt_unroll = rt.replace(unroll_layers=True, attn_impl="xla")
        r1 = self._measured(wl, mesh, points[0][0], 1, rt_unroll)
        r3 = self._measured(wl, mesh, points[1][0], 3, rt_unroll)
        rl = costmodel.extrapolate_roofline(r1, r3, units)
        rt_scan = rt.replace(unroll_layers=False, attn_impl="xla")
        p1 = self._measured(wl, mesh, points[0][0], 1, rt_scan)
        p3 = self._measured(wl, mesh, points[1][0], 3, rt_scan)
        peak = costmodel.extrapolate(p1.peak_mem_bytes or 0.0,
                                     p3.peak_mem_bytes or 0.0, units)
        data_size = 1
        for a in ("pod", "data"):
            data_size *= mesh.shape.get(a, 1)
        model_size = mesh.shape.get("model", 1)
        mem_bytes = costmodel.analytic_memory_bytes(
            wl.cfg, wl.shp, rt, data_size, model_size)
        if rt.attn_impl == "pallas":
            pcorr = costmodel.flash_peak_correction_bytes(
                wl.cfg, wl.shp, rt, data_size, model_size)
            peak = max(peak * 0.02, peak - pcorr)
        return dataclasses.replace(
            rl, memory_s=mem_bytes / costmodel.HW["hbm_bw"],
            bytes_per_chip=mem_bytes, peak_mem_bytes=peak)

    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        acct = self._trial_acct()
        acct["compiles"], acct["compile_s"] = 0, 0.0
        acct["cache_reads"] = 0
        try:
            rl = self.calibrated_roofline(wl, rt)
            peak = rl.peak_mem_bytes
            fits = peak is None or peak <= self.hbm_limit
            res = TrialResult(cost_s=rl.total_s, crashed=not fits,
                              roofline=rl.as_dict(), peak_bytes=peak,
                              fits_hbm=fits,
                              failure="" if fits else FAILURE_DETERMINISTIC)
        except Exception as e:
            # TrialError already carries the stored "TypeName: msg"
            err = str(e) if isinstance(e, TrialError) \
                else f"{type(e).__name__}: {e}"
            res = TrialResult(cost_s=float("inf"), crashed=True,
                              error=err[:500],
                              failure=classify_exception(e))
        res.compiles = acct["compiles"]
        res.compile_s = round(acct["compile_s"], 1)
        # "served from cache" requires the trial to have actually reached
        # a cache lookup — a trial that dies before any calibration
        # compile (e.g. in the mesh factory) was not cached, it crashed
        res.cached = acct["compiles"] == 0 and acct["cache_reads"] > 0
        return res


def _zeros_args(bundle) -> Tuple:
    """Concrete zero-filled arguments matching a bundle's abstract
    argument structs (the default when no ``make_args`` is supplied —
    timing does not care about values, only shapes/dtypes/shardings)."""
    import jax.numpy as jnp
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), bundle.args)


class WallClockEvaluator:
    """The paper's protocol: median of n repeats of the real step.

    Hardened to the same contract as :class:`RooflineEvaluator` (the
    measured tier, core/measure.py, runs it under the deadline/retry
    executor): exceptions are classified through
    :func:`classify_exception` — a :class:`TrialError` raised by a
    caching wrapper keeps its pre-tagged class — and every result
    carries ``compiles``/``compile_s``/``cached`` accounting, so
    measured crashes and costs land in checkpoints/history with the
    full PR-6 taxonomy instead of a bare string.

    ``make_args`` defaults to zero-filled concrete arguments derived
    from the step bundle; ``mesh_factory`` defaults to the production
    mesh (real hardware).  Tile knobs are validated against the cell's
    sequence length up front, so a non-dividing ``attn_block_q/kv`` is
    a clean deterministic-crash trial, not a Pallas grid assertion.
    """

    def __init__(self, mesh_factory: Optional[Callable] = None,
                 make_args: Optional[Callable] = None,
                 repeats: int = 5):
        if mesh_factory is None:
            from repro.launch.mesh import make_production_mesh
            mesh_factory = make_production_mesh
        self._mesh_factory = mesh_factory
        self._make_args = make_args     # (wl, rt, mesh) -> concrete args
        self.repeats = repeats

    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        from repro.core.space import SPACE
        from repro.runtime.stepfn import build_step
        t0 = time.time()
        compile_s = 0.0
        compiles = 0
        try:
            SPACE.validate(rt, seq_len=wl.shp.seq_len)
            mesh = self._mesh_factory(multi_pod=wl.multi_pod)
            bundle = build_step(wl.cfg, wl.shp, rt, mesh)
            args = self._make_args(wl, rt, mesh) \
                if self._make_args is not None else _zeros_args(bundle)
            with mesh:
                c0 = time.time()
                compiled = bundle.fn.lower(*args).compile()
                compile_s = round(time.time() - c0, 2)
                compiles = 1
                ts = []
                for _ in range(self.repeats):
                    t1 = time.time()
                    out = compiled(*args)
                    jax.block_until_ready(out)
                    ts.append(time.time() - t1)
                    if rt.donate_buffers and bundle.kind == "train":
                        args = (out[0], out[1], args[2])
                    elif rt.donate_buffers and bundle.kind == "decode":
                        args = (args[0], out[1], args[2])
            return TrialResult(cost_s=float(np.median(ts)),
                               compiles=compiles, compile_s=compile_s)
        except Exception as e:
            # TrialError already carries the stored "TypeName: msg"
            err = str(e) if isinstance(e, TrialError) \
                else f"{type(e).__name__}: {e}"
            return TrialResult(cost_s=float("inf"), crashed=True,
                               error=err[:500],
                               failure=classify_exception(e),
                               compiles=compiles,
                               compile_s=compile_s or
                               round(time.time() - t0, 2))


@dataclasses.dataclass
class TrialLogEntry:
    name: str
    delta: Dict[str, Any]
    config: Dict[str, Any]
    result: Dict[str, Any]
    accepted: Optional[bool] = None
    note: str = ""


class TrialRunner:
    """Counts and logs every run (the paper's <=10-runs budget is checked
    by tests against this counter).

    ``history`` is an optional emission hook ``(workload, rt, name,
    result, delta) -> None`` (see :meth:`~repro.core.history
    .TrialHistory.sink`): every *evaluated* trial is forwarded to it,
    so campaigns accumulate a persistent trial history; trials replayed
    from a checkpoint (``record(..., replayed=True)``) were already
    emitted by the run that evaluated them and are not re-emitted.
    """

    def __init__(self, workload: Workload, evaluator: Callable,
                 history: Optional[Callable] = None):
        self.workload = workload
        self.evaluator = evaluator
        self.history = history
        self.log: list[TrialLogEntry] = []

    @property
    def n_trials(self) -> int:
        return len(self.log)

    def run(self, rt: TunableConfig, name: str,
            delta: Dict[str, Any] = None) -> TrialResult:
        res = self.evaluator(self.workload, rt)
        self.record(rt, name, res, delta)
        return res

    def record(self, rt: TunableConfig, name: str, res: TrialResult,
               delta: Dict[str, Any] = None,
               replayed: bool = False) -> TrialResult:
        """Log an already-evaluated trial (parallel executor path).

        Exactly one log entry per evaluated configuration — the run
        budget counts evaluations, however they were scheduled."""
        self.log.append(TrialLogEntry(
            name=name, delta=delta or {}, config=rt.as_dict(),
            result={k: v for k, v in res.as_dict().items()
                    if k != "roofline"}))
        if self.history is not None and not replayed:
            self.history(self.workload, rt, name, res, delta or {})
        return res
