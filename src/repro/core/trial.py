"""Black-box trial runner (the paper's "experimental run").

A trial = one configuration of the 12 knobs applied to one workload cell
(arch x shape x mesh).  The application is a black box: the runner only
observes a scalar cost.

Two evaluators:
  * RooflineEvaluator — lower+compile on the production mesh, cost =
    analytic roofline step time (CPU-only infrastructure, DESIGN.md §2.2).
    A config whose compiled peak memory exceeds per-chip HBM *crashes*,
    exactly like the paper's sort-by-key 0.1/0.7 run.
  * WallClockEvaluator — median of N real executions (the paper's
    protocol; used on real hardware and in the CPU examples/tests).

Results are cached on disk keyed by (cell, config) so sensitivity sweeps,
the tuning tree and benchmarks never recompile the same point twice.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
import time
import traceback
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from repro.configs import get_config, get_shape
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core import costmodel
from repro.core.params import TunableConfig

CACHE_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "trials"


@dataclasses.dataclass
class TrialResult:
    cost_s: float                  # observed "runtime" (black-box metric)
    crashed: bool = False
    error: str = ""
    roofline: Optional[Dict] = None
    peak_bytes: Optional[float] = None
    fits_hbm: bool = True
    compile_s: float = 0.0
    cached: bool = False

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Workload:
    """One tunable application instance (cell)."""
    arch: str
    shape: str
    multi_pod: bool = False

    @property
    def cfg(self) -> ArchConfig:
        return get_config(self.arch)

    @property
    def shp(self) -> ShapeConfig:
        return get_shape(self.shape)

    def key(self) -> str:
        return f"{self.arch}__{self.shape}__" + \
            ("multipod" if self.multi_pod else "pod")


class RooflineEvaluator:
    """cost = calibrated analytic roofline seconds of the compiled step.

    XLA counts `while` bodies once, so instead of one full compile the
    evaluator compiles two small UNROLLED variants (1 and 3 layer-units)
    and extrapolates every term to the true depth
    (core/costmodel.calibration_points) — which also makes a trial ~10x
    cheaper than compiling the full stack."""

    def __init__(self, mesh_factory: Callable = None, use_cache: bool = True,
                 hbm_limit: float = None):
        from repro.launch.mesh import make_production_mesh
        self._mesh_factory = mesh_factory or make_production_mesh
        self.use_cache = use_cache
        self.hbm_limit = hbm_limit or costmodel.HW["hbm_per_chip"]

    def _cache_path(self, wl: Workload, rt: TunableConfig) -> pathlib.Path:
        blob = json.dumps(rt.as_dict(), sort_keys=True)
        h = hashlib.sha1(blob.encode()).hexdigest()[:16]
        return CACHE_DIR / f"{wl.key()}__{h}.json"

    def _roofline_at(self, cfg, shape, rt: TunableConfig, mesh,
                     multi_pod: bool):
        from repro.runtime.stepfn import build_step
        bundle = build_step(cfg, shape, rt, mesh)
        with mesh:
            compiled = bundle.lower().compile()
        return costmodel.analyze(
            compiled, compute_dtype=rt.compute_dtype,
            pod_size=256 if multi_pod else 10**9)

    def calibrated_roofline(self, wl: Workload, rt: TunableConfig):
        """Compute + collective terms from two small UNROLLED compiles
        (while bodies count once, §7.1); PEAK memory from two small
        SCANNED compiles (buffer reuse only shows up scanned); the
        MEMORY term from the first-principles analytic traffic model
        (§7.3 — XLA-CPU 'bytes accessed' is unreliable for HBM traffic).
        The pallas-vs-xla attention distinction and every knob
        (remat/microbatch/dtypes/tiles/donation) enter analytically."""
        mesh = self._mesh_factory(multi_pod=wl.multi_pod)
        points, units = costmodel.calibration_points(wl.cfg)
        rt_unroll = rt.replace(unroll_layers=True, attn_impl="xla")
        r1 = self._roofline_at(points[0][0], wl.shp, rt_unroll, mesh,
                               wl.multi_pod)
        r3 = self._roofline_at(points[1][0], wl.shp, rt_unroll, mesh,
                               wl.multi_pod)
        rl = costmodel.extrapolate_roofline(r1, r3, units)
        rt_scan = rt.replace(unroll_layers=False, attn_impl="xla")
        p1 = self._roofline_at(points[0][0], wl.shp, rt_scan, mesh,
                               wl.multi_pod)
        p3 = self._roofline_at(points[1][0], wl.shp, rt_scan, mesh,
                               wl.multi_pod)
        peak = costmodel.extrapolate(p1.peak_mem_bytes or 0.0,
                                     p3.peak_mem_bytes or 0.0, units)
        data_size = 1
        for a in ("pod", "data"):
            data_size *= mesh.shape.get(a, 1)
        model_size = mesh.shape.get("model", 1)
        mem_bytes = costmodel.analytic_memory_bytes(
            wl.cfg, wl.shp, rt, data_size, model_size)
        if rt.attn_impl == "pallas":
            pcorr = costmodel.flash_peak_correction_bytes(
                wl.cfg, wl.shp, rt, data_size, model_size)
            peak = max(peak * 0.02, peak - pcorr)
        return dataclasses.replace(
            rl, memory_s=mem_bytes / costmodel.HW["hbm_bw"],
            bytes_per_chip=mem_bytes, peak_mem_bytes=peak)

    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        path = self._cache_path(wl, rt)
        if self.use_cache and path.exists():
            d = json.loads(path.read_text())
            d["cached"] = True
            return TrialResult(**d)
        t0 = time.time()
        try:
            rl = self.calibrated_roofline(wl, rt)
            peak = rl.peak_mem_bytes
            fits = peak is None or peak <= self.hbm_limit
            res = TrialResult(cost_s=rl.total_s, crashed=not fits,
                              roofline=rl.as_dict(), peak_bytes=peak,
                              fits_hbm=fits,
                              compile_s=round(time.time() - t0, 1))
        except Exception as e:
            res = TrialResult(cost_s=float("inf"), crashed=True,
                              error=f"{type(e).__name__}: {e}"[:500],
                              compile_s=round(time.time() - t0, 1))
        if self.use_cache:
            CACHE_DIR.mkdir(parents=True, exist_ok=True)
            d = res.as_dict()
            d.pop("cached", None)
            d["cost_s"] = d["cost_s"] if np.isfinite(d["cost_s"]) else 1e30
            path.write_text(json.dumps(d))
        return res


class WallClockEvaluator:
    """The paper's protocol: median of n repeats of the real step."""

    def __init__(self, mesh_factory: Callable, make_args: Callable,
                 repeats: int = 5):
        self._mesh_factory = mesh_factory
        self._make_args = make_args     # (wl, rt, mesh) -> concrete args
        self.repeats = repeats

    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        from repro.runtime.stepfn import build_step
        try:
            mesh = self._mesh_factory(multi_pod=wl.multi_pod)
            bundle = build_step(wl.cfg, wl.shp, rt, mesh)
            args = self._make_args(wl, rt, mesh)
            with mesh:
                compiled = bundle.fn.lower(*args).compile()
                ts = []
                for _ in range(self.repeats):
                    t0 = time.time()
                    out = compiled(*args)
                    jax.block_until_ready(out)
                    ts.append(time.time() - t0)
                    if rt.donate_buffers and bundle.kind == "train":
                        args = (out[0], out[1], args[2])
                    elif rt.donate_buffers and bundle.kind == "decode":
                        args = (args[0], out[1], args[2])
            return TrialResult(cost_s=float(np.median(ts)))
        except Exception as e:
            return TrialResult(cost_s=float("inf"), crashed=True,
                               error=f"{type(e).__name__}: {e}"[:500])


@dataclasses.dataclass
class TrialLogEntry:
    name: str
    delta: Dict[str, Any]
    config: Dict[str, Any]
    result: Dict[str, Any]
    accepted: Optional[bool] = None
    note: str = ""


class TrialRunner:
    """Counts and logs every run (the paper's <=10-runs budget is checked
    by tests against this counter)."""

    def __init__(self, workload: Workload, evaluator: Callable):
        self.workload = workload
        self.evaluator = evaluator
        self.log: list[TrialLogEntry] = []

    @property
    def n_trials(self) -> int:
        return len(self.log)

    def run(self, rt: TunableConfig, name: str,
            delta: Dict[str, Any] = None) -> TrialResult:
        res = self.evaluator(self.workload, rt)
        self.log.append(TrialLogEntry(
            name=name, delta=delta or {}, config=rt.as_dict(),
            result={k: v for k, v in res.as_dict().items()
                    if k != "roofline"}))
        return res
