"""Trial-history store — every trial ever run, queryable for warm-starts.

The campaign engine made trials cheap and resumable, but every campaign
still started from zero knowledge: a fresh cell's cursor walked the
whole tree as if no similar cell had ever been tuned.  The
:class:`TrialHistory` store makes campaigns *cumulative*:

  * **append-only JSONL** — every evaluated trial (config, cell, cost,
    compile stats) is appended as one JSON line to a shared
    ``history.jsonl`` next to the campaign checkpoints.  Appends are a
    single ``write(2)`` on an ``O_APPEND`` descriptor, so concurrent
    fabric workers (core/fabric.py) interleave whole lines, never
    bytes; readers skip torn or foreign lines instead of failing;
  * **cell signatures** — :func:`cell_signature` describes a cell by
    the features that determine which knobs matter to it: the shape
    kind, the arch family, and the *active knob set* derived from the
    :data:`~repro.core.space.SPACE` registry (a tunable knob is active
    iff flipping it can change the cell's ``compile_key`` projection,
    plus the always-analytic knobs).  :func:`cell_similarity` scores
    two signatures (kind ≫ family ≫ arch/shape, plus Jaccard overlap
    of the active knob sets), so "nearest cell" means "cell whose
    trials exercised the same knobs";
  * **warm-start queries** — :meth:`TrialHistory.warmstart_configs`
    returns the best observed configs of the nearest already-tuned
    cells (never the cell's own records — a resumed cell replays its
    checkpoint instead).  The campaign seeds each cursor with them via
    the ``SearchCursor.warm_start`` hook, cutting trials-to-convergence
    on fresh cells (retrieval-style warm-starting, 2503.03826).

Configs read back from history are validated against the registry
before they are proposed: records from an older knob space (missing
knobs, retired values) are silently skipped, never crash a campaign.

Two learned layers sit on top of the raw store (PR 10):

  * **featurization** — :func:`featurize` maps a (config, signature)
    pair to a fixed-layout numeric vector (knob one-hots over the
    registry, active-knob indicators, hashed arch/family buckets) that
    the learned proposer (core/proposer.py) fits its ridge cost model
    over.  The layout is a pure function of the knob registry, so the
    same history bytes featurize identically in every process;
  * **fitted similarity** — :meth:`TrialHistory.similarity_weights`
    replaces the hand-set registry weights with weights fit from the
    history itself: cell pairs that evaluated common configs vote on
    how well one cell's cost ordering predicted the other's, and a
    tiny ridge fit over the signature-match features turns those votes
    into weights.  Warm-start retrieval, ``expected_speedup`` and the
    queue's history prioritizer (core/schedule.py) all ride it; with
    too little cross-cell evidence it falls back to the hand-set
    weights, bit-identically.
"""
from __future__ import annotations

import functools
import hashlib
import json
import pathlib
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fsutil import append_jsonl
from repro.core.params import TunableConfig
from repro.core.space import SPACE

HISTORY_VERSION = 1
HISTORY_FILENAME = "history.jsonl"


def _viable(rec: Dict) -> bool:
    """A record a warm-start may build on: a non-crashed trial with a
    finite cost and a config dict."""
    cost = rec.get("cost_s")
    return (rec.get("cell") is not None and not rec.get("crashed")
            and isinstance(cost, (int, float)) and cost == cost
            and cost != float("inf")
            and isinstance(rec.get("config"), dict))


# ------------------------------------------------------ cell signatures
@functools.lru_cache(maxsize=None)
def active_knobs(kind: str, family: str) -> Tuple[str, ...]:
    """The tunable knobs that can matter to a (kind, family) cell.

    A compile-reach knob is active iff some value flip changes the
    cell's ``compile_key`` projection (i.e. the knob is not
    canonicalized away for this cell class); analytic-reach tunables
    are always active (they enter the roofline terms of every cell).
    """
    base = TunableConfig()
    base_key = base.compile_key(kind, family)
    out = []
    for knob in SPACE:
        if not knob.tunable:
            continue
        if knob.reach == "analytic":
            out.append(knob.name)
            continue
        if any(base.replace(**{knob.name: v}).compile_key(kind, family)
               != base_key for v in knob.domain[1:]):
            out.append(knob.name)
    return tuple(out)


def cell_signature(arch: str, shape: str, multi_pod: bool = False) -> Dict:
    """The features warm-start similarity is computed over."""
    if arch.startswith("kernel-"):
        # kernel cells (core/kernel_cell.py) have no arch config /
        # SHAPES entry; their signature comes from the kernel registry
        # so history prioritization and warm-start never crash on them
        from repro.core.kernel_cell import kernel_signature
        return kernel_signature(arch, shape, multi_pod)
    if arch.startswith("serve-"):
        # serve cells (serving/evaluator.py): trace name is the shape,
        # the serving knob subset is the active-knob list
        from repro.serving.evaluator import serve_signature
        return serve_signature(arch, shape, multi_pod)
    from repro.configs import get_config, get_shape
    kind = get_shape(shape).kind
    family = get_config(arch).family
    return {
        "arch": arch,
        "shape": shape,
        "kind": kind,
        "family": family,
        "multi_pod": bool(multi_pod),
        "active_knobs": list(active_knobs(kind, family)),
    }


# hand-set fallback weights: the shape kind dominates (it selects which
# tree stages and sweep knobs even apply), then the arch family, then
# exact arch/shape matches; the active-knob Jaccard term rewards cells
# whose trials exercised the same knob subset.  Feature order matches
# :func:`similarity_features`.
_W_KIND, _W_FAMILY, _W_ARCH, _W_SHAPE, _W_MESH, _W_KNOBS = \
    4.0, 2.0, 1.0, 1.0, 0.5, 4.0
STATIC_SIMILARITY_WEIGHTS: Tuple[float, ...] = (
    _W_KIND, _W_FAMILY, _W_ARCH, _W_SHAPE, _W_MESH, _W_KNOBS)

#: minimum number of cell pairs with overlapping evaluated configs
#: before the fitted similarity replaces the hand-set weights — below
#: it the fit would memorize noise, so retrieval stays bit-identical
#: to the registry weights.
SIMILARITY_MIN_PAIRS = 8
_SIMILARITY_RIDGE = 1e-2


def similarity_features(a: Dict, b: Dict) -> List[float]:
    """The match features :func:`cell_similarity` weights: kind /
    family / arch / shape / mesh equality plus the active-knob
    Jaccard overlap (all in [0, 1])."""
    ka, kb = set(a["active_knobs"]), set(b["active_knobs"])
    return [
        1.0 if a["kind"] == b["kind"] else 0.0,
        1.0 if a["family"] == b["family"] else 0.0,
        1.0 if a["arch"] == b["arch"] else 0.0,
        1.0 if a["shape"] == b["shape"] else 0.0,
        1.0 if a["multi_pod"] == b["multi_pod"] else 0.0,
        len(ka & kb) / max(1, len(ka | kb)),
    ]


def cell_similarity(a: Dict, b: Dict,
                    weights: Optional[Sequence[float]] = None) -> float:
    """Similarity score of two :func:`cell_signature` dicts (≥ 0).

    ``weights`` (one per :func:`similarity_features` entry) default to
    the hand-set registry weights; :class:`TrialHistory` passes its
    history-fit weights instead."""
    w = STATIC_SIMILARITY_WEIGHTS if weights is None else weights
    return float(sum(wi * fi
                     for wi, fi in zip(w, similarity_features(a, b))))


def fit_similarity_weights(records: Sequence[Dict]
                           ) -> Tuple[float, ...]:
    """Fit the similarity weights from history: which cells actually
    predicted which.

    Every pair of recorded cells that evaluated ≥ 2 common configs
    votes with its *concordance* — the fraction of shared-config pairs
    both cells' costs order the same way (ties count half), i.e. how
    well one cell's ranking transferred to the other.  A ridge fit of
    concordance over the signature-match features yields the weights
    (clamped ≥ 0: a feature match can make cells more transferable,
    never less).  With fewer than :data:`SIMILARITY_MIN_PAIRS` pairs —
    or a degenerate all-zero fit — the hand-set registry weights are
    returned unchanged, so thin histories behave bit-identically to
    the pre-fit retrieval.  Deterministic: same records ⇒ same weights
    (pure numpy on a sorted pair list)."""
    per_cell: Dict[str, Dict[str, Any]] = {}
    for rec in records:
        if not _viable(rec):
            continue
        try:
            sig = cell_signature(rec.get("arch"), rec.get("shape"),
                                 rec.get("multi_pod", False))
        except Exception:
            continue                     # cell from a foreign assignment
        fp = json.dumps(rec["config"], sort_keys=True, default=str)
        ent = per_cell.setdefault(rec["cell"], {"sig": sig, "costs": {}})
        cost = float(rec["cost_s"])
        if fp not in ent["costs"] or cost < ent["costs"][fp]:
            ent["costs"][fp] = cost
    cells = sorted(per_cell)
    xs: List[List[float]] = []
    ys: List[float] = []
    for i, a in enumerate(cells):
        for b in cells[i + 1:]:
            ca, cb = per_cell[a]["costs"], per_cell[b]["costs"]
            shared = sorted(set(ca) & set(cb))
            if len(shared) < 2:
                continue
            agree = total = 0.0
            for p in range(len(shared)):
                for q in range(p + 1, len(shared)):
                    da = ca[shared[p]] - ca[shared[q]]
                    db = cb[shared[p]] - cb[shared[q]]
                    total += 1.0
                    if da == 0.0 or db == 0.0:
                        agree += 0.5
                    elif (da > 0) == (db > 0):
                        agree += 1.0
            xs.append(similarity_features(per_cell[a]["sig"],
                                          per_cell[b]["sig"]))
            ys.append(agree / total)
    if len(xs) < SIMILARITY_MIN_PAIRS:
        return STATIC_SIMILARITY_WEIGHTS
    x = np.asarray(xs, dtype=np.float64)
    y = np.asarray(ys, dtype=np.float64)
    a = x.T @ x + _SIMILARITY_RIDGE * np.eye(x.shape[1])
    w = np.clip(np.linalg.solve(a, x.T @ y), 0.0, None)
    if not np.any(w > 0.0):
        return STATIC_SIMILARITY_WEIGHTS
    return tuple(float(v) for v in w)


def config_from_dict(d: Dict[str, Any]) -> TunableConfig:
    """Rehydrate a config recorded by an (older) knob space: unknown
    fields are dropped, missing fields take today's defaults, and the
    result is validated against the registry (raises ``ValueError`` on
    out-of-domain values)."""
    fields = {f.name for f in TunableConfig.__dataclass_fields__.values()}
    cfg = TunableConfig(**{k: v for k, v in d.items() if k in fields})
    SPACE.validate(cfg)
    return cfg


# -------------------------------------------------------- featurization
#: bumped whenever the feature layout changes — enters the learned
#: proposer's fit digest so checkpointed fits from an older layout are
#: rebuilt, never misread.
FEATURES_VERSION = 1

_SIG_HASH_BUCKETS = 8


def _hash_bucket(s: str) -> int:
    """Stable (process- and machine-independent) hash bucket for a
    categorical signature feature — ``hash()`` is salted per process,
    so it would break the same-bytes ⇒ same-features contract."""
    return int(hashlib.sha1(str(s).encode()).hexdigest(), 16) \
        % _SIG_HASH_BUCKETS


@functools.lru_cache(maxsize=1)
def feature_names() -> Tuple[str, ...]:
    """The fixed feature layout: bias, one indicator per (knob, value)
    of the registry plus one active-knob indicator per knob (registry
    order — load-bearing, like ``compile_key``), then hashed family
    and arch buckets.  A pure function of the knob registry."""
    names = ["bias"]
    for knob in SPACE:
        for v in knob.domain:
            names.append(f"{knob.name}={v}")
        names.append(f"active:{knob.name}")
    names.extend(f"family#{i}" for i in range(_SIG_HASH_BUCKETS))
    names.extend(f"arch#{i}" for i in range(_SIG_HASH_BUCKETS))
    return tuple(names)


def featurize(config: Dict[str, Any], sig: Dict) -> np.ndarray:
    """Map one (config dict, :func:`cell_signature`) pair to the fixed
    feature vector the learned proposer fits over.

    Missing knobs take the registry default (an older-space record
    still featurizes); an out-of-domain value raises ``ValueError`` so
    callers skip the record instead of fitting on garbage."""
    active = set(sig.get("active_knobs") or ())
    x = np.zeros(len(feature_names()), dtype=np.float64)
    x[0] = 1.0
    i = 1
    for knob in SPACE:
        v = config.get(knob.name, knob.default)
        try:
            j = list(knob.domain).index(v)
        except ValueError:
            raise ValueError(
                f"{knob.name}={v!r} not in domain {knob.domain}")
        x[i + j] = 1.0
        i += len(knob.domain)
        x[i] = 1.0 if knob.name in active else 0.0
        i += 1
    x[i + _hash_bucket(sig.get("family", ""))] = 1.0
    i += _SIG_HASH_BUCKETS
    x[i + _hash_bucket(sig.get("arch", ""))] = 1.0
    return x


# --------------------------------------------------------------- store
class TrialHistory:
    """Append-only JSONL store of evaluated trials, shared by every
    process that works a campaign directory.

    One line per trial; appends go through a single ``os.write`` on an
    ``O_APPEND`` descriptor so concurrent workers never interleave
    partial lines.  Readers tolerate torn/corrupt lines (a reader can
    race the tail of a concurrent append) by skipping them.
    """

    def __init__(self, path: pathlib.Path):
        self.path = pathlib.Path(path)
        self._cache: Optional[Tuple[Tuple[int, int], List[Dict]]] = None
        self._speedups: Optional[Tuple[Tuple[int, int], Dict]] = None
        self._expected: Optional[Tuple[Tuple[int, int], Dict]] = None
        self._simw: Optional[Tuple[Tuple[int, int],
                                   Tuple[float, ...]]] = None
        # incremental-reader state: records parsed from consumed bytes,
        # the byte offset just past the last *complete*
        # (newline-terminated) line already parsed, and a fingerprint
        # of the bytes leading up to it so a rewritten file (not an
        # append) forces a full re-parse
        self._consumed: List[Dict] = []
        self._tail = 0
        self._tail_fp = b""

    # ------------------------------------------------------- appending
    def append(self, record: Dict[str, Any]) -> None:
        # one O_APPEND line with torn-tail self-healing; the idiom
        # lives in core/fsutil.append_jsonl (shared with the quarantine
        # ledger, core/quarantine.py)
        append_jsonl(self.path, record)

    def record_trial(self, workload, strategy: str, rt: TunableConfig,
                     name: str, result, delta: Optional[Dict] = None
                     ) -> None:
        """Append one evaluated trial (the TrialRunner emission hook)."""
        self.append({
            "v": HISTORY_VERSION,
            "ts": round(time.time(), 3),
            "cell": workload.key(),
            "arch": workload.arch,
            "shape": workload.shape,
            "multi_pod": bool(workload.multi_pod),
            "strategy": strategy,
            "name": name,
            "delta": delta or {},
            "config": rt.as_dict(),
            "cost_s": result.cost_s,
            "crashed": bool(result.crashed),
            "failure": getattr(result, "failure", ""),
            "retries": int(getattr(result, "retries", 0)),
            "compiles": result.compiles,
            "compile_s": result.compile_s,
            "cached": bool(result.cached),
        })

    def sink(self, strategy: str):
        """A ``TrialRunner.history`` callable bound to a strategy name."""
        def emit(workload, rt, name, result, delta):
            self.record_trial(workload, strategy, rt, name, result, delta)
        return emit

    # --------------------------------------------------------- reading
    _TAIL_FP_BYTES = 64

    def _tail_fingerprint(self, f) -> bytes:
        """sha1 of the last ≤ 64 consumed bytes — a cheap probe that
        the file up to ``self._tail`` is still the bytes we parsed
        (append-only growth), not a same-or-larger rewrite."""
        n = min(self._TAIL_FP_BYTES, self._tail)
        f.seek(self._tail - n)
        return hashlib.sha1(f.read(n)).digest()

    def records(self) -> List[Dict]:
        """Parsed records, oldest first; torn/corrupt lines skipped.

        Incremental: the parse is cached per (size, mtime) of the file
        *and* only the appended tail is re-read when the file grows —
        a long-lived fabric worker polling the board between batches
        pays one small tail read per append, not a full re-parse of an
        ever-growing file.  A shrunk or rewritten file (tail
        fingerprint mismatch) falls back to a full re-parse.  Torn-tail
        healing is preserved: an unterminated final line is parsed but
        never *consumed*, so the next read retries it once the
        concurrent appender (or :func:`~repro.core.fsutil.append_jsonl`
        self-healing) completes it."""
        try:
            st = self.path.stat()
        except OSError:
            self._cache = None
            self._consumed = []
            self._tail = 0
            self._tail_fp = b""
            return []
        sig = (st.st_size, st.st_mtime_ns)
        if self._cache is not None and self._cache[0] == sig:
            return list(self._cache[1])
        start = 0
        try:
            with open(self.path, "rb") as f:
                if (self._tail and st.st_size >= self._tail
                        and self._tail_fingerprint(f) == self._tail_fp):
                    start = self._tail   # append-only growth: tail only
                else:
                    self._consumed = []
                    self._tail = 0
                f.seek(start)
                data = f.read()
        except OSError:
            return []
        idx = consumed = 0
        extra: List[Dict] = []           # parsed from unterminated tail
        while True:
            nl = data.find(b"\n", idx)
            line = data[idx:] if nl < 0 else data[idx:nl]
            line = line.strip()
            if line:
                try:
                    rec = json.loads(line)
                except (ValueError, UnicodeDecodeError):
                    rec = None           # torn/corrupt line: skip
                if isinstance(rec, dict):
                    (extra if nl < 0 else self._consumed).append(rec)
            if nl < 0:
                break                    # unterminated tail: not consumed
            idx = nl + 1
            consumed = idx
        self._tail = start + consumed
        try:
            with open(self.path, "rb") as f:
                self._tail_fp = self._tail_fingerprint(f)
        except OSError:
            self._consumed = []
            self._tail = 0
            self._tail_fp = b""
        out = self._consumed + extra
        self._cache = (sig, out)
        return list(out)

    def cells(self) -> List[str]:
        """Distinct cell keys with at least one recorded trial."""
        return sorted({r["cell"] for r in self.records() if "cell" in r})

    def n_records(self) -> int:
        return sum(1 for _ in self.records())

    # ---------------------------------------------- fitted similarity
    def similarity_weights(self) -> Tuple[float, ...]:
        """The similarity-feature weights retrieval runs on: fit from
        this history (:func:`fit_similarity_weights`) when it holds
        enough cross-cell config overlaps, else the hand-set registry
        weights.  Cached on the same (size, mtime) signature as
        :meth:`records` — one fit per history growth, not per query."""
        recs = self.records()            # refreshes self._cache
        sig = self._cache[0] if self._cache is not None else None
        if sig is not None and self._simw is not None \
                and self._simw[0] == sig:
            return self._simw[1]
        w = fit_similarity_weights(recs)
        if sig is not None:
            self._simw = (sig, w)
        return w

    # ------------------------------------------------- expected speedup
    def cell_speedups(self) -> Dict[str, Dict[str, Any]]:
        """Per recorded cell: the observed baseline cost, best viable
        cost and the implied speedup (baseline / best).

        The baseline is the cheapest viable record named ``baseline``
        (the same deterministic trial every strategy evaluates first);
        a cell whose baseline crashed falls back to its earliest viable
        record, so a "recovered" cell still reports the gain its trials
        actually demonstrated.  Cells with no viable record at all are
        omitted.

        Cached on the same (size, mtime) signature as :meth:`records`:
        a scheduling pass scoring N cells pays one aggregation, not N
        (the online scheduler re-ranks on every queue hand-out).
        """
        recs = self.records()            # refreshes self._cache
        sig = self._cache[0] if self._cache is not None else None
        if sig is not None and self._speedups is not None \
                and self._speedups[0] == sig:
            return dict(self._speedups[1])
        per_cell: Dict[str, List[Dict]] = {}
        for rec in recs:
            if _viable(rec):
                per_cell.setdefault(rec["cell"], []).append(rec)
        out: Dict[str, Dict[str, Any]] = {}
        for cell, recs in per_cell.items():
            base = min((r["cost_s"] for r in recs
                        if r.get("name") == "baseline"),
                       default=None)
            if base is None:
                base = min(recs, key=lambda r: r.get("ts", 0.0))["cost_s"]
            best = min(r["cost_s"] for r in recs)
            first = recs[0]
            out[cell] = {
                "arch": first.get("arch"),
                "shape": first.get("shape"),
                "multi_pod": bool(first.get("multi_pod", False)),
                "baseline_cost": base,
                "best_cost": best,
                "speedup": base / best if best > 0 else float("nan"),
                "trials": len(recs),
            }
        if sig is not None:
            self._speedups = (sig, out)
        return dict(out)

    def expected_speedup(self, arch: str, shape: str,
                         multi_pod: bool = False, *,
                         k_cells: int = 2) -> Optional[float]:
        """Expected-speedup estimate for a cell: the best observed
        speedup among the ``k_cells`` nearest *same-shape-kind* cells
        in the history (best-of-nearest, the same registry-derived
        similarity warm-start retrieval uses).  Unlike
        :meth:`warmstart_configs`, the target cell's own records are
        included — identity similarity dominates, so a cell the history
        has already tuned is scored by its own demonstrated gain.

        Speedups only transfer within a shape kind: the tuning tree's
        stages and the sweepable knobs are kind-keyed, so a train
        cell's demonstrated gain says nothing about a decode cell's
        walk.  ``None`` when no same-kind cell is recorded — the online
        scheduler treats that as *unknown* and schedules the cell
        explore-first.

        Similarity uses the history-fit weights
        (:meth:`similarity_weights`), and the estimate is memoized per
        (cell, k_cells) on the records signature — the online
        scheduler re-ranks the queue at every hand-out, so between
        appends an N-cell re-rank costs N dict hits, not N similarity
        scans."""
        self.records()                   # refreshes self._cache
        sig = self._cache[0] if self._cache is not None else None
        key = (arch, shape, bool(multi_pod), int(k_cells))
        if sig is not None and self._expected is not None \
                and self._expected[0] == sig \
                and key in self._expected[1]:
            return self._expected[1][key]
        weights = self.similarity_weights()
        target_sig = cell_signature(arch, shape, multi_pod)
        scored: List[Tuple[float, str, float]] = []
        for cell, info in self.cell_speedups().items():
            sp = info["speedup"]
            if sp != sp:                 # NaN: nothing demonstrable
                continue
            try:
                csig = cell_signature(info["arch"], info["shape"],
                                      info["multi_pod"])
            except (KeyError, TypeError):
                continue                 # cell from a foreign assignment
            if csig["kind"] != target_sig["kind"]:
                continue                 # gains don't transfer kinds
            scored.append((cell_similarity(target_sig, csig,
                                           weights=weights), cell, sp))
        scored.sort(key=lambda t: (-t[0], t[1]))
        top = scored[:max(0, k_cells)]
        out = max(sp for _, _, sp in top) if top else None
        if sig is not None:
            if self._expected is None or self._expected[0] != sig:
                self._expected = (sig, {})
            self._expected[1][key] = out
        return out

    # ------------------------------------------------------ warm-start
    def warmstart_configs(self, arch: str, shape: str,
                          multi_pod: bool = False, *,
                          k_cells: int = 2, per_cell: int = 1
                          ) -> List[Dict[str, Any]]:
        """Best configs of the ``k_cells`` nearest already-tuned cells
        (the target cell's own records are excluded — resume comes from
        the checkpoint, not from history).  Returns normalized full
        config dicts, registry-validated, deduplicated, ordered by
        descending cell similarity (history-fit weights,
        :meth:`similarity_weights`)."""
        from repro.core.trial import Workload
        target_key = Workload(arch, shape, multi_pod).key()
        target_sig = cell_signature(arch, shape, multi_pod)
        weights = self.similarity_weights()

        # group the viable records per foreign cell
        per_cell_recs: Dict[str, List[Dict]] = {}
        for rec in self.records():
            if not _viable(rec) or rec["cell"] == target_key:
                continue
            per_cell_recs.setdefault(rec["cell"], []).append(rec)

        scored: List[Tuple[float, str]] = []
        for cell, recs in per_cell_recs.items():
            r = recs[0]
            try:
                sig = cell_signature(r.get("arch"), r.get("shape"),
                                     r.get("multi_pod", False))
            except (KeyError, TypeError):
                continue                 # cell from a foreign assignment
            scored.append((cell_similarity(target_sig, sig,
                                           weights=weights), cell))
        # deterministic: similarity desc, then cell key asc
        scored.sort(key=lambda t: (-t[0], t[1]))

        out: List[Dict[str, Any]] = []
        seen = set()
        for _, cell in scored[:max(0, k_cells)]:
            recs = sorted(per_cell_recs[cell],
                          key=lambda r: (r["cost_s"],
                                         r.get("ts", 0.0)))
            taken = 0
            for rec in recs:
                if taken >= per_cell:
                    break
                try:
                    cfg = config_from_dict(rec["config"])
                except (ValueError, TypeError):
                    continue             # older knob space: skip record
                d = cfg.as_dict()
                fp = json.dumps(d, sort_keys=True, default=str)
                if fp in seen:
                    continue
                seen.add(fp)
                out.append(d)
                taken += 1
        return out
