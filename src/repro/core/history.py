"""Trial-history store — every trial ever run, queryable for warm-starts.

The campaign engine made trials cheap and resumable, but every campaign
still started from zero knowledge: a fresh cell's cursor walked the
whole tree as if no similar cell had ever been tuned.  The
:class:`TrialHistory` store makes campaigns *cumulative*:

  * **append-only JSONL** — every evaluated trial (config, cell, cost,
    compile stats) is appended as one JSON line to a shared
    ``history.jsonl`` next to the campaign checkpoints.  Appends are a
    single ``write(2)`` on an ``O_APPEND`` descriptor, so concurrent
    fabric workers (core/fabric.py) interleave whole lines, never
    bytes; readers skip torn or foreign lines instead of failing;
  * **cell signatures** — :func:`cell_signature` describes a cell by
    the features that determine which knobs matter to it: the shape
    kind, the arch family, and the *active knob set* derived from the
    :data:`~repro.core.space.SPACE` registry (a tunable knob is active
    iff flipping it can change the cell's ``compile_key`` projection,
    plus the always-analytic knobs).  :func:`cell_similarity` scores
    two signatures (kind ≫ family ≫ arch/shape, plus Jaccard overlap
    of the active knob sets), so "nearest cell" means "cell whose
    trials exercised the same knobs";
  * **warm-start queries** — :meth:`TrialHistory.warmstart_configs`
    returns the best observed configs of the nearest already-tuned
    cells (never the cell's own records — a resumed cell replays its
    checkpoint instead).  The campaign seeds each cursor with them via
    the ``SearchCursor.warm_start`` hook, cutting trials-to-convergence
    on fresh cells (retrieval-style warm-starting, 2503.03826).

Configs read back from history are validated against the registry
before they are proposed: records from an older knob space (missing
knobs, retired values) are silently skipped, never crash a campaign.
"""
from __future__ import annotations

import functools
import json
import pathlib
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core.fsutil import append_jsonl
from repro.core.params import TunableConfig
from repro.core.space import SPACE

HISTORY_VERSION = 1
HISTORY_FILENAME = "history.jsonl"


def _viable(rec: Dict) -> bool:
    """A record a warm-start may build on: a non-crashed trial with a
    finite cost and a config dict."""
    cost = rec.get("cost_s")
    return (rec.get("cell") is not None and not rec.get("crashed")
            and isinstance(cost, (int, float)) and cost == cost
            and cost != float("inf")
            and isinstance(rec.get("config"), dict))


# ------------------------------------------------------ cell signatures
@functools.lru_cache(maxsize=None)
def active_knobs(kind: str, family: str) -> Tuple[str, ...]:
    """The tunable knobs that can matter to a (kind, family) cell.

    A compile-reach knob is active iff some value flip changes the
    cell's ``compile_key`` projection (i.e. the knob is not
    canonicalized away for this cell class); analytic-reach tunables
    are always active (they enter the roofline terms of every cell).
    """
    base = TunableConfig()
    base_key = base.compile_key(kind, family)
    out = []
    for knob in SPACE:
        if not knob.tunable:
            continue
        if knob.reach == "analytic":
            out.append(knob.name)
            continue
        if any(base.replace(**{knob.name: v}).compile_key(kind, family)
               != base_key for v in knob.domain[1:]):
            out.append(knob.name)
    return tuple(out)


def cell_signature(arch: str, shape: str, multi_pod: bool = False) -> Dict:
    """The features warm-start similarity is computed over."""
    if arch.startswith("kernel-"):
        # kernel cells (core/kernel_cell.py) have no arch config /
        # SHAPES entry; their signature comes from the kernel registry
        # so history prioritization and warm-start never crash on them
        from repro.core.kernel_cell import kernel_signature
        return kernel_signature(arch, shape, multi_pod)
    if arch.startswith("serve-"):
        # serve cells (serving/evaluator.py): trace name is the shape,
        # the serving knob subset is the active-knob list
        from repro.serving.evaluator import serve_signature
        return serve_signature(arch, shape, multi_pod)
    from repro.configs import get_config, get_shape
    kind = get_shape(shape).kind
    family = get_config(arch).family
    return {
        "arch": arch,
        "shape": shape,
        "kind": kind,
        "family": family,
        "multi_pod": bool(multi_pod),
        "active_knobs": list(active_knobs(kind, family)),
    }


# weights: the shape kind dominates (it selects which tree stages and
# sweep knobs even apply), then the arch family, then exact arch/shape
# matches; the active-knob Jaccard term rewards cells whose trials
# exercised the same knob subset.
_W_KIND, _W_FAMILY, _W_ARCH, _W_SHAPE, _W_MESH, _W_KNOBS = \
    4.0, 2.0, 1.0, 1.0, 0.5, 4.0


def cell_similarity(a: Dict, b: Dict) -> float:
    """Similarity score of two :func:`cell_signature` dicts (≥ 0)."""
    s = 0.0
    s += _W_KIND if a["kind"] == b["kind"] else 0.0
    s += _W_FAMILY if a["family"] == b["family"] else 0.0
    s += _W_ARCH if a["arch"] == b["arch"] else 0.0
    s += _W_SHAPE if a["shape"] == b["shape"] else 0.0
    s += _W_MESH if a["multi_pod"] == b["multi_pod"] else 0.0
    ka, kb = set(a["active_knobs"]), set(b["active_knobs"])
    s += _W_KNOBS * len(ka & kb) / max(1, len(ka | kb))
    return s


def config_from_dict(d: Dict[str, Any]) -> TunableConfig:
    """Rehydrate a config recorded by an (older) knob space: unknown
    fields are dropped, missing fields take today's defaults, and the
    result is validated against the registry (raises ``ValueError`` on
    out-of-domain values)."""
    fields = {f.name for f in TunableConfig.__dataclass_fields__.values()}
    cfg = TunableConfig(**{k: v for k, v in d.items() if k in fields})
    SPACE.validate(cfg)
    return cfg


# --------------------------------------------------------------- store
class TrialHistory:
    """Append-only JSONL store of evaluated trials, shared by every
    process that works a campaign directory.

    One line per trial; appends go through a single ``os.write`` on an
    ``O_APPEND`` descriptor so concurrent workers never interleave
    partial lines.  Readers tolerate torn/corrupt lines (a reader can
    race the tail of a concurrent append) by skipping them.
    """

    def __init__(self, path: pathlib.Path):
        self.path = pathlib.Path(path)
        self._cache: Optional[Tuple[Tuple[int, int], List[Dict]]] = None
        self._speedups: Optional[Tuple[Tuple[int, int], Dict]] = None

    # ------------------------------------------------------- appending
    def append(self, record: Dict[str, Any]) -> None:
        # one O_APPEND line with torn-tail self-healing; the idiom
        # lives in core/fsutil.append_jsonl (shared with the quarantine
        # ledger, core/quarantine.py)
        append_jsonl(self.path, record)

    def record_trial(self, workload, strategy: str, rt: TunableConfig,
                     name: str, result, delta: Optional[Dict] = None
                     ) -> None:
        """Append one evaluated trial (the TrialRunner emission hook)."""
        self.append({
            "v": HISTORY_VERSION,
            "ts": round(time.time(), 3),
            "cell": workload.key(),
            "arch": workload.arch,
            "shape": workload.shape,
            "multi_pod": bool(workload.multi_pod),
            "strategy": strategy,
            "name": name,
            "delta": delta or {},
            "config": rt.as_dict(),
            "cost_s": result.cost_s,
            "crashed": bool(result.crashed),
            "failure": getattr(result, "failure", ""),
            "retries": int(getattr(result, "retries", 0)),
            "compiles": result.compiles,
            "compile_s": result.compile_s,
            "cached": bool(result.cached),
        })

    def sink(self, strategy: str):
        """A ``TrialRunner.history`` callable bound to a strategy name."""
        def emit(workload, rt, name, result, delta):
            self.record_trial(workload, strategy, rt, name, result, delta)
        return emit

    # --------------------------------------------------------- reading
    def records(self) -> List[Dict]:
        """Parsed records, oldest first; torn/corrupt lines skipped.
        The parse is cached per (size, mtime) of the file, so a
        campaign querying warm-starts for N cells (or a fabric worker
        polling the board) pays one parse, not N."""
        try:
            st = self.path.stat()
        except OSError:
            return []
        sig = (st.st_size, st.st_mtime_ns)
        if self._cache is not None and self._cache[0] == sig:
            return list(self._cache[1])
        try:
            text = self.path.read_text()
        except OSError:
            return []
        out: List[Dict] = []
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue                 # torn tail of a concurrent append
            if isinstance(rec, dict):
                out.append(rec)
        self._cache = (sig, out)
        return list(out)

    def cells(self) -> List[str]:
        """Distinct cell keys with at least one recorded trial."""
        return sorted({r["cell"] for r in self.records() if "cell" in r})

    def n_records(self) -> int:
        return sum(1 for _ in self.records())

    # ------------------------------------------------- expected speedup
    def cell_speedups(self) -> Dict[str, Dict[str, Any]]:
        """Per recorded cell: the observed baseline cost, best viable
        cost and the implied speedup (baseline / best).

        The baseline is the cheapest viable record named ``baseline``
        (the same deterministic trial every strategy evaluates first);
        a cell whose baseline crashed falls back to its earliest viable
        record, so a "recovered" cell still reports the gain its trials
        actually demonstrated.  Cells with no viable record at all are
        omitted.

        Cached on the same (size, mtime) signature as :meth:`records`:
        a scheduling pass scoring N cells pays one aggregation, not N
        (the online scheduler re-ranks on every queue hand-out).
        """
        recs = self.records()            # refreshes self._cache
        sig = self._cache[0] if self._cache is not None else None
        if sig is not None and self._speedups is not None \
                and self._speedups[0] == sig:
            return dict(self._speedups[1])
        per_cell: Dict[str, List[Dict]] = {}
        for rec in recs:
            if _viable(rec):
                per_cell.setdefault(rec["cell"], []).append(rec)
        out: Dict[str, Dict[str, Any]] = {}
        for cell, recs in per_cell.items():
            base = min((r["cost_s"] for r in recs
                        if r.get("name") == "baseline"),
                       default=None)
            if base is None:
                base = min(recs, key=lambda r: r.get("ts", 0.0))["cost_s"]
            best = min(r["cost_s"] for r in recs)
            first = recs[0]
            out[cell] = {
                "arch": first.get("arch"),
                "shape": first.get("shape"),
                "multi_pod": bool(first.get("multi_pod", False)),
                "baseline_cost": base,
                "best_cost": best,
                "speedup": base / best if best > 0 else float("nan"),
                "trials": len(recs),
            }
        if sig is not None:
            self._speedups = (sig, out)
        return dict(out)

    def expected_speedup(self, arch: str, shape: str,
                         multi_pod: bool = False, *,
                         k_cells: int = 2) -> Optional[float]:
        """Expected-speedup estimate for a cell: the best observed
        speedup among the ``k_cells`` nearest *same-shape-kind* cells
        in the history (best-of-nearest, the same registry-derived
        similarity warm-start retrieval uses).  Unlike
        :meth:`warmstart_configs`, the target cell's own records are
        included — identity similarity dominates, so a cell the history
        has already tuned is scored by its own demonstrated gain.

        Speedups only transfer within a shape kind: the tuning tree's
        stages and the sweepable knobs are kind-keyed, so a train
        cell's demonstrated gain says nothing about a decode cell's
        walk.  ``None`` when no same-kind cell is recorded — the online
        scheduler treats that as *unknown* and schedules the cell
        explore-first."""
        target_sig = cell_signature(arch, shape, multi_pod)
        scored: List[Tuple[float, str, float]] = []
        for cell, info in self.cell_speedups().items():
            sp = info["speedup"]
            if sp != sp:                 # NaN: nothing demonstrable
                continue
            try:
                sig = cell_signature(info["arch"], info["shape"],
                                     info["multi_pod"])
            except (KeyError, TypeError):
                continue                 # cell from a foreign assignment
            if sig["kind"] != target_sig["kind"]:
                continue                 # gains don't transfer kinds
            scored.append((cell_similarity(target_sig, sig), cell, sp))
        scored.sort(key=lambda t: (-t[0], t[1]))
        top = scored[:max(0, k_cells)]
        if not top:
            return None
        return max(sp for _, _, sp in top)

    # ------------------------------------------------------ warm-start
    def warmstart_configs(self, arch: str, shape: str,
                          multi_pod: bool = False, *,
                          k_cells: int = 2, per_cell: int = 1
                          ) -> List[Dict[str, Any]]:
        """Best configs of the ``k_cells`` nearest already-tuned cells
        (the target cell's own records are excluded — resume comes from
        the checkpoint, not from history).  Returns normalized full
        config dicts, registry-validated, deduplicated, ordered by
        descending cell similarity."""
        from repro.core.trial import Workload
        target_key = Workload(arch, shape, multi_pod).key()
        target_sig = cell_signature(arch, shape, multi_pod)

        # group the viable records per foreign cell
        per_cell_recs: Dict[str, List[Dict]] = {}
        for rec in self.records():
            if not _viable(rec) or rec["cell"] == target_key:
                continue
            per_cell_recs.setdefault(rec["cell"], []).append(rec)

        scored: List[Tuple[float, str]] = []
        for cell, recs in per_cell_recs.items():
            r = recs[0]
            try:
                sig = cell_signature(r.get("arch"), r.get("shape"),
                                     r.get("multi_pod", False))
            except (KeyError, TypeError):
                continue                 # cell from a foreign assignment
            scored.append((cell_similarity(target_sig, sig), cell))
        # deterministic: similarity desc, then cell key asc
        scored.sort(key=lambda t: (-t[0], t[1]))

        out: List[Dict[str, Any]] = []
        seen = set()
        for _, cell in scored[:max(0, k_cells)]:
            recs = sorted(per_cell_recs[cell],
                          key=lambda r: (r["cost_s"],
                                         r.get("ts", 0.0)))
            taken = 0
            for rec in recs:
                if taken >= per_cell:
                    break
                try:
                    cfg = config_from_dict(rec["config"])
                except (ValueError, TypeError):
                    continue             # older knob space: skip record
                d = cfg.as_dict()
                fp = json.dumps(d, sort_keys=True, default=str)
                if fp in seen:
                    continue
                seen.add(fp)
                out.append(d)
                taken += 1
        return out
