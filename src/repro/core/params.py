"""The tunable-parameter space — the TPU/JAX analogue of the paper's Sec. 3.

Each field of :class:`TunableConfig` maps 1:1 to one of the 12 Spark
parameters the paper tunes (the two memoryFraction parameters are one
*joint* knob, exactly as the paper tunes them: "shuffle/storage
.memoryFraction = 0.4/0.4").

Every per-knob fact — domain, default, Spark analogue, sensitivity
sweep values, compile-vs-analytic reach class and its evidence — is
declared exactly once in :data:`repro.core.space.SPACE`; the historical
module-level names below (``DOMAINS``, ``SENSITIVITY_SWEEP``,
``PARAM_DOCS``, ``COMPILE_KNOBS``/``ANALYTIC_KNOBS``, ``KNOB_REACH``)
are thin re-exports derived from that registry so existing imports keep
working (tests/test_space.py pins them against the registry).

The tuner strategies (core/strategy.py) treat the step function as a
black box and only ever edit these fields; the runtime
(runtime/stepfn.py) consumes them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

from repro.core.space import SPACE

# value domains per tunable knob (first entry = Spark-like default)
DOMAINS: Dict[str, Tuple[Any, ...]] = SPACE.domains()

# ------------------------------------------------------- knob partition
# Which TunableConfig fields can change the lowered/compiled HLO of a
# step function, vs. which only ever enter the ANALYTIC roofline terms.
# The RooflineEvaluator's calibration compiles force attn_impl="xla"
# (core/trial.py), and the Pallas VMEM tile sizes exist only inside the
# Pallas kernel — so those three knobs never reach the compiled program
# and a sweep over them can reuse a single compile.  The tuple order is
# load-bearing (it fixes the compile_key layout, hence the disk
# compile-cache keys) and comes from the registry's registration order.
COMPILE_KNOBS: Tuple[str, ...] = SPACE.compile_knobs()
ANALYTIC_KNOBS: Tuple[str, ...] = SPACE.analytic_knobs()

# Where each knob actually reaches the step function.  Broader than the
# pre-registry re-export: every knob now carries an evidence line (the
# registry enforces it), not just the eight compile knobs that
# compile_key() conditionally canonicalizes — those eight are still the
# evidence for the canonicalizations below.
KNOB_REACH: Dict[str, str] = SPACE.reach_evidence()

# Spark parameter <-> knob documentation (DESIGN.md §2.1, Table 2 rows)
PARAM_DOCS: Dict[str, str] = SPACE.docs()

# Knobs swept by the Sec.-4 sensitivity analysis, with the values tested
# (default first, mirroring the paper's value-selection rules: binary ->
# non-default; categorical -> all; numeric -> neighbours of default).
SENSITIVITY_SWEEP: Dict[str, Tuple[Any, ...]] = SPACE.sweep()


@dataclasses.dataclass(frozen=True)
class TunableConfig:
    """One point in the 12-knob configuration space (Sec. 3 analogue)."""
    # 1. spark.serializer (Java -> Kryo)
    compute_dtype: str = "float32"
    # 2. spark.shuffle.manager (sort | hash | tungsten-sort)
    shard_strategy: str = "dp"
    # 3. spark.shuffle.compress
    grad_comm_dtype: str = "float32"
    # 4. spark.io.compression.codec (snappy | lzf | lz4; float32 = off)
    comm_codec: str = "bfloat16"
    # 5+6. spark.shuffle.memoryFraction / spark.storage.memoryFraction (joint)
    remat_policy: str = "dots"
    # 7. spark.reducer.maxSizeInFlight
    microbatches: int = 1
    # 8. spark.shuffle.file.buffer (Pallas VMEM tile)
    attn_block_q: int = 128
    attn_block_kv: int = 128
    # 9. spark.shuffle.consolidateFiles
    fuse_grad_collectives: bool = False
    # 10. spark.rdd.compress
    kv_cache_dtype: str = "bfloat16"
    # 11. spark.shuffle.spill.compress
    remat_save_dtype: str = "float32"
    # 12. spark.shuffle.io.preferDirectBufs
    donate_buffers: bool = True
    # beyond-paper
    attn_tp_fallback: str = "replicate"
    attn_impl: str = "xla"       # xla | pallas (pallas on TPU; xla on dry-run)
    seq_parallel: bool = False   # shard residual seq dim over the model axis
    # infrastructure (not tuned): unrolled layer stack for cost
    # calibration / cross-layer fusion experiments
    unroll_layers: bool = False
    # serving knobs (tuned only by serve cells via their own stage tree;
    # analytic reach, so compile keys and step campaigns are unaffected)
    max_wave_size: int = 4
    wave_admission: str = "greedy"

    def replace(self, **kw) -> "TunableConfig":
        return dataclasses.replace(self, **kw)

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def compile_key(self, kind: str = None, family: str = None
                    ) -> Tuple[Tuple[str, Any], ...]:
        """Projection onto the knobs that can reach the compiled HLO.

        Two configs with equal compile keys lower+compile to identical
        programs for a (kind, family) cell, so an evaluator may share
        one compile between them and recompute only the analytic
        roofline terms (the trial-throughput engine, core/trial.py).

        ``ANALYTIC_KNOBS`` are always dropped.  When the cell context is
        given, knobs that provably never reach that cell's step function
        are canonicalized to their defaults (see KNOB_REACH for the
        per-knob evidence).
        """
        d = {k: getattr(self, k) for k in COMPILE_KNOBS}
        dflt = _DEFAULT_CFG
        if kind is not None and kind != "train":
            # serve steps build no gradient/optimizer machinery
            # (runtime/stepfn.py build_prefill_step / build_decode_step)
            for k in ("grad_comm_dtype", "fuse_grad_collectives",
                      "microbatches"):
                d[k] = getattr(dflt, k)
            if kind == "prefill" and family in ("dense", "vlm", "moe"):
                # transformer prefill scans through remat.to_carry: the
                # remat pair only matters via the derived carry dtype
                d["remat_save_dtype"] = _carry_dtype(
                    d["remat_policy"], d["remat_save_dtype"],
                    d["compute_dtype"])
                d["remat_policy"] = "_carry"
            elif kind == "prefill" and family == "encdec":
                # encdec prefill runs the full encoder stack through
                # remat.wrap_layer + to_carry — keep the pair as-is
                pass
            else:
                # decode bodies (and ssm/hybrid prefills) never touch
                # the remat machinery
                d["remat_policy"] = dflt.remat_policy
                d["remat_save_dtype"] = dflt.remat_save_dtype
            if kind == "prefill":
                # build_prefill_step jits with no donate_argnums
                d["donate_buffers"] = dflt.donate_buffers
        if kind == "train":
            # the train step builds no KV cache
            d["kv_cache_dtype"] = dflt.kv_cache_dtype
        if family is not None:
            if family != "moe":
                # the wire codec exists only in the MoE all-to-all
                d["comm_codec"] = dflt.comm_codec
            if family == "ssm":
                # xlstm keeps f32 recurrent state, no attention KV cache
                d["kv_cache_dtype"] = dflt.kv_cache_dtype
            # grad-comm knobs are real only on the explicit path
            # (runtime/gradsync.explicit_applicable)
            if not (d["shard_strategy"] in ("dp", "fsdp")
                    and family != "moe"):
                d["grad_comm_dtype"] = dflt.grad_comm_dtype
                d["fuse_grad_collectives"] = dflt.fuse_grad_collectives
            elif (d["shard_strategy"] != "dp"
                  and d["grad_comm_dtype"] == "int8_ef"):
                d["grad_comm_dtype"] = "bfloat16"   # stepfn fallback
        if d["remat_policy"] == "none":
            d["remat_save_dtype"] = dflt.remat_save_dtype  # nothing saved
        return tuple((k, d[k]) for k in COMPILE_KNOBS)

    def validate(self) -> None:
        SPACE.validate(self)

    def describe_delta(self, other: "TunableConfig") -> str:
        ds = [f"{k}={v!r}" for k, v in other.as_dict().items()
              if self.as_dict().get(k) != v]
        return ", ".join(ds) if ds else "(no change)"


_DEFAULT_CFG = TunableConfig()

_DTYPE_SIZE = {"float32": 4, "bfloat16": 2, "float16": 2}


def _carry_dtype(remat_policy: str, save_dtype: str, compute_dtype: str
                 ) -> str:
    """Mirror of runtime/remat.carry_dtype on knob strings."""
    if remat_policy == "none":
        return compute_dtype
    if _DTYPE_SIZE.get(save_dtype, 4) < _DTYPE_SIZE.get(compute_dtype, 4):
        return save_dtype
    return compute_dtype


def default_config(**overrides) -> TunableConfig:
    """Paper-faithful default (all-Spark-defaults analogue)."""
    c = TunableConfig(**overrides)
    c.validate()
    return c


def exhaustive_size() -> int:
    """Size of the exhaustive grid the paper's 10-trial tree avoids,
    computed arithmetically from the registry (the old implementation
    materialized the full ``itertools.product`` just to ``len`` it)."""
    return SPACE.exhaustive_size()
