"""The tunable-parameter space — the TPU/JAX analogue of the paper's Sec. 3.

Each field of :class:`TunableConfig` maps 1:1 to one of the 12 Spark
parameters the paper tunes (``PARAM_DOCS`` records the mapping; the two
memoryFraction parameters are one *joint* knob, exactly as the paper tunes
them: "shuffle/storage.memoryFraction = 0.4/0.4").

The tuner (core/tree.py) treats the step function as a black box and only
ever edits these fields; the runtime (runtime/stepfn.py) consumes them.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Tuple

# value domains (first entry = Spark-like default)
DOMAINS: Dict[str, Tuple[Any, ...]] = {
    "compute_dtype":        ("float32", "bfloat16"),
    "shard_strategy":       ("dp", "fsdp", "tp", "fsdp_tp"),
    "grad_comm_dtype":      ("float32", "bfloat16", "int8_ef"),
    "comm_codec":           ("bfloat16", "float16", "int8", "float32"),
    # default 'dots' = Spark's balanced default fractions (0.2/0.6);
    # 'none' = storage-heavy (store everything, 0.1/0.7);
    # 'full' = shuffle-heavy (recompute everything)
    "remat_policy":         ("dots", "none", "full"),
    "microbatches":         (1, 2, 4),
    "attn_block_q":         (128, 256, 512),
    "attn_block_kv":        (128, 256, 512),
    "fuse_grad_collectives": (False, True),
    "kv_cache_dtype":       ("bfloat16", "int8", "float32"),
    "remat_save_dtype":     ("float32", "bfloat16"),
    "donate_buffers":       (True, False),
    # beyond-paper knob (see DESIGN.md): how attention is distributed when
    # head counts don't divide the model axis
    "attn_tp_fallback":     ("replicate", "batch_shard"),
}


@dataclasses.dataclass(frozen=True)
class TunableConfig:
    """One point in the 12-knob configuration space (Sec. 3 analogue)."""
    # 1. spark.serializer (Java -> Kryo)
    compute_dtype: str = "float32"
    # 2. spark.shuffle.manager (sort | hash | tungsten-sort)
    shard_strategy: str = "dp"
    # 3. spark.shuffle.compress
    grad_comm_dtype: str = "float32"
    # 4. spark.io.compression.codec (snappy | lzf | lz4; float32 = off)
    comm_codec: str = "bfloat16"
    # 5+6. spark.shuffle.memoryFraction / spark.storage.memoryFraction (joint)
    remat_policy: str = "dots"
    # 7. spark.reducer.maxSizeInFlight
    microbatches: int = 1
    # 8. spark.shuffle.file.buffer (Pallas VMEM tile)
    attn_block_q: int = 128
    attn_block_kv: int = 128
    # 9. spark.shuffle.consolidateFiles
    fuse_grad_collectives: bool = False
    # 10. spark.rdd.compress
    kv_cache_dtype: str = "bfloat16"
    # 11. spark.shuffle.spill.compress
    remat_save_dtype: str = "float32"
    # 12. spark.shuffle.io.preferDirectBufs
    donate_buffers: bool = True
    # beyond-paper
    attn_tp_fallback: str = "replicate"
    attn_impl: str = "xla"       # xla | pallas (pallas on TPU; xla on dry-run)
    seq_parallel: bool = False   # shard residual seq dim over the model axis
    # infrastructure (not tuned): unrolled layer stack for cost
    # calibration / cross-layer fusion experiments
    unroll_layers: bool = False

    def replace(self, **kw) -> "TunableConfig":
        return dataclasses.replace(self, **kw)

    def as_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def validate(self) -> None:
        for k, dom in DOMAINS.items():
            v = getattr(self, k)
            if v not in dom:
                raise ValueError(f"{k}={v!r} not in domain {dom}")

    def describe_delta(self, other: "TunableConfig") -> str:
        ds = [f"{k}={v!r}" for k, v in other.as_dict().items()
              if self.as_dict().get(k) != v]
        return ", ".join(ds) if ds else "(no change)"


# Spark parameter <-> knob documentation (DESIGN.md §2.1, Table 2 rows)
PARAM_DOCS: Dict[str, str] = {
    "compute_dtype":        "spark.serializer (Java -> Kryo)",
    "shard_strategy":       "spark.shuffle.manager (sort/hash/tungsten-sort)",
    "grad_comm_dtype":      "spark.shuffle.compress",
    "comm_codec":           "spark.io.compression.codec (snappy/lzf/lz4)",
    "remat_policy":         "spark.shuffle.memoryFraction + spark.storage.memoryFraction",
    "microbatches":         "spark.reducer.maxSizeInFlight",
    "attn_block_q":         "spark.shuffle.file.buffer (q tile)",
    "attn_block_kv":        "spark.shuffle.file.buffer (kv tile)",
    "fuse_grad_collectives": "spark.shuffle.consolidateFiles",
    "kv_cache_dtype":       "spark.rdd.compress",
    "remat_save_dtype":     "spark.shuffle.spill.compress",
    "donate_buffers":       "spark.shuffle.io.preferDirectBufs",
    "attn_tp_fallback":     "(beyond-paper) attention TP fallback",
}

# Knobs swept by the Sec.-4 sensitivity analysis, with the values tested
# (default first, mirroring the paper's value-selection rules: binary ->
# non-default; categorical -> all; numeric -> neighbours of default).
SENSITIVITY_SWEEP: Dict[str, Tuple[Any, ...]] = {
    "compute_dtype":        ("float32", "bfloat16"),
    "shard_strategy":       ("fsdp_tp", "dp", "fsdp", "tp"),
    "grad_comm_dtype":      ("float32", "bfloat16"),
    "comm_codec":           ("bfloat16", "float16", "int8"),
    "remat_policy":         ("dots", "none", "full"),
    "microbatches":         (1, 2, 4),
    "attn_block_q":         (128, 256, 512),
    "fuse_grad_collectives": (False, True),
    "kv_cache_dtype":       ("bfloat16", "int8"),
    "remat_save_dtype":     ("float32", "bfloat16"),
    "donate_buffers":       (True, False),
}


def default_config(**overrides) -> TunableConfig:
    """Paper-faithful default (all-Spark-defaults analogue)."""
    c = TunableConfig(**overrides)
    c.validate()
    return c


def exhaustive_size() -> int:
    """Size of the exhaustive grid the paper's 10-trial tree avoids."""
    return len(list(itertools.product(*DOMAINS.values())))
