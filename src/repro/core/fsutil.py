"""Shared filesystem idioms for the campaign stack.

Every multi-process coordination file in this codebase — compile-cache
entries (core/trial.py), campaign checkpoints (core/campaign.py), lease
heartbeats (core/fabric.py), intake submissions (core/schedule.py) —
is published the same way: write to a uniquely-named tempfile in the
*same directory*, then atomically ``os.replace`` it over the target.
Concurrent publishers each land a complete file (last writer wins) and
readers never observe a torn one.  This module is the single copy of
that idiom, so a future durability change (e.g. fsync-before-rename
for the NFS requirements documented in core/fabric.py) lands once.
"""
from __future__ import annotations

import os
import pathlib
import tempfile
from typing import Optional


def atomic_publish(path: pathlib.Path, text: str,
                   prefix: Optional[str] = None) -> None:
    """Publish ``text`` at ``path`` atomically (unique tempfile +
    same-directory ``os.replace`` — the same directory is what makes
    the rename atomic).  The parent directory must exist.  On any
    error the tempfile is removed and the exception re-raised; the
    target is either its old content or the complete new content,
    never a mix."""
    path = pathlib.Path(path)
    fd, tmp = tempfile.mkstemp(dir=path.parent,
                               prefix=prefix or f".{path.name}.",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
