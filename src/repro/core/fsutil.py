"""Shared filesystem idioms for the campaign stack.

Every multi-process coordination file in this codebase — compile-cache
entries (core/trial.py), campaign checkpoints (core/campaign.py), lease
heartbeats (core/fabric.py), intake submissions (core/schedule.py) —
is published the same way: write to a uniquely-named tempfile in the
*same directory*, then atomically ``os.replace`` it over the target.
Concurrent publishers each land a complete file (last writer wins) and
readers never observe a torn one.  This module is the single copy of
that idiom.

Two durability levels:

  * default — atomic against concurrent readers/writers, but a host
    crash may lose the rename (the data never hit the platter);
  * ``durable=True`` — fsync the tempfile before the rename and the
    parent directory after it, so the publish survives power loss.
    Lease heartbeats, STOP sentinels and the quarantine ledger use
    this level: they are *correctness* signals across worker processes
    (a lost heartbeat is a false steal; a lost quarantine strike is a
    re-evaluated poison config), per the filesystem requirements
    documented in core/fabric.py.

``append_jsonl`` is the single copy of the history-style torn-tolerant
O_APPEND record append (one line per record, self-healing after a torn
tail) shared by core/history.py, core/quarantine.py and the telemetry
event stream ``events.jsonl`` (core/telemetry.py — non-durable by
design: a lost event line costs observability, never correctness).
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
from typing import Dict, Optional


def _fsync_dir(directory: pathlib.Path) -> None:
    """fsync a directory so a just-renamed/created entry survives a
    crash (no-op on platforms that refuse O_RDONLY dir fds)."""
    try:
        fd = os.open(directory, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_publish(path: pathlib.Path, text: str,
                   prefix: Optional[str] = None,
                   durable: bool = False) -> None:
    """Publish ``text`` at ``path`` atomically (unique tempfile +
    same-directory ``os.replace`` — the same directory is what makes
    the rename atomic).  The parent directory must exist.  On any
    error the tempfile is removed and the exception re-raised; the
    target is either its old content or the complete new content,
    never a mix.

    With ``durable=True`` the tempfile is fsynced before the rename
    and the parent directory after it, so the publish also survives a
    host crash (not just a process crash)."""
    path = pathlib.Path(path)
    fd, tmp = tempfile.mkstemp(dir=path.parent,
                               prefix=prefix or f".{path.name}.",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
            if durable:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)
        if durable:
            _fsync_dir(path.parent)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def append_jsonl(path: pathlib.Path, record: Dict,
                 durable: bool = False) -> None:
    """Append one JSON record as one line, multi-process safe.

    O_APPEND keeps concurrent appenders from interleaving (each line is
    one ``os.write`` well under PIPE_BUF).  A torn tail left by a crashed
    writer self-heals: if the last byte on disk is not a newline, the
    next append starts with one, so the torn line stays parseable-as-bad
    and every later record lands intact (readers skip bad lines).

    ``durable=True`` additionally fsyncs after the write, so the record
    survives a host crash — required for the quarantine ledger, where a
    lost intent record means a poison config gets a free retry."""
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    line = json.dumps(record, sort_keys=True, default=str) + "\n"
    fd = os.open(path, os.O_RDWR | os.O_CREAT | os.O_APPEND, 0o644)
    try:
        try:
            os.lseek(fd, -1, os.SEEK_END)
            if os.read(fd, 1) != b"\n":
                line = "\n" + line
        except OSError:
            pass                        # empty file: no tail to heal
        os.write(fd, line.encode())
        if durable:
            os.fsync(fd)
    finally:
        os.close(fd)
