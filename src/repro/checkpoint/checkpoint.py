"""Atomic, async, mesh-agnostic checkpoints (no orbax dependency).

Layout:  <dir>/step_<N>/  manifest.json  +  one .npy per leaf.
Writes go to ``<dir>/.tmp_step_<N>`` and are committed with an atomic
rename, so a preemption mid-save never corrupts the latest checkpoint.
Restore places leaves with any sharding, so a checkpoint written on one
mesh restores onto another (elastic remesh, ft/elastic.py).
"""
from __future__ import annotations

import concurrent.futures as cf
import json
import os
import pathlib
import shutil
import threading
from typing import Any, Dict, Optional

import numpy as np

import jax


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save(directory: str, step: int, tree, extra: Optional[Dict] = None,
         _sync: bool = True) -> pathlib.Path:
    directory = pathlib.Path(directory)
    tmp = directory / f".tmp_step_{step:08d}"
    final = directory / f"step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    flat = _flatten(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(tmp / fname, arr)
        manifest["leaves"][key] = {"file": fname, "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)        # atomic commit
    return final


def latest_step(directory: str) -> Optional[int]:
    d = pathlib.Path(directory)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.glob("step_*")]
    return max(steps) if steps else None


def restore(directory: str, step: int, target_tree,
            shardings=None) -> Any:
    """Restore into the structure of ``target_tree`` (shapes verified).

    ``shardings``: matching pytree of NamedShardings (or None = default
    placement) — this is where cross-mesh resharding happens."""
    d = pathlib.Path(directory) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    flat_target = _flatten(target_tree)
    flat_shard = _flatten(shardings) if shardings is not None else {}
    if set(manifest["leaves"]) != set(flat_target):
        missing = set(flat_target) ^ set(manifest["leaves"])
        raise ValueError(f"checkpoint/target tree mismatch: {sorted(missing)[:5]}")
    out = {}
    for key, meta in manifest["leaves"].items():
        arr = np.load(d / meta["file"])
        want = flat_target[key]
        if tuple(arr.shape) != tuple(want.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {want.shape}")
        sh = flat_shard.get(key)
        out[key] = (jax.device_put(arr, sh) if sh is not None
                    else jax.device_put(arr))
    # rebuild the original structure
    leaves_in_order = []
    for path, _ in jax.tree_util.tree_flatten_with_path(target_tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves_in_order.append(out[key])
    treedef = jax.tree_util.tree_structure(target_tree)
    return jax.tree_util.tree_unflatten(treedef, leaves_in_order)


def manifest_extra(directory: str, step: int) -> Dict:
    d = pathlib.Path(directory) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text())["extra"]


class CheckpointManager:
    """Periodic + on-demand checkpoints, keep-N retention, async commit."""

    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.directory = pathlib.Path(directory)
        self.interval = interval
        self.keep = keep
        self._pool = cf.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[cf.Future] = None
        self._lock = threading.Lock()

    def maybe_save(self, step: int, tree, extra=None, force: bool = False):
        if not force and (self.interval <= 0 or step % self.interval != 0):
            return None
        return self.save_async(step, tree, extra)

    def save_async(self, step: int, tree, extra=None) -> cf.Future:
        # snapshot to host NOW (donated buffers may be reused next step)
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)
        self.wait()
        with self._lock:
            self._pending = self._pool.submit(self._save_and_gc, step,
                                              host_tree, extra)
        return self._pending

    def _save_and_gc(self, step, host_tree, extra):
        path = save(self.directory, step, host_tree, extra)
        steps = sorted(int(p.name.split("_")[1])
                       for p in self.directory.glob("step_*"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self.directory / f"step_{s:08d}",
                          ignore_errors=True)
        return path

    def wait(self):
        with self._lock:
            pending = self._pending
        if pending is not None:
            pending.result()

    def latest_step(self) -> Optional[int]:
        return latest_step(self.directory)

    def restore_latest(self, target_tree, shardings=None):
        s = self.latest_step()
        if s is None:
            return None, None
        return restore(self.directory, s, target_tree, shardings), s
