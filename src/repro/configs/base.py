"""Architecture configuration system.

Every assigned architecture is a frozen ``ArchConfig``.  The *full* configs
are exercised only through the dry-run (``ShapeDtypeStruct``, no
allocation); ``reduced()`` returns a same-family small config used by the
CPU smoke tests and the end-to-end examples.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int                    # dense MLP width; for moe: per-expert width
    vocab: int
    head_dim: Optional[int] = None   # default d_model // n_heads
    mlp_act: str = "silu"            # silu | gelu | relu2
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_chunk: int = 256
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    attn_every: int = 0          # zamba: shared attn block after every k ssm blocks
    slstm_every: int = 0         # xlstm: sLSTM block every k blocks (others mLSTM)
    # --- encoder-decoder ---
    enc_layers: int = 0          # if >0, n_layers is the decoder depth
    enc_seq_ratio: int = 4       # enc frames = seq_len // ratio (audio frontend stub)
    # --- multimodal frontend stub ---
    frontend_tokens: int = 0     # precomputed patch/frame embeddings prepended
    # --- misc ---
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    optimizer: str = "adamw"     # adamw | adafactor
    param_dtype: str = "float32"  # master-weight dtype (bf16 for 1T-scale)
    fsdp_axes: Tuple[str, ...] = ("data",)   # biggest models add "pod"
    norm_eps: float = 1e-5

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_subquadratic(self) -> bool:
        """Can this arch decode a 500k context without O(S) per-token attention?"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an autoregressive decoder

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic total parameter count (used for 6·N·D model FLOPs)."""
        d, hd = self.d_model, self.hd
        qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
        attn = qkv + (self.n_heads * hd) * d
        if self.mlp_act == "silu":
            mlp_dense = 3 * d * self.d_ff
        else:
            mlp_dense = 2 * d * self.d_ff
        per_layer_norms = 2 * d
        if self.family == "moe":
            router = d * self.n_experts
            mlp = self.n_experts * (3 * d * self.d_ff) + router
            layer = attn + mlp + per_layer_norms
            body = self.n_layers * layer
        elif self.family == "ssm":
            # xlstm: mLSTM blocks ~ linear-attn qkv + out + gates
            m_layer = (d * (self.n_heads * hd) * 3 + (self.n_heads * hd) * d
                       + 4 * d + 2 * d)
            body = self.n_layers * m_layer
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            ssm_layer = (d * 2 * d_in                         # in_proj (x, z)
                         + d * 2 * self.ssm_state             # B, C proj
                         + d * (d_in // self.ssm_head_dim)    # dt proj
                         + d_in * d                           # out proj
                         + 2 * d)
            shared = attn + mlp_dense + per_layer_norms       # one shared attn block
            body = self.n_layers * ssm_layer + shared
        elif self.family == "encdec":
            enc = self.enc_layers * (attn + mlp_dense + per_layer_norms)
            cross = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d
            dec = self.n_layers * (attn + cross + mlp_dense + 3 * d)
            body = enc + dec
        else:  # dense, vlm
            body = self.n_layers * (attn + mlp_dense + per_layer_norms)
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return int(body + embed + d)

    def encdec_split(self):
        """(enc_body, dec_body, embed) params — encdec FLOPs accounting."""
        d, hd = self.d_model, self.hd
        qkv = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd)
        attn = qkv + (self.n_heads * hd) * d
        mlp = (3 if self.mlp_act == "silu" else 2) * d * self.d_ff
        enc = self.enc_layers * (attn + mlp + 2 * d)
        cross = attn
        dec = self.n_layers * (attn + cross + mlp + 3 * d)
        embed = self.vocab * d * (1 if self.tie_embeddings else 2)
        return enc, dec, embed

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if self.family != "moe":
            return self.param_count()
        total = self.param_count()
        expert_p = self.n_experts * 3 * self.d_model * self.d_ff * self.n_layers
        active_expert_p = self.top_k * 3 * self.d_model * self.d_ff * self.n_layers
        return int(total - expert_p + active_expert_p)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a cell runs; (False, reason) for documented skips."""
    if shape.name == "long_500k" and not arch.is_subquadratic:
        return False, ("pure full-attention arch: 500k-token decode has no "
                       "sub-quadratic mechanism in the published architecture "
                       "(skip noted in DESIGN.md §4)")
    return True, ""
