"""smollm-135m — dense llama-arch small [hf:HuggingFaceTB/SmolLM-135M].

30L d_model=576 9H (GQA kv=3) d_ff=1536 vocab=49152.
Also the compute-bound sensitivity workload (k-means analogue) and the
end-to-end CPU training example model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m",
    family="dense",
    n_layers=30,
    d_model=576,
    n_heads=9,
    n_kv_heads=3,
    d_ff=1536,
    vocab=49152,
    head_dim=64,
    mlp_act="silu",
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="smollm-135m-reduced", n_layers=2, d_model=96,
                          n_heads=3, n_kv_heads=3, head_dim=32, d_ff=256,
                          vocab=512)
