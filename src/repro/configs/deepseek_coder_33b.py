"""deepseek-coder-33b — dense llama-arch [arXiv:2401.14196; hf].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-coder-33b",
    family="dense",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=19200,
    vocab=32256,
    head_dim=128,
    mlp_act="silu",
    rope_theta=100000.0,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="deepseek-coder-33b-reduced", n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
                          d_ff=256, vocab=512)
