"""glm4-9b — dense, RoPE, GQA kv=2, large vocab [hf:THUDM/glm-4-9b].

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab=151552,
    head_dim=128,
    mlp_act="silu",
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="glm4-9b-reduced", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=2, head_dim=32, d_ff=384,
                          vocab=512)
