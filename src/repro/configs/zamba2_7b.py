"""zamba2-7b — hybrid: Mamba2 backbone + shared attention block
[arXiv:2411.15242].

81L d_model=3584 (attn: 32H kv=32, d_ff=14336) vocab=32000 ssm_state=64.
81 Mamba2 blocks; ONE shared full transformer block (attn + MLP) is
invoked after every 6th Mamba2 block (13 invocations, weights shared),
following the Zamba2 shared-block design.  Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    mlp_act="gelu",
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    attn_every=6,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="zamba2-7b-reduced", n_layers=4, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=256,
                          vocab=512, ssm_state=16, ssm_head_dim=32,
                          ssm_chunk=32, attn_every=2)
