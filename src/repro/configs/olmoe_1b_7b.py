"""olmoe-1b-7b — MoE, 64 experts top-8 [arXiv:2409.02060; hf].

16L d_model=2048 16H (MHA kv=16) d_ff=1024(per expert) vocab=50304.
Also the all-to-all-dominated sensitivity workload (pure-shuffling
analogue of the paper's Sec. 4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab=50304,
    head_dim=128,
    mlp_act="silu",
    n_experts=64,
    top_k=8,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="olmoe-1b-7b-reduced", n_layers=2, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, d_ff=64,
                          vocab=512, n_experts=8, top_k=2)
