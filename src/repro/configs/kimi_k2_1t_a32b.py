"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2].

61L d_model=7168 64H (GQA kv=8) d_ff=2048(per expert) vocab=163840.
~1.03T total / ~32B active parameters.  Optimizer is Adafactor and FSDP
spans (data, pod): Adam state for 1T params (12 B/param) exceeds 512x16GB
HBM, factored second moments fit.  Documented in DESIGN.md §4.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,
    vocab=163840,
    head_dim=112,
    mlp_act="silu",
    n_experts=384,
    top_k=8,
    optimizer="adafactor",
    param_dtype="bfloat16",      # 1T f32 masters exceed fleet HBM
    fsdp_axes=("data", "pod"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="kimi-k2-1t-a32b-reduced", n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
                          d_ff=64, vocab=512, n_experts=8, top_k=2,
                          optimizer="adamw", fsdp_axes=("data",))
