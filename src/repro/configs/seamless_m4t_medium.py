"""seamless-m4t-medium — encoder-decoder multimodal backbone
[arXiv:2308.11596; hf].

12L(enc)+12L(dec) d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206.
The audio frontend (fbank -> conformer feature extractor) is a STUB:
``input_specs()`` provides precomputed frame embeddings at
seq_len // enc_seq_ratio frames.  Decode shapes run (it has a decoder:
self-attn KV cache + fixed cross-attn cache).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="encdec",
    n_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256206,
    head_dim=64,
    mlp_act="gelu",
    enc_layers=12,
    enc_seq_ratio=4,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="seamless-m4t-medium-reduced", n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=4, head_dim=32,
                          d_ff=256, vocab=512, enc_layers=2)
