"""nemotron-4-340b — dense, GQA, squared-ReLU MLP [arXiv:2402.16819].

96L d_model=18432 96H (GQA kv=8) d_ff=73728 vocab=256000.
Largest dense arch: FSDP spans (data, pod) so optimizer state fits 512 chips.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    head_dim=192,
    mlp_act="relu2",
    optimizer="adafactor",       # Adam state (12 B/param) exceeds one pod
    fsdp_axes=("data", "pod"),
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="nemotron-4-340b-reduced", n_layers=2,
                          d_model=192, n_heads=6, n_kv_heads=2, head_dim=32,
                          d_ff=768, vocab=512, fsdp_axes=("data",))
