"""Architecture registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, shape_applicable

_ARCH_MODULES: Dict[str, str] = {
    "deepseek-coder-33b": "deepseek_coder_33b",
    "nemotron-4-340b": "nemotron_4_340b",
    "smollm-135m": "smollm_135m",
    "glm4-9b": "glm4_9b",
    "llava-next-34b": "llava_next_34b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "zamba2-7b": "zamba2_7b",
    "xlstm-1.3b": "xlstm_1_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES)


def _module(arch_id: str):
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")


def get_config(arch_id: str) -> ArchConfig:
    return _module(arch_id).CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    return _module(arch_id).reduced()


def get_shape(shape_id: str) -> ShapeConfig:
    return SHAPES[shape_id]


def all_cells():
    """Every (arch, shape) cell with its applicability verdict."""
    out = []
    for a in list_archs():
        cfg = get_config(a)
        for s, shp in SHAPES.items():
            ok, reason = shape_applicable(cfg, shp)
            out.append((a, s, ok, reason))
    return out


__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "shape_applicable",
           "list_archs", "get_config", "get_reduced", "get_shape", "all_cells"]
