"""llava-next-34b — VLM backbone with anyres tiling frontend stub
[hf:llava-hf/llava-v1.6].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
The vision tower is a STUB: ``input_specs()`` provides precomputed anyres
patch embeddings (frontend_tokens per image) that are prepended to the
text sequence; the transformer backbone is fully implemented.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    head_dim=128,
    mlp_act="silu",
    frontend_tokens=576,     # one 24x24 anyres base tile of patch embeddings
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="llava-next-34b-reduced", n_layers=2,
                          d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
                          d_ff=256, vocab=512, frontend_tokens=16)
