"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L d_model=2048 4H d_ff=0 vocab=50304.
xLSTM[7:1] layout: every 8th block is an sLSTM (scalar-memory, sequential
recurrence), the rest are mLSTM (matrix-memory, chunkwise-parallel linear
attention).  d_ff=0 per the paper: blocks carry their own up/down
projections instead of a separate FFN.  Sub-quadratic: runs long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    head_dim=512,
    mlp_act="gelu",
    ssm_chunk=256,
    slstm_every=8,
)


def reduced() -> ArchConfig:
    return CONFIG.replace(name="xlstm-1.3b-reduced", n_layers=4, d_model=128,
                          n_heads=4, n_kv_heads=4, head_dim=32, vocab=512,
                          ssm_chunk=32, slstm_every=2)
