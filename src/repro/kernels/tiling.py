"""Shared tile fitting for the public kernel wrappers.

The Pallas kernels require their block size to divide the gridded
dimension (``flash_attention_bhsd``/``ssm_scan_grid`` assert it).  The
public ops wrappers accept *any* shape — model-zoo callers pass ragged
sequence lengths — so each wrapper fits the requested block to the
largest divisor of the dimension that is not larger than the request.
For the power-of-two shapes the models produce this is the identity
(or the historical ``min(block, n)`` clamp); for ragged shapes it
keeps the kernel correct instead of assert-crashing.

The *tuner* is stricter on purpose: a tile knob that does not divide
the cell's sequence is a clean deterministic-crash trial
(``Knob.validate_tile``, core/space.py) — silent re-fitting during
tuning would alias distinct knob values to one measured config.
"""
from __future__ import annotations


def fit_block(block: int, n: int) -> int:
    """Largest divisor of ``n`` that is ``<= min(block, n)`` (and >= 1).

    Scans downward from the clamp; bounded by the clamp value itself,
    which for every kernel tile in the knob space is <= 512.
    """
    b = max(1, min(int(block), int(n)))
    while n % b:
        b -= 1
    return b
