"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd); softmax in f32."""
    B, H, S, hd = q.shape
    scale = 1.0 / math.sqrt(hd)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
