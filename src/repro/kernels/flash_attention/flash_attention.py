"""Causal flash attention Pallas-TPU kernel.

Online-softmax tiling: the S x S score matrix never touches HBM — Q is
read once, K/V are streamed per Q-tile through VMEM blocks of
(block_q x block_kv).  The (block_q, block_kv) tile shape is the
spark.shuffle.file.buffer analogue (DESIGN.md §2.1 row 8): it sets the
VMEM working set and the HBM re-fetch factor for K/V.

Grid: (B, H, S/block_q, S/block_kv); the last axis is sequential on TPU,
so the online-softmax state (m, l, acc) lives in VMEM scratch across
KV steps.  Causal Q-tiles skip fully-masked KV tiles (@pl.when).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                  *, scale: float, block_q: int, block_kv: int,
                  causal: bool):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = kj * block_kv
    # a KV tile entirely in the causal future contributes nothing
    needed = (not causal) or (k_start <= q_start + block_q - 1)

    @pl.when(needed)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                # (bk, hd)
        s = q @ k.T                                        # (bq, bk)
        if causal:
            rows = q_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 0)
            cols = k_start + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_kv), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_ref[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                             # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + p @ v
        m_ref[...] = m_new

    @pl.when(kj == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0, 0, ...] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q",
                                             "block_kv", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = 128, block_kv: int = 128,
                         interpret: bool = False):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd)."""
    B, H, S, hd = q.shape
    block_q = min(block_q, S)
    block_kv = min(block_kv, S)
    assert S % block_q == 0 and S % block_kv == 0, (S, block_q, block_kv)
    scale = 1.0 / math.sqrt(hd)
    grid = (B, H, S // block_q, S // block_kv)
    kernel = functools.partial(_flash_kernel, scale=scale, block_q=block_q,
                               block_kv=block_kv, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q, 1), jnp.float32),   # l (running denom)
            pltpu.VMEM((block_q, hd), jnp.float32),  # acc
        ],
        interpret=interpret,
    )(q, k, v)
