"""Jitted public wrapper: (B, S, H, hd) layout used by the model zoo.

On CPU (tests, this container) the kernel body runs in interpret mode;
on TPU it compiles to Mosaic.  The XLA reference path stays the dry-run
default so cost_analysis reflects honest HLO (DESIGN.md §2.2).
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_bhsd
from repro.kernels.tiling import fit_block


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_attention(q, k, v, *, causal: bool = True, block_q: int = 128,
                    block_kv: int = 128):
    """q/k/v: (B, S, H, hd) (kv already GQA-repeated) -> (B, S, H, hd).

    Blocks are fitted to the largest divisor of S <= the request, so
    ragged sequence lengths stay correct (kernels require block | S)."""
    S = q.shape[1]
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal,
                             block_q=fit_block(block_q, S),
                             block_kv=fit_block(block_kv, S),
                             interpret=_on_cpu())
    return o.transpose(0, 2, 1, 3)
