"""Fused RMSNorm Pallas-TPU kernel.

One pass over HBM: reads a (rows x d) tile into VMEM, computes the
row-wise rms in f32 and writes the scaled result — the unfused XLA path
reads x twice (mean-of-squares, then normalize).  d stays whole per tile
(reductions are row-local); rows per tile sized to VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm_2d(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
               interpret: bool = False):
    """x: (N, d), scale: (d,) -> (N, d)."""
    N, d = x.shape
    block_rows = min(block_rows, N)
    while N % block_rows:
        block_rows //= 2
    block_rows = max(block_rows, 1)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(N // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        interpret=interpret,
    )(x, scale)
