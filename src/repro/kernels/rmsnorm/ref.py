"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps))
            * scale.astype(jnp.float32)).astype(x.dtype)
