"""Jitted public wrapper: accepts (..., d), flattens leading dims."""
from __future__ import annotations

import jax

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_2d


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256):
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = rmsnorm_2d(x2, scale, eps=eps, block_rows=block_rows,
                   interpret=_on_cpu())
    return y.reshape(shape)
