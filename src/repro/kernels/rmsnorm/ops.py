"""Jitted public wrapper: accepts (..., d), flattens leading dims."""
from __future__ import annotations

import jax

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_2d
from repro.kernels.tiling import fit_block


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256):
    """Accepts (..., d); the row block is fitted to the largest divisor
    of the flattened row count <= the request (the kernel's own
    fallback halves, which lands on 1 for odd row counts)."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = rmsnorm_2d(x2, scale, eps=eps,
                   block_rows=fit_block(block_rows, x2.shape[0]),
                   interpret=_on_cpu())
    return y.reshape(shape)
