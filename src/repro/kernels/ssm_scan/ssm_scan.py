"""Chunked Mamba2/SSD scan Pallas-TPU kernel.

TPU adaptation of the SSD algorithm (DESIGN.md §2.2): the within-chunk
quadratic term is tiled per (batch, head, chunk) so the (Q x Q) decay
matrix lives only in VMEM (the XLA reference materializes it in HBM for
every head), and the cross-chunk recurrence exploits the TPU grid's
sequential last axis: the running (P x N) state is VMEM scratch carried
across chunk steps — no HBM round-trip between chunks.

Grid: (B, H, S/chunk).  Layouts prepared by ops.py:
  X  (B, H, nc, Q, P)   token inputs (head-split)
  Bm (B, nc, Q, N)      input projections (shared across heads)
  Cm (B, nc, Q, N)      output projections (shared across heads)
  dt (B, H, nc, Q)      step sizes
  la (B, H, nc, Q)      log decay (dt * A)
Outputs: Y (B, H, nc, Q, P); final state (B, H, P, N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, b_ref, c_ref, dt_ref, la_ref, y_ref, hout_ref,
                h_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    X = x_ref[0, 0, 0].astype(jnp.float32)          # (Q, P)
    Bm = b_ref[0, 0].astype(jnp.float32)            # (Q, N)
    Cm = c_ref[0, 0].astype(jnp.float32)            # (Q, N)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)        # (Q,)
    la = la_ref[0, 0, 0].astype(jnp.float32)        # (Q,)

    cum = jnp.cumsum(la)                             # (Q,)
    # within-chunk: scores[t, j] = (C_t . B_j) * exp(cum_t - cum_j) * dt_j
    Lmat = jnp.exp(cum[:, None] - cum[None, :])
    rows = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lmat = jnp.where(rows >= cols, Lmat, 0.0)
    G = Cm @ Bm.T                                    # (Q, Q)
    scores = G * Lmat * dt[None, :]
    y = scores @ X                                   # (Q, P) intra
    # inter-chunk: y_t += exp(cum_t) * C_t . h_prev
    h = h_ref[...]                                   # (P, N)
    y = y + jnp.exp(cum)[:, None] * (Cm @ h.T)
    y_ref[0, 0, 0] = y.astype(y_ref.dtype)
    # state update: h = exp(cum_last) * h + sum_j w_j X_j (x) B_j
    w = dt * jnp.exp(cum[-1] - cum)                  # (Q,)
    h_ref[...] = jnp.exp(cum[-1]) * h + (w[:, None] * X).T @ Bm

    @pl.when(ci == nc - 1)
    def _emit_state():
        hout_ref[0, 0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssm_scan_grid(X, Bm, Cm, dt, la, *, chunk: int = 256,
                  interpret: bool = False):
    """See module docstring for layouts.  Returns (Y, h_final)."""
    B, H, nc, Q, P = X.shape
    N = Bm.shape[-1]
    assert Q == chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, Q, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, 1, Q), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, Q, P), lambda b, h, c: (b, h, c, 0, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nc, Q, P), X.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(X, Bm, Cm, dt, la)
