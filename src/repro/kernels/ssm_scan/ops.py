"""Jitted public wrapper: model-zoo layout (B,S,H,P) -> kernel layout."""
from __future__ import annotations

import jax

from repro.kernels.ssm_scan.ssm_scan import ssm_scan_grid
from repro.kernels.tiling import fit_block


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def ssm_scan(X, Bm, Cm, dt, la, *, chunk: int = 256):
    """X: (B,S,H,P); Bm/Cm: (B,S,N); dt/la: (B,S,H) -> (Y, h_final).

    The chunk is fitted to the largest divisor of S <= the request, so
    ragged sequence lengths stay correct (the grid requires chunk | S)."""
    B, S, H, P = X.shape
    N = Bm.shape[-1]
    chunk = fit_block(chunk, S)
    nc = S // chunk
    Xg = X.reshape(B, nc, chunk, H, P).transpose(0, 3, 1, 2, 4)
    Bg = Bm.reshape(B, nc, chunk, N)
    Cg = Cm.reshape(B, nc, chunk, N)
    dtg = dt.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)
    lag = la.reshape(B, nc, chunk, H).transpose(0, 3, 1, 2)
    Y, hF = ssm_scan_grid(Xg, Bg, Cg, dtg, lag, chunk=chunk,
                          interpret=_on_cpu())
    Y = Y.transpose(0, 2, 3, 1, 4).reshape(B, S, H, P)
    return Y, hF
