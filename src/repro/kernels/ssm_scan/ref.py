"""Pure-jnp oracle for the chunked SSD kernel: sequential recurrence.

h_t = exp(la_t) * h_{t-1} + dt_t * X_t (x) B_t ;  y_t = C_t . h_t
(the mathematically exact per-token form; the chunked algorithm must
match it up to fp tolerance).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssm_scan_ref(X, Bm, Cm, dt, la):
    """X: (B,S,H,P); Bm/Cm: (B,S,N); dt/la: (B,S,H).

    Returns (Y (B,S,H,P), h_final (B,H,P,N))."""
    B, S, H, P = X.shape
    N = Bm.shape[-1]
    f32 = jnp.float32

    def step(h, inp):
        x_t, b_t, c_t, dt_t, la_t = inp
        h = (jnp.exp(la_t)[:, :, None, None] * h
             + jnp.einsum("bh,bhp,bn->bhpn", dt_t, x_t, b_t))
        y = jnp.einsum("bn,bhpn->bhp", c_t, h)
        return h, y

    mv = lambda t: jnp.moveaxis(t.astype(f32), 1, 0)
    h0 = jnp.zeros((B, H, P, N), f32)
    hF, Y = jax.lax.scan(step, h0,
                         (mv(X), mv(Bm), mv(Cm), mv(dt), mv(la)))
    return jnp.moveaxis(Y, 0, 1).astype(X.dtype), hF
