"""Pure-jnp oracle for the flash-decode kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_ref(q, k, v, k_scale, v_scale, length):
    """q: (B,H,1,hd); k/v: (B,Hkv,S,hd) (+(B,Hkv,S,1) int8 scales);
    length: (1,) live positions.  Returns (B,H,1,hd) f32."""
    B, H, _, hd = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale
        vf = vf * v_scale
    n_rep = H // Hkv
    kf = jnp.repeat(kf, n_rep, axis=1)
    vf = jnp.repeat(vf, n_rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf)
    s = s / (hd ** 0.5)
    mask = jnp.arange(S)[None, None, None, :] < length[0]
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf)
