"""Jitted public wrapper: model-zoo layout (B,1,H,hd) q + (B,S,Hkv,hd)
cache -> (B,1,H,hd)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_decode.flash_decode import flash_decode_bhsd
from repro.kernels.tiling import fit_block


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flash_decode(q, k_cache, v_cache, length, k_scale=None, v_scale=None,
                 *, block_kv: int = 512):
    """q: (B,1,H,hd); caches: (B,S,Hkv,hd) [+ (B,S,Hkv,1) scales];
    length: scalar int32 live length."""
    B, _, H, hd = q.shape
    Hkv = k_cache.shape[2]
    qt = q.transpose(0, 2, 1, 3)
    kt = k_cache.transpose(0, 2, 1, 3)
    vt = v_cache.transpose(0, 2, 1, 3)
    ks = k_scale.transpose(0, 2, 1, 3) if k_scale is not None else None
    vs = v_scale.transpose(0, 2, 1, 3) if v_scale is not None else None
    o = flash_decode_bhsd(qt, kt, vt, ks, vs,
                          jnp.asarray([length], jnp.int32),
                          block_kv=fit_block(block_kv, k_cache.shape[1]),
                          n_rep=H // Hkv,
                          interpret=_on_cpu())
    return o.transpose(0, 2, 1, 3).astype(q.dtype)
