"""Flash-decode Pallas-TPU kernel: one-token attention against a long
KV cache, with fused int8 dequantization.

Decode is KV-bandwidth-bound (the §Roofline decode rows): the cache is
read once per token, so the kernel's job is to stream K/V tiles through
VMEM exactly once at the stored dtype (bf16 or int8+scales — fusing the
dequant means int8 halves HBM traffic end-to-end, the rdd.compress
analogue), computing the online-softmax reduction per tile.

Grid: (B, H, S/block_kv); the KV-position axis is the sequential TPU
axis, so (m, l, acc) live in VMEM scratch across tiles.  GQA: the kernel
sees K/V already expanded to query heads via an index map (no HBM copy —
the same (kv_head) tile is mapped to each query head in its group).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, block_kv: int, quantized: bool,
                   scale: float):
    j = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (1, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0, 0].astype(jnp.float32)         # (bk, 1) scales
        v = v * vs_ref[0, 0].astype(jnp.float32)
    s = (k @ q[0]).reshape(1, -1)                        # (1, bk)
    # mask positions beyond the live cache length
    pos = j * block_kv + jax.lax.broadcasted_iota(jnp.int32,
                                                  (1, block_kv), 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = corr * acc_ref[...] + p @ v
    m_ref[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[0, 0, ...] = (acc_ref[...] /
                            jnp.maximum(l_ref[...], 1e-20)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv", "n_rep",
                                             "interpret"))
def flash_decode_bhsd(q, k, v, k_scale, v_scale, length, *,
                      block_kv: int = 512, n_rep: int = 1,
                      interpret: bool = False):
    """q: (B, H, 1, hd); k/v: (B, Hkv, S, hd) (+ (B, Hkv, S, 1) scales
    when int8); length: (1,) live cache length.  H = Hkv * n_rep."""
    B, H, _, hd = q.shape
    S = k.shape[2]
    block_kv = min(block_kv, S)
    assert S % block_kv == 0
    quantized = k.dtype == jnp.int8
    scale = 1.0 / (hd ** 0.5)
    grid = (B, H, S // block_kv)
    kv_map = lambda b, h, j: (b, h // n_rep, j, 0)   # GQA group mapping
    dummy = jnp.zeros((B, k.shape[1], S, 1), jnp.float32)
    ks = k_scale if k_scale is not None else dummy
    vs = v_scale if v_scale is not None else dummy
    kernel = functools.partial(_decode_kernel, block_kv=block_kv,
                               quantized=quantized, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), kv_map),
            pl.BlockSpec((1, 1, block_kv, hd), kv_map),
            pl.BlockSpec((1, 1, block_kv, 1), kv_map),
            pl.BlockSpec((1, 1, block_kv, 1), kv_map),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, hd), lambda b, h, j: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, 1, hd), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, 1), jnp.float32),
            pltpu.VMEM((1, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, ks, vs, length)
