"""Safe online serving tuning: SLO guardrails, shadow slices, promotion.

Per 2309.01901 (safe exploration on live Spark jobs), a candidate
config must never be allowed to ruin the stream it is being trialed on.
Three mechanisms, all riding the existing hardening machinery:

  * :class:`SLOGuard` — watches every request served during a candidate
    replay.  The first ``shadow_frac`` of the stream is the **shadow
    slice**: per-request checks, strictest, so a bad config is aborted
    within its first waves.  After the candidate graduates the shadow
    slice the guard keeps watching running means (a slow regression
    still aborts).  An abort raises :class:`SLOViolation`, a
    :class:`~repro.core.trial.TrialError` pre-tagged
    ``deterministic`` — the evaluator scores the trial as a
    deterministic crash (cost inf), the quarantine ledger records the
    crashed completion, and the trace is never finished under the bad
    config.
  * thresholds are **relative to the incumbent**: ``slo_ttft`` is a
    multiplier over the incumbent's replay stats for the same trace
    (floored by the absolute constants below so a near-zero incumbent
    cannot make every candidate a violator).
  * :class:`PromotionBoard` — atomic winner promotion into a per-cell
    live-config file (core/fsutil.atomic_publish: readers never see a
    torn config) with an append-only promotions/demotions history.  A
    promotion only lands if it strictly improves on the incumbent's
    recorded cost — the live file never regresses.
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Dict, List, Optional

from repro.core import telemetry as _telemetry
from repro.core.fsutil import append_jsonl, atomic_publish
from repro.core.trial import FAILURE_DETERMINISTIC, TrialError

#: absolute floors (seconds) under the relative thresholds: an incumbent
#: that serves in microseconds must not turn measurement noise into
#: SLO violations
SLO_TTFT_FLOOR_S = 0.25
SLO_QDELAY_FLOOR_S = 0.25

SERVING_DIRNAME = "serving"
PROMOTIONS_FILENAME = "promotions.jsonl"


class SLOViolation(TrialError):
    """A candidate regressed TTFT / queue delay past the guardrail —
    pre-tagged deterministic so quarantine accounting applies."""

    def __init__(self, message: str):
        super().__init__(message, failure=FAILURE_DETERMINISTIC)


class SLOGuard:
    """Per-replay guardrail.  ``observe`` is called once per served
    request (serving/evaluator.ServeEvaluator.replay) and raises
    :class:`SLOViolation` to abort the replay mid-trace."""

    def __init__(self, slo_ttft: float, incumbent: Dict[str, float],
                 shadow_frac: float = 0.25):
        self.factor = float(slo_ttft)
        self.ttft_limit = self.factor * max(
            float(incumbent.get("mean_ttft_s", 0.0)), SLO_TTFT_FLOOR_S)
        self.qdelay_limit = self.factor * max(
            float(incumbent.get("p95_qdelay_s", 0.0)), SLO_QDELAY_FLOOR_S)
        self.shadow_frac = float(shadow_frac)
        self._sum_ttft = 0.0
        self._n = 0

    def observe(self, ttft_s: float, qdelay_s: float,
                served: int, total: int) -> None:
        self._n += 1
        self._sum_ttft += float(ttft_s)
        shadow_n = max(1, int(self.shadow_frac * max(1, total) + 0.999))
        in_shadow = served <= shadow_n
        # queue delay is a virtual-clock quantity — deterministic per
        # (config, trace) — so it is checked per-request everywhere
        if qdelay_s > self.qdelay_limit:
            self._abort("qdelay", qdelay_s, served, total, in_shadow,
                        f"slo-violation: queue delay {qdelay_s:.3f}s "
                        f"exceeds {self.qdelay_limit:.3f}s "
                        f"({self.factor:g}x incumbent) "
                        f"after {served}/{total} requests"
                        f"{' (shadow slice)' if in_shadow else ''}")
        ttft_signal = ttft_s if in_shadow else self._sum_ttft / self._n
        if ttft_signal > self.ttft_limit:
            kind = "TTFT" if in_shadow else "mean TTFT"
            self._abort("ttft", ttft_signal, served, total, in_shadow,
                        f"slo-violation: {kind} {ttft_signal:.3f}s "
                        f"exceeds {self.ttft_limit:.3f}s "
                        f"({self.factor:g}x incumbent) "
                        f"after {served}/{total} requests"
                        f"{' (shadow slice)' if in_shadow else ''}")

    def _abort(self, signal: str, value: float, served: int, total: int,
               in_shadow: bool, message: str) -> None:
        """Emit the SLO-abort telemetry event, then raise.  The event is
        observability only — the decision (abort, scored deterministic
        crash) is the exception, identical with telemetry on or off."""
        tel = _telemetry.current()
        if tel.enabled:
            tel.emit("slo.abort", signal=signal, value=round(value, 4),
                     served=served, total=total, shadow=in_shadow)
        raise SLOViolation(message)


# -------------------------------------------------------------- promotion
class PromotionBoard:
    """Per-cell live-config files + append-only promotion history under
    ``<campaign dir>/serving/``.  Multi-process safe by the same idioms
    as the rest of the fabric: atomic_publish for the live files (last
    complete writer wins, readers never torn), append_jsonl for the
    history."""

    def __init__(self, directory: pathlib.Path):
        self.dir = pathlib.Path(directory) / SERVING_DIRNAME
        self.live_dir = self.dir / "live"
        self.history_path = self.dir / PROMOTIONS_FILENAME

    def live_path(self, cell_key: str) -> pathlib.Path:
        return self.live_dir / f"{cell_key}.json"

    def live(self, cell_key: str) -> Optional[Dict]:
        """The currently promoted record for a cell (None if nothing
        has ever been promoted)."""
        try:
            return json.loads(self.live_path(cell_key).read_text())
        except (OSError, ValueError):
            return None

    def promote(self, cell_key: str, config: Dict[str, Any],
                cost_s: float, source: str = "",
                stats: Optional[Dict] = None) -> Dict:
        """Promote ``config`` as the cell's live config iff it strictly
        improves on the incumbent's recorded cost; the displaced
        incumbent goes to the demotion history.  Returns the history
        record (``action``: promoted | kept-incumbent)."""
        incumbent = self.live(cell_key)
        rec: Dict[str, Any] = {
            "v": 1, "ts": round(time.time(), 3), "cell": cell_key,
            "cost_s": float(cost_s), "source": source,
        }
        if incumbent is not None and \
                float(incumbent.get("cost_s", float("inf"))) <= float(cost_s):
            # never regress the live file: the incumbent stays
            rec.update(action="kept-incumbent",
                       incumbent_cost_s=incumbent.get("cost_s"))
            append_jsonl(self.history_path, rec)
            return rec
        live = {
            "v": 1, "cell": cell_key, "config": dict(config),
            "cost_s": float(cost_s), "promoted_ts": rec["ts"],
            "source": source,
        }
        if stats:
            live["stats"] = dict(stats)
        self.live_dir.mkdir(parents=True, exist_ok=True)
        atomic_publish(self.live_path(cell_key),
                       json.dumps(live, indent=1, sort_keys=True) + "\n",
                       prefix="live")
        rec.update(action="promoted", config=dict(config),
                   demoted=({"config": incumbent.get("config"),
                             "cost_s": incumbent.get("cost_s"),
                             "promoted_ts": incumbent.get("promoted_ts")}
                            if incumbent is not None else None))
        append_jsonl(self.history_path, rec)
        return rec

    def history(self) -> List[Dict]:
        out = []
        try:
            text = self.history_path.read_text()
        except OSError:
            return out
        for line in text.splitlines():
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except ValueError:
                continue
            if isinstance(rec, dict):
                out.append(rec)
        return out


def promote_winners(directory: pathlib.Path, reports: Dict[str, Any],
                    source: str = "") -> List[Dict]:
    """Promote every serve cell's surviving winner from a campaign's
    reports (cell key -> TuningReport).  Crashed finals (cost inf/nan)
    never promote; the measured-tier winner overrides the model winner
    when attached.  Returns the history records written."""
    from repro.serving.evaluator import SERVE_ARCH_PREFIX
    board = PromotionBoard(directory)
    out = []
    for key, rep in sorted(reports.items()):
        if not key.startswith(SERVE_ARCH_PREFIX):
            continue
        config = dict(rep.final_config)
        cost = float(rep.final_cost)
        meas = getattr(rep, "measured", None)
        if meas and meas.get("winner"):
            config = dict(meas["winner"].get("config", config))
            cost = float(meas["winner"].get("cost_s", cost))
        if not (cost == cost) or cost == float("inf"):
            continue
        out.append(board.promote(key, config, cost, source=source))
    return out
