"""Batched serving scheduler: request queue -> prefill waves -> decode.

Iteration-level wave batching: requests are admitted from the queue until
the wave is full (or ``max_wait_s`` passes), prefilled together (padded to
the wave's max prompt length), then decoded step-by-step; finished lanes
(EOS or token budget) are masked out and the wave retires when all lanes
finish or the step budget is hit.  Tracks TTFT / throughput / queue-delay
metrics per request.

This is the serving-path integration point for the tuner: the scheduler
takes a TunableConfig, so kv_cache_dtype / donate_buffers trials apply to
a live serving workload (WallClockEvaluator).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.params import TunableConfig
from repro.models.model import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    t_submit: float = 0.0
    # outputs
    generated: List[int] = dataclasses.field(default_factory=list)
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        return (self.t_first_token - self.t_submit
                if self.t_first_token else None)


@dataclasses.dataclass
class ServeMetrics:
    requests: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    wall_s: float = 0.0
    ttft_s: List[float] = dataclasses.field(default_factory=list)

    def summary(self) -> Dict[str, float]:
        return {
            "requests": self.requests,
            "decode_tok_per_s": self.decode_tokens / max(self.wall_s, 1e-9),
            "prefill_tokens": self.prefill_tokens,
            "mean_ttft_s": (sum(self.ttft_s) / len(self.ttft_s)
                            if self.ttft_s else 0.0),
        }


class BatchScheduler:
    def __init__(self, cfg: ArchConfig, rt: TunableConfig, params,
                 wave_size: int = 4, max_seq: int = 128,
                 max_wait_s: float = 0.0):
        self.cfg = cfg
        self.rt = rt
        self.params = params
        self.model: Model = build_model(cfg)
        self.wave_size = wave_size
        self.max_seq = max_seq
        self.max_wait_s = max_wait_s
        self.queue: Deque[Request] = collections.deque()
        self.metrics = ServeMetrics()
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill_fn(p, b, rt, max_seq=max_seq))
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_fn(p, c, t, rt))

    def submit(self, req: Request):
        req.t_submit = req.t_submit or time.time()
        self.queue.append(req)

    # ------------------------------------------------------------ waves
    def _admit_wave(self) -> List[Request]:
        deadline = time.time() + self.max_wait_s
        while (len(self.queue) < self.wave_size
               and time.time() < deadline):
            time.sleep(0.001)
        wave = []
        while self.queue and len(wave) < self.wave_size:
            wave.append(self.queue.popleft())
        return wave

    def _pad_prompts(self, wave: List[Request]):
        # left-pad to a common length so last prompt token aligns
        L = max(len(r.tokens) for r in wave)
        toks = np.zeros((len(wave), L), np.int32)
        for i, r in enumerate(wave):
            toks[i, L - len(r.tokens):] = r.tokens
        return jnp.asarray(toks)

    def run_wave(self) -> List[Request]:
        wave = self._admit_wave()
        if not wave:
            return []
        t0 = time.time()
        tokens = self._pad_prompts(wave)
        batch = {"tokens": tokens}
        if self.cfg.family == "encdec":
            S = tokens.shape[1]
            batch["frames"] = jnp.zeros(
                (len(wave), max(1, S // self.cfg.enc_seq_ratio),
                 self.cfg.d_model), jnp.dtype(self.rt.compute_dtype))
        logits, cache = self._prefill(self.params, batch)
        self.metrics.prefill_tokens += int(tokens.size)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        now = time.time()
        for i, r in enumerate(wave):
            r.t_first_token = now
            r.generated.append(int(tok[i, 0]))
        done = np.array([r.eos_id is not None
                         and r.generated[-1] == r.eos_id for r in wave])
        budget = max(r.max_new_tokens for r in wave) - 1
        steps = min(budget, self.max_seq - tokens.shape[1] - 1)
        for _ in range(max(0, steps)):
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            self.metrics.decode_tokens += int((~done).sum())
            for i, r in enumerate(wave):
                if done[i]:
                    continue
                t = int(tok[i, 0])
                r.generated.append(t)
                if ((r.eos_id is not None and t == r.eos_id)
                        or len(r.generated) >= r.max_new_tokens):
                    done[i] = True
                    r.t_done = time.time()
        now = time.time()
        for r in wave:
            r.t_done = r.t_done or now
            self.metrics.ttft_s.append(r.ttft_s or 0.0)
        self.metrics.requests += len(wave)
        self.metrics.wall_s += now - t0
        return wave

    def run_until_drained(self) -> List[Request]:
        out = []
        while self.queue:
            out.extend(self.run_wave())
        return out
