"""Batched serving scheduler: request queue -> prefill waves -> decode.

Iteration-level wave batching: requests are admitted from the queue until
the wave is full (or ``max_wait_s`` passes), prefilled together (padded to
the wave's max prompt length), then decoded step-by-step; finished lanes
(EOS or token budget) are masked out and the wave retires when all lanes
finish or the step budget is hit.  Tracks TTFT / throughput / queue-delay
metrics per request.

This is the serving-path integration point for the tuner: the scheduler
takes a TunableConfig, so kv_cache_dtype / donate_buffers trials apply to
a live serving workload (WallClockEvaluator).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.params import TunableConfig
from repro.models.model import Model, build_model


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray               # (prompt_len,) int32
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    # None = "stamp at submit"; an explicit value (virtual-clock replay,
    # serving/evaluator.py) is preserved even when it is exactly 0.0
    t_submit: Optional[float] = None
    # outputs
    generated: List[int] = dataclasses.field(default_factory=list)
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None

    @property
    def ttft_s(self) -> Optional[float]:
        # explicit None checks: a first token at timestamp 0.0 (virtual
        # clocks start there) is a served token, not an unserved request
        if self.t_first_token is None or self.t_submit is None:
            return None
        return self.t_first_token - self.t_submit


@dataclasses.dataclass
class ServeMetrics:
    requests: int = 0
    decode_tokens: int = 0
    prefill_tokens: int = 0
    wall_s: float = 0.0
    ttft_s: List[float] = dataclasses.field(default_factory=list)

    def summary(self) -> Dict[str, float]:
        # every ratio is guarded: a drained-empty scheduler (zero
        # completed requests, zero wall time) summarizes to zeros
        # instead of dividing by zero
        if self.ttft_s:
            ordered = sorted(self.ttft_s)
            mean_ttft = sum(ordered) / len(ordered)
            p95_ttft = ordered[min(len(ordered) - 1,
                                   int(0.95 * len(ordered)))]
        else:
            mean_ttft = p95_ttft = 0.0
        return {
            "requests": self.requests,
            "decode_tok_per_s": (self.decode_tokens / self.wall_s
                                 if self.wall_s > 0 else 0.0),
            "prefill_tokens": self.prefill_tokens,
            "mean_ttft_s": mean_ttft,
            "p95_ttft_s": p95_ttft,
        }


class BatchScheduler:
    def __init__(self, cfg: ArchConfig, rt: TunableConfig, params,
                 wave_size: int = 4, max_seq: int = 128,
                 max_wait_s: float = 0.0,
                 pad_to: Optional[int] = None,
                 pad_wave: bool = False):
        self.cfg = cfg
        self.rt = rt
        self.params = params
        self.model: Model = build_model(cfg)
        self.wave_size = wave_size
        self.max_seq = max_seq
        self.max_wait_s = max_wait_s
        # pad_to fixes the padded prompt length across waves (one
        # prefill compile per config during trace replay); None keeps
        # the historical per-wave max.  pad_wave additionally pads the
        # batch dimension to wave_size with filler lanes (excluded from
        # all metrics), fixing the compile geometry entirely.
        self.pad_to = pad_to
        self.pad_wave = pad_wave
        self.queue: Deque[Request] = collections.deque()
        self.metrics = ServeMetrics()
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill_fn(p, b, rt, max_seq=max_seq))
        # donate the cache operand, mirroring stepfn.build_decode_step:
        # donate_buffers is a tunable and must reach the decode path here
        # exactly as it does in the step-function tier
        self._decode_donate = (1,) if rt.donate_buffers else ()
        self._decode = jax.jit(
            lambda p, c, t: self.model.decode_fn(p, c, t, rt),
            donate_argnums=self._decode_donate)

    def submit(self, req: Request):
        if req.t_submit is None:     # preserve explicit virtual clocks,
            req.t_submit = time.time()   # including a legitimate 0.0
        self.queue.append(req)

    # ------------------------------------------------------------ waves
    def _admit_wave(self) -> List[Request]:
        if not self.queue and self.max_wait_s <= 0:
            return []
        deadline = time.time() + self.max_wait_s
        while (len(self.queue) < self.wave_size
               and time.time() < deadline):
            time.sleep(0.001)
        wave = []
        while self.queue and len(wave) < self.wave_size:
            wave.append(self.queue.popleft())
        return wave

    def _pad_prompts(self, wave: List[Request]):
        # left-pad to a common length so last prompt token aligns
        L = max(len(r.tokens) for r in wave)
        if self.pad_to is not None:
            L = max(L, int(self.pad_to))
        B = max(len(wave), self.wave_size) if self.pad_wave else len(wave)
        toks = np.zeros((B, L), np.int32)
        for i, r in enumerate(wave):
            toks[i, L - len(r.tokens):] = r.tokens
        return jnp.asarray(toks)

    def run_wave(self) -> List[Request]:
        wave = self._admit_wave()
        if not wave:
            return []
        t0 = time.time()
        tokens = self._pad_prompts(wave)
        batch = {"tokens": tokens}
        if self.cfg.family == "encdec":
            S = tokens.shape[1]
            batch["frames"] = jnp.zeros(
                (tokens.shape[0], max(1, S // self.cfg.enc_seq_ratio),
                 self.cfg.d_model), jnp.dtype(self.rt.compute_dtype))
        logits, cache = self._prefill(self.params, batch)
        # filler lanes (pad_wave) never count toward metrics
        self.metrics.prefill_tokens += int(len(wave) * tokens.shape[1])
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        now = time.time()
        for i, r in enumerate(wave):
            r.t_first_token = now
            r.generated.append(int(tok[i, 0]))
        done = np.array([r.eos_id is not None
                         and r.generated[-1] == r.eos_id for r in wave])
        budget = max(r.max_new_tokens for r in wave) - 1
        steps = min(budget, self.max_seq - tokens.shape[1] - 1)
        for _ in range(max(0, steps)):
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            self.metrics.decode_tokens += int((~done).sum())
            for i, r in enumerate(wave):
                if done[i]:
                    continue
                t = int(tok[i, 0])
                r.generated.append(t)
                if ((r.eos_id is not None and t == r.eos_id)
                        or len(r.generated) >= r.max_new_tokens):
                    done[i] = True
                    r.t_done = time.time()
        now = time.time()
        for r in wave:
            if r.t_done is None:
                r.t_done = now
            ttft = r.ttft_s
            self.metrics.ttft_s.append(ttft if ttft is not None else 0.0)
        self.metrics.requests += len(wave)
        self.metrics.wall_s += now - t0
        return wave

    def run_until_drained(self) -> List[Request]:
        out = []
        while self.queue:
            wave = self.run_wave()
            if not wave:         # guard: an empty admission must not
                break            # spin the drain loop forever
            out.extend(wave)
        return out
