"""Seeded synthetic serving-traffic traces — generation and replay format.

The serving tuner's first requirement is that every trial of every
candidate config sees *bit-identical* traffic: the trial cost must be a
property of the config, not of the RNG draw, or the campaign's accept
rule compares noise and fabric workers disagree on cached cost keys.
So traffic is split into two layers:

  * a **generator** (:func:`generate`) that expands a small declarative
    :class:`TraceSpec` — arrival pattern (Poisson / bursty Markov-
    modulated / diurnal), mean rate, and a multi-tenant mix of
    prompt-length / max-token distributions — into a concrete list of
    :class:`TraceRequest` s using one ``np.random.RandomState(seed)``;
  * a **replay format** (:class:`Trace`): canonical JSON
    (``sort_keys=True``, fixed float rounding) so the same seed
    serializes to the same bytes on every host, with a sha1
    ``trace_key`` over those bytes that evaluators fold into their
    timing-cache keys.

Prompt token ids are *not* stored in the trace (they would dominate the
file); each request carries a derived per-request ``seed`` and
:func:`request_tokens` regenerates the same tokens at replay time.

Named tiny traces live in the :data:`TRACES` registry — they are the
"shape" axis of ``serve:<arch>:<trace>`` cells (serving/evaluator.py)
and are small enough to replay through a reduced model on CPU in CI.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.fsutil import atomic_publish

TRACE_VERSION = "trace-v1"

# replayed prompts draw token ids from [1, VOCAB_LO) — small enough for
# every reduced vocab, never 0 (the schedulers' left-pad value)
_TOKEN_LO, _TOKEN_HI = 1, 500


@dataclasses.dataclass(frozen=True)
class Tenant:
    """One traffic class in a multi-tenant mix."""
    name: str
    weight: float                 # relative share of requests
    prompt_len: Tuple[int, int]   # inclusive [lo, hi] prompt tokens
    max_new: Tuple[int, int]      # inclusive [lo, hi] decode budget


@dataclasses.dataclass(frozen=True)
class TraceSpec:
    """Declarative description a generator expands deterministically."""
    name: str
    pattern: str                  # poisson | bursty | diurnal
    n_requests: int
    mean_rate: float              # mean arrivals per virtual second
    seed: int
    tenants: Tuple[Tenant, ...]
    # bursty: burst-state rate multiplier + mean dwell (requests/state)
    burst_factor: float = 8.0
    burst_dwell: float = 4.0
    # diurnal: sinusoidal rate modulation amplitude + period (virtual s)
    diurnal_amp: float = 0.8
    diurnal_period_s: float = 20.0


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    rid: int
    arrival_s: float              # virtual arrival time (s from start)
    prompt_len: int
    max_new_tokens: int
    tenant: str
    seed: int                     # per-request token-generation seed

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)


class Trace:
    """A fully-expanded, replayable traffic trace."""

    def __init__(self, meta: Dict, requests: Sequence[TraceRequest]):
        self.meta = dict(meta)
        self.requests: List[TraceRequest] = list(requests)

    # ------------------------------------------------------ serialization
    def to_json(self) -> str:
        """Canonical byte-stable serialization: sorted keys, arrival
        times pre-rounded at generation, newline-terminated."""
        doc = {
            "version": TRACE_VERSION,
            "meta": self.meta,
            "requests": [r.as_dict() for r in self.requests],
        }
        return json.dumps(doc, sort_keys=True,
                          separators=(",", ":")) + "\n"

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        doc = json.loads(text)
        if doc.get("version") != TRACE_VERSION:
            raise ValueError(f"unsupported trace version "
                             f"{doc.get('version')!r}")
        reqs = [TraceRequest(**r) for r in doc["requests"]]
        return cls(doc.get("meta", {}), reqs)

    def save(self, path) -> None:
        p = pathlib.Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        atomic_publish(p, self.to_json(), prefix="trace")

    @classmethod
    def load(cls, path) -> "Trace":
        return cls.from_json(pathlib.Path(path).read_text())

    # ------------------------------------------------------------- identity
    def key(self) -> str:
        """sha1 over the canonical bytes — the identity evaluators fold
        into their timing-cache keys, so two fabric workers replaying
        the same spec agree on every cached trial cost."""
        return hashlib.sha1(self.to_json().encode()).hexdigest()[:16]

    @property
    def name(self) -> str:
        return str(self.meta.get("name", "trace"))

    def span_s(self) -> float:
        return self.requests[-1].arrival_s if self.requests else 0.0

    def max_prompt_len(self) -> int:
        return max((r.prompt_len for r in self.requests), default=0)

    def max_new_tokens(self) -> int:
        return max((r.max_new_tokens for r in self.requests), default=0)


def request_tokens(req: TraceRequest) -> np.ndarray:
    """Regenerate the request's prompt tokens from its stored seed —
    identical on every replaying process."""
    rng = np.random.RandomState(req.seed)
    return rng.randint(_TOKEN_LO, _TOKEN_HI,
                       size=req.prompt_len).astype(np.int32)


# ------------------------------------------------------------- generators
def _interarrivals(spec: TraceSpec, rng: np.random.RandomState
                   ) -> np.ndarray:
    """One inter-arrival gap per request, by pattern."""
    n, rate = spec.n_requests, max(spec.mean_rate, 1e-9)
    if spec.pattern == "poisson":
        return rng.exponential(1.0 / rate, size=n)
    if spec.pattern == "bursty":
        # two-state Markov-modulated Poisson: calm at the mean rate,
        # bursts at burst_factor x, geometric dwell per state
        gaps = np.empty(n)
        burst = False
        for i in range(n):
            r = rate * (spec.burst_factor if burst else 1.0)
            gaps[i] = rng.exponential(1.0 / r)
            if rng.uniform() < 1.0 / max(spec.burst_dwell, 1.0):
                burst = not burst
        return gaps
    if spec.pattern == "diurnal":
        # sinusoidal rate modulation around the mean (a compressed
        # day): the instantaneous rate at the running arrival time
        # scales the next exponential gap
        gaps = np.empty(n)
        t = 0.0
        for i in range(n):
            phase = 2.0 * np.pi * t / max(spec.diurnal_period_s, 1e-9)
            r = rate * max(1e-3, 1.0 + spec.diurnal_amp * np.sin(phase))
            gaps[i] = rng.exponential(1.0 / r)
            t += gaps[i]
        return gaps
    raise ValueError(f"unknown arrival pattern {spec.pattern!r} "
                     "(known: poisson, bursty, diurnal)")


def generate(spec: TraceSpec) -> Trace:
    """Expand a spec into a concrete trace, deterministically."""
    if not spec.tenants:
        raise ValueError(f"trace {spec.name!r}: empty tenant mix")
    rng = np.random.RandomState(spec.seed)
    gaps = _interarrivals(spec, rng)
    arrivals = np.cumsum(gaps)
    weights = np.array([t.weight for t in spec.tenants], dtype=float)
    weights = weights / weights.sum()
    reqs = []
    for rid in range(spec.n_requests):
        ten = spec.tenants[int(rng.choice(len(spec.tenants), p=weights))]
        plen = int(rng.randint(ten.prompt_len[0], ten.prompt_len[1] + 1))
        mnew = int(rng.randint(ten.max_new[0], ten.max_new[1] + 1))
        # per-request token seed derived from (trace seed, rid): stable
        # across processes without storing the tokens themselves
        tok_seed = int(hashlib.sha1(
            f"{spec.seed}:{spec.name}:{rid}".encode()
        ).hexdigest()[:8], 16)
        reqs.append(TraceRequest(
            rid=rid,
            # fixed rounding keeps the JSON byte-stable across platforms
            arrival_s=round(float(arrivals[rid]), 6),
            prompt_len=plen, max_new_tokens=mnew,
            tenant=ten.name, seed=tok_seed))
    meta = {
        "name": spec.name, "pattern": spec.pattern,
        "n_requests": spec.n_requests, "mean_rate": spec.mean_rate,
        "seed": spec.seed,
        "tenants": [dataclasses.asdict(t) for t in spec.tenants],
    }
    return Trace(meta, reqs)


# --------------------------------------------------------------- registry
# Tiny named traces: the "shape" axis of serve:<arch>:<trace> cells.
# Prompt lengths / decode budgets are sized for reduced models on CPU
# (max_seq stays small); virtual spans are a few tens of seconds so
# admission-policy differences show up in queue delay without any
# real-time sleeping.
_CHAT = Tenant("chat", 0.7, (4, 12), (3, 6))
_BATCH = Tenant("batch", 0.3, (12, 24), (2, 4))

TRACE_SPECS: Dict[str, TraceSpec] = {
    "poisson_tiny": TraceSpec(
        name="poisson_tiny", pattern="poisson", n_requests=8,
        mean_rate=0.25, seed=1234, tenants=(_CHAT, _BATCH)),
    "bursty_tiny": TraceSpec(
        name="bursty_tiny", pattern="bursty", n_requests=10,
        mean_rate=0.5, seed=5678, tenants=(_CHAT, _BATCH)),
    "diurnal_tiny": TraceSpec(
        name="diurnal_tiny", pattern="diurnal", n_requests=10,
        mean_rate=0.5, seed=4321, tenants=(_CHAT, _BATCH)),
}

_TRACE_CACHE: Dict[str, Trace] = {}


def trace_names() -> Tuple[str, ...]:
    return tuple(sorted(TRACE_SPECS))


def get_trace(name: str) -> Trace:
    """Expand (once per process) a registered trace by name."""
    if name not in TRACE_SPECS:
        raise ValueError(f"unknown trace {name!r} "
                         f"(known: {', '.join(trace_names())})")
    if name not in _TRACE_CACHE:
        _TRACE_CACHE[name] = generate(TRACE_SPECS[name])
    return _TRACE_CACHE[name]
