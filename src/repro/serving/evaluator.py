"""ServeCell / ServeEvaluator — traffic-replay trials as campaign cells.

The paper's claim is that a handful of trial-and-error runs on the
*real workload* beats tuning a model of it; this module makes the real
workload the serving path itself.  A ``serve:<arch>:<trace>`` cell
replays one registered traffic trace (serving/traffic.py) through the
wave scheduler (serving/scheduler.py) under each candidate config and
scores a scalar cost from TTFT / decode throughput / p95 queue delay —
the campaign / strategy / fabric / quarantine / measured-tier machinery
runs unchanged on top.

Structure mirrors core/kernel_cell.py (the new-cell-kind template):

  * :class:`ServeCell` is a :class:`~repro.core.campaign.CellSpec` whose
    ``arch`` is ``serve-<arch>`` and whose shape is the trace name, so
    cell keys stay three ``__``-separated parts and every checkpoint /
    lease / report path behaves identically;
  * the serving knobs (``max_wave_size`` / ``wave_admission``) are
    SPACE entries with ``tunable=False, reach="analytic"`` — only serve
    cells propose deltas on them (:func:`serve_stages`), so DOMAINS,
    sweeps, compile keys and every non-serving strategy decision stay
    byte-identical to the pre-serving code;
  * replay uses a **virtual clock**: requests carry the trace's virtual
    arrival times, the clock advances by each wave's measured wall
    time, and queue delay is virtual-arrival vs virtual-wave-start.
    Served order is the trace's FIFO arrival order on every host
    (the determinism the fabric needs); the *cost* is a measured wall
    quantity and is cached behind the existing TimingCache policy
    (:class:`CachedServe` folds the trace's content key into the cache
    key, so two fabric workers always agree on what a cached cost
    means);
  * with an SLO guard (``--slo-ttft``), candidate replays shadow the
    stream: the guard watches every served request and aborts the trial
    as a **deterministic crash** (serving/canary.py) the moment TTFT or
    queue delay regresses past the threshold vs the incumbent — the
    trace is never finished under a bad config, and the quarantine
    ledger records the abort like any other deterministic failure.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import numpy as np

from repro.configs.base import ShapeConfig
from repro.core.campaign import CellSpec
from repro.core.measure import CachedMeasure, TimingCache, measure_key
from repro.core.params import TunableConfig, default_config
from repro.core.space import SPACE
from repro.core.tree import Stage
from repro.core.trial import (FAILURE_DETERMINISTIC, TrialError,
                              TrialResult, Workload, classify_exception)
from repro.serving.traffic import Trace, get_trace, request_tokens

SERVE_ARCH_PREFIX = "serve-"

#: bump when the replay protocol / cost formula changes (invalidates
#: cached trace costs)
SERVE_MEASURE_VERSION = "serve-v1"

# scalar-cost weights: mean TTFT is what a user feels first, p95 queue
# delay is the tail the SLO protects, mean decode seconds per request
# is the throughput term (tokens / measured decode rate)
W_TTFT, W_P95_QDELAY, W_DECODE = 1.0, 0.5, 1.0


def is_serve_workload(wl: Any) -> bool:
    return str(getattr(wl, "arch", "")).startswith(SERVE_ARCH_PREFIX)


# ---------------------------------------------------------------- cells
@dataclasses.dataclass
class ServeWorkload(Workload):
    """A serve cell's workload: cell identity is (serve-<arch>, trace);
    ``cfg`` is the arch's *reduced* config (the replay actually runs,
    on CPU in CI) and ``shp`` is derived from the trace geometry."""

    @property
    def base_arch(self) -> str:
        return self.arch[len(SERVE_ARCH_PREFIX):]

    @property
    def cfg(self):
        from repro.configs import get_reduced
        return get_reduced(self.base_arch)

    @property
    def shp(self) -> ShapeConfig:
        tr = get_trace(self.shape)
        seq = tr.max_prompt_len() + tr.max_new_tokens() + 2
        return ShapeConfig(self.shape, seq, len(tr.requests), "serve")


@dataclasses.dataclass(frozen=True)
class ServeCell(CellSpec):
    """One (arch, trace) serving cell.  ``arch`` is ``serve-<arch>`` so
    cell keys keep the three-part ``arch__shape__mesh`` layout."""

    @property
    def base_arch(self) -> str:
        return self.arch[len(SERVE_ARCH_PREFIX):]

    def workload(self) -> ServeWorkload:
        return ServeWorkload(self.arch, self.shape, self.multi_pod)

    def spec(self) -> str:
        return f"serve:{self.base_arch}:{self.shape}"


def serve_cell(arch: str, trace: str) -> ServeCell:
    from repro.configs import list_archs
    from repro.serving.traffic import trace_names
    if arch not in list_archs():
        raise ValueError(f"unknown arch {arch!r} "
                         f"(known: {', '.join(list_archs())})")
    if trace not in trace_names():
        raise ValueError(f"unknown trace {trace!r} "
                         f"(known: {', '.join(trace_names())})")
    return ServeCell(SERVE_ARCH_PREFIX + arch, trace, False)


def parse_serve_cell(item: str) -> ServeCell:
    """Parse one ``serve:<arch>:<trace>`` cell spec (the string
    :meth:`ServeCell.spec` emits and the fabric round-trips)."""
    parts = item.strip().split(":")
    if len(parts) != 3 or parts[0] != "serve":
        raise ValueError(f"bad serve cell spec {item!r} "
                         "(want serve:<arch>:<trace>)")
    return serve_cell(parts[1], parts[2])


#: the knobs a serve cell's stage tree proposes deltas on — the serving
#: infrastructure knobs plus the step knobs that provably reach the
#: scheduler's prefill/decode path
SERVE_KNOBS = ("max_wave_size", "wave_admission", "kv_cache_dtype",
               "donate_buffers", "compute_dtype")


def serve_signature(arch: str, shape: str, multi_pod: bool = False
                    ) -> Dict:
    """Warm-start similarity features for a serve cell (counterpart of
    :func:`repro.core.history.cell_signature`)."""
    from repro.configs import get_config
    base = arch[len(SERVE_ARCH_PREFIX):]
    try:
        family = get_config(base).family
    except KeyError:
        family = base
    return {
        "arch": arch,
        "shape": shape,
        "kind": "serve",
        "family": family,
        "multi_pod": bool(multi_pod),
        "active_knobs": list(SERVE_KNOBS),
    }


# --------------------------------------------------------------- stages
def serve_stages(spec: Any) -> List[Stage]:
    """The serving stage tree: scheduler knobs first (wave size, then
    admission policy), then the step knobs that reach the decode path —
    6 alternatives + baseline, inside the paper's ≤ 10-trial budget."""
    for name in SERVE_KNOBS:
        assert name in SPACE, name
    return [
        Stage("parallelism", SPACE["max_wave_size"].spark,
              [dict(max_wave_size=2), dict(max_wave_size=8)],
              kinds=("serve",)),
        Stage("locality.wait", SPACE["wave_admission"].spark,
              [dict(wave_admission="full")], kinds=("serve",)),
        Stage("rdd.compress", SPACE["kv_cache_dtype"].spark,
              [dict(kv_cache_dtype="int8")], kinds=("serve",)),
        Stage("preferDirectBufs", SPACE["donate_buffers"].spark,
              [dict(donate_buffers=False)], kinds=("serve",)),
        Stage("serializer", SPACE["compute_dtype"].spark,
              [dict(compute_dtype="bfloat16")], kinds=("serve",)),
    ]


# ------------------------------------------------------------ evaluator
class ServeEvaluator:
    """Replay the cell's trace through :class:`BatchScheduler` under a
    candidate config; score W_TTFT·mean-TTFT + W_P95_QDELAY·p95-queue-
    delay + W_DECODE·mean-decode-seconds.  Hardened like every other
    evaluator: any fault is a crashed TrialResult; an SLO-guard abort is
    a pre-tagged *deterministic* crash (``slo-violation`` in the error),
    raised mid-trace so a bad config never finishes its replay."""

    def __init__(self, slo_ttft: Optional[float] = None,
                 shadow_frac: float = 0.25):
        self.slo_ttft = slo_ttft
        self.shadow_frac = shadow_frac
        self.repeats = 1
        # per-process incumbent stats per cell key (the guard's
        # comparison basis: the default config's replay of the trace)
        self._incumbent: Dict[str, Dict[str, float]] = {}

    # ------------------------------------------------------------ replay
    @staticmethod
    def _mesh():
        """A single-device host mesh (same rationale as the measured
        tier's _measure_mesh: always valid on the CI CPU container)."""
        from repro.launch.mesh import make_mesh
        return make_mesh((1, 1), ("data", "model"))

    def _build_scheduler(self, wl: ServeWorkload, rt: TunableConfig,
                         trace: Trace):
        import jax
        from repro.serving.scheduler import (BatchScheduler, Request,
                                             ServeMetrics)
        cfg = wl.cfg
        max_seq = trace.max_prompt_len() + trace.max_new_tokens() + 2
        sched = BatchScheduler(
            cfg, rt, params=None,
            wave_size=int(rt.max_wave_size),
            max_seq=max_seq, max_wait_s=0.0,
            # pad every wave to the trace's max prompt length and the
            # full wave width: ONE prefill + ONE decode geometry per
            # config instead of a compile per distinct wave shape
            pad_to=trace.max_prompt_len(), pad_wave=True)
        sched.params = sched.model.init(jax.random.PRNGKey(0))
        # warm-up wave: pay the prefill/decode compiles before the
        # replay clock starts, so TTFT / queue delay measure serving,
        # not XLA compilation (and the SLO guard compares like with
        # like across candidate and incumbent)
        for i in range(sched.wave_size):
            sched.submit(Request(rid=-1 - i,
                                 tokens=np.ones(4, np.int32),
                                 max_new_tokens=2, t_submit=0.0))
        sched.run_wave()
        sched.metrics = ServeMetrics()
        return sched

    def replay(self, wl: ServeWorkload, rt: TunableConfig,
               guard=None) -> Dict[str, Any]:
        """Drive the trace through the scheduler on a virtual clock.

        Returns the replay stats dict (see keys below).  ``guard`` (a
        serving/canary.SLOGuard) observes every served request and may
        raise :class:`TrialError` to abort the replay mid-trace.
        """
        from repro.serving.scheduler import Request
        trace = get_trace(wl.shape)
        with self._mesh():
            return self._replay_inner(wl, rt, trace, guard)

    def _replay_inner(self, wl: ServeWorkload, rt: TunableConfig,
                      trace: Trace, guard) -> Dict[str, Any]:
        from repro.serving.scheduler import Request
        sched = self._build_scheduler(wl, rt, trace)
        pending = collections.deque(trace.requests)
        admission = str(rt.wave_admission)
        vnow = 0.0
        ttft, qdelay, served = [], [], []
        t_run0 = time.time()
        while pending or sched.queue:
            if not sched.queue and pending:
                # idle: jump the virtual clock to the next arrival
                vnow = max(vnow, pending[0].arrival_s)
            while pending and pending[0].arrival_s <= vnow + 1e-9:
                tr = pending.popleft()
                sched.submit(Request(
                    rid=tr.rid, tokens=request_tokens(tr),
                    max_new_tokens=tr.max_new_tokens,
                    t_submit=tr.arrival_s))
            if (admission == "full" and pending
                    and len(sched.queue) < sched.wave_size):
                # hold the wave until it can be full: advance the
                # virtual clock to the next arrival and re-admit
                vnow = max(vnow, pending[0].arrival_s)
                continue
            v_start = vnow
            t0 = time.time()
            wave = sched.run_wave()
            wall = time.time() - t0
            vnow += wall
            for r in wave:
                # virtual queue delay + real prefill latency = the TTFT
                # a user on the virtual timeline would see
                qd = max(0.0, v_start - r.t_submit)
                tt = qd + max(0.0, (r.t_first_token or t0) - t0)
                qdelay.append(qd)
                ttft.append(tt)
                served.append(r.rid)
                if guard is not None:
                    guard.observe(ttft_s=tt, qdelay_s=qd,
                                  served=len(served),
                                  total=len(trace.requests))
        summary = sched.metrics.summary()
        qsorted = sorted(qdelay)
        n = len(served)
        return {
            "trace": trace.name,
            "trace_key": trace.key(),
            "served": n,
            "served_order": served,
            "mean_ttft_s": (sum(ttft) / n) if n else 0.0,
            "p95_qdelay_s": (qsorted[min(n - 1, int(0.95 * n))]
                             if n else 0.0),
            "decode_tok_per_s": summary["decode_tok_per_s"],
            "decode_tokens": sched.metrics.decode_tokens,
            "wall_s": round(time.time() - t_run0, 3),
        }

    @staticmethod
    def cost_of(stats: Dict[str, Any]) -> float:
        """The scalar trial cost: TTFT + tail queue delay + mean decode
        seconds per request."""
        n = max(1, int(stats.get("served", 0)))
        rate = stats.get("decode_tok_per_s", 0.0)
        decode_s = (stats.get("decode_tokens", 0) / rate / n
                    if rate > 0 else 0.0)
        return (W_TTFT * stats.get("mean_ttft_s", 0.0)
                + W_P95_QDELAY * stats.get("p95_qdelay_s", 0.0)
                + W_DECODE * decode_s)

    # -------------------------------------------------------- incumbent
    def incumbent_stats(self, wl: ServeWorkload) -> Dict[str, float]:
        """The guard's comparison basis: the default config's replay of
        this cell's trace (computed once per process per cell)."""
        key = wl.key()
        if key not in self._incumbent:
            stats = self.replay(wl, default_config(), guard=None)
            self._incumbent[key] = {
                "mean_ttft_s": stats["mean_ttft_s"],
                "p95_qdelay_s": stats["p95_qdelay_s"],
            }
        return self._incumbent[key]

    # --------------------------------------------------------- protocol
    def __call__(self, wl: Workload, rt: TunableConfig) -> TrialResult:
        t0 = time.time()
        try:
            if not is_serve_workload(wl):
                raise TrialError(f"{wl.key()} is not a serve cell")
            SPACE.validate(rt)
            for name in ("max_wave_size", "wave_admission"):
                SPACE[name].validate(getattr(rt, name))
            guard = None
            if self.slo_ttft is not None:
                from repro.serving.canary import SLOGuard
                guard = SLOGuard(self.slo_ttft, self.incumbent_stats(wl),
                                 shadow_frac=self.shadow_frac)
            stats = self.replay(wl, rt, guard=guard)
            return TrialResult(cost_s=float(self.cost_of(stats)),
                               compiles=1,
                               compile_s=round(time.time() - t0, 2))
        except Exception as e:
            err = str(e) if isinstance(e, TrialError) \
                else f"{type(e).__name__}: {e}"
            return TrialResult(cost_s=float("inf"), crashed=True,
                               error=err[:500],
                               failure=classify_exception(e),
                               compile_s=round(time.time() - t0, 2))


class CachedServe(CachedMeasure):
    """The serve tier's TimingCache wrapper: same two-level policy as
    every measured evaluation, with the trace's *content* key and the
    SLO setting folded into the cache key — a registry edit or a
    different guard threshold can never alias onto a stale cost, and
    two fabric workers replaying the same spec agree on every key."""

    def _key(self, wl: Workload, rt: TunableConfig) -> str:
        ev = self.evaluator
        slo = getattr(ev, "slo_ttft", None)
        tag = (f"{SERVE_MEASURE_VERSION}:{get_trace(wl.shape).key()}"
               f":slo={slo}")
        return measure_key(wl, rt, self.repeats, tag)


def make_serve_evaluator(slo_ttft: Optional[float] = None,
                         cache: Optional[TimingCache] = None
                         ) -> CachedServe:
    """The serve branch of the campaign's dispatch evaluator."""
    return CachedServe(ServeEvaluator(slo_ttft=slo_ttft), cache=cache,
                       repeats=1)


def make_evaluator() -> "Any":
    """Zero-arg factory (``--evaluator repro.serving.evaluator:
    make_evaluator``): the standard dispatch stack with the serve tier
    attached — identical to the campaign default."""
    from repro.core.kernel_cell import DispatchEvaluator
    return DispatchEvaluator()
