"""Optimizers (optax-free, pjit-friendly pure transformations).

AdamW for everything that fits; Adafactor (factored second moments) for
the trillion-parameter archs whose Adam state exceeds the fleet's HBM
(DESIGN.md §4).  All state lives in a pytree mirroring the param tree so
FSDP sharding rules apply to it transparently.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    # (grads, state, params) -> (new_params, new_state, metrics)
    update: Callable[[Any, Any, Any], Tuple[Any, Any, Dict]]
    # param PartitionSpec tree -> state PartitionSpec tree
    state_specs: Callable[[Any], Any]


def _global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    gn = _global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), gn


# ------------------------------------------------------------ schedules
def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        warm = peak_lr * jnp.minimum(1.0, step / max(1, warmup))
        frac = jnp.clip((step - warmup) / max(1, total - warmup), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 *
                         (1 + jnp.cos(jnp.pi * frac)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def constant_schedule(lr_val: float) -> Callable:
    return lambda step: jnp.asarray(lr_val, jnp.float32)


# ------------------------------------------------------------ adamw
def adamw(lr: Callable, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          clip_norm: Optional[float] = 1.0) -> Optimizer:
    def init(params):
        zeros = lambda: jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"mu": zeros(), "nu": zeros(),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if clip_norm is not None:
            grads, gn = clip_by_global_norm(grads, clip_norm)
        else:
            gn = _global_norm(grads)
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        lr_t = lr(step)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g),
                          state["nu"], grads)
        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype)
        new_p = jax.tree.map(upd, params, mu, nu)
        return (new_p, {"mu": mu, "nu": nu, "step": step},
                {"grad_norm": gn, "lr": lr_t})

    def state_specs(param_specs, param_shapes=None):
        from jax.sharding import PartitionSpec as P
        return {"mu": param_specs, "nu": param_specs, "step": P()}

    return Optimizer(init, update, state_specs)


# ------------------------------------------------------------ adafactor
def adafactor(lr: Callable, eps: float = 1e-30, clip_threshold: float = 1.0,
              weight_decay: float = 0.0, min_dim: int = 64) -> Optimizer:
    """Factored second moments over the last two dims of big matrices."""
    def _factored(p):
        return p.ndim >= 2 and p.shape[-1] >= min_dim and p.shape[-2] >= min_dim

    def init(params):
        def mk(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"fac": jax.tree.map(mk, params,
                                    is_leaf=lambda x: hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        lr_t = lr(step)
        beta2 = 1.0 - t ** -0.8

        def upd(p, g, s):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps
            if _factored(p):
                vr = beta2 * s["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * s["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True),
                                    eps)[..., None]
                u = g * jax.lax.rsqrt(vr[..., None] / denom) \
                      * jax.lax.rsqrt(vc[..., None, :])
                new_s = {"vr": vr, "vc": vc}
            else:
                v = beta2 * s["v"] + (1 - beta2) * g2
                u = g * jax.lax.rsqrt(v)
                new_s = {"v": v}
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), new_s

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["fac"])
        outs = [upd(p, g, s) for p, g, s in zip(flat_p, flat_g, flat_s)]
        new_p = jax.tree.unflatten(tdef, [o[0] for o in outs])
        new_fac = jax.tree.unflatten(tdef, [o[1] for o in outs])
        return (new_p, {"fac": new_fac, "step": step},
                {"grad_norm": _global_norm(grads), "lr": lr_t})

    def state_specs(param_specs, param_shapes):
        from jax.sharding import PartitionSpec as P

        def mk(spec, shp):
            spec = tuple(spec) + (None,) * (len(shp.shape) - len(tuple(spec)))
            if _factored(shp):
                # vr drops the last dim, vc drops the second-to-last
                return {"vr": P(*spec[:-1]),
                        "vc": P(*(tuple(spec[:-2]) + (spec[-1],)))}
            return {"v": P(*spec)}

        return {"fac": jax.tree.map(
            mk, param_specs, param_shapes,
            is_leaf=lambda s: isinstance(s, P)), "step": P()}

    return Optimizer(init, update, state_specs)


def make_optimizer(name: str, lr: Callable = None, **kw) -> Optimizer:
    lr = lr or cosine_schedule(3e-4, 100, 10_000)
    if name == "adamw":
        return adamw(lr, **kw)
    if name == "adafactor":
        return adafactor(lr, **kw)
    raise ValueError(f"unknown optimizer {name!r}")
