"""Straggler mitigation: k-of-median step-time detection per host.

On a real fleet every host reports a heartbeat (host_id, step, seconds);
the detector flags hosts whose trailing-window median exceeds
``factor`` x the fleet median, and fires ``action`` (e.g. cordon +
respawn, or trigger an elastic remesh without the slow host).  The
container exercises it with simulated heartbeats (tests/test_ft.py).
"""
from __future__ import annotations

import collections
import statistics
from typing import Callable, Deque, Dict, List, Optional


class StragglerDetector:
    def __init__(self, factor: float = 2.0, window: int = 16,
                 min_samples: int = 4,
                 action: Optional[Callable[[str, float, float], None]] = None):
        self.factor = factor
        self.window = window
        self.min_samples = min_samples
        self.action = action
        self._times: Dict[str, Deque[float]] = collections.defaultdict(
            lambda: collections.deque(maxlen=window))
        self.flagged: List[str] = []

    def heartbeat(self, host: str, step: int, seconds: float):
        self._times[host].append(seconds)

    def _host_median(self, host: str) -> Optional[float]:
        t = self._times[host]
        if len(t) < self.min_samples:
            return None
        return statistics.median(t)

    def check(self) -> List[str]:
        """Returns hosts currently flagged as stragglers."""
        meds = {h: m for h in self._times
                if (m := self._host_median(h)) is not None}
        if len(meds) < 2:
            return []
        fleet = statistics.median(meds.values())
        out = []
        for h, m in meds.items():
            if m > self.factor * fleet:
                out.append(h)
                if h not in self.flagged:
                    self.flagged.append(h)
                    if self.action:
                        self.action(h, m, fleet)
        return out
