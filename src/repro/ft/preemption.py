"""Preemption handling: SIGTERM/SIGINT -> graceful checkpoint -> restart.

The training driver polls ``requested()`` each step; on preemption it
commits a final checkpoint and exits with RESTART_EXIT_CODE, which the
cluster launcher (or launch/train.py --supervise) maps to a relaunch
with --resume.
"""
from __future__ import annotations

import signal
import threading

RESTART_EXIT_CODE = 42


class PreemptionHandler:
    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def install(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._on_signal)
        return self

    def _on_signal(self, signum, frame):
        self._flag.set()

    def requested(self) -> bool:
        return self._flag.is_set()

    def trigger(self):            # tests / simulated preemption
        self._flag.set()

    def uninstall(self):
        for s, prev in self._prev.items():
            signal.signal(s, prev)
