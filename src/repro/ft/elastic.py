"""Elastic scaling: rebuild the mesh for a changed device count and
reshard the training state from the (mesh-agnostic) checkpoint.

Checkpoints store plain host arrays, so a job that loses (or gains) a
slice restarts with a new mesh factorization; only the data-parallel
extent changes — the model-axis extent is preserved when possible so
TP/EP layouts stay valid.
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro.checkpoint import checkpoint as ckpt
from repro.compat import axis_types_kw


def remesh(n_devices: int, model_axis: int,
           devices=None) -> Mesh:
    """Largest (data x model) mesh fitting n_devices, model extent fixed."""
    devices = devices if devices is not None else jax.devices()
    if n_devices > len(devices):
        raise ValueError(f"asked for {n_devices}, have {len(devices)}")
    while model_axis > 1 and n_devices % model_axis != 0:
        model_axis //= 2
    data = n_devices // model_axis
    grid = np.array(devices[:data * model_axis]).reshape(data, model_axis)
    return Mesh(grid, ("data", "model"), **axis_types_kw(2))


def restore_resharded(directory: str, step: int, target_tree, new_shardings):
    """Restore a checkpoint onto a different mesh (new shardings tree)."""
    return ckpt.restore(directory, step, target_tree, new_shardings)


def survivors_mesh(old_mesh: Mesh, lost: int) -> Tuple[Mesh, int]:
    """Mesh after losing ``lost`` devices (keeps model axis if possible)."""
    n = old_mesh.devices.size - lost
    model = old_mesh.shape.get("model", 1)
    new = remesh(n - (n % model) if n % model else n, model,
                 devices=list(old_mesh.devices.flatten()))
    return new, new.devices.size
