"""Explicit-collective gradient synchronization (full-manual shard_map).

This path makes the paper's shuffle knobs *real* in the HLO:
  * ``grad_comm_dtype``  (spark.shuffle.compress)  — the wire dtype of the
    gradient all-reduce / reduce-scatter.
  * ``fuse_grad_collectives`` (spark.shuffle.consolidateFiles) — bucket all
    same-axis reductions into one flat-buffer collective.

Availability mirrors Spark's manager-dependent parameters: the explicit
path supports ``dp`` (replicated params, psum grads) and ``fsdp``
(hand-rolled ZeRO-3: all-gather params on entry, psum_scatter grads),
for families without an inner expert-parallel shard_map (i.e. not moe).
``tp``/``fsdp_tp`` use the auto-SPMD path where XLA schedules collectives
(grad-comm knobs are documented no-ops there, DESIGN.md §2.2).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.params import TunableConfig
from repro.runtime.sharding import ShardingRules


def explicit_applicable(family: str, rt: TunableConfig) -> bool:
    return rt.shard_strategy in ("dp", "fsdp") and family != "moe"


def _fsdp_dim(spec: P) -> Optional[Tuple[int, Tuple[str, ...]]]:
    """(dim index, mesh axes) of the fsdp-sharded dim of a param spec."""
    for i, ax in enumerate(spec):
        axes = (ax,) if isinstance(ax, str) else tuple(ax or ())
        if axes:
            return i, axes
    return None


def gather_params(params, specs):
    """all-gather fsdp-sharded params to full (inside manual shard_map)."""
    def g(p, spec):
        hit = _fsdp_dim(spec)
        if hit is None:
            return p
        i, axes = hit
        for ax in axes:
            p = jax.lax.all_gather(p, ax, axis=i, tiled=True)
        return p
    return jax.tree.map(g, params, specs,
                        is_leaf=lambda s: isinstance(s, P))


def quantize_ef(g, state):
    """int8 error-feedback compression for gradient all-reduce.

    Adds the residual from the previous step before quantizing and keeps
    the new residual (EF-SGD): unbiased in the long run even at 8 bits.
    Returns (int8 payload, f32 scale, new residual)."""
    g = g.astype(jnp.float32) + (state if state is not None else 0.0)
    amax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    residual = g - q.astype(jnp.float32) * scale
    return q, scale, residual


def dequantize_ef(q, scale):
    return q.astype(jnp.float32) * scale


def int8_allreduce_ef(flat, resid, axis: str, n: int):
    """2-phase int8 all-reduce with error feedback over one mesh axis.

    Phase 1: quantize (EF), all_to_all int8 chunks, dequantize + sum in
    f32.  Phase 2: requantize the reduced chunk, all_gather int8.  Wire
    bytes ~= 2 x N x 1B vs the f32 ring's 2 x N x 4B.  The second-stage
    quantization error is not fed back (documented; first-stage EF
    dominates).  flat: (N,) f32; resid: (N,) f32.  Returns (sum, resid).
    """
    N = flat.shape[0]
    pad = (-N) % n
    fp = jnp.pad(flat, (0, pad))
    rp = jnp.pad(resid, (0, pad))
    q, scale, new_resid = quantize_ef(fp, rp)
    chunks = q.reshape(n, -1)
    recv = jax.lax.all_to_all(chunks, axis, 0, 0, tiled=True)  # (n, m)
    scales = jax.lax.all_gather(scale, axis)                   # (n,)
    partial = jnp.sum(recv.astype(jnp.float32) * scales[:, None], axis=0)
    amax = jnp.maximum(jnp.max(jnp.abs(partial)), 1e-12)
    s2 = amax / 127.0
    q2 = jnp.clip(jnp.round(partial / s2), -127, 127).astype(jnp.int8)
    all_q = jax.lax.all_gather(q2, axis)                       # (n, m)
    all_s = jax.lax.all_gather(s2, axis)                       # (n,)
    out = (all_q.astype(jnp.float32) * all_s[:, None]).reshape(-1)
    return out[:N], new_resid[:N]


def reduce_grads(grads, specs, rt: TunableConfig, data_axes: Tuple[str, ...],
                 scale: float):
    """Reduce local grads across data axes with the comm-dtype knob.

    fsdp params: psum_scatter back to the shard; others: psum.
    ``fuse_grad_collectives``: one flat bucket for all plain psums.
    """
    comm = jnp.dtype(rt.grad_comm_dtype)
    flat, tdef = jax.tree.flatten(grads)
    spec_flat = tdef.flatten_up_to(specs)
    sdims = [_fsdp_dim(s) for s in spec_flat]

    out: List[Any] = [None] * len(flat)
    # fsdp leaves: reduce-scatter back to the shard, psum over the rest
    for i, (g, sd) in enumerate(zip(flat, sdims)):
        if sd is None:
            continue
        dim, axes = sd
        g = g.astype(comm)
        for ax in reversed(axes):
            g = jax.lax.psum_scatter(g, ax, scatter_dimension=dim,
                                     tiled=True)
        rest = tuple(a for a in data_axes if a not in axes)
        if rest:
            g = jax.lax.psum(g, rest)
        out[i] = (g.astype(jnp.float32) * scale)

    plain = [(i, g) for i, (g, sd) in enumerate(zip(flat, sdims))
             if sd is None]
    if plain:
        if rt.fuse_grad_collectives:
            shapes = [g.shape for _, g in plain]
            sizes = [int(np.prod(s)) if s else 1 for s in shapes]
            flatbuf = jnp.concatenate(
                [g.astype(comm).reshape(-1) for _, g in plain])
            flatbuf = jax.lax.psum(flatbuf, data_axes)
            off = 0
            for (i, _), s, n in zip(plain, shapes, sizes):
                out[i] = (flatbuf[off:off + n].reshape(s)
                          .astype(jnp.float32) * scale)
                off += n
        else:
            for i, g in plain:
                g = jax.lax.psum(g.astype(comm), data_axes)
                out[i] = g.astype(jnp.float32) * scale
    return jax.tree.unflatten(tdef, out)


def reduce_grads_int8_ef(grads, rt: TunableConfig,
                         data_axes: Tuple[str, ...],
                         axis_sizes: Dict[str, int], ef_state, scale: float):
    """Bucketed int8-EF gradient reduction (dp strategy: every leaf is
    replicated).  ef_state: (1, N_total) per-shard residual.  Returns
    (grad tree, new ef_state)."""
    flat, tdef = jax.tree.flatten(grads)
    shapes = [g.shape for g in flat]
    sizes = [int(np.prod(s)) if s else 1 for s in shapes]
    buf = jnp.concatenate([g.astype(jnp.float32).reshape(-1)
                           for g in flat])
    resid = ef_state.reshape(-1)
    for ax in data_axes:
        buf, resid = int8_allreduce_ef(buf, resid, ax, axis_sizes[ax])
    outs, off = [], 0
    for s, n in zip(shapes, sizes):
        outs.append(buf[off:off + n].reshape(s) * scale)
        off += n
    return jax.tree.unflatten(tdef, outs), resid.reshape(ef_state.shape)
