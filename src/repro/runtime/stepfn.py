"""Train / serve step builders: the executable a workload cell lowers.

``build_train_step`` returns a :class:`StepBundle` carrying the jitted
function plus the abstract arguments and shardings needed to
``.lower().compile()`` it with no allocation (dry-run protocol) or to run
it for real (examples, smoke tests).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.params import TunableConfig
from repro.models import layers as L
from repro.models.model import Model, batch_logical, build_model, input_specs
from repro.optim.optimizers import Optimizer, make_optimizer
from repro.runtime import gradsync
from repro.runtime.loops import scan_layers
from repro.runtime.sharding import ShardingRules


def build_rules(mesh: Mesh, cfg: ArchConfig, rt: TunableConfig) -> ShardingRules:
    return ShardingRules(mesh=mesh, strategy=rt.shard_strategy,
                         fsdp_axes=cfg.fsdp_axes,
                         attn_tp_fallback=rt.attn_tp_fallback)


def cast_params_for_compute(params, rt: TunableConfig):
    """Cast master weights to the compute dtype ONCE, before any use —
    so FSDP all-gathers move compute-dtype bytes, not f32 masters
    (standard practice; halves the param-gather collective term under
    bf16).  Gradients still accumulate in f32 through the cast."""
    comp = jnp.dtype(rt.compute_dtype)
    return jax.tree.map(
        lambda x: x.astype(comp)
        if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype != comp
        else x, params)


@dataclasses.dataclass
class StepBundle:
    """Everything needed to lower/compile/run one workload cell."""
    fn: Any                       # jitted step function
    args: Tuple                   # abstract ShapeDtypeStructs (lowering order)
    rules: ShardingRules
    kind: str                     # train | prefill | decode
    notes: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def lower(self):
        return self.fn.lower(*self.args)


def _param_shardings(model: Model, rules: ShardingRules):
    shapes = model.param_shapes()
    logical = model.logical()
    specs = jax.tree.map(
        lambda lg, sd: rules.param_spec(lg, sd.shape), logical, shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    sh = jax.tree.map(lambda s: rules.sharding(s), specs,
                      is_leaf=lambda s: isinstance(s, P))
    return shapes, specs, sh


def _batch_shardings(cfg, shape, rt, rules):
    specs = input_specs(cfg, shape, rt)
    lg = batch_logical(cfg, shape, rt)
    sh = {k: rules.sharding(rules.act_spec(lg[k], specs[k].shape))
          for k in specs}
    return specs, sh


def _cache_shardings(model: Model, batch: int, max_seq: int,
                     rt: TunableConfig, rules: ShardingRules):
    shapes, logical = model.cache_shapes(batch, max_seq, rt)
    def spec_of(lg, sd):
        return rules.sharding(rules.act_spec(lg, sd.shape))
    sh = jax.tree.map(spec_of, logical, shapes,
                      is_leaf=lambda x: isinstance(x, tuple) and all(
                          isinstance(e, (str, type(None))) for e in x))
    return shapes, sh


# ===================================================================== train
def build_train_step(cfg: ArchConfig, shape: ShapeConfig, rt: TunableConfig,
                     mesh: Mesh, optimizer: Optional[Optimizer] = None
                     ) -> StepBundle:
    model = build_model(cfg)
    rules = build_rules(mesh, cfg, rt)
    optimizer = optimizer or make_optimizer(cfg.optimizer)
    p_shapes, p_specs, p_sh = _param_shardings(model, rules)
    b_shapes, b_sh = _batch_shardings(cfg, shape, rt, rules)
    o_shapes = jax.eval_shape(optimizer.init, p_shapes)
    o_specs = optimizer.state_specs(p_specs, p_shapes)
    o_sh = jax.tree.map(lambda s: rules.sharding(s), o_specs,
                        is_leaf=lambda s: isinstance(s, P))

    explicit = (gradsync.explicit_applicable(cfg.family, rt)
                and mesh.shape.get("data", 1) > 1)
    # int8+error-feedback gradient compression: dp strategy only (every
    # leaf replicated -> one fused bucket); falls back to bf16 otherwise
    ef = (explicit and rt.grad_comm_dtype == "int8_ef"
          and rt.shard_strategy == "dp")
    if rt.grad_comm_dtype == "int8_ef" and not ef:
        rt = rt.replace(grad_comm_dtype="bfloat16")
    m = rt.microbatches

    def split_mb(batch):
        return jax.tree.map(
            lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]), batch)

    if explicit:
        # ---- full-manual shard_map over every mesh axis; the model runs
        # on local shards with rules=None; grad collectives are explicit.
        data_axes = rules.batch_axes
        axis_sizes = {a: mesh.shape[a] for a in data_axes}
        n_shards = rules.data_axis_size()
        n_total = int(sum(int(np.prod(s.shape)) if s.shape else 1
                          for s in jax.tree.leaves(p_shapes))) if ef else 0

        def local_grads(params_local, batch_local, ef_local):
            # cast before the gather: wire bytes at compute dtype
            full = gradsync.gather_params(
                cast_params_for_compute(params_local, rt), p_specs)
            def loss_of(p, b):
                return model.loss_fn(p, b, rt, None)[0]
            if m == 1:
                loss, g = jax.value_and_grad(loss_of)(full, batch_local)
            else:
                def mb_step(acc, mb):
                    l, g = jax.value_and_grad(loss_of)(full, mb)
                    return jax.tree.map(jnp.add, acc,
                                        (l, jax.tree.map(
                                            lambda x: x.astype(jnp.float32),
                                            g))), None
                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(lambda s: jnp.zeros(s.shape,
                                                         jnp.float32), full))
                (loss, g), _ = scan_layers(mb_step, zero,
                                           split_mb(batch_local),
                                           unroll=rt.unroll_layers)
                loss, g = loss / m, jax.tree.map(lambda x: x / m, g)
            scale = 1.0 / n_shards
            if ef:
                g, ef_local = gradsync.reduce_grads_int8_ef(
                    g, rt, data_axes, axis_sizes, ef_local, scale)
            else:
                g = gradsync.reduce_grads(g, p_specs, rt, data_axes, scale)
            loss = jax.lax.pmean(loss, data_axes)
            return loss, g, ef_local

        # under dp/fsdp, param specs reference only data/pod axes, so they
        # are valid manual specs as-is; batch is manual over the data axes
        in_b_specs = {k: P(*([data_axes] + [None] * (len(b_shapes[k].shape)
                                                     - 1)))
                      for k in b_shapes}
        ef_spec = P(data_axes, None)
        sm = compat.shard_map(local_grads, mesh=mesh,
                              in_specs=(p_specs, in_b_specs, ef_spec),
                              out_specs=(P(), p_specs, ef_spec),
                              check_vma=False)

        if ef:
            # augment the optimizer state with the per-shard EF residual
            o_shapes = {"opt": o_shapes,
                        "ef": jax.ShapeDtypeStruct((n_shards, n_total),
                                                   jnp.float32)}
            o_sh = {"opt": o_sh,
                    "ef": rules.sharding(P(data_axes, None))}

            def step(params, opt_state, batch):
                loss, grads, ef_new = sm(params, batch, opt_state["ef"])
                new_p, new_s, met = optimizer.update(grads,
                                                     opt_state["opt"],
                                                     params)
                return new_p, {"opt": new_s, "ef": ef_new}, dict(met,
                                                                 loss=loss)
        else:
            def step(params, opt_state, batch):
                dummy = jnp.zeros((n_shards, 1), jnp.float32)
                loss, grads, _ = sm(params, batch, dummy)
                new_p, new_s, met = optimizer.update(grads, opt_state,
                                                     params)
                return new_p, new_s, dict(met, loss=loss)

    else:
        # ---- auto-SPMD path: XLA schedules all collectives
        def loss_of(p, b):
            loss, _ = model.loss_fn(cast_params_for_compute(p, rt), b, rt,
                                    rules)
            return loss

        def step(params, opt_state, batch):
            if m == 1:
                loss, grads = jax.value_and_grad(loss_of)(params, batch)
            else:
                def mb_step(acc, mb):
                    l, g = jax.value_and_grad(loss_of)(params, mb)
                    g = jax.tree.map(lambda x: x.astype(jnp.float32), g)
                    return jax.tree.map(jnp.add, acc, (l, g)), None
                zero = (jnp.zeros((), jnp.float32),
                        jax.tree.map(lambda s: jnp.zeros(s.shape, jnp.float32),
                                     params))
                (loss, grads), _ = scan_layers(mb_step, zero, split_mb(batch),
                                               unroll=rt.unroll_layers)
                loss = loss / m
                grads = jax.tree.map(lambda x: x / m, grads)
            new_p, new_s, met = optimizer.update(grads, opt_state, params)
            met = dict(met, loss=loss)
            return new_p, new_s, met

    donate = (0, 1) if rt.donate_buffers else ()
    jitted = jax.jit(step,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, None),
                     donate_argnums=donate)
    args = (p_shapes, o_shapes, b_shapes)
    return StepBundle(jitted, args, rules, "train",
                      notes={"explicit_comm": explicit,
                             "sharding_notes": list(rules.notes)})


# ===================================================================== serve
def build_prefill_step(cfg: ArchConfig, shape: ShapeConfig,
                       rt: TunableConfig, mesh: Mesh) -> StepBundle:
    model = build_model(cfg)
    rules = build_rules(mesh, cfg, rt)
    p_shapes, p_specs, p_sh = _param_shardings(model, rules)
    b_shapes, b_sh = _batch_shardings(cfg, shape, rt, rules)

    def step(params, batch):
        return model.prefill_fn(params, batch, rt, rules,
                                max_seq=shape.seq_len)

    jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
    return StepBundle(jitted, (p_shapes, b_shapes), rules, "prefill",
                      notes={"sharding_notes": list(rules.notes)})


def build_decode_step(cfg: ArchConfig, shape: ShapeConfig,
                      rt: TunableConfig, mesh: Mesh) -> StepBundle:
    """One-token serve_step against a seq_len-deep cache."""
    model = build_model(cfg)
    rules = build_rules(mesh, cfg, rt)
    p_shapes, p_specs, p_sh = _param_shardings(model, rules)
    c_shapes, c_sh = _cache_shardings(model, shape.global_batch,
                                      shape.seq_len, rt, rules)
    t_shape = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    t_sh = rules.sharding(rules.act_spec(("batch", None), t_shape.shape))

    def step(params, cache, tokens):
        return model.decode_fn(params, cache, tokens, rt, rules)

    donate = (1,) if rt.donate_buffers else ()
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh),
                     out_shardings=(None, c_sh),
                     donate_argnums=donate)
    return StepBundle(jitted, (p_shapes, c_shapes, t_shape), rules, "decode",
                      notes={"sharding_notes": list(rules.notes)})


def build_step(cfg: ArchConfig, shape: ShapeConfig, rt: TunableConfig,
               mesh: Mesh) -> StepBundle:
    """Dispatch on the cell kind (train_4k -> train, decode_* -> decode...)."""
    if shape.kind == "train":
        return build_train_step(cfg, shape, rt, mesh)
    if shape.kind == "prefill":
        return build_prefill_step(cfg, shape, rt, mesh)
    return build_decode_step(cfg, shape, rt, mesh)
