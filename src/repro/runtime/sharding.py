"""Sharding rules: logical axis names -> mesh axes, per shard strategy.

The four strategies map to the paper's shuffle managers (DESIGN.md §2.1):
``dp`` (sort/default: replicate params, all-reduce grads), ``fsdp`` (hash:
shard params over the data axis, all-gather on use), ``tp`` (tungsten-sort:
Megatron column/row parallel over the model axis), ``fsdp_tp`` (2D).

Every mapping is divisibility-guarded: a logical dim that does not divide
the mesh axis product falls back (recorded in ``notes``) instead of
failing — head counts like 56 or 9 must still compile on a 16-wide model
axis.  Attention's fallback behaviour is itself a tunable
(``attn_tp_fallback``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisName = Union[str, Tuple[str, ...], None]

# logical dim names used by the model zoo when annotating parameters
PARAM_LOGICAL = ("layers", "vocab", "embed", "heads", "kv_heads", "mlp",
                 "expert", "ssm_heads", "ssm_inner", "state", None)


def _axes_size(mesh: Mesh, axes: AxisName) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    strategy: str                       # dp | fsdp | tp | fsdp_tp
    fsdp_axes: Tuple[str, ...] = ("data",)
    attn_tp_fallback: str = "replicate"  # replicate | batch_shard
    notes: List[str] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        if self.strategy not in ("dp", "fsdp", "tp", "fsdp_tp"):
            raise ValueError(f"unknown strategy {self.strategy}")
        # fsdp axes that exist in this mesh (single-pod mesh has no 'pod')
        self.fsdp_axes = tuple(a for a in self.fsdp_axes
                               if a in self.mesh.shape)
        self._batch_axes = tuple(a for a in ("pod", "data")
                                 if a in self.mesh.shape)

    # -------------------------------------------------- helpers
    def _fit(self, dim: Optional[int], axes: AxisName, what: str) -> AxisName:
        """Return ``axes`` if ``dim`` divides their product, else None."""
        if axes is None:
            return None
        if dim is not None and dim % _axes_size(self.mesh, axes) != 0:
            self.notes.append(
                f"{what}: dim {dim} not divisible by {axes} "
                f"({_axes_size(self.mesh, axes)}); left unsharded")
            return None
        return axes

    @property
    def tp(self) -> bool:
        return self.strategy in ("tp", "fsdp_tp")

    @property
    def fsdp(self) -> bool:
        return self.strategy in ("fsdp", "fsdp_tp")

    @property
    def batch_axes(self) -> Tuple[str, ...]:
        return self._batch_axes

    def data_axis_size(self) -> int:
        return _axes_size(self.mesh, self._batch_axes)

    def model_axis_size(self) -> int:
        return self.mesh.shape.get("model", 1)

    # -------------------------------------------------- parameters
    def param_spec(self, logical: Sequence[Optional[str]],
                   shape: Sequence[int]) -> P:
        """PartitionSpec for a parameter annotated with logical dim names."""
        assert len(logical) == len(shape), (logical, shape)
        out: List[AxisName] = [None] * len(shape)
        heads_sharded = False
        # the model axis goes to at most ONE dim per param; priority:
        # experts (EP) > heads (attention TP) > column/row dims
        priority = ("expert", "heads", "kv_heads", "mlp", "vocab",
                    "ssm_heads", "ssm_inner")
        if self.tp:
            for want in priority:
                placed = False
                for i, (name, dim) in enumerate(zip(logical, shape)):
                    if name == want and out[i] is None:
                        got = self._fit(dim, "model", f"param.{name}")
                        if got is not None:
                            out[i] = got
                            placed = True
                            if name in ("heads", "kv_heads"):
                                heads_sharded = True
                            break
                if placed:
                    break
        model_placed = any(
            "model" in ((ax,) if isinstance(ax, str) else (ax or ()))
            for ax in out)
        if self.fsdp:
            for i, (name, dim) in enumerate(zip(logical, shape)):
                if name == "embed" and out[i] is None:
                    out[i] = self._fit(dim, self.fsdp_axes, "param.embed")
        # TP couldn't shard ANY dim of an attention weight: fold the
        # model axis into the embed dim (fully-sharded, all-gather on use)
        if (self.tp and not model_placed
                and any(n in ("heads", "kv_heads") for n in logical)):
            for i, (name, dim) in enumerate(zip(logical, shape)):
                if name == "embed":
                    cur = out[i]
                    cand = (tuple(cur) if isinstance(cur, tuple)
                            else (cur,) if cur else ())
                    cand = cand + ("model",)
                    out[i] = self._fit(dim, cand, "param.embed+model")
        return P(*out)

    # -------------------------------------------------- activations
    def act_spec(self, logical: Sequence[Optional[str]],
                 shape: Sequence[int]) -> P:
        """PartitionSpec for an activation (batch/seq/heads/embed dims)."""
        out: List[AxisName] = [None] * len(shape)
        for i, (name, dim) in enumerate(zip(logical, shape)):
            if name == "batch":
                out[i] = self._fit(dim, self._batch_axes, "act.batch")
            elif name == "heads" and self.tp:
                out[i] = self._fit(dim, "model", "act.heads")
            elif name == "kv_heads" and self.tp:
                out[i] = self._fit(dim, "model", "act.kv_heads")
            elif name in ("mlp", "vocab", "expert", "ssm_heads",
                          "ssm_inner") and self.tp:
                out[i] = self._fit(dim, "model", f"act.{name}")
            elif name == "seq_model" and self.tp:   # explicit seq-sharding ask
                out[i] = self._fit(dim, "model", "act.seq")
            elif name == "seq_data":
                out[i] = self._fit(dim, self._batch_axes, "act.seq")
        return P(*out)

    def constrain(self, x, *logical: Optional[str]):
        """with_sharding_constraint by logical names (no-op outside mesh)."""
        spec = self.act_spec(logical, x.shape)
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    # attention fallback: batch-shard the attention op over the model axis
    def attn_batch_spec(self, batch: int) -> Optional[P]:
        if self.attn_tp_fallback != "batch_shard" or not self.tp:
            return None
        axes = self._batch_axes + ("model",)
        if batch % _axes_size(self.mesh, axes) == 0:
            return P(axes)
        self.notes.append(f"attn batch_shard: batch {batch} does not divide "
                          f"{axes}; using replicate fallback")
        return None

    # -------------------------------------------------- named shardings
    def sharding(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def param_sharding_tree(self, logical_tree, shape_tree):
        """Map parallel pytrees of logical names and ShapeDtypeStructs to
        NamedShardings."""
        return jax.tree.map(
            lambda lg, sd: self.sharding(self.param_spec(lg, sd.shape)),
            logical_tree, shape_tree,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
