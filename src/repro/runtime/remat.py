"""Activation-checkpointing policies — the memoryFraction knob (DESIGN §2.1).

``remat_policy``: 'dots' (balanced — Spark's default 0.2/0.6 fractions),
'none' (store everything = storage-heavy 0.1/0.7), 'full' (recompute
everything = shuffle-heavy).
``remat_save_dtype``: dtype the saved residual stream is kept in between
layers (spark.shuffle.spill.compress analogue) — the scan carry itself is
held in this dtype when remat is active.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.params import TunableConfig

_POLICIES = {
    "dots": jax.checkpoint_policies.dots_saveable,
    "full": jax.checkpoint_policies.nothing_saveable,
}


def wrap_layer(fn, rt: TunableConfig):
    """Apply the remat policy to a scan-body layer function."""
    if rt.remat_policy == "none":
        return fn
    return jax.checkpoint(fn, policy=_POLICIES[rt.remat_policy],
                          prevent_cse=False)


def carry_dtype(rt: TunableConfig):
    """Dtype of the saved residual stream between layers."""
    if rt.remat_policy == "none":
        return jnp.dtype(rt.compute_dtype)
    save = jnp.dtype(rt.remat_save_dtype)
    comp = jnp.dtype(rt.compute_dtype)
    return save if save.itemsize < comp.itemsize else comp


def to_carry(x, rt: TunableConfig):
    return x.astype(carry_dtype(rt))


def from_carry(x, rt: TunableConfig):
    return x.astype(jnp.dtype(rt.compute_dtype))
