"""Layer-stack iteration: lax.scan (deployable; small HLO) or an
unrolled python loop.

The unrolled form exists because XLA's ``cost_analysis`` counts a
``while`` body ONCE regardless of trip count (verified in
tests/test_costmodel_calibration.py), so roofline terms for scanned
stacks must be calibrated from small unrolled compiles
(core/costmodel.calibrated_roofline).  It is also a legitimate runtime
mode (unrolling exposes cross-layer fusion to XLA at higher compile
cost).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def scan_layers(body, carry, xs, *, unroll: bool = False, length=None):
    """drop-in for jax.lax.scan(body, carry, xs) over a layer stack."""
    if not unroll:
        return jax.lax.scan(body, carry, xs, length=length)
    n = length if length is not None else jax.tree.leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = (jax.tree.map(lambda a: a[i], xs) if xs is not None else None)
        carry, y = body(carry, xi)
        ys.append(y)
    if not ys or all(y is None for y in ys):
        return carry, None
    stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked
