"""Apply the paper's trial-and-error methodology to one workload cell.

MUST set the placeholder device count before ANY jax-touching import.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import pathlib
import sys

from repro.core import report
from repro.core.params import default_config
from repro.core.tree import run_tuning
from repro.core.trial import RooflineEvaluator, TrialRunner, Workload

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "tuning"


def tune_cell(arch: str, shape: str, multi_pod: bool = False,
              threshold: float = 0.05, baseline_overrides=None):
    from repro.core.executor import SweepExecutor
    wl = Workload(arch, shape, multi_pod)
    # attn_impl=pallas is infrastructure (the execution engine's kernel),
    # not one of the 12 tunables — see DESIGN.md §2.2
    baseline = default_config(shard_strategy="fsdp_tp",
                              attn_impl="pallas",
                              **(baseline_overrides or {}))
    with SweepExecutor(RooflineEvaluator()) as executor:
        runner = TrialRunner(wl, executor.evaluator)
        rep = run_tuning(runner, baseline, threshold=threshold,
                         executor=executor)
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{wl.key()}.json").write_text(
        json.dumps(rep.__dict__, indent=1, default=str))
    (RESULTS_DIR / f"{wl.key()}.md").write_text(report.tuning_markdown(rep))
    return rep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--threshold", type=float, default=0.05)
    args = ap.parse_args(argv)
    rep = tune_cell(args.arch, args.shape, args.multi_pod, args.threshold)
    print(report.tuning_markdown(rep))
    print(f"\nspeedup: x{rep.speedup:.2f} in {rep.n_trials} trials")
    return 0


if __name__ == "__main__":
    sys.exit(main())
