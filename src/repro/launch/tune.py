"""Apply the paper's trial-and-error methodology to workload cells.

Single-cell mode (``--arch/--shape``) runs one (arch, shape, mesh)
cell.  Campaign mode (``--cells a:s,...`` or ``--all``) runs a whole
batch of cells in one concurrent campaign (core/campaign.py): every
cell's cursor interleaves over one shared executor + compile cache,
per-cell state checkpoints under ``results/campaign/`` (an interrupted
campaign resumes without re-paying completed trials), and the per-cell
reports are bit-identical to running the single-cell mode per cell.

``--strategy`` picks the search procedure (core/strategy.py) and
composes with both modes:

  * ``tree`` (default) — the paper's Fig.-4 ≤10-trial tuning tree;
  * ``short`` — the two-runs-shorter tree variant;
  * ``sensitivity`` — the Sec.-4 OFAT matrix (Table 2);
    ``--sweep-knobs`` restricts it to a knob subset;
  * ``random`` — budget-matched random-search baseline
    (``--budget``, ``--seed``).

MUST set the placeholder device count before ANY jax-touching import.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json
import pathlib
import sys

from repro.core import report
from repro.core.params import SENSITIVITY_SWEEP, default_config
from repro.core.trial import RooflineEvaluator, TrialRunner, Workload

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "tuning"


def _baseline(overrides=None):
    # attn_impl=pallas is infrastructure (the execution engine's kernel),
    # not one of the 12 tunables — see DESIGN.md §2.2
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas",
                          **(overrides or {}))


def _strategy_options(strategy, sweep_knobs=None, budget=None, seed=None):
    """CLI flags -> the strategy's cursor-factory options."""
    if strategy in ("sensitivity",) and sweep_knobs:
        names = [k.strip() for k in sweep_knobs.split(",") if k.strip()]
        unknown = [k for k in names if k not in SENSITIVITY_SWEEP]
        if unknown:
            raise ValueError(
                f"--sweep-knobs: {', '.join(unknown)} not in the "
                f"sensitivity sweep ({', '.join(SENSITIVITY_SWEEP)})")
        return {"knobs": {k: SENSITIVITY_SWEEP[k] for k in names}}
    if strategy == "random":
        opts = {}
        if budget is not None:
            opts["budget"] = budget
        if seed is not None:
            opts["seed"] = seed
        return opts
    return {}


def _save_cell_report(rep, strategy: str = "tree") -> None:
    # non-tree strategies write under results/tuning/<strategy>/ so two
    # strategies on the same cell never clobber each other's report
    # (mirrors the per-strategy checkpoint split in tune_campaign)
    out_dir = RESULTS_DIR if strategy == "tree" else RESULTS_DIR / strategy
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{rep.workload}.json").write_text(
        json.dumps(dataclasses.asdict(rep), indent=1, default=str))
    (out_dir / f"{rep.workload}.md").write_text(
        report.cell_markdown(rep))


def tune_cell(arch: str, shape: str, multi_pod: bool = False,
              threshold: float = 0.05, baseline_overrides=None,
              strategy: str = "tree", strategy_options=None):
    from repro.core.executor import SweepExecutor
    from repro.core.strategy import drive, make_cursor
    wl = Workload(arch, shape, multi_pod)
    baseline = _baseline(baseline_overrides)
    with SweepExecutor(RooflineEvaluator()) as executor:
        runner = TrialRunner(wl, executor.evaluator)
        cursor = make_cursor(strategy, runner, baseline,
                             threshold=threshold,
                             options=strategy_options)
        rep = drive(cursor, executor=executor)
    _save_cell_report(rep, strategy)
    return rep


def tune_campaign(cells, threshold: float = 0.05, baseline_overrides=None,
                  fresh: bool = False, checkpoint_dir=None,
                  strategy: str = "tree", strategy_options=None):
    """Run a strategy over a batch of cells in one concurrent campaign;
    returns ``{cell_key: report}`` plus the campaign's throughput
    stats.  Non-tree strategies checkpoint under a per-strategy
    subdirectory so campaigns with different strategies on the same
    cells never clobber each other."""
    from repro.core.campaign import CAMPAIGN_DIR, Campaign
    if checkpoint_dir:
        ckpt = pathlib.Path(checkpoint_dir)
    else:
        ckpt = CAMPAIGN_DIR if strategy == "tree" \
            else CAMPAIGN_DIR / strategy
    camp = Campaign(
        cells, strategy=strategy, strategy_options=strategy_options,
        threshold=threshold, checkpoint_dir=ckpt,
        baseline_factory=lambda spec: _baseline(baseline_overrides))
    if fresh:
        camp.discard_checkpoints()
    reports = camp.run()
    for rep in reports.values():
        _save_cell_report(rep, strategy)
    ckpt.mkdir(parents=True, exist_ok=True)
    (ckpt / "campaign.md").write_text(report.strategy_markdown(reports))
    (ckpt / "campaign_stats.json").write_text(
        json.dumps(camp.last_stats, indent=1))
    return reports, camp.last_stats


def _print_cell_summary(rep) -> None:
    if hasattr(rep, "speedup"):
        print(f"\nspeedup: x{rep.speedup:.2f} in {rep.n_trials} trials")
    else:
        top = max(rep.impacts, key=lambda i: i.mean_abs_pct)
        print(f"\ntop knob: {top.knob} ({top.mean_abs_pct:.1f}% mean "
              f"|deviation|) in {rep.n_trials} trials")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="single-cell mode: arch id")
    ap.add_argument("--shape", help="single-cell mode: shape id")
    ap.add_argument("--cells",
                    help="campaign mode: comma-separated "
                         "arch:shape[:pod|multipod] cell specs")
    ap.add_argument("--all", action="store_true",
                    help="campaign mode: every applicable cell of the "
                         "assignment")
    ap.add_argument("--strategy", default="tree",
                    choices=["tree", "short", "sensitivity", "random"],
                    help="search strategy (core/strategy.py registry)")
    ap.add_argument("--sweep-knobs",
                    help="sensitivity strategy: comma-separated knob "
                         "subset (default: the full SENSITIVITY_SWEEP)")
    ap.add_argument("--budget", type=int,
                    help="random strategy: trial budget (default 10)")
    ap.add_argument("--seed", type=int,
                    help="random strategy: sampling seed (default 0)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--threshold", type=float, default=0.05)
    ap.add_argument("--fresh", action="store_true",
                    help="campaign mode: discard checkpoints, re-tune")
    args = ap.parse_args(argv)

    if args.sweep_knobs and args.strategy != "sensitivity":
        ap.error("--sweep-knobs only applies to --strategy sensitivity")
    if (args.budget is not None or args.seed is not None) \
            and args.strategy != "random":
        ap.error("--budget/--seed only apply to --strategy random")
    options = _strategy_options(args.strategy, args.sweep_knobs,
                                args.budget, args.seed)
    if args.all or args.cells:
        from repro.core.campaign import enumerate_cells, parse_cells
        cells = parse_cells(args.cells,
                            default_multi_pod=args.multi_pod) \
            if args.cells else enumerate_cells(meshes=(args.multi_pod,))
        reports, stats = tune_campaign(cells, threshold=args.threshold,
                                       fresh=args.fresh,
                                       strategy=args.strategy,
                                       strategy_options=options)
        print(report.strategy_markdown(reports))
        print(f"\n[{stats['strategy']}] {stats['cells']} cells in "
              f"{stats['wall_s']}s "
              f"({stats['cells_per_hour']} cells/h; "
              f"{stats['evaluated_trials']} trials evaluated, "
              f"{stats['replayed_trials']} replayed from checkpoint)")
        return 0
    if not (args.arch and args.shape):
        ap.error("need --arch and --shape, or --cells/--all")
    rep = tune_cell(args.arch, args.shape, args.multi_pod, args.threshold,
                    strategy=args.strategy, strategy_options=options)
    print(report.cell_markdown(rep))
    _print_cell_summary(rep)
    return 0


if __name__ == "__main__":
    sys.exit(main())
