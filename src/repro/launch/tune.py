"""Apply the paper's trial-and-error methodology to workload cells.

Single-cell mode (``--arch/--shape``) tunes one (arch, shape, mesh)
cell, exactly as before.  Campaign mode (``--cells a:s,...`` or
``--all``) tunes a whole batch of cells in one concurrent campaign
(core/campaign.py): every cell's tree walk interleaves over one shared
executor + compile cache, per-cell state checkpoints under
``results/campaign/`` (an interrupted campaign resumes without
re-paying completed trials), and the per-cell reports are bit-identical
to running the single-cell mode per cell.

MUST set the placeholder device count before ANY jax-touching import.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import json
import pathlib
import sys

from repro.core import report
from repro.core.params import default_config
from repro.core.tree import run_tuning
from repro.core.trial import RooflineEvaluator, TrialRunner, Workload

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "tuning"


def _baseline(overrides=None):
    # attn_impl=pallas is infrastructure (the execution engine's kernel),
    # not one of the 12 tunables — see DESIGN.md §2.2
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas",
                          **(overrides or {}))


def _save_cell_report(rep) -> None:
    RESULTS_DIR.mkdir(parents=True, exist_ok=True)
    (RESULTS_DIR / f"{rep.workload}.json").write_text(
        json.dumps(rep.__dict__, indent=1, default=str))
    (RESULTS_DIR / f"{rep.workload}.md").write_text(
        report.tuning_markdown(rep))


def tune_cell(arch: str, shape: str, multi_pod: bool = False,
              threshold: float = 0.05, baseline_overrides=None):
    from repro.core.executor import SweepExecutor
    wl = Workload(arch, shape, multi_pod)
    baseline = _baseline(baseline_overrides)
    with SweepExecutor(RooflineEvaluator()) as executor:
        runner = TrialRunner(wl, executor.evaluator)
        rep = run_tuning(runner, baseline, threshold=threshold,
                         executor=executor)
    _save_cell_report(rep)
    return rep


def tune_campaign(cells, threshold: float = 0.05, baseline_overrides=None,
                  fresh: bool = False, checkpoint_dir=None):
    """Tune a batch of cells in one concurrent campaign; returns
    ``{cell_key: TuningReport}`` plus the campaign's throughput stats."""
    from repro.core.campaign import CAMPAIGN_DIR, Campaign
    ckpt = pathlib.Path(checkpoint_dir) if checkpoint_dir else CAMPAIGN_DIR
    camp = Campaign(
        cells, threshold=threshold, checkpoint_dir=ckpt,
        baseline_factory=lambda spec: _baseline(baseline_overrides))
    if fresh:
        camp.discard_checkpoints()
    reports = camp.run()
    for rep in reports.values():
        _save_cell_report(rep)
    ckpt.mkdir(parents=True, exist_ok=True)
    (ckpt / "campaign.md").write_text(report.campaign_markdown(reports))
    (ckpt / "campaign_stats.json").write_text(
        json.dumps(camp.last_stats, indent=1))
    return reports, camp.last_stats


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="single-cell mode: arch id")
    ap.add_argument("--shape", help="single-cell mode: shape id")
    ap.add_argument("--cells",
                    help="campaign mode: comma-separated "
                         "arch:shape[:pod|multipod] cell specs")
    ap.add_argument("--all", action="store_true",
                    help="campaign mode: every applicable cell of the "
                         "assignment")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--threshold", type=float, default=0.05)
    ap.add_argument("--fresh", action="store_true",
                    help="campaign mode: discard checkpoints, re-tune")
    args = ap.parse_args(argv)

    if args.all or args.cells:
        from repro.core.campaign import enumerate_cells, parse_cells
        cells = parse_cells(args.cells,
                            default_multi_pod=args.multi_pod) \
            if args.cells else enumerate_cells(meshes=(args.multi_pod,))
        reports, stats = tune_campaign(cells, threshold=args.threshold,
                                       fresh=args.fresh)
        print(report.campaign_markdown(reports))
        print(f"\n{stats['cells']} cells in {stats['wall_s']}s "
              f"({stats['cells_per_hour']} cells/h; "
              f"{stats['evaluated_trials']} trials evaluated, "
              f"{stats['replayed_trials']} replayed from checkpoint)")
        return 0
    if not (args.arch and args.shape):
        ap.error("need --arch and --shape, or --cells/--all")
    rep = tune_cell(args.arch, args.shape, args.multi_pod, args.threshold)
    print(report.tuning_markdown(rep))
    print(f"\nspeedup: x{rep.speedup:.2f} in {rep.n_trials} trials")
    return 0


if __name__ == "__main__":
    sys.exit(main())
