"""Apply the paper's trial-and-error methodology to workload cells.

Single-cell mode (``--arch/--shape``) runs one (arch, shape, mesh)
cell.  Campaign mode (``--cells a:s,...`` or ``--all``) runs a whole
batch of cells in one concurrent campaign (core/campaign.py): every
cell's cursor interleaves over one shared executor + compile cache,
per-cell state checkpoints under ``results/campaign/`` (an interrupted
campaign resumes without re-paying completed trials), and the per-cell
reports are bit-identical to running the single-cell mode per cell.

``--strategy`` picks the search procedure (core/strategy.py) and
composes with both modes:

  * ``tree`` (default) — the paper's Fig.-4 ≤10-trial tuning tree;
  * ``short`` — the two-runs-shorter tree variant;
  * ``sensitivity`` — the Sec.-4 OFAT matrix (Table 2);
    ``--sweep-knobs`` restricts it to a knob subset;
  * ``random`` — budget-matched random-search baseline
    (``--budget``, ``--seed``);
  * ``model`` — learned cost-model proposer (core/proposer.py): a
    ridge fit on the shared trial history proposes the top-k predicted
    configs per batch and refits online (``--budget``, ``--seed``,
    ``--model-min-records``, ``--model-top-k``); with fewer than
    ``--model-min-records`` usable same-kind history records the cell
    falls back bit-identically to the ``tree`` walk.

Fabric modes (core/fabric.py) shard a campaign's cells across worker
*processes* that coordinate through lease files in one shared
directory (multi-host-ready — point workers on several hosts at a
shared mount):

  * ``--workers N`` (or ``--coordinate``) — spawn N local workers over
    the per-strategy campaign directory and wait; per-cell decisions
    are identical to the single-process campaign;
  * ``--worker`` — join an existing shared directory (``--dir``) as
    one worker; start any number, anywhere, any time.

``--warm-start`` seeds each fresh cell's cursor from the best configs
of the nearest already-tuned cells in the shared ``history.jsonl``
trial store (core/history.py); every campaign appends to that store,
so each run makes the next one cheaper.

Online mode (core/schedule.py) turns a campaign/fabric into a tuning
*service*:

  * ``--add-cells a:s,...`` — submit cells to the (per-strategy)
    campaign directory's ``intake/``; a *running* campaign or fabric
    admits them between batches, no restart needed;
  * ``--prioritize {arch,history}`` — cell scheduling order: ``arch``
    is the historical arch-grouped order, ``history`` starts the
    highest expected-speedup cells first (estimates from the trial
    history; unknown cells explore-first);
  * ``--watch`` — fabric workers idle and keep re-scanning the intake
    once the board is drained, instead of exiting;
  * ``--status`` — the operator's queue view: pending/claimed/done
    cells, intake submissions, the live lease board, and per-cell
    failure/retry/quarantine counts (a degrading campaign is visible
    before it finishes);
  * ``--stop`` — drop the STOP sentinel: ``--watch`` workers exit once
    everything admitted is done.

Measured tier (core/measure.py): ``--measure-top-k K`` re-evaluates
each cell's top-K surviving configs with real median-of-N jitted step
timings after the model-driven walk finishes, and publishes the
measured winner next to the model's choice in the report/checkpoint.
Kernel cells (``--cells kernel:flash_attention:tiny``) sweep Pallas
tile knobs with the kernel itself as the trial (core/kernel_cell.py).

Serving loop (serving/): ``--cells serve:<arch>:<trace>`` cells replay
a seeded synthetic traffic trace (serving/traffic.py) through the wave
scheduler as the trial, scored on TTFT / p95 queue delay / decode
throughput.  ``--slo-ttft F`` arms the SLO guardrail: a candidate that
regresses TTFT or queue delay past F x the incumbent's replay stats is
aborted mid-trace as a deterministic crash (shadow slice first, running
means after — serving/canary.py).  ``--promote`` publishes each serve
cell's surviving winner to the campaign directory's per-cell live-config
board (``<dir>/serving/live/``, atomic, never-regressing) with an
append-only promotion/demotion history.

Observability (core/telemetry.py): ``--trace`` records every trial,
compile, cache lookup, lease claim/steal, retry, strike and SLO abort
as structured span events in the campaign directory's ``events.jsonl``
and publishes live aggregate ``metrics.json`` — decisions are
bit-identical with tracing on or off.  ``--trace-out trace.json``
exports the recorded events as Chrome-trace/Perfetto JSON (workers as
tracks, trials as slices).  ``--status --json`` emits the queue view
plus live metrics as one machine-readable JSON object; ``REPRO_LOG``
(debug|info|warn) sets fleet log verbosity.

Trial hardening (core/executor.py + core/quarantine.py) keeps faults
from wasting the ≤10-run budget: ``--trial-timeout`` bounds every
evaluation (a hang becomes a ``timeout`` failure instead of wedging
the sweep), ``--max-retries`` re-runs transient faults with backoff,
and the always-on quarantine ledger stops a worker-killing config from
crash-looping the fabric (``--strike-threshold`` evaluations fleet-wide,
then it is skipped everywhere).

MUST set the placeholder device count before ANY jax-touching import.
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import time
# captured before the multi-second jax-touching imports below: the
# stale-STOP guard for --watch workers (core/schedule.clear_stop) must
# reference the process start, not post-import construction time
_START_TS = time.time()

import argparse
import dataclasses
import json
import pathlib
import sys

from repro.core import report
from repro.core.params import SENSITIVITY_SWEEP, default_config
from repro.core.trial import RooflineEvaluator, TrialRunner, Workload

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "tuning"


def _baseline(overrides=None):
    # attn_impl=pallas is infrastructure (the execution engine's kernel),
    # not one of the 12 tunables — see DESIGN.md §2.2
    return default_config(shard_strategy="fsdp_tp", attn_impl="pallas",
                          **(overrides or {}))


def _strategy_options(strategy, sweep_knobs=None, budget=None, seed=None,
                      model_min_records=None, model_top_k=None,
                      history=None):
    """CLI flags -> the strategy's cursor-factory options."""
    if strategy in ("sensitivity",) and sweep_knobs:
        names = [k.strip() for k in sweep_knobs.split(",") if k.strip()]
        unknown = [k for k in names if k not in SENSITIVITY_SWEEP]
        if unknown:
            raise ValueError(
                f"--sweep-knobs: {', '.join(unknown)} not in the "
                f"sensitivity sweep ({', '.join(SENSITIVITY_SWEEP)})")
        return {"knobs": {k: SENSITIVITY_SWEEP[k] for k in names}}
    if strategy in ("random", "model"):
        opts = {}
        if budget is not None:
            opts["budget"] = budget
        if seed is not None:
            opts["seed"] = seed
        if strategy == "model":
            if model_min_records is not None:
                opts["min_records"] = model_min_records
            if model_top_k is not None:
                opts["top_k"] = model_top_k
            if history is not None:
                # single-cell mode fit source; campaigns prime their
                # cursors from their own history explicitly instead
                opts["history"] = str(history)
        return opts
    return {}


def _save_cell_report(rep, strategy: str = "tree") -> None:
    # non-tree strategies write under results/tuning/<strategy>/ so two
    # strategies on the same cell never clobber each other's report
    # (mirrors the per-strategy checkpoint split in tune_campaign)
    out_dir = RESULTS_DIR if strategy == "tree" else RESULTS_DIR / strategy
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{rep.workload}.json").write_text(
        json.dumps(dataclasses.asdict(rep), indent=1, default=str))
    (out_dir / f"{rep.workload}.md").write_text(
        report.cell_markdown(rep))


def tune_cell(arch: str, shape: str, multi_pod: bool = False,
              threshold: float = 0.05, baseline_overrides=None,
              strategy: str = "tree", strategy_options=None):
    from repro.core.executor import SweepExecutor
    from repro.core.strategy import drive, make_cursor
    wl = Workload(arch, shape, multi_pod)
    baseline = _baseline(baseline_overrides)
    with SweepExecutor(RooflineEvaluator()) as executor:
        runner = TrialRunner(wl, executor.evaluator)
        cursor = make_cursor(strategy, runner, baseline,
                             threshold=threshold,
                             options=strategy_options)
        rep = drive(cursor, executor=executor)
    _save_cell_report(rep, strategy)
    return rep


def campaign_dir(strategy: str = "tree", override=None) -> pathlib.Path:
    """The per-strategy shared campaign directory: checkpoints, lease
    board and trial history all live here.  Non-tree strategies get a
    subdirectory so two strategies on the same cells never clobber each
    other's state."""
    from repro.core.campaign import CAMPAIGN_DIR
    if override:
        return pathlib.Path(override)
    return CAMPAIGN_DIR if strategy == "tree" else CAMPAIGN_DIR / strategy


def fresh_campaign_dir(ckpt: pathlib.Path, cells) -> None:
    """``--fresh``: discard the cells' checkpoints AND their leases in
    the (per-strategy) campaign directory, the *whole* intake (every
    submission plus any STOP sentinel — a stale ``--add-cells`` file
    must not silently re-admit a foreign cell into the fresh campaign)
    and stale cross-cell summaries.  The trial history is deliberately
    kept — re-tuning is exactly when accumulated knowledge pays
    (``--warm-start``)."""
    from repro.core.fabric import LeaseBoard
    from repro.core.schedule import clear_intake
    for spec in cells:
        path = ckpt / f"{spec.key()}.json"
        if path.exists():
            path.unlink()
    LeaseBoard(ckpt).clear([spec.key() for spec in cells])
    clear_intake(ckpt)
    for name in ("campaign.md", "campaign_stats.json"):
        if (ckpt / name).exists():
            (ckpt / name).unlink()


def _serving_board_markdown(ckpt: pathlib.Path) -> str:
    """The promotion-board section of the campaign summary ('' when the
    directory has no serving board yet)."""
    from repro.serving.canary import PromotionBoard
    board = PromotionBoard(ckpt)
    live = {p.stem: board.live(p.stem)
            for p in sorted(board.live_dir.glob("*.json"))}
    history = board.history()
    if not live and not history:
        return ""
    return report.serving_markdown(live, history)


def _write_campaign_summary(ckpt: pathlib.Path, reports, stats) -> None:
    from repro.core import telemetry as _telemetry
    ckpt.mkdir(parents=True, exist_ok=True)
    text = report.strategy_markdown(reports, queue=stats.get("queue"))
    serving = _serving_board_markdown(ckpt)
    if serving:
        text = text.rstrip("\n") + "\n\n" + serving + "\n"
    metrics = _telemetry.load_metrics(ckpt)
    if metrics:                          # untraced output unchanged
        text = text.rstrip("\n") + "\n\n" \
            + report.telemetry_markdown(metrics) + "\n"
    (ckpt / "campaign.md").write_text(text)
    (ckpt / "campaign_stats.json").write_text(
        json.dumps(stats, indent=1))


def tune_campaign(cells, threshold: float = 0.05, baseline_overrides=None,
                  fresh: bool = False, checkpoint_dir=None,
                  strategy: str = "tree", strategy_options=None,
                  evaluator=None, warm_start: bool = False,
                  prioritize: str = "arch", intake: bool = True,
                  trial_timeout_s=None, max_retries: int = 0,
                  strike_threshold=None, measure_top_k: int = 0,
                  measured_evaluator=None, slo_ttft=None,
                  promote: bool = False, trace: bool = False):
    """Run a strategy over a batch of cells in one concurrent campaign;
    returns ``{cell_key: report}`` plus the campaign's throughput
    stats.  Non-tree strategies checkpoint under a per-strategy
    subdirectory so campaigns with different strategies on the same
    cells never clobber each other.  The campaign scans the
    directory's ``intake/`` between batches (``--add-cells``
    submissions join a running campaign live)."""
    from repro.core.campaign import Campaign
    ckpt = campaign_dir(strategy, checkpoint_dir)
    if fresh:
        fresh_campaign_dir(ckpt, cells)
    if trace:
        # observability only — the campaign's decisions are
        # bit-identical with tracing on or off (tests/test_telemetry)
        from repro.core import telemetry as _telemetry
        ckpt.mkdir(parents=True, exist_ok=True)
        _telemetry.install(_telemetry.Telemetry(ckpt))
    if evaluator is None and slo_ttft is not None:
        # the default dispatch stack, with the serve tier's SLO guard
        # armed — step/kernel cells are routed exactly as before
        from repro.core.kernel_cell import DispatchEvaluator
        evaluator = DispatchEvaluator(slo_ttft=slo_ttft)
    camp = Campaign(
        cells, strategy=strategy, strategy_options=strategy_options,
        threshold=threshold, checkpoint_dir=ckpt, evaluator=evaluator,
        warm_start=warm_start, prioritize=prioritize, intake=intake,
        trial_timeout_s=trial_timeout_s, max_retries=max_retries,
        strike_threshold=strike_threshold,
        measure_top_k=measure_top_k,
        measured_evaluator=measured_evaluator,
        baseline_factory=lambda spec: _baseline(baseline_overrides))
    reports = camp.run()
    if trace:
        from repro.core import telemetry as _telemetry
        _telemetry.publish_metrics(ckpt)
    for rep in reports.values():
        _save_cell_report(rep, strategy)
    if promote:
        from repro.serving.canary import promote_winners
        promote_winners(ckpt, reports, source=f"campaign:{strategy}")
    _write_campaign_summary(ckpt, reports, camp.last_stats)
    return reports, camp.last_stats


def _load_measured(args):
    """Resolve --measured-evaluator (None -> the campaign's default
    measured-tier dispatcher, built lazily only when K > 0)."""
    if not args.measured_evaluator:
        return None
    from repro.core.fabric import load_evaluator
    return load_evaluator(args.measured_evaluator)


def run_worker(args, cells, options) -> int:
    """``--worker``: one fabric worker over a shared directory."""
    from repro.core.fabric import FabricWorker, load_evaluator
    ckpt = campaign_dir(args.strategy, args.dir)
    if args.evaluator:
        evaluator = load_evaluator(args.evaluator)
    elif args.slo_ttft is not None:
        # default dispatch stack with the serve tier's SLO guard armed
        from repro.core.kernel_cell import DispatchEvaluator
        evaluator = DispatchEvaluator(slo_ttft=args.slo_ttft)
    else:
        evaluator = load_evaluator(None)
    worker = FabricWorker(
        cells, ckpt, strategy=args.strategy, strategy_options=options,
        threshold=args.threshold,
        evaluator=evaluator,
        baseline_factory=lambda spec: _baseline(),
        worker_id=args.worker_id, ttl_s=args.worker_ttl,
        warm_start=args.warm_start,
        prioritize=args.prioritize, watch=args.watch,
        started_at=_START_TS,
        ready_file=pathlib.Path(args.ready_file)
        if args.ready_file else None,
        go_file=pathlib.Path(args.go_file) if args.go_file else None,
        trial_timeout_s=args.trial_timeout,
        max_retries=args.max_retries,
        strike_threshold=args.strike_threshold,
        measure_top_k=args.measure_top_k,
        measured_evaluator=load_evaluator(args.measured_evaluator)
        if args.measured_evaluator else None,
        promote=args.promote, trace=args.trace)
    stats = worker.run()
    print(json.dumps(stats, indent=1))
    return 0


def run_fabric(args, cells, options) -> int:
    """``--workers N`` / ``--coordinate``: spawn local workers over the
    per-strategy campaign directory, wait, summarize."""
    from repro.core.fabric import run_coordinator
    ckpt = campaign_dir(args.strategy, args.dir)
    if args.fresh:
        fresh_campaign_dir(ckpt, cells)
    n = args.workers or 2
    out = run_coordinator(
        cells, ckpt, workers=n, strategy=args.strategy,
        strategy_options=options,
        evaluator_spec=args.evaluator, ttl_s=args.worker_ttl,
        threshold=args.threshold, warm_start=args.warm_start,
        prioritize=args.prioritize, watch=args.watch,
        trial_timeout_s=args.trial_timeout,
        max_retries=args.max_retries,
        strike_threshold=args.strike_threshold,
        measure_top_k=args.measure_top_k,
        measured_evaluator_spec=args.measured_evaluator,
        slo_ttft=args.slo_ttft, promote=args.promote,
        trace=args.trace,
        extra_args=_worker_passthrough(args),
        log_dir=ckpt / "worker_logs")
    reports, stats = out["reports"], out["stats"]
    if args.trace:
        # final coordinator-side fold over every worker's events
        from repro.core import telemetry as _telemetry
        _telemetry.publish_metrics(ckpt)
    for rep in reports.values():
        _save_cell_report(rep, args.strategy)
    _write_campaign_summary(ckpt, reports, stats)
    print(report.strategy_markdown(reports))
    print(f"\n[fabric:{stats['strategy']}] {stats['cells']} cells, "
          f"{stats['workers']} workers, {stats['wall_s']}s "
          f"({stats['cells_per_hour']} cells/h)")
    return 0


def run_add_cells(args) -> int:
    """``--add-cells``: submit cells to a (possibly running) campaign
    directory's intake — a watching fabric or an in-flight campaign
    admits them between batches, no restart needed."""
    from repro.core.campaign import parse_cells
    from repro.core.schedule import submit_cells
    cells = parse_cells(args.add_cells,
                        default_multi_pod=args.multi_pod)
    ckpt = campaign_dir(args.strategy, args.dir)
    paths = submit_cells(ckpt, cells)
    for spec, path in zip(cells, paths):
        print(f"submitted {spec.key()} -> {path}")
    print(f"{len(cells)} cell(s) in intake of {ckpt}")
    return 0


def run_status(args, cells) -> int:
    """``--status``: the operator's queue view — pending/claimed/done
    depth, per-cell state (intake submissions included) and the live
    lease board (held/expired leases, no lease-file spelunking)."""
    from repro.core import telemetry as _telemetry
    from repro.core.schedule import queue_status
    ckpt = campaign_dir(args.strategy, args.dir)
    status = queue_status(ckpt, strategy=args.strategy, cells=cells)
    # live metrics: folded from the event stream right now, not the
    # last published metrics.json snapshot
    events = _telemetry.read_events(ckpt)
    metrics = _telemetry.fold_metrics(events) if events else None
    if args.json:
        print(json.dumps({"v": 1, "queue": status, "metrics": metrics},
                         indent=1, sort_keys=True))
        return 0
    depth = status["depth"]
    print(f"campaign dir: {status['dir']}")
    print(f"strategy:     {status['strategy']}")
    stop = ""
    if status["stop_requested"]:
        age = time.time() - (status["stop_requested_at"] or 0.0)
        stop = f"  [STOP requested {age:.0f}s ago — a watch worker " \
               "started since then ignores it]"
    print(f"queue depth:  {depth['pending']} pending / "
          f"{depth['claimed']} claimed / {depth['done']} done" + stop)
    for d in status["cells"]:
        state = "done" if d["done"] else (
            f"claimed by {d['claimed_by']}" if "claimed_by" in d
            else "pending")
        line = f"  {d['cell']:<40} {state:<28} ({d['source']})"
        health = d.get("health")
        if health:
            bits = [f"{n} {kind}" for kind, n in
                    sorted((health.get("failures") or {}).items())]
            if health.get("retries"):
                bits.append(f"{health['retries']} retried")
            if health.get("quarantined"):
                bits.append(f"{health['quarantined']} quarantined")
            if health.get("degraded"):
                bits.append("DEGRADED")
            line += "  [" + "; ".join(bits) + "]"
        print(line)
    if status["leases"]:
        print("leases:")
        for lease in status["leases"]:
            flag = "EXPIRED" if lease["expired"] else "live"
            print(f"  {lease['cell']:<40} {lease['worker']} "
                  f"@{lease['host']} hb {lease['age_s']}s/"
                  f"{lease['ttl_s']}s [{flag}]")
    else:
        print("leases: (none held)")
    quarantine = status.get("quarantine")
    if quarantine:
        print(f"quarantine:   {quarantine['intents']} intents / "
              f"{quarantine['completions']} completions, "
              f"{len(quarantine['quarantined'])} config(s) quarantined "
              f"(threshold {quarantine['strike_threshold']})")
        for key, n in quarantine["strikes"].items():
            mark = " QUARANTINED" if key in quarantine["quarantined"] \
                else ""
            print(f"  config {key}: {n} strike(s){mark}")
    if metrics:
        g = metrics["gauges"]
        a = metrics["attribution"]
        c = metrics["counters"]
        hit = g.get("cache_hit_rate")
        print(f"telemetry:    {metrics['events']} events / "
              f"{a['wall_s']}s wall — {g['trials_per_s']} trials/s, "
              f"cache hit {'—' if hit is None else format(hit, '.0%')}, "
              f"{c['lease_steals']} steal(s), "
              f"{c['quarantine_strikes']} strike(s), "
              f"{c['slo_aborts']} SLO abort(s)")
        for w, d in metrics["per_worker"].items():
            print(f"  {w:<40} {d['trials']} trial(s), busy "
                  f"{d['busy_s']}s ({format(d['utilization'], '.0%')})")
    return 0


def run_trace_out(args) -> int:
    """``--trace-out``: fold the campaign directory's recorded event
    stream into Chrome-trace/Perfetto JSON (workers as process tracks,
    trials/compiles as duration slices, steals/strikes/aborts as
    instants), then exit."""
    from repro.core import telemetry as _telemetry
    ckpt = campaign_dir(args.strategy, args.dir)
    n = _telemetry.export_chrome_trace(ckpt, args.trace_out)
    src = ckpt / _telemetry.EVENTS_NAME
    if not n:
        print(f"no events recorded in {src} (run with --trace); "
              f"wrote an empty trace to {args.trace_out}")
        return 1
    print(f"wrote {n} trace event(s) from {src} -> {args.trace_out}")
    return 0


def run_stop(args) -> int:
    """``--stop``: drop the STOP sentinel — ``--watch`` workers exit
    once every admitted cell is done."""
    from repro.core.schedule import request_stop
    ckpt = campaign_dir(args.strategy, args.dir)
    path = request_stop(ckpt)
    print(f"stop requested: {path}")
    return 0


def _worker_passthrough(args) -> list:
    """Strategy options forwarded verbatim to spawned workers."""
    extra = []
    if args.sweep_knobs:
        extra += ["--sweep-knobs", args.sweep_knobs]
    if args.budget is not None:
        extra += ["--budget", str(args.budget)]
    if args.seed is not None:
        extra += ["--seed", str(args.seed)]
    if args.model_min_records is not None:
        extra += ["--model-min-records", str(args.model_min_records)]
    if args.model_top_k is not None:
        extra += ["--model-top-k", str(args.model_top_k)]
    return extra


def _print_cell_summary(rep) -> None:
    if hasattr(rep, "speedup"):
        print(f"\nspeedup: x{rep.speedup:.2f} in {rep.n_trials} trials")
    else:
        top = max(rep.impacts, key=lambda i: i.mean_abs_pct)
        print(f"\ntop knob: {top.knob} ({top.mean_abs_pct:.1f}% mean "
              f"|deviation|) in {rep.n_trials} trials")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="single-cell mode: arch id")
    ap.add_argument("--shape", help="single-cell mode: shape id")
    ap.add_argument("--cells",
                    help="campaign mode: comma-separated "
                         "arch:shape[:pod|multipod] cell specs")
    ap.add_argument("--all", action="store_true",
                    help="campaign mode: every applicable cell of the "
                         "assignment")
    ap.add_argument("--strategy", default="tree",
                    choices=["tree", "short", "sensitivity", "random",
                             "model"],
                    help="search strategy (core/strategy.py registry)")
    ap.add_argument("--sweep-knobs",
                    help="sensitivity strategy: comma-separated knob "
                         "subset (default: the full SENSITIVITY_SWEEP)")
    ap.add_argument("--budget", type=int,
                    help="random/model strategies: trial budget "
                         "(default 10)")
    ap.add_argument("--seed", type=int,
                    help="random/model strategies: sampling seed "
                         "(default 0)")
    ap.add_argument("--model-min-records", type=int, default=None,
                    metavar="N",
                    help="model strategy: cold-start rule — with fewer "
                         "than N usable same-kind history records the "
                         "cell falls back bit-identically to the tree "
                         "walk (default 24)")
    ap.add_argument("--model-top-k", type=int, default=None, metavar="K",
                    help="model strategy: predicted configs proposed "
                         "per batch (default 3)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--threshold", type=float, default=0.05)
    ap.add_argument("--fresh", action="store_true",
                    help="campaign/fabric mode: discard the cells' "
                         "checkpoints, leases and intake submissions "
                         "in the per-strategy directory, re-tune (the "
                         "trial history is kept)")
    online = ap.add_argument_group("online scheduler (core/schedule.py)")
    online.add_argument("--prioritize", default="arch",
                        choices=["arch", "history"],
                        help="cell scheduling order: arch = historical "
                             "arch-grouped; history = expected speedup "
                             "from the trial history (unknown cells "
                             "explore-first)")
    online.add_argument("--add-cells",
                        help="submit arch:shape[:pod|multipod] cells to "
                             "the campaign directory's intake (a "
                             "running campaign/fabric admits them "
                             "live), then exit")
    online.add_argument("--watch", action="store_true",
                        help="fabric workers: keep re-scanning the "
                             "intake when the board is drained instead "
                             "of exiting (end with --stop)")
    online.add_argument("--status", action="store_true",
                        help="print the queue view (pending/claimed/"
                             "done cells, intake, lease board), then "
                             "exit")
    online.add_argument("--stop", action="store_true",
                        help="request watching workers to exit once "
                             "every admitted cell is done, then exit")
    fab = ap.add_argument_group("campaign fabric (core/fabric.py)")
    fab.add_argument("--workers", type=int,
                     help="fabric mode: spawn N local worker processes "
                          "over the shared per-strategy directory")
    fab.add_argument("--coordinate", action="store_true",
                     help="fabric mode with the default worker count "
                          "(2) — same as --workers 2")
    fab.add_argument("--worker", action="store_true",
                     help="join a shared directory as one fabric "
                          "worker (start any number, on any host)")
    fab.add_argument("--dir",
                     help="shared fabric directory (default: the "
                          "per-strategy campaign checkpoint dir)")
    fab.add_argument("--evaluator",
                     help="module:factory dotted path for the trial "
                          "evaluator (default: RooflineEvaluator; "
                          "benchmarks/tests swap in synthetic surfaces)")
    fab.add_argument("--worker-ttl", type=float, default=30.0,
                     help="lease TTL seconds: a lease whose heartbeat "
                          "is older than this is recovered (default 30)")
    fab.add_argument("--worker-id", help="explicit worker id")
    fab.add_argument("--warm-start", action="store_true",
                     help="seed fresh cells from the best configs of "
                          "the nearest already-tuned cells in the "
                          "trial history")
    fab.add_argument("--ready-file",
                     help="touch this file once initialized (benchmark "
                          "start barrier)")
    fab.add_argument("--go-file",
                     help="wait for this file before claiming cells "
                          "(benchmark start barrier)")
    hard = ap.add_argument_group(
        "trial hardening (core/executor.py + core/quarantine.py)")
    hard.add_argument("--trial-timeout", type=float, default=None,
                      metavar="SECONDS",
                      help="per-trial evaluation deadline: a trial "
                           "exceeding it is recorded as a timeout "
                           "failure and abandoned (the sweep never "
                           "wedges on a hanging compile); default: no "
                           "deadline")
    hard.add_argument("--max-retries", type=int, default=0,
                      help="re-evaluate transient failures (OSError/"
                           "MemoryError class faults) up to N times "
                           "with exponential backoff + jitter "
                           "(default 0: no retries)")
    hard.add_argument("--strike-threshold", type=int, default=None,
                      help="quarantine a config fleet-wide after this "
                           "many strikes (orphaned evaluation intents "
                           "from dead workers, or timeouts); default 3")
    meas = ap.add_argument_group("measured tier (core/measure.py)")
    meas.add_argument("--measure-top-k", type=int, default=0,
                      metavar="K",
                      help="after each cell's model-driven walk, "
                           "re-evaluate its top-K surviving configs "
                           "with real median-of-N jitted step timings "
                           "and publish the measured winner (default "
                           "0: model-only, exactly the historical "
                           "behavior)")
    meas.add_argument("--measured-evaluator",
                      help="module:factory dotted path for the "
                           "measured-tier evaluator (default: reduced "
                           "wall-clock proxy + kernel bench, behind "
                           "the disk timing cache)")
    serve = ap.add_argument_group("serving tuning loop (serving/)")
    serve.add_argument("--slo-ttft", type=float, default=None,
                       metavar="FACTOR",
                       help="SLO guardrail for serve:<arch>:<trace> "
                            "cells: abort (as a deterministic crash) "
                            "any candidate whose TTFT or queue delay "
                            "exceeds FACTOR x the incumbent's replay "
                            "stats — shadow slice per-request first, "
                            "running means after (default: guard off)")
    serve.add_argument("--promote", action="store_true",
                       help="after each serve cell completes, publish "
                            "its surviving winner to the campaign "
                            "directory's per-cell live-config board "
                            "(atomic, never regresses the incumbent, "
                            "demotions recorded)")
    obs = ap.add_argument_group("observability (core/telemetry.py)")
    obs.add_argument("--trace", action="store_true",
                     help="record structured telemetry while tuning: "
                          "every trial/compile/cache/lease/strike "
                          "appends a span event to the campaign "
                          "directory's events.jsonl and live metrics "
                          "are published as metrics.json; decisions "
                          "are bit-identical with tracing on or off")
    obs.add_argument("--trace-out", metavar="PATH",
                     help="export the campaign directory's recorded "
                          "events as Chrome-trace/Perfetto JSON to "
                          "PATH (open in ui.perfetto.dev), then exit "
                          "(standalone action, like --status)")
    obs.add_argument("--json", action="store_true",
                     help="with --status: print the queue view plus "
                          "live telemetry metrics as one JSON object "
                          "on stdout (machine-readable)")
    args = ap.parse_args(argv)

    if args.sweep_knobs and args.strategy != "sensitivity":
        ap.error("--sweep-knobs only applies to --strategy sensitivity")
    if (args.budget is not None or args.seed is not None) \
            and args.strategy not in ("random", "model"):
        ap.error("--budget/--seed only apply to --strategy "
                 "random/model")
    if (args.model_min_records is not None
            or args.model_top_k is not None) \
            and args.strategy != "model":
        ap.error("--model-min-records/--model-top-k only apply to "
                 "--strategy model")
    if args.add_cells or args.stop:
        # standalone actions against a campaign directory: any other
        # mode flag would be silently ignored, so reject the combination
        # instead of letting the operator believe it took effect
        ignored = [flag for flag, on in (
            ("--arch", args.arch), ("--shape", args.shape),
            ("--cells", args.cells), ("--all", args.all),
            ("--fresh", args.fresh), ("--watch", args.watch),
            ("--status", args.status), ("--worker", args.worker),
            ("--workers", args.workers),
            ("--coordinate", args.coordinate),
            ("--warm-start", args.warm_start),
            ("--trial-timeout", args.trial_timeout is not None),
            ("--max-retries", bool(args.max_retries)),
            ("--strike-threshold",
             args.strike_threshold is not None),
            ("--measure-top-k", bool(args.measure_top_k)),
            ("--measured-evaluator",
             bool(args.measured_evaluator)),
            ("--slo-ttft", args.slo_ttft is not None),
            ("--promote", args.promote),
            ("--trace", args.trace),
            ("--trace-out", bool(args.trace_out)),
            ("--json", args.json)) if on]
        if args.add_cells and args.stop:
            ap.error("--add-cells and --stop are separate actions; "
                     "run them as two invocations")
        if ignored:
            action = "--add-cells" if args.add_cells else "--stop"
            ap.error(f"{action} is a standalone action; "
                     f"{', '.join(ignored)} would be ignored — "
                     "drop it or run it separately")
        return run_add_cells(args) if args.add_cells else run_stop(args)
    if args.json and not args.status:
        ap.error("--json is the machine-readable form of --status; "
                 "add --status or drop --json")
    if args.trace_out:
        # standalone export over an existing campaign directory: any
        # tuning-mode flag would be silently ignored — reject it
        ignored = [flag for flag, on in (
            ("--arch", args.arch), ("--shape", args.shape),
            ("--cells", args.cells), ("--all", args.all),
            ("--fresh", args.fresh), ("--watch", args.watch),
            ("--status", args.status), ("--worker", args.worker),
            ("--workers", args.workers),
            ("--coordinate", args.coordinate),
            ("--trace", args.trace)) if on]
        if ignored:
            ap.error("--trace-out is a standalone export; "
                     f"{', '.join(ignored)} would be ignored — "
                     "drop it or run it separately")
        return run_trace_out(args)
    if args.status:
        # read-only action: --cells/--all scope the view, but a fabric
        # or fresh flag would be silently ignored — reject it
        ignored = [flag for flag, on in (
            ("--arch", args.arch), ("--shape", args.shape),
            ("--fresh", args.fresh), ("--watch", args.watch),
            ("--worker", args.worker), ("--workers", args.workers),
            ("--coordinate", args.coordinate),
            ("--warm-start", args.warm_start),
            ("--trial-timeout", args.trial_timeout is not None),
            ("--max-retries", bool(args.max_retries)),
            ("--strike-threshold",
             args.strike_threshold is not None),
            ("--measure-top-k", bool(args.measure_top_k)),
            ("--measured-evaluator",
             bool(args.measured_evaluator)),
            ("--slo-ttft", args.slo_ttft is not None),
            ("--promote", args.promote),
            ("--trace", args.trace)) if on]
        if ignored:
            ap.error("--status is a read-only action; "
                     f"{', '.join(ignored)} would be ignored — "
                     "drop it or run it separately")
    from repro.core.history import HISTORY_FILENAME
    options = _strategy_options(
        args.strategy, args.sweep_knobs, args.budget, args.seed,
        model_min_records=args.model_min_records,
        model_top_k=args.model_top_k,
        history=campaign_dir(args.strategy, args.dir) / HISTORY_FILENAME)
    if args.measure_top_k < 0:
        ap.error("--measure-top-k must be >= 0")
    if args.measured_evaluator and not args.measure_top_k:
        ap.error("--measured-evaluator requires --measure-top-k > 0")
    fabric_mode = args.worker or args.coordinate or args.workers
    if args.trace and not (args.all or args.cells or fabric_mode):
        ap.error("--trace records telemetry into the campaign "
                 "directory; it applies to campaign/fabric modes "
                 "(--cells/--all/--worker/--workers)")
    if args.slo_ttft is not None and args.slo_ttft <= 0:
        ap.error("--slo-ttft is a multiplier over the incumbent's "
                 "replay stats; it must be > 0 (e.g. 3.0)")
    if args.slo_ttft is not None and args.evaluator:
        ap.error("--evaluator replaces the dispatch stack that carries "
                 "the SLO guard; drop --slo-ttft or arm the guard "
                 "inside the custom evaluator factory")
    if (args.slo_ttft is not None or args.promote) \
            and not (args.all or args.cells or fabric_mode):
        ap.error("--slo-ttft/--promote apply to campaign/fabric modes "
                 "over serve:<arch>:<trace> cells")
    if args.fresh and not (args.all or args.cells):
        ap.error("--fresh only applies to campaign/fabric modes")
    if args.worker and args.fresh:
        ap.error("--fresh is a coordinator/campaign action; workers "
                 "join shared state, they must not clear it")
    if args.watch and not fabric_mode:
        ap.error("--watch only applies to fabric modes (--worker / "
                 "--workers / --coordinate)")
    if fabric_mode and not (args.all or args.cells) \
            and not (args.worker and args.watch):
        ap.error("fabric modes need --cells or --all (a --watch "
                 "--worker may start empty and live off the intake)")
    if args.all or args.cells or (args.worker and args.watch) \
            or args.status:
        from repro.core.campaign import enumerate_cells, parse_cells
        if args.cells:
            cells = parse_cells(args.cells,
                                default_multi_pod=args.multi_pod)
        elif args.all:
            cells = enumerate_cells(meshes=(args.multi_pod,))
        else:
            cells = []
        if args.status:
            return run_status(args, cells)
        if args.worker:
            return run_worker(args, cells, options)
        if args.coordinate or args.workers:
            return run_fabric(args, cells, options)
        reports, stats = tune_campaign(cells, threshold=args.threshold,
                                       fresh=args.fresh,
                                       strategy=args.strategy,
                                       strategy_options=options,
                                       warm_start=args.warm_start,
                                       prioritize=args.prioritize,
                                       trial_timeout_s=args.trial_timeout,
                                       max_retries=args.max_retries,
                                       strike_threshold=
                                       args.strike_threshold,
                                       measure_top_k=args.measure_top_k,
                                       measured_evaluator=
                                       _load_measured(args),
                                       slo_ttft=args.slo_ttft,
                                       promote=args.promote,
                                       trace=args.trace)
        print(report.strategy_markdown(reports,
                                       queue=stats.get("queue")))
        print(f"\n[{stats['strategy']}] {stats['cells']} cells in "
              f"{stats['wall_s']}s "
              f"({stats['cells_per_hour']} cells/h; "
              f"{stats['evaluated_trials']} trials evaluated, "
              f"{stats['replayed_trials']} replayed from checkpoint)")
        return 0
    if not (args.arch and args.shape):
        ap.error("need --arch and --shape, or --cells/--all")
    if args.measure_top_k:
        ap.error("--measure-top-k applies to campaign/fabric modes "
                 "(--cells/--all); single-cell mode is model-only")
    rep = tune_cell(args.arch, args.shape, args.multi_pod, args.threshold,
                    strategy=args.strategy, strategy_options=options)
    print(report.cell_markdown(rep))
    _print_cell_summary(rep)
    return 0


if __name__ == "__main__":
    sys.exit(main())
