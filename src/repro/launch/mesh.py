"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state.  The single-pod mesh is
(data=16, model=16) = 256 chips (one TPU v5e pod in this work's target);
the multi-pod mesh adds a leading DCN "pod" axis.  The pod axis composes
with "data" for batch/FSDP sharding, so the same configs scale to any
pod count (DESIGN.md §5).
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np

import jax
from jax.sharding import Mesh

from repro.compat import axis_types_kw


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    """Arbitrary mesh (elastic remesh, tests)."""
    return jax.make_mesh(shape, axes, **axis_types_kw(len(axes)))


def make_host_mesh(model: Optional[int] = None) -> Mesh:
    """Best-effort mesh over whatever devices exist (CPU tests, examples).

    Factors the local device count into (data, model)."""
    n = len(jax.devices())
    if model is None:
        model = 1
        for cand in (16, 8, 4, 2):
            if n % cand == 0 and n >= cand:
                model = cand
                break
    data = n // model
    devs = np.array(jax.devices()[:data * model]).reshape(data, model)
    return Mesh(devs, ("data", "model"), **axis_types_kw(2))
