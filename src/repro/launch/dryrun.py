"""Multi-pod dry-run: prove the distribution config is coherent.

MUST set the placeholder device count before ANY jax-touching import —
do not move these two lines.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import pathlib
import sys
import time
import traceback

import jax

from repro.configs import (get_config, get_shape, list_archs, SHAPES,
                           shape_applicable)
from repro.core import costmodel
from repro.core.params import TunableConfig, default_config
from repro.launch.mesh import make_production_mesh
from repro.runtime.stepfn import build_step

RESULTS_DIR = pathlib.Path(__file__).resolve().parents[3] / "results" / "dryrun"


def infra_default_rt(arch: str, **overrides) -> TunableConfig:
    """The cluster-level baseline configuration (DESIGN.md §2.2).

    Mirrors the paper: cluster settings (here: a 2D sharding able to hold
    every assigned model) are fixed infrastructure-wide per [8]; the 12
    application-level knobs start from Spark-like defaults (f32
    "Java serializer", no compression, balanced memory fractions ...).
    """
    base = dict(shard_strategy="fsdp_tp")
    base.update(overrides)
    return default_config(**base)


def run_cell(arch: str, shape_id: str, multi_pod: bool,
             rt: TunableConfig = None, save: bool = True) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_id)
    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    rec = {"arch": arch, "shape": shape_id, "mesh": mesh_name,
           "kind": shape.kind}
    ok, reason = shape_applicable(cfg, shape)
    if not ok:
        rec.update(status="skip", reason=reason)
        if save:
            RESULTS_DIR.mkdir(parents=True, exist_ok=True)
            out = RESULTS_DIR / f"{arch}__{shape_id}__{mesh_name}.json"
            out.write_text(json.dumps(rec, indent=1))
        return rec
    rt = rt or infra_default_rt(arch)
    rec["tunable"] = rt.as_dict()
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    t0 = time.time()
    try:
        bundle = build_step(cfg, shape, rt, mesh)
        with mesh:
            lowered = bundle.lower()
            compiled = lowered.compile()
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "peak_bytes": int(ma.argument_size_in_bytes
                              + ma.temp_size_in_bytes),
        }
        raw = costmodel.analyze(
            compiled, compute_dtype=rt.compute_dtype,
            pod_size=256 if multi_pod else 10**9)
        rec["roofline_raw"] = raw.as_dict()   # body-once HLO (uncalibrated)
        # calibrated terms: extrapolated from two small unrolled compiles
        from repro.core.trial import RooflineEvaluator, Workload
        ev = RooflineEvaluator(use_cache=False)
        rl = ev.calibrated_roofline(Workload(arch, shape_id, multi_pod), rt)
        rec["roofline"] = rl.as_dict()
        rec["model_flops"] = costmodel.model_flops(cfg, shape)
        per_chip_model = rec["model_flops"] / chips
        rec["useful_flops_ratio"] = (
            per_chip_model / rl.flops_per_chip if rl.flops_per_chip else 0.0)
        hbm = costmodel.HW["hbm_per_chip"]
        rec["fits_hbm"] = rec["memory_analysis"]["peak_bytes"] <= hbm
        rec["sharding_notes"] = bundle.notes.get("sharding_notes", [])
        rec["status"] = "ok"
        rec["compile_s"] = round(time.time() - t0, 1)
    except Exception as e:
        rec.update(status="fail", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-2000:],
                   compile_s=round(time.time() - t0, 1))
    if save:
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        out = RESULTS_DIR / f"{arch}__{shape_id}__{mesh_name}.json"
        out.write_text(json.dumps(rec, indent=1))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape id (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for arch in archs:
        for shape_id in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape_id, mp)
                if rec["status"] == "ok":
                    rl = rec["roofline"]
                    ma = rec["memory_analysis"]
                    msg = (f"OK   {rec['mesh']:18s} {arch:22s} {shape_id:12s}"
                           f" bottleneck={rl['bottleneck']:10s}"
                           f" total={rl['total_s']*1e3:9.2f}ms"
                           f" peak/chip={ma['peak_bytes']/1e9:7.2f}GB"
                           f" fits={rec['fits_hbm']}")
                elif rec["status"] == "skip":
                    msg = (f"SKIP {rec['mesh']:18s} {arch:22s} {shape_id:12s}"
                           f" ({rec['reason'][:60]}...)")
                else:
                    failures += 1
                    msg = (f"FAIL {rec['mesh']:18s} {arch:22s} {shape_id:12s}"
                           f" {rec['error'][:120]}")
                if not args.quiet or rec["status"] != "ok":
                    print(msg, flush=True)
                if rec["status"] == "ok" and not args.quiet:
                    print(f"     memory_analysis: {rec['memory_analysis']}")
                    print(f"     cost_analysis: flops/chip="
                          f"{rl['flops_per_chip']:.3e} bytes/chip="
                          f"{rl['bytes_per_chip']:.3e} coll_bytes="
                          f"{rl['collective_bytes']:.3e}", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
