"""End-to-end training driver: data pipeline -> sharded train step ->
checkpoints, preemption-safe restart, straggler watchdog.

CPU (this container): ``--reduced`` trains a reduced config for real.
TPU fleet: the same driver with the production mesh and a full config.

Exit code 42 = preempted-after-checkpoint (relaunch with the same args;
--resume is implicit: the driver always resumes from the latest
checkpoint in --ckpt-dir if one exists).
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpoint import CheckpointManager
from repro.configs import get_config, get_reduced, get_shape
from repro.configs.base import ShapeConfig
from repro.core.params import default_config
from repro.data.pipeline import SyntheticLM
from repro.ft.preemption import PreemptionHandler, RESTART_EXIT_CODE
from repro.ft.straggler import StragglerDetector
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.model import build_model
from repro.optim.optimizers import cosine_schedule, make_optimizer
from repro.runtime.stepfn import build_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-interval", type=int, default=20)
    ap.add_argument("--log-interval", type=int, default=5)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--compute-dtype", default="bfloat16")
    ap.add_argument("--shard-strategy", default="dp")
    ap.add_argument("--remat", default="none")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    rt = default_config(compute_dtype=args.compute_dtype,
                        shard_strategy=args.shard_strategy,
                        remat_policy=args.remat,
                        microbatches=args.microbatches)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    print(f"mesh: {dict(mesh.shape)}  arch: {cfg.name}  "
          f"params≈{cfg.param_count()/1e6:.1f}M", flush=True)

    optimizer = make_optimizer(cfg.optimizer,
                               cosine_schedule(args.lr, 10, args.steps))
    bundle = build_train_step(cfg, shape, rt, mesh, optimizer)
    model = build_model(cfg)

    pre = PreemptionHandler().install()
    mgr = CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
    watchdog = StragglerDetector(factor=3.0)

    with mesh:
        start = mgr.latest_step()
        if start is not None:
            print(f"resuming from step {start}", flush=True)
            target = {"params": model.param_shapes(),
                      "opt": jax.eval_shape(optimizer.init,
                                            model.param_shapes())}
            state, _ = mgr.restore_latest(
                jax.tree.map(lambda s: np.zeros(s.shape, s.dtype), target))
            params, opt_state = state["params"], state["opt"]
            start += 1
        else:
            params = model.init(jax.random.PRNGKey(args.seed))
            opt_state = optimizer.init(params)
            start = 0

        data = SyntheticLM(cfg, shape, rt, mesh, seed=args.seed)
        host = "host0"
        t_compile = time.time()
        for step in range(start, args.steps):
            batch = data.batch_at(step)
            t0 = time.time()
            params, opt_state, metrics = bundle.fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt_step = time.time() - t0
            watchdog.heartbeat(host, step, dt_step)
            if step == start:
                print(f"first step (incl. compile): "
                      f"{time.time()-t_compile:.1f}s", flush=True)
            if step % args.log_interval == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):8.4f} "
                      f"gnorm {float(metrics['grad_norm']):8.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt_step*1e3:7.1f}ms",
                      flush=True)
            if watchdog.check():
                print(f"stragglers: {watchdog.flagged}", flush=True)
            mgr.maybe_save(step, {"params": params, "opt": opt_state},
                           extra={"step": step})
            if pre.requested():
                print("preemption requested -> checkpoint + exit",
                      flush=True)
                mgr.maybe_save(step, {"params": params, "opt": opt_state},
                               extra={"step": step}, force=True)
                mgr.wait()
                return RESTART_EXIT_CODE
        mgr.maybe_save(args.steps - 1,
                       {"params": params, "opt": opt_state},
                       extra={"step": args.steps - 1}, force=True)
        mgr.wait()
    print("done.", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
