"""Batched serving driver: prefill a prompt batch, decode N tokens.

CPU (this container): ``--reduced`` serves a reduced config for real.
The full configs' serve_step is exercised via the dry-run.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_reduced
from repro.configs.base import ShapeConfig
from repro.core.params import default_config
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model, synth_inputs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-tokens", type=int, default=16)
    ap.add_argument("--kv-dtype", default="bfloat16")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    rt = default_config(compute_dtype="bfloat16",
                        kv_cache_dtype=args.kv_dtype)
    mesh = make_host_mesh()
    model = build_model(cfg)
    max_seq = args.prompt_len + args.gen_tokens

    with mesh:
        params = model.init(jax.random.PRNGKey(args.seed))
        pshape = ShapeConfig("serve", args.prompt_len, args.batch, "prefill")
        batch = synth_inputs(cfg, pshape, rt, jax.random.PRNGKey(args.seed))

        prefill = jax.jit(
            lambda p, b: model.prefill_fn(p, b, rt, max_seq=max_seq))
        decode = jax.jit(lambda p, c, t: model.decode_fn(p, c, t, rt))

        t0 = time.time()
        logits, cache = prefill(params, batch)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)

        generated = [tok]
        t0 = time.time()
        for _ in range(args.gen_tokens - 1):
            logits, cache = decode(params, cache, tok)
            tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
            generated.append(tok)
        jax.block_until_ready(tok)
        t_dec = time.time() - t0
        toks = jnp.concatenate(generated, axis=1)

    n_dec = args.batch * (args.gen_tokens - 1)
    print(f"arch={cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill: {t_prefill*1e3:.1f} ms "
          f"({args.batch*args.prompt_len/t_prefill:.0f} tok/s)")
    print(f"decode:  {t_dec*1e3:.1f} ms for {n_dec} tokens "
          f"({n_dec/max(t_dec,1e-9):.0f} tok/s)")
    print(f"sample tokens[0,:8]: {toks[0,:8].tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
