"""Shared model-zoo building blocks (pure-JAX, pytree params).

Parameters are declared as ``PSpec`` trees: shape + logical dim names +
init scale.  The same tree yields real arrays (``init_params``), dry-run
``ShapeDtypeStruct``s (``param_shapes``) and sharding specs
(``logical_tree`` consumed by runtime.sharding).
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.params import TunableConfig


# ---------------------------------------------------------------- params
@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    scale: Any = "fan_in"          # "fan_in" | float | "zeros" | "ones"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical), (self.shape, self.logical)


def _is_pspec(x):
    return isinstance(x, PSpec)


def init_params(spec_tree, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(spec_tree, is_leaf=_is_pspec)
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.scale == "zeros":
            out.append(jnp.zeros(s.shape, dtype))
        elif s.scale == "ones":
            out.append(jnp.ones(s.shape, dtype))
        else:
            if s.scale == "fan_in":
                fan = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
                sd = 1.0 / math.sqrt(max(1, fan))
            else:
                sd = float(s.scale)
            out.append((jax.random.normal(k, s.shape) * sd).astype(dtype))
    return jax.tree.unflatten(treedef, out)


def param_shapes(spec_tree, dtype=jnp.float32):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), spec_tree,
        is_leaf=_is_pspec)


def logical_tree(spec_tree):
    return jax.tree.map(lambda s: s.logical, spec_tree, is_leaf=_is_pspec)


def stacked(n: int, spec_tree):
    """Prepend a scanned 'layers' dim to every PSpec in the tree."""
    return jax.tree.map(
        lambda s: PSpec((n,) + s.shape, ("layers",) + s.logical, s.scale),
        spec_tree, is_leaf=_is_pspec)


# ---------------------------------------------------------------- dtypes
def dt(rt: TunableConfig):
    return jnp.dtype(rt.compute_dtype)


def cast(x, rt: TunableConfig):
    return x.astype(dt(rt))


# ---------------------------------------------------------------- norms
def rmsnorm_spec(d: int) -> PSpec:
    return PSpec((d,), ("embed",), "ones")


def rmsnorm(x, scale, rt: TunableConfig, eps: float = 1e-5):
    if rt.attn_impl == "pallas" and x.ndim == 3:
        from repro.kernels.rmsnorm import ops as rms_ops
        return rms_ops.rmsnorm(x, scale, eps=eps)
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def layernorm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------- rope
def rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: (..., S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None, None] * freq  # (...,S,1,half)
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------- attention
def attn_spec(cfg) -> Dict[str, PSpec]:
    d, H, Hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {
        "wq": PSpec((d, H, hd), ("embed", "heads", None)),
        "wk": PSpec((d, Hkv, hd), ("embed", "kv_heads", None)),
        "wv": PSpec((d, Hkv, hd), ("embed", "kv_heads", None)),
        "wo": PSpec((H, hd, d), ("heads", None, "embed")),
    }


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, hkv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, n_rep, hd))
    return k.reshape(b, s, hkv * n_rep, hd)


def full_attention(q, k, v, *, causal: bool, rt: TunableConfig, rules=None,
                   q_positions=None, kv_positions=None):
    """q: (B,Sq,H,hd), k/v: (B,Skv,H,hd) (already GQA-repeated)."""
    if rt.attn_impl == "pallas" and causal and q.shape[1] == k.shape[1]:
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=True,
                                      block_q=rt.attn_block_q,
                                      block_kv=rt.attn_block_kv)
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        if q_positions is None:
            q_positions = jnp.arange(sq)
        if kv_positions is None:
            kv_positions = jnp.arange(sk)
        mask = q_positions[:, None] >= kv_positions[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def attention_block(p, x, *, cfg, rt: TunableConfig, rules, positions,
                    causal=True, kv_x=None, kv_positions=None):
    """Full (train/prefill) attention sub-block.  kv_x!=None => cross-attn."""
    B, S, _ = x.shape
    src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"], rt))
    k = jnp.einsum("bsd,dhk->bshk", src, cast(p["wk"], rt))
    v = jnp.einsum("bsd,dhk->bshk", src, cast(p["wv"], rt))
    if kv_x is None:  # self-attention gets RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions if kv_positions is None else kv_positions,
                 cfg.rope_theta)
    k = _repeat_kv(k, cfg.n_heads // cfg.n_kv_heads)
    v = _repeat_kv(v, cfg.n_heads // cfg.n_kv_heads)
    bspec = rules.attn_batch_spec(B) if rules is not None else None
    if bspec is not None:
        # beyond-paper fallback: reshard so the attention op is
        # batch-parallel over (data, model) when heads don't divide TP
        resh = lambda t: jax.lax.with_sharding_constraint(
            t, rules.sharding(jax.sharding.PartitionSpec(*bspec, None, None, None)))
        q, k, v = resh(q), resh(k), resh(v)
    elif rules is not None:
        q = rules.constrain(q, "batch", None, "heads", None)
        k = rules.constrain(k, "batch", None, "heads", None)
        v = rules.constrain(v, "batch", None, "heads", None)
    o = full_attention(q, k, v, causal=causal and kv_x is None, rt=rt,
                       rules=rules)
    if rules is not None:
        o = rules.constrain(o, "batch", None, "heads", None)
    return jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"], rt))


# ------------------------------------------------------- KV-cache decode
def quantize_kv(x, kv_dtype: str):
    """x: (B,S,Hkv,hd) -> (stored, scale).  int8: per-(token,head) scale."""
    if kv_dtype != "int8":
        return x.astype(jnp.dtype(kv_dtype)), None
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(stored, scale, out_dtype):
    if scale is None:
        return stored.astype(out_dtype)
    return (stored.astype(jnp.float32) * scale).astype(out_dtype)


def attn_cache_shapes(cfg, batch: int, max_seq: int, rt: TunableConfig,
                      layers: Optional[int] = None):
    """ShapeDtypeStructs + logical names for a stacked KV cache."""
    L = cfg.n_layers if layers is None else layers
    kvd = jnp.int8 if rt.kv_cache_dtype == "int8" else jnp.dtype(rt.kv_cache_dtype)
    shp = (L, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    logical = ("layers", "batch", "seq_data" if batch == 1 else None,
               "kv_heads", None)
    out = {"k": jax.ShapeDtypeStruct(shp, kvd),
           "v": jax.ShapeDtypeStruct(shp, kvd)}
    lg = {"k": logical, "v": logical}
    if rt.kv_cache_dtype == "int8":
        sshp = (L, batch, max_seq, cfg.n_kv_heads, 1)
        out["k_scale"] = jax.ShapeDtypeStruct(sshp, jnp.float32)
        out["v_scale"] = jax.ShapeDtypeStruct(sshp, jnp.float32)
        lg["k_scale"] = logical
        lg["v_scale"] = logical
    return out, lg


def decode_attention_block(p, x, layer_cache, pos, *, cfg, rt: TunableConfig,
                           rules):
    """One-token decode self-attention against a KV cache.

    x: (B,1,d); layer_cache: {'k','v'[,scales]} with shapes (B,Smax,Hkv,hd).
    pos: scalar int32 current position.  Returns (out, updated_cache).
    """
    B = x.shape[0]
    q = jnp.einsum("bsd,dhk->bshk", x, cast(p["wq"], rt))
    k = jnp.einsum("bsd,dhk->bshk", x, cast(p["wk"], rt))
    v = jnp.einsum("bsd,dhk->bshk", x, cast(p["wv"], rt))
    posv = jnp.full((B, 1), pos, dtype=jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)
    kq, ks = quantize_kv(k, rt.kv_cache_dtype)
    vq, vs = quantize_kv(v, rt.kv_cache_dtype)
    upd = lambda buf, new: jax.lax.dynamic_update_slice(
        buf, new, (0, pos, 0, 0))
    cache = dict(layer_cache)
    cache["k"] = upd(layer_cache["k"], kq)
    cache["v"] = upd(layer_cache["v"], vq)
    if ks is not None:
        cache["k_scale"] = upd(layer_cache["k_scale"], ks)
        cache["v_scale"] = upd(layer_cache["v_scale"], vs)
    if rt.attn_impl == "pallas":
        # flash-decode kernel: streams the cache once at stored dtype
        # (int8 dequant fused), online softmax in VMEM
        from repro.kernels.flash_decode import ops as fd_ops
        o = fd_ops.flash_decode(q, cache["k"], cache["v"], pos + 1,
                                cache.get("k_scale"), cache.get("v_scale"),
                                block_kv=rt.attn_block_kv)
    else:
        kf = dequantize_kv(cache["k"], cache.get("k_scale"), dt(rt))
        vf = dequantize_kv(cache["v"], cache.get("v_scale"), dt(rt))
        kf = _repeat_kv(kf, cfg.n_heads // cfg.n_kv_heads)
        vf = _repeat_kv(vf, cfg.n_heads // cfg.n_kv_heads)
        smax = kf.shape[1]
        scale = 1.0 / math.sqrt(q.shape[-1])
        scores = jnp.einsum("bqhd,bkhd->bhqk", q, kf,
                            preferred_element_type=jnp.float32) * scale
        mask = (jnp.arange(smax) <= pos)[None, None, None, :]
        scores = jnp.where(mask, scores, -1e30)
        pr = jax.nn.softmax(scores.astype(jnp.float32),
                            axis=-1).astype(q.dtype)
        o = jnp.einsum("bhqk,bkhd->bqhd", pr, vf)
    out = jnp.einsum("bshk,hkd->bsd", o, cast(p["wo"], rt))
    return out, cache


# ---------------------------------------------------------------- mlp
def mlp_spec(cfg, d_ff: Optional[int] = None) -> Dict[str, PSpec]:
    d = cfg.d_model
    ff = cfg.d_ff if d_ff is None else d_ff
    if cfg.mlp_act == "silu":
        return {"wg": PSpec((d, ff), ("embed", "mlp")),
                "wu": PSpec((d, ff), ("embed", "mlp")),
                "wd": PSpec((ff, d), ("mlp", "embed"))}
    return {"wu": PSpec((d, ff), ("embed", "mlp")),
            "wd": PSpec((ff, d), ("mlp", "embed"))}


def mlp_block(p, x, *, cfg, rt: TunableConfig, rules):
    if cfg.mlp_act == "silu":
        h = jax.nn.silu(x @ cast(p["wg"], rt)) * (x @ cast(p["wu"], rt))
    elif cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(x @ cast(p["wu"], rt)))
    else:
        h = jax.nn.gelu(x @ cast(p["wu"], rt))
    if rules is not None:
        h = rules.constrain(h, "batch", None, "mlp")
    return h @ cast(p["wd"], rt)


# ---------------------------------------------------------------- embed/loss
def padded_vocab(cfg, multiple: int = 512) -> int:
    return ((cfg.vocab + multiple - 1) // multiple) * multiple


def embed_spec(cfg) -> Dict[str, PSpec]:
    V = padded_vocab(cfg)
    out = {"embedding": PSpec((V, cfg.d_model), ("vocab", "embed"), 0.02)}
    if not cfg.tie_embeddings:
        out["unembed"] = PSpec((cfg.d_model, V), ("embed", "vocab"))
    return out


def embed(p, tokens, rt: TunableConfig):
    return jnp.take(cast(p["embedding"], rt), tokens, axis=0)


def unembed(p, x, cfg, rt: TunableConfig, rules):
    w = p.get("unembed")
    if w is None:
        w = p["embedding"].T
    logits = jnp.einsum("bsd,dv->bsv", x, cast(w, rt),
                        preferred_element_type=jnp.float32)
    if rules is not None:
        logits = rules.constrain(logits, "batch", None, "vocab")
    return logits


def xent_loss(logits, labels, cfg):
    """logits: (B,S,Vpad) f32; labels: (B,S) int32. Mean over tokens."""
    V = padded_vocab(cfg)
    mask = jnp.arange(V) < cfg.vocab
    logits = jnp.where(mask[None, None, :], logits, -1e30)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
