"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention block.

81 Mamba2 blocks; a single shared transformer block (attn + MLP, weights
shared) is invoked after every ``attn_every``-th Mamba2 block.  Decode
carries SSM/conv states for every Mamba2 block plus a KV cache per shared-
block invocation.  Sub-quadratic: runs the long_500k cell.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.params import TunableConfig
from repro.models import layers as L
from repro.models import mamba2
from repro.runtime import remat
from repro.runtime.loops import scan_layers


def _shared_spec(cfg) -> Dict[str, L.PSpec]:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attn_spec(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


def _split(cfg):
    g = cfg.n_layers // cfg.attn_every        # full groups
    rem = cfg.n_layers - g * cfg.attn_every
    return g, rem


def spec(cfg) -> Dict:
    g, rem = _split(cfg)
    out = {
        "embed": L.embed_spec(cfg),
        "groups": L.stacked(g, L.stacked(cfg.attn_every, mamba2.mamba_spec(cfg))),
        "shared": _shared_spec(cfg),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }
    if rem:
        out["rem"] = L.stacked(rem, mamba2.mamba_spec(cfg))
    return out


def _shared_block(sp, x, positions, cfg, rt, rules):
    h = L.rmsnorm(x, sp["ln1"], rt, cfg.norm_eps)
    x = x + L.attention_block(sp["attn"], h, cfg=cfg, rt=rt, rules=rules,
                              positions=positions)
    h = L.rmsnorm(x, sp["ln2"], rt, cfg.norm_eps)
    return x + L.mlp_block(sp["mlp"], h, cfg=cfg, rt=rt, rules=rules)


def forward(p, h, positions, cfg, rt: TunableConfig, rules):
    def group(x, gp):
        x = remat.from_carry(x, rt)
        def inner(xc, mp):
            return mamba2.mamba_block(mp, xc, cfg, rt, rules), None
        x, _ = scan_layers(inner, x, gp, unroll=rt.unroll_layers)
        x = _shared_block(p["shared"], x, positions, cfg, rt, rules)
        return remat.to_carry(x, rt), None
    h, _ = scan_layers(remat.wrap_layer(group, rt),
                       remat.to_carry(h, rt), p["groups"],
                       unroll=rt.unroll_layers)
    h = remat.from_carry(h, rt)
    if "rem" in p:
        def inner(xc, mp):
            return mamba2.mamba_block(mp, xc, cfg, rt, rules), None
        h, _ = scan_layers(inner, h, p["rem"], unroll=rt.unroll_layers)
    return L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)


def loss_fn(p, batch, cfg, rt: TunableConfig, rules):
    h = L.embed(p["embed"], batch["tokens"], rt)
    if rules is not None:
        h = rules.constrain(h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h = forward(p, h, positions, cfg, rt, rules)
    logits = L.unembed(p["embed"], h, cfg, rt, rules)
    return L.xent_loss(logits, batch["labels"], cfg), {}


# ------------------------------------------------------------- serving
def cache_shapes(cfg, batch: int, max_seq: int, rt: TunableConfig):
    g, rem = _split(cfg)
    mg, mg_lg = mamba2.mamba_cache_shapes(cfg, batch, g * cfg.attn_every)
    mg = jax.tree.map(lambda s: jax.ShapeDtypeStruct(
        (g, cfg.attn_every) + s.shape[1:], s.dtype), mg)
    mg_lg = jax.tree.map(lambda t: ("layers",) + t, mg_lg,
                         is_leaf=lambda t: isinstance(t, tuple))
    kv, kv_lg = L.attn_cache_shapes(cfg, batch, max_seq, rt, layers=g)
    shp = {"groups": mg, "kv": kv, "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    lg = {"groups": mg_lg, "kv": kv_lg, "pos": ()}
    if rem:
        mr, mr_lg = mamba2.mamba_cache_shapes(cfg, batch, rem)
        shp["rem"] = mr
        lg["rem"] = mr_lg
    return shp, lg


def init_cache(cfg, batch: int, max_seq: int, rt: TunableConfig):
    shp, _ = cache_shapes(cfg, batch, max_seq, rt)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shp)


def prefill_fn(p, batch, cfg, rt: TunableConfig, rules, max_seq: int):
    h = L.embed(p["embed"], batch["tokens"], rt)
    if rules is not None:
        h = rules.constrain(h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def group(x, gp):
        def inner(xc, mp):
            xc, st = mamba2.mamba_block(mp, xc, cfg, rt, rules,
                                        want_state=True)
            return xc, st
        x, states = scan_layers(inner, x, gp, unroll=rt.unroll_layers)
        hn = L.rmsnorm(x, p["shared"]["ln1"], rt, cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", hn, L.cast(p["shared"]["attn"]["wk"], rt))
        v = jnp.einsum("bsd,dhk->bshk", hn, L.cast(p["shared"]["attn"]["wv"], rt))
        k = L.rope(k, positions, cfg.rope_theta)
        x = _shared_block(p["shared"], x, positions, cfg, rt, rules)
        kq, ks = L.quantize_kv(k, rt.kv_cache_dtype)
        vq, vs = L.quantize_kv(v, rt.kv_cache_dtype)
        extras = (kq, vq) if ks is None else (kq, vq, ks, vs)
        return x, (states, extras)

    h, (gstates, extras) = scan_layers(group, h, p["groups"],
                                       unroll=rt.unroll_layers)
    pad = max_seq - S
    def pad_seq(t):
        return jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    kv = {"k": pad_seq(extras[0]), "v": pad_seq(extras[1])}
    if len(extras) == 4:
        kv["k_scale"] = pad_seq(extras[2])
        kv["v_scale"] = pad_seq(extras[3])
    cache = {"groups": gstates, "kv": kv, "pos": jnp.array(S, jnp.int32)}
    if "rem" in p:
        def inner(xc, mp):
            xc, st = mamba2.mamba_block(mp, xc, cfg, rt, rules,
                                        want_state=True)
            return xc, st
        h, rstates = scan_layers(inner, h, p["rem"],
                                 unroll=rt.unroll_layers)
        cache["rem"] = rstates
    h = L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h[:, -1:], cfg, rt, rules)
    return logits, cache


def decode_fn(p, cache, tokens, cfg, rt: TunableConfig, rules):
    h = L.embed(p["embed"], tokens, rt)
    pos = cache["pos"]

    def group(x, args):
        gp, gstate, gkv = args
        def inner(xc, margs):
            mp, mstate = margs
            return mamba2.mamba_decode_block(mp, xc, mstate, cfg, rt, rules)
        x, new_states = scan_layers(inner, x, (gp, gstate),
                                    unroll=rt.unroll_layers)
        hn = L.rmsnorm(x, p["shared"]["ln1"], rt, cfg.norm_eps)
        a, gkv = L.decode_attention_block(p["shared"]["attn"], hn, gkv, pos,
                                          cfg=cfg, rt=rt, rules=rules)
        x = x + a
        hn = L.rmsnorm(x, p["shared"]["ln2"], rt, cfg.norm_eps)
        x = x + L.mlp_block(p["shared"]["mlp"], hn, cfg=cfg, rt=rt,
                            rules=rules)
        return x, (new_states, gkv)

    h, (gstates, kv) = scan_layers(group, h,
                                   (p["groups"], cache["groups"],
                                    cache["kv"]),
                                   unroll=rt.unroll_layers)
    new_cache = {"groups": gstates, "kv": kv, "pos": pos + 1}
    if "rem" in p:
        def inner(xc, margs):
            mp, mstate = margs
            return mamba2.mamba_decode_block(mp, xc, mstate, cfg, rt, rules)
        h, rstates = scan_layers(inner, h, (p["rem"], cache["rem"]),
                                 unroll=rt.unroll_layers)
        new_cache["rem"] = rstates
    h = L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h, cfg, rt, rules)
    return logits, new_cache
