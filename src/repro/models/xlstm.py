"""xLSTM: alternating mLSTM (matrix memory, chunkwise-parallel) and sLSTM
(scalar memory, strictly sequential) blocks, layout [slstm_every-1 : 1].

Deviations from the paper, documented in DESIGN.md: the mLSTM input gate
uses sigmoid stabilisation (instead of the running-max exponential-gate
stabiliser) so the chunkwise form shares the SSD machinery; sLSTM
recurrent weights are diagonal (element-wise) rather than block-diagonal.
Sub-quadratic: runs the long_500k cell.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.params import TunableConfig
from repro.models import layers as L
from repro.runtime import remat
from repro.runtime.loops import scan_layers


# ----------------------------------------------------------- mLSTM
def mlstm_spec(cfg) -> Dict[str, L.PSpec]:
    d, H, hd = cfg.d_model, cfg.n_heads, cfg.hd
    return {
        "ln": L.rmsnorm_spec(d),
        "wq": L.PSpec((d, H, hd), ("embed", None, None)),
        "wk": L.PSpec((d, H, hd), ("embed", None, None)),
        "wv": L.PSpec((d, H, hd), ("embed", None, "ssm_inner")),
        "wi": L.PSpec((d, H), ("embed", None)),
        "wf": L.PSpec((d, H), ("embed", None)),
        "wog": L.PSpec((d, d), ("embed", None)),
        "wo": L.PSpec((d, d), ("ssm_inner", "embed")),
    }


def _mlstm_chunked(q, k, v, ig, lf, chunk: int, state=None):
    """Chunkwise mLSTM.  q/k/v: (B,S,H,hd); ig (input gate, (B,S,H)),
    lf (log forget, (B,S,H)).  Returns (y, (h_state, n_state))."""
    Bsz, S, H, hd = q.shape
    f32 = jnp.float32
    if S % chunk:
        # pad with no-op tokens: input gate 0, log-forget 0 (no decay)
        pad = chunk - S % chunk
        pz = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                               [(0, 0)] * (t.ndim - 2))
        y, st = _mlstm_chunked(pz(q), pz(k), pz(v), pz(ig), pz(lf),
                               chunk, state)
        return y[:, :S], st
    nc, Q = S // chunk, chunk
    rs = lambda t: t.reshape((Bsz, nc, Q) + t.shape[2:])
    qc, kc, vc = rs(q), rs(k), rs(v)
    igc, lfc = rs(ig).astype(f32), rs(lf).astype(f32)
    cum = jnp.cumsum(lfc, axis=2)                         # (B,nc,Q,H)
    Lmat = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], Lmat, 0.0)
    G = jnp.einsum("bcqhn,bckhn->bcqkh", qc.astype(f32), kc.astype(f32))
    W = Lmat * igc[:, :, None, :, :]        # decay-gate weights (no q.k)
    scores = G * W
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores, vc.astype(f32))
    n_intra = jnp.einsum("bcqkh,bckhn->bcqhn", W, kc.astype(f32))
    dec_last = jnp.exp(cum[:, :, -1:, :] - cum)
    Sc = jnp.einsum("bckh,bckhp,bckhn->bchpn", igc * dec_last,
                    vc.astype(f32), kc.astype(f32))
    Nc = jnp.einsum("bckh,bckhn->bchn", igc * dec_last, kc.astype(f32))
    a_chunk = jnp.exp(cum[:, :, -1, :])

    def step(carry, inp):
        h, n = carry
        a_c, S_c, N_c, q_c, cum_c = inp
        dec = jnp.exp(cum_c)                              # (B,Q,H)
        y_in = jnp.einsum("bqhn,bqh,bhpn->bqhp", q_c, dec, h)
        nn_in = jnp.einsum("bqhn,bqh,bhn->bqh", q_c, dec, n)
        h = a_c[:, :, None, None] * h + S_c
        n = a_c[:, :, None] * n + N_c
        return (h, n), (y_in, nn_in)

    if state is None:
        h0 = jnp.zeros((Bsz, H, hd, hd), f32)
        n0 = jnp.zeros((Bsz, H, hd), f32)
    else:
        h0, n0 = state
    mv = lambda t: jnp.moveaxis(t, 1, 0)
    (hF, nF), (y_inter, nn_inter) = jax.lax.scan(
        step, (h0, n0), (mv(a_chunk), mv(Sc), mv(Nc), mv(qc.astype(f32)),
                         mv(cum)))
    y_inter = jnp.moveaxis(y_inter, 0, 1)
    nn_inter = jnp.moveaxis(nn_inter, 0, 1)
    y = y_intra + y_inter                                  # (B,nc,Q,H,hd)
    # normalizer: q . n  (intra part from n_intra, inter part nn_inter)
    qn = jnp.einsum("bcqhn,bcqhn->bcqh", qc.astype(f32), n_intra) + nn_inter
    denom = jnp.maximum(jnp.abs(qn), 1.0)[..., None]
    y = (y / denom).reshape(Bsz, S, H, hd)
    return y.astype(q.dtype), (hF, nF)


def mlstm_block(p, x, cfg, rt: TunableConfig, rules, want_state=False,
                state=None, decode=False):
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    h = L.rmsnorm(x, p["ln"], rt, cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, L.cast(p["wq"], rt)) / (hd ** 0.5)
    k = jnp.einsum("bsd,dhk->bshk", h, L.cast(p["wk"], rt))
    v = jnp.einsum("bsd,dhk->bshk", h, L.cast(p["wv"], rt))
    if rules is not None:
        v = rules.constrain(v, "batch", None, None, "ssm_inner")
    ig = jax.nn.sigmoid((h @ L.cast(p["wi"], rt)).astype(jnp.float32))
    lf = jax.nn.log_sigmoid((h @ L.cast(p["wf"], rt)).astype(jnp.float32))
    og = jax.nn.sigmoid(h @ L.cast(p["wog"], rt))
    if decode:
        hs, ns = state
        f32 = jnp.float32
        a = jnp.exp(lf[:, 0])                              # (B,H)
        hs = (a[:, :, None, None] * hs
              + jnp.einsum("bh,bhp,bhn->bhpn", ig[:, 0], v[:, 0].astype(f32),
                           k[:, 0].astype(f32)))
        ns = a[:, :, None] * ns + ig[:, 0, :, None] * k[:, 0].astype(f32)
        yq = jnp.einsum("bhn,bhpn->bhp", q[:, 0].astype(f32), hs)
        qn = jnp.einsum("bhn,bhn->bh", q[:, 0].astype(f32), ns)
        y = (yq / jnp.maximum(jnp.abs(qn), 1.0)[:, :, None])[:, None]
        new_state = (hs, ns)
    else:
        y, new_state = _mlstm_chunked(q, k, v, ig, lf, cfg.ssm_chunk, state)
    y = y.reshape(B, S, d).astype(x.dtype) * og
    out = x + y @ L.cast(p["wo"], rt)
    if want_state or decode:
        return out, new_state
    return out


# ----------------------------------------------------------- sLSTM
def slstm_spec(cfg) -> Dict[str, L.PSpec]:
    d = cfg.d_model
    return {
        "ln": L.rmsnorm_spec(d),
        "wi": L.PSpec((d, d), ("embed", None)),
        "wf": L.PSpec((d, d), ("embed", None)),
        "wz": L.PSpec((d, d), ("embed", None)),
        "wog": L.PSpec((d, d), ("embed", None)),
        "ri": L.PSpec((d,), (None,), "zeros"),
        "rf": L.PSpec((d,), (None,), "zeros"),
        "rz": L.PSpec((d,), (None,), "zeros"),
        "ro": L.PSpec((d,), (None,), "zeros"),
        "wo": L.PSpec((d, d), ("embed", None)),
    }


def _slstm_step(p, carry, zi, zf, zz, zo):
    """One sLSTM timestep.  carry: (c, n, m, h) each (B,d) f32."""
    c, n, m, h = carry
    zi = zi + h * p["ri"]
    zf = zf + h * p["rf"]
    zz = jnp.tanh(zz + h * p["rz"])
    zo = jax.nn.sigmoid(zo + h * p["ro"])
    lf = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(lf + m, zi)
    c = jnp.exp(lf + m - m_new) * c + jnp.exp(zi - m_new) * zz
    n = jnp.exp(lf + m - m_new) * n + jnp.exp(zi - m_new)
    h = zo * c / jnp.maximum(n, 1.0)
    return (c, n, m_new, h)


def slstm_block(p, x, cfg, rt: TunableConfig, rules, want_state=False,
                state=None, decode=False):
    B, S, d = x.shape
    f32 = jnp.float32
    hn = L.rmsnorm(x, p["ln"], rt, cfg.norm_eps)
    zi = (hn @ L.cast(p["wi"], rt)).astype(f32)
    zf = (hn @ L.cast(p["wf"], rt)).astype(f32)
    zz = (hn @ L.cast(p["wz"], rt)).astype(f32)
    zo = (hn @ L.cast(p["wog"], rt)).astype(f32)
    pf = {k2: p[k2].astype(f32) for k2 in ("ri", "rf", "rz", "ro")}
    if state is None:
        z = jnp.zeros((B, d), f32)
        state = (z, z, jnp.full((B, d), -1e30, f32), z)
    if decode:
        new_state = _slstm_step(pf, state, zi[:, 0], zf[:, 0], zz[:, 0],
                                zo[:, 0])
        y = new_state[3][:, None, :]
    else:
        def step(carry, inp):
            carry = _slstm_step(pf, carry, *inp)
            return carry, carry[3]
        mv = lambda t: jnp.moveaxis(t, 1, 0)
        new_state, ys = jax.lax.scan(step, state,
                                     (mv(zi), mv(zf), mv(zz), mv(zo)))
        y = jnp.moveaxis(ys, 0, 1)
    out = x + y.astype(x.dtype) @ L.cast(p["wo"], rt)
    if want_state or decode:
        return out, new_state
    return out


# ----------------------------------------------------------- model
def _layout(cfg):
    g = cfg.n_layers // cfg.slstm_every
    m_per_group = cfg.slstm_every - 1
    return g, m_per_group


def spec(cfg) -> Dict:
    g, mpg = _layout(cfg)
    return {
        "embed": L.embed_spec(cfg),
        "mblocks": L.stacked(g, L.stacked(mpg, mlstm_spec(cfg))),
        "sblocks": L.stacked(g, slstm_spec(cfg)),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }


def forward(p, h, cfg, rt: TunableConfig, rules):
    def group(x, gp):
        mp, sp = gp
        x = remat.from_carry(x, rt)
        def inner(xc, mpp):
            return mlstm_block(mpp, xc, cfg, rt, rules), None
        x, _ = scan_layers(inner, x, mp, unroll=rt.unroll_layers)
        x = slstm_block(sp, x, cfg, rt, rules)
        return remat.to_carry(x, rt), None
    h, _ = scan_layers(remat.wrap_layer(group, rt),
                       remat.to_carry(h, rt),
                       (p["mblocks"], p["sblocks"]),
                       unroll=rt.unroll_layers)
    return L.rmsnorm(remat.from_carry(h, rt), p["final_norm"], rt,
                     cfg.norm_eps)


def loss_fn(p, batch, cfg, rt: TunableConfig, rules):
    h = L.embed(p["embed"], batch["tokens"], rt)
    if rules is not None:
        h = rules.constrain(h, "batch", None, None)
    h = forward(p, h, cfg, rt, rules)
    logits = L.unembed(p["embed"], h, cfg, rt, rules)
    return L.xent_loss(logits, batch["labels"], cfg), {}


def cache_shapes(cfg, batch: int, max_seq: int, rt: TunableConfig):
    g, mpg = _layout(cfg)
    H, hd, d = cfg.n_heads, cfg.hd, cfg.d_model
    f32 = jnp.float32
    shp = {
        "m_h": jax.ShapeDtypeStruct((g, mpg, batch, H, hd, hd), f32),
        "m_n": jax.ShapeDtypeStruct((g, mpg, batch, H, hd), f32),
        "s": tuple(jax.ShapeDtypeStruct((g, batch, d), f32)
                   for _ in range(4)),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }
    lg = {"m_h": ("layers", "layers", "batch", None, "ssm_inner", None),
          "m_n": ("layers", "layers", "batch", None, None),
          "s": tuple(("layers", "batch", None) for _ in range(4)),
          "pos": ()}
    return shp, lg


def init_cache(cfg, batch: int, max_seq: int, rt: TunableConfig):
    shp, _ = cache_shapes(cfg, batch, max_seq, rt)
    c = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shp)
    # sLSTM m-state starts at -inf surrogate
    c["s"] = (c["s"][0], c["s"][1], c["s"][2] - 1e30, c["s"][3])
    return c


def prefill_fn(p, batch, cfg, rt: TunableConfig, rules, max_seq: int):
    h = L.embed(p["embed"], batch["tokens"], rt)
    if rules is not None:
        h = rules.constrain(h, "batch", None, None)

    def group(x, gp):
        mp, sp = gp
        def inner(xc, mpp):
            xc, st = mlstm_block(mpp, xc, cfg, rt, rules, want_state=True)
            return xc, st
        x, mstates = scan_layers(inner, x, mp, unroll=rt.unroll_layers)
        x, sstate = slstm_block(sp, x, cfg, rt, rules, want_state=True)
        return x, (mstates, sstate)

    h, (mstates, sstates) = scan_layers(group, h,
                                        (p["mblocks"], p["sblocks"]),
                                        unroll=rt.unroll_layers)
    h = L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h[:, -1:], cfg, rt, rules)
    cache = {"m_h": mstates[0], "m_n": mstates[1], "s": sstates,
             "pos": jnp.array(batch["tokens"].shape[1], jnp.int32)}
    return logits, cache


def decode_fn(p, cache, tokens, cfg, rt: TunableConfig, rules):
    h = L.embed(p["embed"], tokens, rt)

    def group(x, args):
        gp, sp, m_h, m_n, s_st = args
        def inner(xc, margs):
            mpp, hh, nn = margs
            xc, st = mlstm_block(mpp, xc, cfg, rt, rules, state=(hh, nn),
                                 decode=True)
            return xc, st
        x, mst = scan_layers(inner, x, (gp, m_h, m_n),
                             unroll=rt.unroll_layers)
        x, s_new = slstm_block(sp, x, cfg, rt, rules, state=s_st,
                               decode=True)
        return x, (mst, s_new)

    h, (mstates, sstates) = scan_layers(
        group, h, (p["mblocks"], p["sblocks"], cache["m_h"], cache["m_n"],
                   cache["s"]), unroll=rt.unroll_layers)
    h = L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h, cfg, rt, rules)
    return logits, {"m_h": mstates[0], "m_n": mstates[1], "s": sstates,
                    "pos": cache["pos"] + 1}
