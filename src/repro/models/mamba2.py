"""Mamba2 (SSD) blocks — chunked state-space duality algorithm.

Training/prefill uses the chunkwise-parallel SSD form (within-chunk
quadratic term + sequential cross-chunk state scan); decode is the O(1)
recurrent update.  The within-chunk term is the compute hot-spot the
``ssm_scan`` Pallas kernel tiles on TPU (ref semantics identical to
``ssd_chunked`` here).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.core.params import TunableConfig
from repro.models import layers as L


def mamba_spec(cfg) -> Dict[str, L.PSpec]:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    return {
        "ln": L.rmsnorm_spec(d),
        "wx": L.PSpec((d, d_in), ("embed", "ssm_inner")),
        "wz": L.PSpec((d, d_in), ("embed", "ssm_inner")),
        "conv": L.PSpec((4, d_in), (None, "ssm_inner"), 0.2),
        "wB": L.PSpec((d, N), ("embed", None)),
        "wC": L.PSpec((d, N), ("embed", None)),
        "wdt": L.PSpec((d, H), ("embed", "ssm_heads")),
        "dt_bias": L.PSpec((H,), ("ssm_heads",), "zeros"),
        "A_log": L.PSpec((H,), ("ssm_heads",), "zeros"),
        "D": L.PSpec((H,), ("ssm_heads",), "ones"),
        "gln": L.PSpec((d_in,), ("ssm_inner",), "ones"),
        "wo": L.PSpec((d_in, d), ("ssm_inner", "embed")),
    }


def _causal_conv(x, w, state=None):
    """Depthwise causal conv, kernel 4.  x: (B,S,C), w: (4,C).

    state: (B,3,C) previous inputs for decode; returns (y, new_state)."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):, :] if x.shape[1] >= 1 else state
    return y, new_state


def _gates(p, x, cfg, rt):
    """Common projections.  x:(B,S,d) -> (xin(B,S,H,P), z, Bm, Cm, dt, a)."""
    comp = L.dt(rt)
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    z = x @ L.cast(p["wz"], rt)
    xin = x @ L.cast(p["wx"], rt)
    Bm = (x @ L.cast(p["wB"], rt)).astype(jnp.float32)
    Cm = (x @ L.cast(p["wC"], rt)).astype(jnp.float32)
    dt = jax.nn.softplus(
        (x @ L.cast(p["wdt"], rt)).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    loga = dt * A                    # (B,S,H) log decay <= 0
    return xin, z, Bm, Cm, dt, loga


def ssd_chunked(X, Bm, Cm, dt, loga, chunk: int, h0=None):
    """Chunkwise SSD.  X:(B,S,H,P), Bm/Cm:(B,S,N), dt/loga:(B,S,H).

    Returns (Y:(B,S,H,P), h_final:(B,H,P,N))."""
    Bsz, S, H, P = X.shape
    N = Bm.shape[-1]
    if S % chunk:
        # pad with no-op tokens: dt=0 (no input), loga=0 (no decay)
        pad = chunk - S % chunk
        pz = lambda t: jnp.pad(t, [(0, 0), (0, pad)] +
                               [(0, 0)] * (t.ndim - 2))
        Y, h = ssd_chunked(pz(X), pz(Bm), pz(Cm), pz(dt), pz(loga),
                           chunk, h0)
        return Y[:, :S], h
    nc = S // chunk
    Q = chunk
    f32 = jnp.float32
    Xc = X.reshape(Bsz, nc, Q, H, P)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    dtc = dt.reshape(Bsz, nc, Q, H)
    lac = loga.reshape(Bsz, nc, Q, H)
    cum = jnp.cumsum(lac, axis=2)                       # (B,nc,Q,H)
    # within-chunk
    Lmat = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    tri = jnp.tril(jnp.ones((Q, Q), bool))
    Lmat = jnp.where(tri[None, None, :, :, None], Lmat, 0.0)
    G = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)           # shared across heads
    scores = G[..., None] * Lmat * dtc[:, :, None, :, :]
    Y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", scores.astype(f32),
                         Xc.astype(f32))
    # per-chunk state contribution
    dec_last = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,Q,H)
    Sc = jnp.einsum("bckh,bckhp,bckn->bchpn",
                    (dtc * dec_last).astype(f32), Xc.astype(f32), Bc)
    a_chunk = jnp.exp(cum[:, :, -1, :])                 # (B,nc,H)
    # sequential cross-chunk state scan
    def step(h, inp):
        a_c, S_c, C_c, cum_c = inp
        y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", C_c,
                             jnp.exp(cum_c), h)
        h = a_c[:, :, None, None] * h + S_c
        return h, y_inter
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)
    xs = (jnp.moveaxis(a_chunk, 1, 0), jnp.moveaxis(Sc, 1, 0),
          jnp.moveaxis(Cc, 1, 0), jnp.moveaxis(cum, 1, 0))
    h_final, Y_inter = jax.lax.scan(step, h0, xs)
    Y_inter = jnp.moveaxis(Y_inter, 0, 1).reshape(Bsz, nc, Q, H, P)
    Y = (Y_intra + Y_inter).reshape(Bsz, S, H, P)
    return Y.astype(X.dtype), h_final


def mamba_block(p, x, cfg, rt: TunableConfig, rules, want_state: bool = False):
    """Full Mamba2 block (train/prefill).  x: (B,S,d) -> (B,S,d).

    want_state=True additionally returns the decode cache entry."""
    B, S, d = x.shape
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    h = L.rmsnorm(x, p["ln"], rt, cfg.norm_eps)
    xin, z, Bm, Cm, dt, loga = _gates(p, h, cfg, rt)
    xin, conv_state = _causal_conv(xin, L.cast(p["conv"], rt))
    xin = jax.nn.silu(xin)
    if rules is not None:
        xin = rules.constrain(xin, "batch", None, "ssm_inner")
    X = xin.reshape(B, S, H, P)
    if rt.attn_impl == "pallas":
        from repro.kernels.ssm_scan import ops as ssm_ops
        Y, h_final = ssm_ops.ssm_scan(X, Bm, Cm, dt, loga,
                                      chunk=cfg.ssm_chunk)
    else:
        Y, h_final = ssd_chunked(X, Bm, Cm, dt, loga, cfg.ssm_chunk)
    Y = Y + p["D"].astype(Y.dtype)[None, None, :, None] * X
    y = Y.reshape(B, S, d_in)
    y = L.rmsnorm(y * jax.nn.silu(z), p["gln"], rt, cfg.norm_eps)
    if rules is not None:
        y = rules.constrain(y, "batch", None, "ssm_inner")
    out = x + y @ L.cast(p["wo"], rt)
    if want_state:
        return out, {"ssm": h_final,
                     "conv": conv_state.astype(jnp.float32)}
    return out


# ------------------------------------------------------------- decode
def mamba_cache_shapes(cfg, batch: int, layers: int):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    shp = {
        "ssm": jax.ShapeDtypeStruct(
            (layers, batch, H, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
        "conv": jax.ShapeDtypeStruct((layers, batch, 3, d_in), jnp.float32),
    }
    lg = {"ssm": ("layers", "batch", "ssm_heads", None, None),
          "conv": ("layers", "batch", None, "ssm_inner")}
    return shp, lg


def mamba_decode_block(p, x, layer_cache, cfg, rt: TunableConfig, rules):
    """One-token recurrent update.  x: (B,1,d)."""
    B, _, d = x.shape
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    h = L.rmsnorm(x, p["ln"], rt, cfg.norm_eps)
    xin, z, Bm, Cm, dt, loga = _gates(p, h, cfg, rt)
    xin, conv_state = _causal_conv(xin, L.cast(p["conv"], rt),
                                   state=layer_cache["conv"])
    xin = jax.nn.silu(xin)
    X = xin.reshape(B, H, P).astype(jnp.float32)
    a = jnp.exp(loga[:, 0, :])                          # (B,H)
    hs = layer_cache["ssm"]                             # (B,H,P,N)
    hs = (a[:, :, None, None] * hs
          + jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], X, Bm[:, 0]))
    Y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0], hs)
    Y = Y + p["D"].astype(Y.dtype)[None, :, None] * X
    y = Y.reshape(B, 1, d_in).astype(x.dtype)
    y = L.rmsnorm(y * jax.nn.silu(z), p["gln"], rt, cfg.norm_eps)
    out = x + y @ L.cast(p["wo"], rt)
    return out, {"ssm": hs, "conv": conv_state.astype(jnp.float32)}
