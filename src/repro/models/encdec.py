"""Encoder-decoder transformer (Seamless-M4T backbone).

Encoder consumes precomputed frame embeddings from the (stubbed) audio
frontend; decoder is a causal transformer with cross-attention.  Decode
carries a self-attention KV cache plus a fixed cross-attention cache
computed at prefill.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.params import TunableConfig
from repro.models import layers as L
from repro.runtime import remat
from repro.runtime.loops import scan_layers


def _enc_block_spec(cfg) -> Dict[str, L.PSpec]:
    return {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attn_spec(cfg),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "mlp": L.mlp_spec(cfg),
    }


def _dec_block_spec(cfg) -> Dict[str, L.PSpec]:
    out = _enc_block_spec(cfg)
    out["lnx"] = L.rmsnorm_spec(cfg.d_model)
    out["xattn"] = L.attn_spec(cfg)
    return out


def spec(cfg) -> Dict:
    return {
        "embed": L.embed_spec(cfg),
        "enc_blocks": L.stacked(cfg.enc_layers, _enc_block_spec(cfg)),
        "dec_blocks": L.stacked(cfg.n_layers, _dec_block_spec(cfg)),
        "enc_norm": L.rmsnorm_spec(cfg.d_model),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }


def encode(p, frames, cfg, rt: TunableConfig, rules):
    """frames: (B, S_enc, d) stub frontend embeddings -> encoder output."""
    h = L.cast(frames, rt)
    if rules is not None:
        h = rules.constrain(h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, bp):
        x = remat.from_carry(x, rt)
        hn = L.rmsnorm(x, bp["ln1"], rt, cfg.norm_eps)
        x = x + L.attention_block(bp["attn"], hn, cfg=cfg, rt=rt,
                                  rules=rules, positions=positions,
                                  causal=False)
        hn = L.rmsnorm(x, bp["ln2"], rt, cfg.norm_eps)
        x = x + L.mlp_block(bp["mlp"], hn, cfg=cfg, rt=rt, rules=rules)
        return remat.to_carry(x, rt), None

    h, _ = scan_layers(remat.wrap_layer(body, rt), remat.to_carry(h, rt),
                       p["enc_blocks"], unroll=rt.unroll_layers)
    return L.rmsnorm(remat.from_carry(h, rt), p["enc_norm"], rt,
                     cfg.norm_eps)


def _dec_block(bp, x, enc_out, positions, cfg, rt, rules):
    hn = L.rmsnorm(x, bp["ln1"], rt, cfg.norm_eps)
    x = x + L.attention_block(bp["attn"], hn, cfg=cfg, rt=rt, rules=rules,
                              positions=positions)
    hn = L.rmsnorm(x, bp["lnx"], rt, cfg.norm_eps)
    x = x + L.attention_block(bp["xattn"], hn, cfg=cfg, rt=rt, rules=rules,
                              positions=positions, kv_x=enc_out)
    hn = L.rmsnorm(x, bp["ln2"], rt, cfg.norm_eps)
    return x + L.mlp_block(bp["mlp"], hn, cfg=cfg, rt=rt, rules=rules)


def loss_fn(p, batch, cfg, rt: TunableConfig, rules):
    enc_out = encode(p, batch["frames"], cfg, rt, rules)
    h = L.embed(p["embed"], batch["tokens"], rt)
    if rules is not None:
        h = rules.constrain(h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, bp):
        x = remat.from_carry(x, rt)
        x = _dec_block(bp, x, enc_out, positions, cfg, rt, rules)
        return remat.to_carry(x, rt), None

    h, _ = scan_layers(remat.wrap_layer(body, rt), remat.to_carry(h, rt),
                       p["dec_blocks"], unroll=rt.unroll_layers)
    h = L.rmsnorm(remat.from_carry(h, rt), p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h, cfg, rt, rules)
    return L.xent_loss(logits, batch["labels"], cfg), {}


# ------------------------------------------------------------- serving
def cache_shapes(cfg, batch: int, max_seq: int, rt: TunableConfig,
                 enc_len: int = None):
    if enc_len is None:
        enc_len = max_seq // cfg.enc_seq_ratio
    self_kv, self_lg = L.attn_cache_shapes(cfg, batch, max_seq, rt)
    comp = jnp.dtype(rt.compute_dtype)
    xshape = (cfg.n_layers, batch, enc_len, cfg.n_kv_heads, cfg.hd)
    xlg = ("layers", "batch", None, "kv_heads", None)
    shp = {"self": self_kv,
           "cross_k": jax.ShapeDtypeStruct(xshape, comp),
           "cross_v": jax.ShapeDtypeStruct(xshape, comp),
           "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    lg = {"self": self_lg, "cross_k": xlg, "cross_v": xlg, "pos": ()}
    return shp, lg


def init_cache(cfg, batch: int, max_seq: int, rt: TunableConfig,
               enc_len: int = None):
    shp, _ = cache_shapes(cfg, batch, max_seq, rt, enc_len)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shp)


def prefill_fn(p, batch, cfg, rt: TunableConfig, rules, max_seq: int):
    enc_out = encode(p, batch["frames"], cfg, rt, rules)
    h = L.embed(p["embed"], batch["tokens"], rt)
    if rules is not None:
        h = rules.constrain(h, "batch", None, None)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, bp):
        hn = L.rmsnorm(x, bp["ln1"], rt, cfg.norm_eps)
        k = jnp.einsum("bsd,dhk->bshk", hn, L.cast(bp["attn"]["wk"], rt))
        v = jnp.einsum("bsd,dhk->bshk", hn, L.cast(bp["attn"]["wv"], rt))
        k = L.rope(k, positions, cfg.rope_theta)
        xk = jnp.einsum("bsd,dhk->bshk", enc_out,
                        L.cast(bp["xattn"]["wk"], rt))
        xv = jnp.einsum("bsd,dhk->bshk", enc_out,
                        L.cast(bp["xattn"]["wv"], rt))
        x = _dec_block(bp, x, enc_out, positions, cfg, rt, rules)
        kq, ks = L.quantize_kv(k, rt.kv_cache_dtype)
        vq, vs = L.quantize_kv(v, rt.kv_cache_dtype)
        extras = (kq, vq) if ks is None else (kq, vq, ks, vs)
        return x, (extras, xk, xv)

    h, (extras, xk, xv) = scan_layers(body, h, p["dec_blocks"],
                                      unroll=rt.unroll_layers)
    h = L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h[:, -1:], cfg, rt, rules)
    pad = max_seq - S
    def pad_seq(t):
        return jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    self_kv = {"k": pad_seq(extras[0]), "v": pad_seq(extras[1])}
    if len(extras) == 4:
        self_kv["k_scale"] = pad_seq(extras[2])
        self_kv["v_scale"] = pad_seq(extras[3])
    cache = {"self": self_kv, "cross_k": xk, "cross_v": xv,
             "pos": jnp.array(S, jnp.int32)}
    return logits, cache


def decode_fn(p, cache, tokens, cfg, rt: TunableConfig, rules):
    h = L.embed(p["embed"], tokens, rt)
    pos = cache["pos"]

    def body(x, args):
        bp, self_cache, xk, xv = args
        hn = L.rmsnorm(x, bp["ln1"], rt, cfg.norm_eps)
        a, self_cache = L.decode_attention_block(
            bp["attn"], hn, self_cache, pos, cfg=cfg, rt=rt, rules=rules)
        x = x + a
        # cross-attention against the fixed encoder cache
        hn = L.rmsnorm(x, bp["lnx"], rt, cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", hn, L.cast(bp["xattn"]["wq"], rt))
        kf = L._repeat_kv(xk.astype(L.dt(rt)),
                          cfg.n_heads // cfg.n_kv_heads)
        vf = L._repeat_kv(xv.astype(L.dt(rt)),
                          cfg.n_heads // cfg.n_kv_heads)
        o = L.full_attention(q, kf, vf, causal=False, rt=rt)
        x = x + jnp.einsum("bshk,hkd->bsd", o, L.cast(bp["xattn"]["wo"], rt))
        hn = L.rmsnorm(x, bp["ln2"], rt, cfg.norm_eps)
        x = x + L.mlp_block(bp["mlp"], hn, cfg=cfg, rt=rt, rules=rules)
        return x, self_cache

    h, new_self = scan_layers(
        body, h, (p["dec_blocks"], cache["self"], cache["cross_k"],
                  cache["cross_v"]), unroll=rt.unroll_layers)
    h = L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h, cfg, rt, rules)
    return logits, {"self": new_self, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "pos": pos + 1}
