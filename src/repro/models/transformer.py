"""Dense decoder-only transformer (llama-style).

Also the backbone for the VLM (frontend embeds prepended) and MoE
(FFN swapped for expert-parallel MoE) families.  The layer stack is a
``lax.scan`` over stacked params (small HLO, remat-able).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.core.params import TunableConfig
from repro.models import layers as L
from repro.models import moe
from repro.runtime import remat
from repro.runtime.loops import scan_layers


def block_spec(cfg) -> Dict[str, L.PSpec]:
    out = {
        "ln1": L.rmsnorm_spec(cfg.d_model),
        "ln2": L.rmsnorm_spec(cfg.d_model),
        "attn": L.attn_spec(cfg),
    }
    if cfg.family == "moe":
        out["moe"] = moe.moe_spec(cfg)
    else:
        out["mlp"] = L.mlp_spec(cfg)
    return out


def spec(cfg) -> Dict:
    return {
        "embed": L.embed_spec(cfg),
        "blocks": L.stacked(cfg.n_layers, block_spec(cfg)),
        "final_norm": L.rmsnorm_spec(cfg.d_model),
    }


def _ffn(bp, h, cfg, rt, rules):
    """FFN sub-block -> (y, aux_loss)."""
    if "moe" in bp:
        return moe.moe_mlp(bp["moe"], h, cfg, rt, rules)
    return L.mlp_block(bp["mlp"], h, cfg=cfg, rt=rt, rules=rules), 0.0


def _block(bp, x, positions, cfg, rt: TunableConfig, rules):
    h = L.rmsnorm(x, bp["ln1"], rt, cfg.norm_eps)
    x = x + L.attention_block(bp["attn"], h, cfg=cfg, rt=rt, rules=rules,
                              positions=positions)
    h = L.rmsnorm(x, bp["ln2"], rt, cfg.norm_eps)
    y, aux = _ffn(bp, h, cfg, rt, rules)
    x = x + y
    if rules is not None:
        # sequence parallelism (beyond-paper): between blocks the residual
        # is seq-sharded over the model axis; XLA inserts the gather at
        # the attention boundary and the scatter after the FFN
        x = rules.constrain(x, "batch",
                            "seq_model" if rt.seq_parallel else None, None)
    return x, aux


def forward(p, h, positions, cfg, rt: TunableConfig, rules):
    """h: (B,S,d) embeddings -> (final hidden states, total aux loss)."""
    def body(x, bp):
        x = remat.from_carry(x, rt)
        x, aux = _block(bp, x, positions, cfg, rt, rules)
        return remat.to_carry(x, rt), aux
    body = remat.wrap_layer(body, rt)
    h, auxs = scan_layers(body, remat.to_carry(h, rt), p["blocks"],
                          unroll=rt.unroll_layers)
    h = remat.from_carry(h, rt)
    return L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps), jnp.sum(auxs)


def embed_inputs(p, batch, cfg, rt: TunableConfig, rules):
    """tokens (+ optional precomputed frontend embeddings) -> (B,S,d)."""
    h = L.embed(p["embed"], batch["tokens"], rt)
    if "frontend_embeds" in batch:  # vlm/audio stub: prepend patch embeds
        h = jnp.concatenate([L.cast(batch["frontend_embeds"], rt), h], axis=1)
    if rules is not None:
        h = rules.constrain(h, "batch", None, None)
    return h


AUX_COEF = 0.01


def loss_fn(p, batch, cfg, rt: TunableConfig, rules):
    h = embed_inputs(p, batch, cfg, rt, rules)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    h, aux = forward(p, h, positions, cfg, rt, rules)
    logits = L.unembed(p["embed"], h, cfg, rt, rules)
    labels = batch["labels"]
    if labels.shape[1] < S:  # frontend positions carry no labels
        logits = logits[:, S - labels.shape[1]:]
    loss = L.xent_loss(logits, labels, cfg)
    return loss + AUX_COEF * aux, {"xent": loss, "aux": aux}


# ------------------------------------------------------------- serving
def cache_shapes(cfg, batch: int, max_seq: int, rt: TunableConfig):
    shp, lg = L.attn_cache_shapes(cfg, batch, max_seq, rt)
    return ({"layers": shp, "pos": jax.ShapeDtypeStruct((), jnp.int32)},
            {"layers": lg, "pos": ()})


def init_cache(cfg, batch: int, max_seq: int, rt: TunableConfig):
    shp, _ = cache_shapes(cfg, batch, max_seq, rt)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), shp)


def prefill_fn(p, batch, cfg, rt: TunableConfig, rules, max_seq: int):
    """Run the full prompt, build the KV cache, return last-token logits."""
    h = embed_inputs(p, batch, cfg, rt, rules)
    B, S, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(x, bp):
        x = remat.from_carry(x, rt)
        hn = L.rmsnorm(x, bp["ln1"], rt, cfg.norm_eps)
        # k/v recomputed once for cache storage (cheap vs attention itself)
        k = jnp.einsum("bsd,dhk->bshk", hn, L.cast(bp["attn"]["wk"], rt))
        v = jnp.einsum("bsd,dhk->bshk", hn, L.cast(bp["attn"]["wv"], rt))
        k = L.rope(k, positions, cfg.rope_theta)
        x, _ = _block(bp, x, positions, cfg, rt, rules)
        kq, ks = L.quantize_kv(k, rt.kv_cache_dtype)
        vq, vs = L.quantize_kv(v, rt.kv_cache_dtype)
        extras = (kq, vq) if ks is None else (kq, vq, ks, vs)
        return remat.to_carry(x, rt), extras

    h, extras = scan_layers(body, remat.to_carry(h, rt), p["blocks"],
                            unroll=rt.unroll_layers)
    h = remat.from_carry(h, rt)
    h = L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h[:, -1:], cfg, rt, rules)

    pad = max_seq - S
    def pad_seq(t):
        return jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": pad_seq(extras[0]), "v": pad_seq(extras[1])}
    if len(extras) == 4:
        cache["k_scale"] = pad_seq(extras[2])
        cache["v_scale"] = pad_seq(extras[3])
    return logits, {"layers": cache, "pos": jnp.array(S, jnp.int32)}


def decode_fn(p, cache, tokens, cfg, rt: TunableConfig, rules):
    """One decode step.  tokens: (B,1) int32.  Returns (logits, cache)."""
    h = L.embed(p["embed"], tokens, rt)
    pos = cache["pos"]

    def body(x, args):
        bp, layer_cache = args
        hn = L.rmsnorm(x, bp["ln1"], rt, cfg.norm_eps)
        a, layer_cache = L.decode_attention_block(
            bp["attn"], hn, layer_cache, pos, cfg=cfg, rt=rt, rules=rules)
        x = x + a
        hn = L.rmsnorm(x, bp["ln2"], rt, cfg.norm_eps)
        y, _ = _ffn(bp, hn, cfg, rt, rules)
        return x + y, layer_cache

    h, new_layers = scan_layers(body, h, (p["blocks"], cache["layers"]),
                                unroll=rt.unroll_layers)
    h = L.rmsnorm(h, p["final_norm"], rt, cfg.norm_eps)
    logits = L.unembed(p["embed"], h, cfg, rt, rules)
    return logits, {"layers": new_layers, "pos": pos + 1}
