"""Model dispatch: one uniform API over all families.

``build_model(cfg)`` returns a :class:`Model` whose functions close over
the architecture config; ``input_specs`` builds the ShapeDtypeStruct
stand-ins for every workload cell (dry-run protocol, DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.core.params import TunableConfig
from repro.models import encdec, layers as L, transformer, xlstm, zamba

_FAMILY_MODULES = {
    "dense": transformer,
    "vlm": transformer,
    "moe": transformer,
    "hybrid": zamba,
    "ssm": xlstm,
    "encdec": encdec,
}


@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    mod: Any

    # ---- parameters
    def spec(self):
        return self.mod.spec(self.cfg)

    def init(self, key, dtype=None):
        return L.init_params(self.spec(), key,
                             dtype or jnp.dtype(self.cfg.param_dtype))

    def param_shapes(self, dtype=None):
        return L.param_shapes(self.spec(),
                              dtype or jnp.dtype(self.cfg.param_dtype))

    def logical(self):
        return L.logical_tree(self.spec())

    # ---- steps
    def loss_fn(self, params, batch, rt: TunableConfig, rules=None):
        return self.mod.loss_fn(params, batch, self.cfg, rt, rules)

    def prefill_fn(self, params, batch, rt: TunableConfig, rules=None,
                   max_seq: Optional[int] = None):
        ms = max_seq or batch["tokens"].shape[1]
        return self.mod.prefill_fn(params, batch, self.cfg, rt, rules, ms)

    def decode_fn(self, params, cache, tokens, rt: TunableConfig,
                  rules=None):
        return self.mod.decode_fn(params, cache, tokens, self.cfg, rt, rules)

    # ---- caches
    def cache_shapes(self, batch: int, max_seq: int, rt: TunableConfig):
        return self.mod.cache_shapes(self.cfg, batch, max_seq, rt)

    def init_cache(self, batch: int, max_seq: int, rt: TunableConfig):
        return self.mod.init_cache(self.cfg, batch, max_seq, rt)


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg, _FAMILY_MODULES[cfg.family])


# ------------------------------------------------------------- inputs
def input_specs(cfg: ArchConfig, shape: ShapeConfig,
                rt: TunableConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for one workload cell (no allocation).

    train  -> {tokens, labels [, frames/frontend_embeds]}
    prefill-> {tokens [, frames/frontend_embeds]}
    decode -> {tokens (B,1)}   (cache comes from Model.cache_shapes)
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    comp = jnp.dtype(rt.compute_dtype)
    tok = lambda s: jax.ShapeDtypeStruct((B, s), i32)

    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}

    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        F = cfg.frontend_tokens
        out["frontend_embeds"] = jax.ShapeDtypeStruct((B, F, cfg.d_model),
                                                      comp)
        out["tokens"] = tok(S - F)
        if shape.kind == "train":
            out["labels"] = tok(S - F)
    elif cfg.family == "encdec":
        out["frames"] = jax.ShapeDtypeStruct(
            (B, S // cfg.enc_seq_ratio, cfg.d_model), comp)
        out["tokens"] = tok(S)
        if shape.kind == "train":
            out["labels"] = tok(S)
    else:
        out["tokens"] = tok(S)
        if shape.kind == "train":
            out["labels"] = tok(S)
    return out


def synth_inputs(cfg: ArchConfig, shape: ShapeConfig, rt: TunableConfig,
                 key) -> Dict[str, jnp.ndarray]:
    """Materialized random inputs matching ``input_specs`` (smoke tests)."""
    specs = input_specs(cfg, shape, rt)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab, s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, s.dtype)
    return out


def batch_logical(cfg: ArchConfig, shape: ShapeConfig,
                  rt: TunableConfig) -> Dict[str, Tuple]:
    """Logical axis names for every input (for in_shardings)."""
    specs = input_specs(cfg, shape, rt)
    out = {}
    for name, s in specs.items():
        if name in ("frontend_embeds", "frames"):
            out[name] = ("batch", None, None)
        else:
            out[name] = ("batch", None)
    return out
