"""Mixture-of-Experts FFN with expert parallelism.

Three execution paths (chosen per workload shape, DESIGN.md §5):
  * ``ep_alltoall`` — training/prefill: tokens seq-sharded over the model
    axis, capacity-based dispatch, ``all_to_all`` to expert shards and
    back (the paper's "shuffle").  The wire dtype is the ``comm_codec``
    knob (spark.io.compression.codec analogue).
  * ``ep_gather`` — decode (few tokens): replicated dispatch, expert-
    sharded FFN, ``all_gather`` combine (no all-to-all for tiny T).
  * ``dense`` — single-device smoke tests / reference: exact top-k MoE
    with no capacity drops.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.core.params import TunableConfig
from repro.models import layers as L


def moe_spec(cfg) -> Dict[str, L.PSpec]:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": L.PSpec((d, E), ("embed", "expert"), 0.02),
        "wg": L.PSpec((E, d, ff), ("expert", "embed", "mlp")),
        "wu": L.PSpec((E, d, ff), ("expert", "embed", "mlp")),
        "wd": L.PSpec((E, ff, d), ("expert", "mlp", "embed")),
    }


def _route(xt, router_w, cfg):
    """xt: (T,d) -> (gate_vals (T,k) renormalized, gate_idx (T,k), aux)."""
    logits = (xt @ router_w).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    gv, gi = jax.lax.top_k(gates, cfg.top_k)
    gv = gv / jnp.maximum(jnp.sum(gv, -1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(gi, cfg.n_experts, dtype=jnp.float32).sum(1), axis=0)
    aux = cfg.n_experts * jnp.sum(me * ce)
    return gv.astype(xt.dtype), gi, aux


def _expert_ffn(tokens, wg, wu, wd, cfg, rt):
    """tokens: (E_local, C, d); weights: (E_local, ...)."""
    if cfg.mlp_act == "silu":
        h = (jax.nn.silu(jnp.einsum("ecd,edf->ecf", tokens, wg))
             * jnp.einsum("ecd,edf->ecf", tokens, wu))
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", tokens, wu))
    return jnp.einsum("ecf,efd->ecd", h, wd)


def _encode_wire(x, codec: str):
    """comm_codec knob: cast/quantize before putting bytes on the wire."""
    if codec == "int8":
        amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-6) / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return q.astype(jnp.int8), scale.astype(jnp.float32)
    return x.astype(jnp.dtype(codec)), None


def _decode_wire(x, scale, out_dtype):
    if scale is None:
        return x.astype(out_dtype)
    return (x.astype(jnp.float32) * scale).astype(out_dtype)


def _dispatch(xt, gv, gi, E, C):
    """Capacity-based dispatch.  Returns (buf (E,C,d), keep, slot, flat_e)."""
    T, d = xt.shape
    k = gv.shape[1]
    flat_e = gi.reshape(-1)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    slot = jnp.sum((jnp.cumsum(onehot, axis=0) - 1) * onehot, axis=-1)
    keep = slot < C
    src = jnp.repeat(xt, k, axis=0)
    buf = jnp.zeros((E, C, d), xt.dtype)
    buf = buf.at[jnp.where(keep, flat_e, E),
                 jnp.where(keep, slot, 0)].add(src * keep[:, None],
                                               mode="drop")
    return buf, keep, slot, flat_e


def _combine(back, gv, keep, slot, flat_e, T, k, d):
    g = back[jnp.where(keep, flat_e, 0), jnp.where(keep, slot, 0)]
    g = g * keep[:, None] * gv.reshape(-1)[:, None]
    return g.reshape(T, k, d).sum(axis=1)


# ---------------------------------------------------------------- paths
def _dense_moe(p, x, cfg, rt):
    """Exact (no-capacity) reference path; also the 1-device path."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    gv, gi, aux = _route(xt, L.cast(p["router"], rt), cfg)
    outs = []
    for j in range(cfg.top_k):
        wgj = L.cast(p["wg"], rt)[gi[:, j]]
        wuj = L.cast(p["wu"], rt)[gi[:, j]]
        wdj = L.cast(p["wd"], rt)[gi[:, j]]
        if cfg.mlp_act == "silu":
            h = (jax.nn.silu(jnp.einsum("td,tdf->tf", xt, wgj))
                 * jnp.einsum("td,tdf->tf", xt, wuj))
        else:
            h = jax.nn.gelu(jnp.einsum("td,tdf->tf", xt, wuj))
        outs.append(jnp.einsum("tf,tfd->td", h, wdj) * gv[:, j:j+1])
    y = sum(outs).reshape(B, S, d)
    return y, aux


def _ep_paths_applicable(cfg, rules, S):
    if rules is None:
        return None
    ep = rules.model_axis_size()
    if ep <= 1 or cfg.n_experts % ep != 0:
        return None
    if S > 1 and S % ep == 0:
        return "ep_alltoall"
    return "ep_gather"


def moe_mlp(p, x, cfg, rt: TunableConfig, rules):
    """MoE FFN sub-block.  x: (B,S,d) -> (y, aux_loss)."""
    path = _ep_paths_applicable(cfg, rules, x.shape[1])
    if path is None:
        return _dense_moe(p, x, cfg, rt)

    mesh = rules.mesh
    ep = rules.model_axis_size()
    E, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    B, S, _ = x.shape
    batch_axes = rules.batch_axes
    dp = rules.data_axis_size()
    manual_axes = tuple(mesh.shape.keys())
    fsdp_in_mesh = tuple(a for a in rules.fsdp_axes if a in mesh.shape) \
        if rules.fsdp else ()
    comp = L.dt(rt)

    def gather_w(w):
        """FSDP all-gather of an expert weight's embed dim inside shard_map."""
        for a in fsdp_in_mesh:
            w = jax.lax.all_gather(w, a, axis=1, tiled=True)
        return w

    if path == "ep_alltoall":
        B_local = B // dp
        S_local = S // ep
        T = B_local * S_local
        C = max(1, int(math.ceil(T * k * cfg.capacity_factor / E)))

        def body(xs, rw, wg, wu, wd):
            xt = xs.reshape(T, d)
            gv, gi, aux = _route(xt, rw, cfg)
            buf, keep, slot, fe = _dispatch(xt, gv, gi, E, C)
            wire, scale = _encode_wire(buf, rt.comm_codec)
            recv = jax.lax.all_to_all(wire, "model", 0, 1, tiled=True)
            rscale = (jax.lax.all_to_all(scale, "model", 0, 1, tiled=True)
                      if scale is not None else None)
            toks = _decode_wire(recv, rscale, comp)
            out = _expert_ffn(toks, gather_w(wg).astype(comp),
                              gather_w(wu).astype(comp),
                              jnp.swapaxes(gather_w(
                                  jnp.swapaxes(wd, 1, 2)), 1, 2).astype(comp),
                              cfg, rt)
            wire2, scale2 = _encode_wire(out, rt.comm_codec)
            back = jax.lax.all_to_all(wire2, "model", 1, 0, tiled=True)
            bscale = (jax.lax.all_to_all(scale2, "model", 1, 0, tiled=True)
                      if scale2 is not None else None)
            back = _decode_wire(back, bscale, comp)
            y = _combine(back, gv, keep, slot, fe, T, k, d)
            aux = jax.lax.pmean(aux, manual_axes)
            return y.reshape(B_local, S_local, d), aux

        xspec = P(batch_axes or None, "model", None)
        f = compat.shard_map(
            body, mesh=mesh,
            in_specs=(xspec, P(None, None),
                      P("model", fsdp_in_mesh or None, None),
                      P("model", fsdp_in_mesh or None, None),
                      P("model", None, fsdp_in_mesh or None)),
            out_specs=(xspec, P()), check_vma=False)
        y, aux = f(x, L.cast(p["router"], rt), p["wg"], p["wu"], p["wd"])
        return y, aux

    # ep_gather: decode-time few-token path
    B_local = max(1, B // dp)
    T = B_local * S

    def body_g(xs, rw, wg, wu, wd):
        xt = xs.reshape(T, d)
        gv, gi, aux = _route(xt, rw, cfg)
        C = max(1, int(math.ceil(T * k * cfg.capacity_factor / E)))
        buf, keep, slot, fe = _dispatch(xt, gv, gi, E, C)
        e_local = E // ep
        ridx = jax.lax.axis_index("model")
        mine = jax.lax.dynamic_slice_in_dim(buf, ridx * e_local, e_local, 0)
        out = _expert_ffn(mine, gather_w(wg).astype(comp),
                          gather_w(wu).astype(comp),
                          jnp.swapaxes(gather_w(
                              jnp.swapaxes(wd, 1, 2)), 1, 2).astype(comp),
                          cfg, rt)
        wire, scale = _encode_wire(out, rt.comm_codec)
        full = jax.lax.all_gather(wire, "model", axis=0, tiled=True)
        fscale = (jax.lax.all_gather(scale, "model", axis=0, tiled=True)
                  if scale is not None else None)
        back = _decode_wire(full, fscale, comp)
        y = _combine(back, gv, keep, slot, fe, T, k, d)
        aux = jax.lax.pmean(aux, manual_axes)
        return y.reshape(B_local, S, d), aux

    xspec = P(batch_axes or None, None, None)
    f = compat.shard_map(
        body_g, mesh=mesh,
        in_specs=(xspec, P(None, None),
                  P("model", fsdp_in_mesh or None, None),
                  P("model", fsdp_in_mesh or None, None),
                  P("model", None, fsdp_in_mesh or None)),
        out_specs=(xspec, P()), check_vma=False)
    y, aux = f(x, L.cast(p["router"], rt), p["wg"], p["wu"], p["wd"])
    return y, aux
