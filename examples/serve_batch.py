"""Batched serving example: prefill a prompt batch, stream decode steps,
compare bf16 vs int8 KV cache (spark.rdd.compress analogue).

    PYTHONPATH=src python examples/serve_batch.py
"""
from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    for kv in ("bfloat16", "int8"):
        print(f"\n=== kv_cache_dtype={kv} ===")
        serve_main(["--arch", "glm4-9b", "--reduced", "--batch", "4",
                    "--prompt-len", "32", "--gen-tokens", "12",
                    "--kv-dtype", kv])
