"""The paper's methodology, live: tune a real (runnable) workload with
the WALL-CLOCK evaluator — the exact Sec.-5 protocol (median of 5 runs,
threshold accept, <=10 trials) — on a reduced model on local devices.

    PYTHONPATH=src python examples/tune_trial_and_error.py

(The production-mesh version of the same flow is
``python -m repro.launch.tune --arch <id> --shape <cell>`` which uses
the roofline evaluator on the 256-chip mesh.)
"""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.configs.base import ShapeConfig
from repro.core import report
from repro.core.params import default_config
from repro.core.tree import Stage, run_tuning
from repro.core.trial import TrialRunner, WallClockEvaluator, Workload
from repro.data.pipeline import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model
from repro.optim.optimizers import make_optimizer

ARCH = "smollm-135m"


def make_args(wl, rt, mesh):
    cfg = get_reduced(ARCH)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    optimizer = make_optimizer(cfg.optimizer)
    opt_state = optimizer.init(params)
    data = SyntheticLM(cfg, wl.shp, rt, mesh, seed=0)
    return (params, opt_state, data.batch_at(0))


class ReducedWorkload(Workload):
    """Same cell semantics, reduced config + host mesh (runnable)."""
    @property
    def cfg(self):
        return get_reduced(self.arch)

    @property
    def shp(self):
        return ShapeConfig("mini_train", 128, 8, "train")


def main():
    wl = ReducedWorkload(ARCH, "train_4k")
    ev = WallClockEvaluator(lambda multi_pod=False: make_host_mesh(),
                            make_args, repeats=5)
    runner = TrialRunner(wl, ev)
    # CPU-relevant stages (single device: sharding stages are no-ops)
    stages = [
        Stage("serializer", "spark.serializer",
              [dict(compute_dtype="bfloat16")]),
        Stage("memoryFraction", "spark.shuffle/storage.memoryFraction",
              [dict(remat_policy="dots"), dict(remat_policy="full")]),
        Stage("spill.compress", "spark.shuffle.spill.compress",
              [dict(remat_save_dtype="bfloat16")]),
        Stage("maxSizeInFlight", "spark.reducer.maxSizeInFlight",
              [dict(microbatches=2)]),
        Stage("directBufs", "spark.shuffle.io.preferDirectBufs",
              [dict(donate_buffers=False)]),
    ]
    rep = run_tuning(runner, default_config(), threshold=0.05,
                     stages=stages)
    print(report.tuning_markdown(rep))
    print(f"\n==> wall-clock speedup x{rep.speedup:.2f} "
          f"in {rep.n_trials} trials (cap 10)")


if __name__ == "__main__":
    main()
