"""Quickstart: train a small LM end-to-end on whatever devices exist.

    PYTHONPATH=src python examples/quickstart.py [--steps 100]

Uses the public API only: config registry -> model -> train step bundle
-> data pipeline -> checkpointed loop (same path as launch/train.py).
"""
import argparse
import sys

sys.argv = [sys.argv[0]]  # delegate with explicit args below

from repro.launch.train import main as train_main

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--arch", default="smollm-135m")
    args, _ = ap.parse_known_args()
    raise SystemExit(train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", "8", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_quickstart_ckpt",
        "--ckpt-interval", "25",
    ]))
