"""Batched serving via the wave scheduler: submit a mixed queue of
variable-length requests, report TTFT + decode throughput.

    PYTHONPATH=src python examples/serve_scheduler.py
"""
import numpy as np

import jax

from repro.configs import get_reduced
from repro.core.params import default_config
from repro.models.model import build_model
from repro.serving.scheduler import BatchScheduler, Request


def main():
    cfg = get_reduced("glm4-9b")
    rt = default_config(compute_dtype="bfloat16", kv_cache_dtype="int8")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    sched = BatchScheduler(cfg, rt, params, wave_size=4, max_seq=96)

    rng = np.random.RandomState(0)
    for rid in range(10):
        n = int(rng.randint(8, 48))
        sched.submit(Request(rid=rid,
                             tokens=rng.randint(1, 500, n).astype(np.int32),
                             max_new_tokens=12))
    done = sched.run_until_drained()
    for r in done[:4]:
        print(f"req {r.rid}: prompt {len(r.tokens):2d} tok -> "
              f"{len(r.generated):2d} new, ttft {r.ttft_s*1e3:7.1f} ms")
    print("metrics:", sched.metrics.summary())


if __name__ == "__main__":
    main()
