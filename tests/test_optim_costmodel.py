"""Optimizers converge on a quadratic; cost model parses known HLO."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import costmodel
from repro.optim.optimizers import (adafactor, adamw, constant_schedule,
                                    cosine_schedule)


@pytest.mark.parametrize("make", [
    lambda: adamw(constant_schedule(0.05), weight_decay=0.0),
    lambda: adafactor(constant_schedule(0.1), min_dim=4),
])
def test_optimizer_converges_quadratic(make):
    opt = make()
    target = jnp.array(np.random.RandomState(0)
                       .standard_normal((8, 8)), jnp.float32)
    params = {"w": jnp.zeros((8, 8))}
    state = opt.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda pp: jnp.mean((pp["w"] - target) ** 2))(p)
        return opt.update(g, s, p)

    for _ in range(600):
        params, state, met = step(params, state)
    assert float(jnp.mean((params["w"] - target) ** 2)) < 5e-2


def test_state_specs_match_state_structure():
    from jax.sharding import PartitionSpec as P
    shapes = {"a": jax.ShapeDtypeStruct((128, 256), jnp.float32),
              "b": jax.ShapeDtypeStruct((7,), jnp.float32)}
    specs = {"a": P("data", "model"), "b": P(None)}
    for opt in (adamw(constant_schedule(1e-3)),
                adafactor(constant_schedule(1e-3))):
        st_shapes = jax.eval_shape(opt.init, shapes)
        st_specs = opt.state_specs(specs, shapes)
        # same tree structure (so shardings can be zipped)
        assert (jax.tree.structure(jax.tree.map(lambda x: 0, st_shapes))
                == jax.tree.structure(
                    jax.tree.map(lambda x: 0, st_specs,
                                 is_leaf=lambda s: isinstance(s, P))))


def test_cosine_schedule_shape():
    lr = cosine_schedule(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1e-3, rel=1e-5)
    assert float(lr(100)) == pytest.approx(1e-4, rel=1e-3)


# ------------------------------------------------------------ costmodel
HLO_SAMPLE = """
  %all-reduce.7 = (f32[], f32[4,8]{1,0}, f32[8,4]{1,0}) all-reduce(%a, %b, %c), channel_id=2, replica_groups=[2,4]<=[8]
  %all-gather.3 = bf16[64,128]{1,0} all-gather(%x), channel_id=3, replica_groups=[16,16]<=[256]
  %rs = f32[32]{0} reduce-scatter(%y), channel_id=4, replica_groups=[1,512]<=[512]
  %a2a = s8[4,16,8]{2,1,0} all-to-all(%z), channel_id=5, replica_groups=[16,16]<=[256]
  %cp = f32[8]{0} collective-permute(%w), channel_id=6, source_target_pairs={{0,1}}
  %dot.4 = f32[8,8] dot(%p, %q)
"""


def test_parse_collectives_kinds_and_bytes():
    stats = costmodel.parse_collectives(HLO_SAMPLE)
    kinds = sorted(op.kind for op in stats.ops)
    assert kinds == ["all-gather", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    s = stats.summary()
    assert s["all-reduce"]["bytes"] == 4 + 32 * 4 + 32 * 4
    assert s["all-gather"]["bytes"] == 64 * 128 * 2
    assert s["all-to-all"]["bytes"] == 4 * 16 * 8
    ar = [op for op in stats.ops if op.kind == "all-reduce"][0]
    assert ar.group_size == 4
    ag = [op for op in stats.ops if op.kind == "all-gather"][0]
    assert ag.group_size == 16


def test_collective_seconds_ring_model():
    stats = costmodel.CollectiveStats(
        [costmodel.CollectiveOp("all-reduce", 1000_000, 16)])
    t = costmodel.collective_seconds(stats, pod_size=10**9)
    expect = 2 * (15 / 16) * 1e6 / costmodel.HW["ici_bw"] \
        + costmodel.HW["ici_latency"]
    assert t == pytest.approx(expect, rel=1e-6)
    # pod-axis group (size <= 4) goes over DCN
    stats2 = costmodel.CollectiveStats(
        [costmodel.CollectiveOp("all-gather", 1000_000, 2)])
    t2 = costmodel.collective_seconds(stats2, pod_size=256)
    assert t2 == pytest.approx((1 / 2) * 1e6 / costmodel.HW["dcn_bw"]
                               + costmodel.HW["ici_latency"], rel=1e-6)


def test_cost_analysis_is_per_partition():
    """Verify the per-partition normalization assumption on real HLO."""
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.compat import axis_types_kw
        mesh = jax.make_mesh((4,), ("data",), **axis_types_kw(1))
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        f = jax.jit(lambda a: a @ a,
                    in_shardings=NamedSharding(mesh, P("data", None)))
        from repro.core.costmodel import cost_analysis_dict
        fl = cost_analysis_dict(f.lower(x).compile())["flops"]
        # full matmul = 2*64^3; per-partition should be ~1/4
        print(fl / (2 * 64**3))
    """)
    import os, pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    env = dict(os.environ,
               PYTHONPATH=str(root / "src"),
               JAX_PLATFORMS=os.environ.get("JAX_PLATFORMS", "cpu"))
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=300, env=env, cwd=str(root))
    assert out.returncode == 0, out.stderr[-1500:]
    ratio = float(out.stdout.strip().splitlines()[-1])
    assert 0.2 <= ratio <= 0.35, f"per-partition ratio {ratio}"
