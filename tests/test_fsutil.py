"""Shared filesystem idioms (core/fsutil.py): atomic publish, the
durable (fsync) level, and the torn-tolerant JSONL append."""
import json
import os

import pytest

from repro.core.fsutil import append_jsonl, atomic_publish


class FsyncRecorder:
    """Injected-failure fake for os.fsync: records every call with
    whether the fd was a directory, and optionally fails on demand."""

    def __init__(self, monkeypatch, fail_on=None):
        self.calls = []                    # "file" | "dir"
        self.fail_on = fail_on
        self._real = os.fsync
        monkeypatch.setattr(os, "fsync", self)

    def __call__(self, fd):
        import stat
        kind = "dir" if stat.S_ISDIR(os.fstat(fd).st_mode) else "file"
        self.calls.append(kind)
        if self.fail_on == kind:
            raise OSError(f"injected fsync failure on {kind}")
        self._real(fd)


# ------------------------------------------------------ atomic publish
def test_atomic_publish_replaces_content(tmp_path):
    p = tmp_path / "board.json"
    atomic_publish(p, "one")
    atomic_publish(p, "two")
    assert p.read_text() == "two"
    # no tempfile debris left behind
    assert os.listdir(tmp_path) == ["board.json"]


def test_atomic_publish_failure_keeps_old_content(tmp_path, monkeypatch):
    p = tmp_path / "board.json"
    atomic_publish(p, "old")

    def boom(src, dst):
        raise OSError("injected replace failure")

    monkeypatch.setattr(os, "replace", boom)
    with pytest.raises(OSError, match="injected"):
        atomic_publish(p, "new")
    monkeypatch.undo()
    assert p.read_text() == "old"          # target untouched
    assert os.listdir(tmp_path) == ["board.json"]   # tempfile cleaned


def test_default_publish_never_fsyncs(tmp_path, monkeypatch):
    rec = FsyncRecorder(monkeypatch)
    atomic_publish(tmp_path / "x", "data")
    assert rec.calls == []


def test_durable_publish_fsyncs_file_before_rename_then_dir(
        tmp_path, monkeypatch):
    events = []
    real_replace = os.replace
    rec = FsyncRecorder(monkeypatch)
    monkeypatch.setattr(os, "fsync",
                        lambda fd: (events.append("fsync"), rec(fd))[0])

    def replace(src, dst):
        events.append("replace")
        real_replace(src, dst)

    monkeypatch.setattr(os, "replace", replace)
    atomic_publish(tmp_path / "x", "data", durable=True)
    # the ordering IS the durability contract: data on the platter
    # before the rename makes it visible, directory entry after
    assert rec.calls == ["file", "dir"]
    assert events == ["fsync", "replace", "fsync"]
    assert (tmp_path / "x").read_text() == "data"


def test_durable_publish_survives_dir_fsync_failure(tmp_path, monkeypatch):
    """Platforms that refuse directory fsync degrade gracefully."""
    FsyncRecorder(monkeypatch, fail_on="dir")
    atomic_publish(tmp_path / "x", "data", durable=True)
    assert (tmp_path / "x").read_text() == "data"


def test_durable_publish_file_fsync_failure_aborts(tmp_path, monkeypatch):
    """If the *data* cannot be made durable the publish must not happen
    at all — the old content stays, the tempfile is removed."""
    p = tmp_path / "x"
    atomic_publish(p, "old")
    FsyncRecorder(monkeypatch, fail_on="file")
    with pytest.raises(OSError, match="injected"):
        atomic_publish(p, "new", durable=True)
    monkeypatch.undo()
    assert p.read_text() == "old"
    assert os.listdir(tmp_path) == ["x"]


# -------------------------------------------------------- append_jsonl
def test_append_jsonl_round_trips_records(tmp_path):
    p = tmp_path / "ledger.jsonl"
    append_jsonl(p, {"b": 2, "a": 1})
    append_jsonl(p, {"n": 2})
    lines = p.read_text().splitlines()
    assert [json.loads(s) for s in lines] == [{"a": 1, "b": 2}, {"n": 2}]
    assert lines[0] == '{"a": 1, "b": 2}'   # sorted keys: stable diffs


def test_append_jsonl_creates_parent_dirs(tmp_path):
    p = tmp_path / "deep" / "er" / "ledger.jsonl"
    append_jsonl(p, {"ok": True})
    assert json.loads(p.read_text()) == {"ok": True}


def test_append_jsonl_heals_torn_tail(tmp_path):
    """A crashed writer's partial line must not corrupt the next
    record: the append starts a fresh line, the torn tail stays
    isolated as one unparseable line that readers skip."""
    p = tmp_path / "ledger.jsonl"
    append_jsonl(p, {"first": 1})
    with open(p, "ab") as f:
        f.write(b'{"torn": tr')             # crash mid-record, no newline
    append_jsonl(p, {"second": 2})
    lines = p.read_text().splitlines()
    assert len(lines) == 3
    assert json.loads(lines[0]) == {"first": 1}
    with pytest.raises(ValueError):
        json.loads(lines[1])                # the torn line, isolated
    assert json.loads(lines[2]) == {"second": 2}


def test_append_jsonl_durable_fsyncs(tmp_path, monkeypatch):
    rec = FsyncRecorder(monkeypatch)
    append_jsonl(tmp_path / "l.jsonl", {"a": 1})
    assert rec.calls == []
    append_jsonl(tmp_path / "l.jsonl", {"a": 2}, durable=True)
    assert rec.calls == ["file"]
