"""Learned cost-model proposer (core/proposer.py): fit, cold start,
checkpointable fit state, determinism.

Load-bearing invariants:

  * records from an older knob space are *skipped* by the featurizer
    and the fit-row builder — never a crash, never a proposal;
  * with thin history the ``model`` strategy is bit-identical to the
    ``tree`` walk (cold-start rule), and the decision is checkpointed;
  * a campaign killed mid-walk resumes replay-exact even after the
    history has grown underneath the checkpointed fit (the primer
    re-fits on the stored append-only record *prefix*);
  * same history bytes + same seed ⇒ same fit digest and same proposal
    order in *any* process (subprocess-verified).
"""
import json
import pathlib
import subprocess
import sys

import pytest

from repro.core.campaign import Campaign, CellSpec, tuning_fingerprint
from repro.core.history import (TrialHistory, cell_signature, featurize)
from repro.core.params import default_config
from repro.core.proposer import (MIN_RECORDS, ModelCursor, fit_rows)
from repro.core.strategy import drive, make_cursor
from repro.core.tree import run_tuning
from repro.core.trial import TrialResult, TrialRunner, Workload

ARCH, SHAPE = "smollm-135m", "train_4k"
WL = Workload(ARCH, SHAPE)
SIG = cell_signature(ARCH, SHAPE, False)
BASE = default_config(shard_strategy="fsdp_tp")


def surface(wl, rt):
    """Multiplicative synthetic surface — log-cost is exactly linear
    in the knob one-hots, so the ridge fit can nail it."""
    if rt.remat_policy == "full":
        return TrialResult(cost_s=float("inf"), crashed=True)
    c = 100.0
    if rt.compute_dtype == "bfloat16":
        c *= 0.7
    if rt.shard_strategy == "tp":
        c *= 0.9
    if rt.remat_policy == "none":
        c *= 0.85
    if rt.microbatches == 2:
        c *= 0.97
    if rt.attn_block_q == 256:
        c *= 0.92
    return TrialResult(cost_s=round(c, 6))


def _rec(cost, config, arch=ARCH, shape=SHAPE, **over):
    d = {"v": 1, "ts": 1.0, "cell": Workload(arch, shape).key(),
         "arch": arch, "shape": shape, "multi_pod": False,
         "strategy": "tree", "name": "t", "delta": {},
         "config": config, "cost_s": cost, "crashed": False,
         "compiles": 0, "compile_s": 0.0, "cached": False}
    d.update(over)
    return d


def seed_history(path, n=MIN_RECORDS + 6):
    """Append ``n`` viable same-kind records sampled from the synthetic
    surface (deterministic knob sweep — no RNG)."""
    h = TrialHistory(path)
    combos = [(cd, ss, rp, mb, q)
              for cd in ("float32", "bfloat16")
              for ss in ("fsdp_tp", "tp", "dp")
              for rp in ("dots", "none")
              for mb in (1, 2)
              for q in (128, 256)]
    for i, (cd, ss, rp, mb, q) in enumerate(combos[:n]):
        cfg = BASE.replace(compute_dtype=cd, shard_strategy=ss,
                           remat_policy=rp, microbatches=mb,
                           attn_block_q=q)
        res = surface(WL, cfg)
        arch = (ARCH, "glm4-9b")[i % 2]   # two same-kind cells
        h.append(_rec(res.cost_s, cfg.as_dict(), arch=arch))
    return h


# --------------------------------------------------------- featurizing
def test_old_space_records_skipped_not_crashed(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    good = _rec(70.0, BASE.as_dict())
    h.append(good)
    # value outside today's domain
    h.append(_rec(65.0, {**BASE.as_dict(), "compute_dtype": "fp8"}))
    # knob renamed away in an older space — unknown keys are dropped by
    # config_from_dict, so the record degrades to defaults and stays
    h.append(_rec(60.0, {**BASE.as_dict(), "tensor_parallel": 4}))
    # crash + nonpositive cost rows can't feed a log-cost fit
    h.append(_rec(float("inf"), BASE.as_dict(), crashed=True))
    h.append(_rec(0.0, BASE.as_dict()))
    rows, raw, digest = fit_rows(h, SIG)
    assert raw == 5
    assert len(rows) == 2                 # good + renamed-knob record
    assert digest == fit_rows(h, SIG)[2]  # deterministic


def test_featurize_out_of_domain_raises():
    x = featurize(BASE.as_dict(), SIG)
    assert x.ndim == 1 and x[0] == 1.0    # bias is set
    with pytest.raises(ValueError):
        featurize({**BASE.as_dict(), "compute_dtype": "fp8"}, SIG)


def test_fit_rows_skips_other_kinds(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    h.append(_rec(70.0, BASE.as_dict()))
    decode = default_config()
    h.append(_rec(10.0, decode.as_dict(), shape="decode_32k"))
    rows, raw, _ = fit_rows(h, SIG)
    assert (len(rows), raw) == (1, 2)     # decode row filtered out


# ---------------------------------------------------------- cold start
def test_cold_start_bit_identical_to_tree(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    for _ in range(3):                    # well under MIN_RECORDS
        h.append(_rec(70.0, BASE.as_dict()))
    cursor = make_cursor("model", TrialRunner(WL, surface), BASE,
                         options={"history": str(tmp_path / "h.jsonl")})
    rep = drive(cursor)
    assert cursor.cold is True
    ref = run_tuning(TrialRunner(WL, surface), BASE)
    assert rep.__dict__ == ref.__dict__   # bytes, not just decisions
    assert rep.proposer is None


def test_warm_model_reports_fit_and_predictions(tmp_path):
    seed_history(tmp_path / "h.jsonl")
    cursor = make_cursor("model", TrialRunner(WL, surface), BASE,
                         threshold=0.0,   # accept every real improvement
                         options={"history": str(tmp_path / "h.jsonl")})
    rep = drive(cursor)
    assert cursor.cold is False
    p = rep.proposer
    assert p and p["cold"] is False and p["records"] >= MIN_RECORDS
    assert p["rows"] and all("predicted_s" in r for r in p["rows"])
    assert rep.n_trials <= cursor.budget
    # the surface's optimum is reachable from history signal alone
    assert rep.final_cost == pytest.approx(100.0 * 0.7 * 0.9 * 0.85
                                           * 0.97 * 0.92, rel=1e-6)


def test_cold_decision_is_checkpointed(tmp_path):
    h = seed_history(tmp_path / "h.jsonl", n=5)
    cursor = ModelCursor(TrialRunner(WL, surface), BASE)
    state = cursor.build_primer(h)
    assert state["cold"] is True
    cursor.prime(state, h)
    assert cursor.cold is True
    assert any(isinstance(p, dict) and p.get("cold") is True
               for p in cursor.signature_parts())


# ------------------------------------------------- campaign kill/resume
def test_kill_mid_campaign_resumes_fitted_model(tmp_path):
    """Kill a warm model walk mid-campaign; resume after the history
    has grown (its own appended trials): the checkpointed primer
    re-fits on the stored record prefix and the final report is
    bit-identical to the uninterrupted run."""
    spec = CellSpec(ARCH, SHAPE)
    for d in ("a", "b"):
        (tmp_path / d).mkdir()
        seed_history(tmp_path / d / "history.jsonl")

    class Killer:
        calls = 0

        def __call__(self, wl, rt):
            Killer.calls += 1
            if Killer.calls > 3:
                raise KeyboardInterrupt("simulated kill")
            return surface(wl, rt)

    camp = Campaign([spec], strategy="model", evaluator=Killer(),
                    baseline_factory=lambda s: BASE,
                    checkpoint_dir=tmp_path / "a")
    with pytest.raises(KeyboardInterrupt):
        camp.run()
    ckpt = json.loads((tmp_path / "a" / f"{spec.key()}.json").read_text())
    assert ckpt["primer"]["cold"] is False
    assert ckpt["log"]                     # the kill landed mid-walk
    # history grew past the primed prefix before the resume
    h = TrialHistory(tmp_path / "a" / "history.jsonl")
    assert h.n_records() > ckpt["primer"]["raw"]

    replayed = []

    def resumer(wl, rt):
        replayed.append(rt.as_dict())
        return surface(wl, rt)

    camp2 = Campaign([spec], strategy="model", evaluator=resumer,
                     baseline_factory=lambda s: BASE,
                     checkpoint_dir=tmp_path / "a")
    resumed = camp2.run()[spec.key()]
    absorbed = {json.dumps(e["config"], sort_keys=True)
                for e in ckpt["log"]}
    assert not absorbed & {json.dumps(c, sort_keys=True)
                           for c in replayed}    # nothing re-paid
    ref = Campaign([spec], strategy="model", evaluator=surface,
                   baseline_factory=lambda s: BASE,
                   checkpoint_dir=tmp_path / "b").run()[spec.key()]
    assert tuning_fingerprint(resumed) == tuning_fingerprint(ref)
    assert resumed.proposer == ref.proposer


def test_rewritten_history_invalidates_primer(tmp_path):
    h = seed_history(tmp_path / "h.jsonl")
    cursor = ModelCursor(TrialRunner(WL, surface), BASE)
    state = cursor.build_primer(h)
    lines = (tmp_path / "h.jsonl").read_text().splitlines()
    (tmp_path / "h.jsonl").write_text("\n".join(lines[1:]) + "\n")
    with pytest.raises(ValueError):
        cursor.prime(state, TrialHistory(tmp_path / "h.jsonl"))


# ------------------------------------------------------- determinism
_SUBPROC = r"""
import json, sys
sys.path.insert(0, {src!r})
from repro.core.executor import run_trials
from repro.core.history import TrialHistory, cell_signature
from repro.core.params import default_config
from repro.core.proposer import ModelCursor, fit_rows
from repro.core.trial import TrialResult, TrialRunner, Workload

def surface(wl, rt):
    return TrialResult(cost_s=70.0)

wl = Workload({arch!r}, {shape!r})
h = TrialHistory({path!r})
base = default_config(shard_strategy="fsdp_tp")
cursor = ModelCursor(TrialRunner(wl, surface), base, history=h)
_, _, digest = fit_rows(h, cell_signature(wl.arch, wl.shape, False))
batch = cursor.propose()                       # baseline
pairs = run_trials(cursor.runner, [c.as_trial() for c in batch])
cursor.absorb([r for _, r in pairs], [i for i, _ in pairs])
batch = cursor.propose()                       # first model round
print(json.dumps({{"digest": digest,
                   "names": [c.name for c in batch],
                   "configs": [c.config.as_dict() for c in batch]}},
                 sort_keys=True))
"""


def test_cross_process_fit_determinism(tmp_path):
    """Same history bytes ⇒ same digest and same proposal order from
    two fresh interpreter processes."""
    seed_history(tmp_path / "h.jsonl")
    import repro.core.proposer as _p
    src = str(pathlib.Path(_p.__file__).resolve().parents[2])
    code = _SUBPROC.format(src=src, arch=ARCH, shape=SHAPE,
                           path=str(tmp_path / "h.jsonl"))
    outs = [subprocess.run([sys.executable, "-c", code],
                           capture_output=True, text=True, check=True,
                           ).stdout for _ in range(2)]
    assert outs[0] == outs[1]
    got = json.loads(outs[0])
    assert got["names"] and got["names"][0].startswith("model:1.")
