"""End-to-end behaviour tests: every assigned architecture's reduced
config trains a step (finite loss/grads) and serves (prefill+decode),
per the smoke-test requirement; plus decode-vs-prefill consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_reduced, list_archs
from repro.configs.base import ShapeConfig
from repro.core.params import default_config
from repro.models.model import build_model, synth_inputs
from repro.optim.optimizers import constant_schedule, make_optimizer

RT = default_config()
TRAIN = ShapeConfig("t", 64, 2, "train")
PREFILL = ShapeConfig("p", 32, 2, "prefill")


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch, key):
    """One forward+backward+optimizer step: shapes ok, no NaNs."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(key)
    batch = synth_inputs(cfg, TRAIN, RT, key)
    opt = make_optimizer(cfg.optimizer, constant_schedule(1e-3))
    state = opt.init(params)

    @jax.jit
    def step(p, s, b):
        (loss, _), g = jax.value_and_grad(
            lambda pp: model.loss_fn(pp, b, RT), has_aux=True)(p)
        new_p, new_s, met = opt.update(g, s, p)
        return new_p, new_s, loss, met

    new_params, new_state, loss, met = step(params, state, batch)
    assert jnp.isfinite(loss), f"{arch}: loss not finite"
    assert jnp.isfinite(met["grad_norm"]), f"{arch}: grad norm not finite"
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         params, new_params)
    assert max(jax.tree.leaves(moved)) > 0, f"{arch}: params did not move"
    for leaf in jax.tree.leaves(new_params):
        assert jnp.isfinite(leaf).all(), f"{arch}: non-finite param"


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_smoke(arch, key):
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(key)
    batch = synth_inputs(cfg, PREFILL, RT, key)
    logits, cache = jax.jit(
        lambda p, b: model.prefill_fn(p, b, RT, max_seq=48))(params, batch)
    assert logits.shape[0] == 2 and jnp.isfinite(logits).all()
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = jax.jit(
        lambda p, c, t: model.decode_fn(p, c, t, RT))(params, cache, tok)
    assert jnp.isfinite(logits2).all(), f"{arch}: decode logits not finite"
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["smollm-135m", "glm4-9b", "zamba2-7b",
                                  "xlstm-1.3b", "seamless-m4t-medium"])
def test_decode_matches_teacher_forcing(arch, key):
    """Prefill(S) last-token logits == prefill(S-1) + decode(token S-1).

    Uses an f32 KV cache so the check is exact (bf16 caches round at the
    ~1e-1 logit level by design — spark.rdd.compress trade-off)."""
    cfg = get_reduced(arch)
    model = build_model(cfg)
    params = model.init(key)
    rt = default_config(kv_cache_dtype="float32")
    S = 16
    full = synth_inputs(cfg, ShapeConfig("p", S, 2, "prefill"), rt, key)
    logits_full, _ = model.prefill_fn(params, full, rt, max_seq=S)
    short = dict(full)
    short["tokens"] = full["tokens"][:, :S - 1]
    _, cache = model.prefill_fn(params, short, rt, max_seq=S)
    logits_dec, _ = model.decode_fn(params, cache,
                                    full["tokens"][:, S - 1:S], rt)
    err = float(jnp.max(jnp.abs(logits_full[:, -1] - logits_dec[:, 0])))
    assert err < 2e-2, f"{arch}: decode/prefill mismatch {err}"


def test_vlm_frontend_positions(key):
    """VLM: patch embeddings actually feed the backbone."""
    cfg = get_reduced("llava-next-34b")
    model = build_model(cfg)
    params = model.init(key)
    batch = synth_inputs(cfg, TRAIN, RT, key)
    l1, _ = model.loss_fn(params, batch, RT)
    batch2 = dict(batch, frontend_embeds=batch["frontend_embeds"] * 2.0)
    l2, _ = model.loss_fn(params, batch2, RT)
    assert abs(float(l1) - float(l2)) > 0, "frontend embeds ignored"


def test_decode_pallas_matches_xla(key):
    """The flash-decode kernel path == the XLA decode path."""
    cfg = get_reduced("glm4-9b")
    model = build_model(cfg)
    params = model.init(key)
    rt_x = default_config(kv_cache_dtype="float32")
    batch = synth_inputs(cfg, ShapeConfig("p", 16, 2, "prefill"), rt_x, key)
    logits, cache = model.prefill_fn(params, batch, rt_x, max_seq=32)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out_x, _ = model.decode_fn(params, cache, tok, rt_x)
    rt_p = rt_x.replace(attn_impl="pallas", attn_block_kv=16)
    out_p, _ = model.decode_fn(params, cache, tok, rt_p)
    err = float(jnp.max(jnp.abs(out_x - out_p)))
    assert err < 2e-3, err


def test_int8_kv_cache_close_to_bf16(key):
    """rdd.compress analogue: int8 KV decode stays close to bf16."""
    cfg = get_reduced("glm4-9b")
    model = build_model(cfg)
    params = model.init(key)
    batch = synth_inputs(cfg, PREFILL, RT, key)
    outs = {}
    for kv in ("bfloat16", "int8"):
        rt = default_config(kv_cache_dtype=kv)
        logits, cache = model.prefill_fn(params, batch, rt, max_seq=40)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        l2, _ = model.decode_fn(params, cache, tok, rt)
        outs[kv] = l2
    err = float(jnp.max(jnp.abs(outs["bfloat16"] - outs["int8"])))
    assert err < 0.5, f"int8 kv cache diverges: {err}"
