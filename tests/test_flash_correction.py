"""Analytic flash-attention correction: sanity + knob monotonicity."""
import pytest

from repro.configs import get_config, get_shape
from repro.core import costmodel
from repro.core.params import default_config


BASE = default_config(shard_strategy="fsdp_tp", compute_dtype="bfloat16",
                      attn_impl="pallas")


def test_zero_without_pallas():
    cfg, shp = get_config("glm4-9b"), get_shape("train_4k")
    rt = BASE.replace(attn_impl="xla")
    assert costmodel.flash_memory_correction_bytes(cfg, shp, rt, 16, 16) == 0
    assert costmodel.flash_peak_correction_bytes(cfg, shp, rt, 16, 16) == 0


def test_bigger_tiles_reduce_refetch():
    """file.buffer knob: larger q tiles -> fewer K/V refetches -> larger
    net traffic saving."""
    cfg, shp = get_config("glm4-9b"), get_shape("train_4k")
    small = costmodel.flash_memory_correction_bytes(
        cfg, shp, BASE.replace(attn_block_q=128), 16, 16)
    big = costmodel.flash_memory_correction_bytes(
        cfg, shp, BASE.replace(attn_block_q=512), 16, 16)
    assert big > small > 0


def test_remat_full_stores_fewer_scores():
    cfg, shp = get_config("glm4-9b"), get_shape("train_4k")
    none = costmodel.flash_peak_correction_bytes(cfg, shp, BASE, 16, 16)
    full = costmodel.flash_peak_correction_bytes(
        cfg, shp, BASE.replace(remat_policy="full"), 16, 16)
    assert none > full > 0            # none stores all layers' scores


def test_attention_shards_replicated_heads():
    """9 heads on a 16-wide model axis -> replicated over model."""
    smollm = get_config("smollm-135m")
    glm = get_config("glm4-9b")
    assert costmodel.attention_shards(smollm, BASE, 16, 16) == 16
    assert costmodel.attention_shards(glm, BASE, 16, 16) == 256
    bs = BASE.replace(attn_tp_fallback="batch_shard")
    assert costmodel.attention_shards(smollm, bs, 16, 16) == 256


def test_ssm_family_has_no_attention_apps():
    cfg = get_config("xlstm-1.3b")
    assert costmodel.attention_applications(cfg, get_shape("train_4k")) == []
    zam = get_config("zamba2-7b")
    apps = costmodel.attention_applications(zam, get_shape("train_4k"))
    assert apps == [(81 // 6, 4096, 4096)]


def test_decode_has_no_correction():
    cfg = get_config("glm4-9b")
    assert costmodel.attention_applications(cfg, get_shape("decode_32k")) \
        == []
