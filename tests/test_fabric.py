"""Campaign fabric: lease lifecycle, crash recovery, worker scheduling.

In-process tests drive synthetic evaluators (no XLA compiles, no
subprocesses).  Load-bearing invariants:

  * a lease is exclusive while its heartbeat is fresh; an expired lease
    is stolen by exactly one contender;
  * a worker that crashes mid-cell leaves an expiring lease + a
    checkpoint of everything absorbed — the recovering worker re-pays
    none of it;
  * any number of workers over one directory complete all cells with
    per-cell decisions bit-identical to the single-process campaign.

The multi-*process* path (subprocess workers, SIGKILL recovery, scaling)
is exercised end-to-end by ``benchmarks/bench_fabric.py`` and the CI
fabric smoke.
"""
import json
import threading
import time

import pytest

from repro.core.campaign import Campaign, CellSpec, tuning_fingerprint
from repro.core.fabric import (FabricWorker, Heartbeat, Lease,
                               LeaseBoard, LeaseLost, checkpoint_done,
                               load_evaluator, worker_argv)
from repro.core.params import default_config
from repro.core.trial import TrialRunner
from repro.core.tree import run_tuning

from test_campaign import CELLS, CountingSurface, baseline_factory, \
    surface


# ---------------------------------------------------------------- leases
def test_lease_exclusive_until_released(tmp_path):
    a = LeaseBoard(tmp_path, worker_id="a", ttl_s=30)
    b = LeaseBoard(tmp_path, worker_id="b", ttl_s=30)
    lease = a.try_acquire("cell-1")
    assert lease is not None
    assert b.try_acquire("cell-1") is None
    assert b.try_acquire("cell-2") is not None    # other cells are free
    lease.release()
    assert b.try_acquire("cell-1") is not None


def test_expired_lease_is_stolen(tmp_path):
    a = LeaseBoard(tmp_path, worker_id="a", ttl_s=0.1)
    b = LeaseBoard(tmp_path, worker_id="b", ttl_s=30)
    assert a.try_acquire("cell-1") is not None
    assert b.try_acquire("cell-1") is None        # still fresh
    time.sleep(0.15)
    stolen = b.try_acquire("cell-1")
    assert stolen is not None and stolen.state.worker == "b"


def test_steal_race_single_winner(tmp_path):
    dead = LeaseBoard(tmp_path, worker_id="dead", ttl_s=0.05)
    assert dead.try_acquire("cell-1") is not None
    time.sleep(0.1)
    boards = [LeaseBoard(tmp_path, worker_id=f"w{i}", ttl_s=30)
              for i in range(6)]
    got = [None] * len(boards)

    def claim(i):
        got[i] = boards[i].try_acquire("cell-1")

    ts = [threading.Thread(target=claim, args=(i,))
          for i in range(len(boards))]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    winners = [lease for lease in got if lease is not None]
    assert len(winners) == 1
    held = LeaseBoard(tmp_path).held()
    assert [st.worker for st in held] == [winners[0].state.worker]


def test_heartbeat_keeps_lease_fresh_then_expires(tmp_path):
    a = LeaseBoard(tmp_path, worker_id="a", ttl_s=0.4)
    b = LeaseBoard(tmp_path, worker_id="b", ttl_s=30)
    lease = a.try_acquire("cell-1")
    with Heartbeat(lease, interval=0.1):
        time.sleep(0.8)                  # > ttl, but heartbeats refresh
        assert b.try_acquire("cell-1") is None
    time.sleep(0.5)                      # heartbeat stopped: expires
    assert b.try_acquire("cell-1") is not None


def test_refresh_after_steal_raises_lease_lost(tmp_path):
    a = LeaseBoard(tmp_path, worker_id="a", ttl_s=0.05)
    b = LeaseBoard(tmp_path, worker_id="b", ttl_s=30)
    lease = a.try_acquire("cell-1")
    time.sleep(0.1)
    assert b.try_acquire("cell-1") is not None
    with pytest.raises(LeaseLost):
        lease.refresh()


def test_torn_lease_file_is_stealable(tmp_path):
    board = LeaseBoard(tmp_path, worker_id="w", ttl_s=30)
    (tmp_path / "leases").mkdir()
    (tmp_path / "leases" / "cell-1.lease").write_text("{torn")
    lease = board.try_acquire("cell-1")
    assert lease is not None and lease.state.worker == "w"


def test_reap_expired(tmp_path):
    a = LeaseBoard(tmp_path, worker_id="a", ttl_s=0.05)
    b = LeaseBoard(tmp_path, worker_id="b", ttl_s=30)
    a.try_acquire("done-cell")
    b.try_acquire("live-cell")
    time.sleep(0.1)
    board = LeaseBoard(tmp_path, ttl_s=30)
    assert board.reap_expired() == ["done-cell"]
    assert [st.cell for st in board.held()] == ["live-cell"]


# --------------------------------------------------------------- workers
def test_single_worker_matches_single_process_campaign(tmp_path):
    worker = FabricWorker(CELLS, tmp_path / "fab", evaluator=surface,
                          baseline_factory=baseline_factory, ttl_s=30)
    stats = worker.run()
    assert sorted(stats["cells_completed"]) \
        == sorted(c.key() for c in CELLS)
    assert LeaseBoard(tmp_path / "fab").held() == []
    ref = Campaign(CELLS, evaluator=surface,
                   baseline_factory=baseline_factory,
                   checkpoint_dir=tmp_path / "ref").run()
    for spec in CELLS:
        assert checkpoint_done(tmp_path / "fab", spec.key(), "tree")
        d = json.loads((tmp_path / "fab" / f"{spec.key()}.json")
                       .read_text())
        rep = worker.strategy.load_report(d["report"])
        assert tuning_fingerprint(rep) \
            == tuning_fingerprint(ref[spec.key()])
    # every evaluated trial landed in the shared history
    assert worker.history.n_records() \
        == sum(r.n_trials for r in ref.values())


def test_two_workers_share_the_board_disjointly(tmp_path):
    d = tmp_path / "fab"
    counting = CountingSurface()
    workers = [FabricWorker(CELLS, d, evaluator=counting,
                            baseline_factory=baseline_factory,
                            worker_id=f"w{i}", ttl_s=30, poll_s=0.05)
               for i in range(2)]
    stats = [None, None]

    def drive(i):
        stats[i] = workers[i].run()

    ts = [threading.Thread(target=drive, args=(i,)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    done = stats[0]["cells_completed"] + stats[1]["cells_completed"]
    assert sorted(done) == sorted(c.key() for c in CELLS)  # no overlap
    assert LeaseBoard(d).held() == []
    # no trial ran twice: the lease made the cells disjoint
    ref_trials = {}
    for spec in CELLS:
        runner = TrialRunner(spec.workload(), surface)
        ref_trials[spec.key()] = run_tuning(
            runner, baseline_factory(spec), threshold=0.05).n_trials
    assert len(counting.calls) == sum(ref_trials.values())


def test_worker_releases_lease_on_evaluator_fault(tmp_path):
    """An exception (not a SIGKILL) unwinds the worker's finally: the
    lease is released immediately — recovery needs no TTL wait."""
    d = tmp_path / "fab"
    killer = CountingSurface(fail_after=3)
    a = FabricWorker(CELLS, d, evaluator=killer,
                     baseline_factory=baseline_factory,
                     worker_id="a", ttl_s=30, poll_s=0.05)
    with pytest.raises(KeyboardInterrupt):
        a.run()
    assert LeaseBoard(d).held() == []


def test_crashed_worker_recovered_without_repaying(tmp_path):
    """The fabric acceptance invariant, in-process: worker A is
    SIGKILL-dead mid-cell — checkpoints hold everything absorbed, its
    lease is still on the board with a stopped heartbeat.  Worker B
    steals the expired lease and completes everything without
    re-evaluating one absorbed trial.  (bench_fabric.py stages the
    same scenario with a real SIGKILL across processes.)"""
    d = tmp_path / "fab"
    killer = CountingSurface(fail_after=9)
    camp = Campaign(CELLS, evaluator=killer,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=d, max_workers=2)
    with pytest.raises(KeyboardInterrupt):
        camp.run()                       # A's work until the kill
    absorbed = []
    unfinished = []
    for spec in CELLS:
        path = d / f"{spec.key()}.json"
        if path.exists():
            ck = json.loads(path.read_text())
            absorbed += [(ck["cell"], e["config"]) for e in ck["log"]]
            if not ck.get("done"):
                unfinished.append(spec.key())
        else:
            unfinished.append(spec.key())
    assert absorbed and unfinished
    # the dead worker's lease survives it, heartbeat frozen
    dead_board = LeaseBoard(d, worker_id="a", ttl_s=0.3)
    assert dead_board.try_acquire(unfinished[0]) is not None
    time.sleep(0.4)                      # let A's lease expire
    resumer = CountingSurface()
    b = FabricWorker(CELLS, d, evaluator=resumer,
                     baseline_factory=baseline_factory,
                     worker_id="b", ttl_s=30, poll_s=0.05)
    stats = b.run()
    assert sorted(stats["cells_completed"]) \
        == sorted(c.key() for c in CELLS)
    assert LeaseBoard(d).held() == []
    repaid = {(k, json.dumps(c, sort_keys=True)) for k, c in
              ((k, c) for k, c in resumer.calls)} \
        & {(k, json.dumps(c, sort_keys=True)) for k, c in absorbed}
    assert repaid == set()
    assert stats["replayed_trials"] == len(absorbed)
    # decisions identical to the uninterrupted single-process campaign
    ref = Campaign(CELLS, evaluator=surface,
                   baseline_factory=baseline_factory,
                   checkpoint_dir=tmp_path / "ref").run()
    for spec in CELLS:
        ck = json.loads((d / f"{spec.key()}.json").read_text())
        rep = b.strategy.load_report(ck["report"])
        assert rep.__dict__ == ref[spec.key()].__dict__


def test_worker_skips_done_cells(tmp_path):
    d = tmp_path / "fab"
    FabricWorker(CELLS, d, evaluator=surface,
                 baseline_factory=baseline_factory).run()
    counting = CountingSurface()
    stats = FabricWorker(CELLS, d, evaluator=counting,
                         baseline_factory=baseline_factory).run()
    assert counting.calls == []
    assert stats["cells_completed"] == []


def test_worker_retunes_done_checkpoints_with_stale_parameters(tmp_path):
    """A done checkpoint written under a different threshold must read
    as not-done: the fabric claims the cell and re-tunes it, exactly
    like the single-process campaign would (the weak strategy-only
    check would silently skip it)."""
    d = tmp_path / "fab"
    FabricWorker(CELLS[:2], d, evaluator=surface,
                 baseline_factory=baseline_factory,
                 threshold=0.05).run()
    counting = CountingSurface()
    stats = FabricWorker(CELLS[:2], d, evaluator=counting,
                         baseline_factory=baseline_factory,
                         threshold=0.10).run()
    assert counting.calls                # really re-tuned
    assert sorted(stats["cells_completed"]) \
        == sorted(c.key() for c in CELLS[:2])
    ref = Campaign(CELLS[:2], threshold=0.10, evaluator=surface,
                   baseline_factory=baseline_factory,
                   checkpoint_dir=tmp_path / "ref").run()
    from repro.core.strategy import get_strategy
    for spec in CELLS[:2]:
        ck = json.loads((d / f"{spec.key()}.json").read_text())
        assert ck["threshold"] == 0.10
        rep = get_strategy("tree").load_report(ck["report"])
        assert rep.__dict__ == ref[spec.key()].__dict__


def test_worker_with_start_barrier(tmp_path):
    d = tmp_path / "fab"
    ready, go = tmp_path / "ready", tmp_path / "go"
    worker = FabricWorker(CELLS[:1], d, evaluator=surface,
                          baseline_factory=baseline_factory,
                          ready_file=ready, go_file=go)
    out = {}

    def drive():
        out["stats"] = worker.run()

    t = threading.Thread(target=drive)
    t.start()
    deadline = time.time() + 5
    while not ready.exists() and time.time() < deadline:
        time.sleep(0.01)
    assert ready.exists()
    assert "stats" not in out            # blocked on the go barrier
    go.touch()
    t.join(timeout=5)
    assert out["stats"]["cells_completed"] == [CELLS[0].key()]


# ------------------------------------------------------------- plumbing
def test_worker_argv_roundtrip(tmp_path):
    argv = worker_argv(CELLS[:2], tmp_path, strategy="random",
                       evaluator_spec="benchmarks.fabric_surface:"
                                      "make_evaluator",
                       ttl_s=5.0, warm_start=True,
                       extra=["--budget", "3"])
    assert "--worker" in argv and "--warm-start" in argv
    assert argv[argv.index("--cells") + 1] \
        == f"{CELLS[0].spec()},{CELLS[1].spec()}"
    assert argv[-2:] == ["--budget", "3"]


def test_load_evaluator_spec():
    ev = load_evaluator("benchmarks.fabric_surface:make_evaluator")
    res = ev(CELLS[0].workload(),
             default_config(shard_strategy="fsdp_tp",
                            attn_impl="pallas"))
    assert res.cost_s > 0
    with pytest.raises(ValueError):
        load_evaluator("missing-colon")


def test_checkpoint_done_checks_strategy(tmp_path):
    FabricWorker(CELLS[:1], tmp_path, evaluator=surface,
                 baseline_factory=baseline_factory).run()
    key = CELLS[0].key()
    assert checkpoint_done(tmp_path, key, "tree")
    assert not checkpoint_done(tmp_path, key, "random")
    assert not checkpoint_done(tmp_path, "no-such-cell", "tree")


# --------------------------------------------------- poison quarantine
def test_worker_reaps_orphaned_intents_and_quarantines(tmp_path):
    """A dead worker's in-flight evaluation left an orphaned intent on
    the quarantine ledger; the next claimer of that cell strikes it on
    activation, and at the threshold the config is skipped fleet-wide
    (scored as a crash) instead of re-evaluated."""
    from repro.core.quarantine import Quarantine, config_key
    d = tmp_path / "fab"
    d.mkdir(parents=True)
    bf16 = baseline_factory(None).replace(compute_dtype="bfloat16")
    dead = Quarantine(d, worker="dead-worker")
    dead.begin(CELLS[0].key(), bf16)     # intent, never completed
    counting = CountingSurface()
    worker = FabricWorker(CELLS[:1], d, evaluator=counting,
                          baseline_factory=baseline_factory,
                          worker_id="b", strike_threshold=1)
    stats = worker.run()
    assert stats["cells_completed"] == [CELLS[0].key()]
    evaluated = {json.dumps(c, sort_keys=True) for _, c in counting.calls}
    assert json.dumps(bf16.as_dict(), sort_keys=True) not in evaluated
    s = worker.quarantine.summary()
    assert s["quarantined"] == [config_key(bf16)]
    ck = json.loads((d / f"{CELLS[0].key()}.json").read_text())
    assert ck["done"] and ck["health"]["degraded"]
    assert ck["health"]["quarantined"] >= 1


@pytest.mark.slow
def test_poison_config_quarantined_across_worker_deaths(tmp_path):
    """End-to-end with real SIGKILLs: a config that kills its worker is
    evaluated exactly K times fleet-wide.  Worker 0 dies evaluating it;
    worker 1 steals the expired lease, reaps the orphaned intent
    (strike 1), re-proposes the config and dies too; worker 2 reaps
    (strike 2 = K), quarantines it fleet-wide and completes the cell
    degraded.  The co-scheduled control cell stays bit-identical to a
    fault-free campaign."""
    import os
    import pathlib
    from benchmarks.fabric_surface import surface_cost
    from repro.core.fabric import spawn_worker
    from repro.core.quarantine import Quarantine
    from repro.core.strategy import get_strategy

    K = 2
    root = pathlib.Path(__file__).resolve().parents[1]
    cells = [CellSpec("smollm-135m", "train_4k"),
             CellSpec("smollm-135m", "prefill_32k")]
    d = tmp_path / "fab"
    d.mkdir()
    ledger = tmp_path / "ledger.jsonl"
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join([str(root / "src"), str(root)]),
               CHAOS_KILL_DELTA="remat_policy=full",
               CHAOS_LEDGER=str(ledger))

    def worker(i):
        return spawn_worker(cells, d, strategy="tree",
                            evaluator_spec="benchmarks.chaos_surface:"
                                           "make_evaluator",
                            ttl_s=1.0, worker_id=f"w{i}",
                            strike_threshold=K,
                            log_path=d / "logs" / f"w{i}.log", env=env)

    rcs = [p.wait(timeout=120) for p in [worker(0), worker(1)]]
    assert rcs == [-9, -9]               # both died evaluating the poison
    held = LeaseBoard(d).held()
    assert [st.cell for st in held] == [cells[0].key()]  # lease left held
    finisher = worker(2)
    assert finisher.wait(timeout=120) == 0
    assert LeaseBoard(d).held() == []    # stolen, completed, released

    records = [json.loads(s) for s in ledger.read_text().splitlines()]
    poison_evals = [r for r in records
                    if r["config"]["remat_policy"] == "full"]
    assert len(poison_evals) == K        # the fleet-wide evaluation cap
    summary = Quarantine(d, strike_threshold=K).summary()
    assert len(summary["quarantined"]) == 1
    assert summary["strikes"][summary["quarantined"][0]] == K
    ck = json.loads((d / f"{cells[0].key()}.json").read_text())
    assert ck["done"] and ck["health"]["degraded"]
    assert ck["health"]["quarantined"] >= 1
    assert ck["health"]["failures"]["worker-death"] >= 1
    # the control cell never saw the chaos
    ref = Campaign([cells[1]], evaluator=surface_cost,
                   baseline_factory=baseline_factory,
                   checkpoint_dir=None).run()
    ck1 = json.loads((d / f"{cells[1].key()}.json").read_text())
    assert "health" not in ck1
    rep = get_strategy("tree").load_report(ck1["report"])
    assert tuning_fingerprint(rep) \
        == tuning_fingerprint(ref[cells[1].key()])
