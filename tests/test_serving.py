"""Batched serving scheduler: wave admission, EOS/budget retirement,
metrics, variable-length prompts, padded replay geometry, and the
tunables (kv_cache_dtype / donate_buffers) that must reach the
prefill/decode path."""
import numpy as np
import pytest

import jax

from repro.configs import get_reduced
from repro.core.params import default_config
from repro.models.model import build_model
from repro.serving.scheduler import (BatchScheduler, Request,
                                     ServeMetrics)


@pytest.fixture(scope="module")
def sched():
    cfg = get_reduced("smollm-135m")
    rt = default_config()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return BatchScheduler(cfg, rt, params, wave_size=3, max_seq=64)


def _req(rid, n, max_new=6, eos=None):
    rng = np.random.RandomState(rid)
    return Request(rid=rid, tokens=rng.randint(1, 500, n).astype(np.int32),
                   max_new_tokens=max_new, eos_id=eos)


def test_wave_serves_all_requests(sched):
    for i in range(5):
        sched.submit(_req(i, 8 + i))
    done = sched.run_until_drained()
    assert len(done) == 5
    for r in done:
        assert 1 <= len(r.generated) <= r.max_new_tokens
        assert r.t_first_token is not None and r.t_done is not None
        assert all(0 <= t < 512 for t in r.generated)


def test_variable_length_prompts_left_padded(sched):
    a, b = _req(10, 4, max_new=3), _req(11, 20, max_new=3)
    sched.submit(a)
    sched.submit(b)
    done = sched.run_until_drained()
    assert {r.rid for r in done} == {10, 11}
    assert all(len(r.generated) == 3 for r in done)


def test_metrics_accumulate(sched):
    before = sched.metrics.requests
    sched.submit(_req(20, 8, max_new=4))
    sched.run_until_drained()
    m = sched.metrics.summary()
    assert m["requests"] == before + 1
    assert m["decode_tok_per_s"] >= 0
    assert m["mean_ttft_s"] > 0


def test_eos_retires_lane_early():
    cfg = get_reduced("smollm-135m")
    rt = default_config()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    s = BatchScheduler(cfg, rt, params, wave_size=1, max_seq=64)
    # every token is "eos" -> must stop after the first generated token
    s.submit(Request(rid=1, tokens=np.arange(1, 9, dtype=np.int32),
                     max_new_tokens=10, eos_id=None))
    r = s.run_until_drained()[0]
    eos = r.generated[0]
    s2 = BatchScheduler(cfg, rt, params, wave_size=1, max_seq=64)
    s2.submit(Request(rid=2, tokens=np.arange(1, 9, dtype=np.int32),
                      max_new_tokens=10, eos_id=eos))
    r2 = s2.run_until_drained()[0]
    assert len(r2.generated) == 1


# ---------------------------------------------------- edge cases (ISSUE 8)
def test_drained_empty_queue_returns_empty(sched):
    assert sched.run_until_drained() == []


def test_empty_metrics_summary_is_all_zeros():
    m = ServeMetrics().summary()
    assert m["requests"] == 0
    assert m["decode_tok_per_s"] == 0.0
    assert m["mean_ttft_s"] == 0.0
    assert m["p95_ttft_s"] == 0.0


def test_admit_wave_empty_queue_no_wait(sched):
    # max_wait_s=0 + empty queue must return immediately, not poll
    assert sched._admit_wave() == []


def test_explicit_t_submit_zero_is_preserved(sched):
    # virtual-clock replays submit requests with t_submit=0.0; the
    # scheduler must not clobber that falsy-but-legitimate timestamp
    r = _req(30, 6, max_new=2)
    r.t_submit = 0.0
    sched.submit(r)
    done = sched.run_until_drained()
    got = [x for x in done if x.rid == 30][0]
    assert got.t_submit == 0.0
    assert got.ttft_s is not None and got.ttft_s > 1.0  # wall - 0.0


def test_ttft_none_until_first_token():
    r = _req(31, 4)
    assert r.ttft_s is None          # not yet submitted or served
    r.t_submit = 0.0
    assert r.ttft_s is None          # submitted, nothing served yet


def test_wave_admission_respects_wave_size(sched):
    for i in range(40, 45):
        sched.submit(_req(i, 6, max_new=2))
    wave = sched.run_wave()
    assert len(wave) == sched.wave_size
    sched.run_until_drained()


def test_pad_to_and_pad_wave_fix_geometry():
    cfg = get_reduced("smollm-135m")
    rt = default_config()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    s = BatchScheduler(cfg, rt, params, wave_size=3, max_seq=64,
                       pad_to=32, pad_wave=True)
    s.submit(_req(1, 5, max_new=2))
    toks = s._pad_prompts([s.queue[0]])
    # one request still pads to the full (wave_size, pad_to) geometry:
    # every wave of the replay compiles exactly one prefill program
    assert toks.shape == (3, 32)
    done = s.run_until_drained()
    assert [r.rid for r in done] == [1]
    # filler lanes never count toward metrics
    assert s.metrics.requests == 1
    assert s.metrics.prefill_tokens == 32


def test_kv_cache_dtype_reaches_decode_path():
    cfg = get_reduced("smollm-135m")
    params = build_model(cfg).init(jax.random.PRNGKey(0))

    def cache_dtypes(rt):
        s = BatchScheduler(cfg, rt, params, wave_size=1, max_seq=64)
        _, cache = s._prefill(params, {"tokens": np.ones((1, 8),
                                                         np.int32)})
        return {str(x.dtype) for x in jax.tree_util.tree_leaves(cache)}

    assert "int8" in cache_dtypes(default_config(kv_cache_dtype="int8"))
    assert "int8" not in cache_dtypes(default_config())


def test_donate_buffers_reaches_decode_jit():
    cfg = get_reduced("smollm-135m")
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    on = BatchScheduler(cfg, default_config(donate_buffers=True),
                        params, wave_size=1, max_seq=64)
    off = BatchScheduler(cfg, default_config(donate_buffers=False),
                         params, wave_size=1, max_seq=64)
    assert on._decode_donate == (1,)    # the cache operand is donated
    assert off._decode_donate == ()
