"""Batched serving scheduler: wave admission, EOS/budget retirement,
metrics, variable-length prompts."""
import numpy as np
import pytest

import jax

from repro.configs import get_reduced
from repro.core.params import default_config
from repro.models.model import build_model
from repro.serving.scheduler import BatchScheduler, Request


@pytest.fixture(scope="module")
def sched():
    cfg = get_reduced("smollm-135m")
    rt = default_config()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    return BatchScheduler(cfg, rt, params, wave_size=3, max_seq=64)


def _req(rid, n, max_new=6, eos=None):
    rng = np.random.RandomState(rid)
    return Request(rid=rid, tokens=rng.randint(1, 500, n).astype(np.int32),
                   max_new_tokens=max_new, eos_id=eos)


def test_wave_serves_all_requests(sched):
    for i in range(5):
        sched.submit(_req(i, 8 + i))
    done = sched.run_until_drained()
    assert len(done) == 5
    for r in done:
        assert 1 <= len(r.generated) <= r.max_new_tokens
        assert r.t_first_token is not None and r.t_done is not None
        assert all(0 <= t < 512 for t in r.generated)


def test_variable_length_prompts_left_padded(sched):
    a, b = _req(10, 4, max_new=3), _req(11, 20, max_new=3)
    sched.submit(a)
    sched.submit(b)
    done = sched.run_until_drained()
    assert {r.rid for r in done} == {10, 11}
    assert all(len(r.generated) == 3 for r in done)


def test_metrics_accumulate(sched):
    before = sched.metrics.requests
    sched.submit(_req(20, 8, max_new=4))
    sched.run_until_drained()
    m = sched.metrics.summary()
    assert m["requests"] == before + 1
    assert m["decode_tok_per_s"] >= 0
    assert m["mean_ttft_s"] > 0


def test_eos_retires_lane_early():
    cfg = get_reduced("smollm-135m")
    rt = default_config()
    params = build_model(cfg).init(jax.random.PRNGKey(0))
    s = BatchScheduler(cfg, rt, params, wave_size=1, max_seq=64)
    # every token is "eos" -> must stop after the first generated token
    s.submit(Request(rid=1, tokens=np.arange(1, 9, dtype=np.int32),
                     max_new_tokens=10, eos_id=None))
    r = s.run_until_drained()[0]
    eos = r.generated[0]
    s2 = BatchScheduler(cfg, rt, params, wave_size=1, max_seq=64)
    s2.submit(Request(rid=2, tokens=np.arange(1, 9, dtype=np.int32),
                      max_new_tokens=10, eos_id=eos))
    r2 = s2.run_until_drained()[0]
    assert len(r2.generated) == 1
