"""ParamSpace registry: single-source-of-truth consistency + drift.

The registry (core/space.SPACE) is the only declaration of the knob
space; everything else — DOMAINS, SENSITIVITY_SWEEP, PARAM_DOCS, the
COMPILE/ANALYTIC partition, KNOB_REACH, TunableConfig defaults, the
tree's stage deltas — is derived.  These tests pin the derivations so
the historical names can never drift from the registry again."""
import dataclasses
import itertools

import pytest

from repro.core import params
from repro.core.params import (ANALYTIC_KNOBS, COMPILE_KNOBS, DOMAINS,
                               KNOB_REACH, PARAM_DOCS, SENSITIVITY_SWEEP,
                               TunableConfig, default_config,
                               exhaustive_size)
from repro.core.space import SPACE, Knob, ParamSpace
from repro.core.tree import default_tree, short_tree


# ------------------------------------------------------------ registry
def test_every_sweep_value_in_domain():
    for knob in SPACE:
        for v in knob.sweep:
            assert v in knob.domain, f"{knob.name}: sweep {v!r}"


def test_defaults_validate_and_match_tunableconfig():
    cfg = default_config()            # validates
    for knob in SPACE:
        assert getattr(cfg, knob.name) == knob.default, knob.name


def test_every_knob_has_reach_class_and_evidence():
    for knob in SPACE:
        assert knob.reach in ("compile", "analytic"), knob.name
        assert knob.reach_evidence, f"{knob.name}: no reach evidence"
    # everything the compile_key canonicalizes must carry its own line
    for name in ("grad_comm_dtype", "fuse_grad_collectives",
                 "microbatches", "remat_policy", "remat_save_dtype",
                 "kv_cache_dtype", "comm_codec", "donate_buffers"):
        assert KNOB_REACH[name]


def test_registry_covers_tunableconfig_exactly():
    fields = tuple(f.name for f in dataclasses.fields(TunableConfig))
    assert SPACE.names() == fields


# --------------------------------------------------------- re-exports
def test_domains_reexport_in_sync():
    assert DOMAINS == SPACE.domains()
    assert list(DOMAINS) == [k.name for k in SPACE if k.tunable]
    for name, dom in DOMAINS.items():
        assert dom[0] == getattr(TunableConfig(), name)   # default first


def test_sweep_reexport_in_sync():
    assert SENSITIVITY_SWEEP == SPACE.sweep()
    for name, values in SENSITIVITY_SWEEP.items():
        assert set(values) <= set(DOMAINS[name]), name


def test_docs_reexport_in_sync():
    assert PARAM_DOCS == SPACE.docs()
    assert set(PARAM_DOCS) == set(DOMAINS)


def test_partition_reexport_in_sync():
    assert COMPILE_KNOBS == SPACE.compile_knobs()
    assert ANALYTIC_KNOBS == SPACE.analytic_knobs()
    assert KNOB_REACH == SPACE.reach_evidence()
    # the partition covers the registry with no overlap, in
    # registration order (the order fixes compile_key / disk-cache keys)
    assert set(COMPILE_KNOBS) | set(ANALYTIC_KNOBS) == set(SPACE.names())
    assert not set(COMPILE_KNOBS) & set(ANALYTIC_KNOBS)
    assert [n for n in SPACE.names() if n in COMPILE_KNOBS] \
        == list(COMPILE_KNOBS)


def test_exhaustive_size_is_arithmetic():
    # same number the old materialize-the-grid implementation produced,
    # without building the cross-product
    lazy_count = sum(1 for _ in itertools.product(*DOMAINS.values()))
    assert exhaustive_size() == lazy_count
    assert exhaustive_size() == SPACE.exhaustive_size() >= 512


# --------------------------------------------------------- validation
def test_validate_delta():
    SPACE.validate_delta({"compute_dtype": "bfloat16", "microbatches": 2})
    with pytest.raises(KeyError):
        SPACE.validate_delta({"no_such_knob": 1})
    with pytest.raises(ValueError):
        SPACE.validate_delta({"microbatches": 3})
    with pytest.raises(ValueError):
        params.default_config(compute_dtype="float64")


def test_knob_declaration_errors():
    with pytest.raises(ValueError):
        Knob("k", (1, 2), "nope")                       # bad reach
    with pytest.raises(ValueError):
        Knob("k", (), "compile")                        # empty domain
    with pytest.raises(ValueError):
        Knob("k", (1, 2), "compile", sweep=(3,))        # sweep ∉ domain
    with pytest.raises(ValueError):
        ParamSpace([Knob("k", (1,), "compile"),
                    Knob("k", (2,), "compile")])        # duplicate


# -------------------------------------------------- derived tree deltas
@pytest.mark.parametrize("tree_fn", [default_tree, short_tree])
@pytest.mark.parametrize("kind", ["train", "prefill", "decode"])
def test_tree_stage_deltas_lie_in_space(tree_fn, kind):
    for stage in tree_fn(kind):
        for alt in stage.alternatives:
            SPACE.validate_delta(alt)                   # raises on drift
        # the stage's spark label comes from the registry
        assert any(SPACE[k].spark == stage.spark_name
                   for alt in stage.alternatives for k in alt)
