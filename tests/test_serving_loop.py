"""The serving tuning loop (serving/evaluator.py + serving/canary.py):
serve cells, the SLO guardrail, winner promotion, and the bit-identity
guarantee that adding the serving stack changes nothing for step cells.
"""
import json
import types

import pytest

from repro.core.campaign import (Campaign, CellSpec, parse_cells,
                                 tuning_fingerprint)
from repro.core.history import cell_signature
from repro.core.params import default_config
from repro.core.space import SPACE
from repro.core.trial import FAILURE_DETERMINISTIC, TrialResult
from repro.serving.canary import (SLO_QDELAY_FLOOR_S, SLO_TTFT_FLOOR_S,
                                  PromotionBoard, SLOGuard,
                                  SLOViolation, promote_winners)
from repro.serving.evaluator import (SERVE_KNOBS, CachedServe,
                                     ServeEvaluator, parse_serve_cell,
                                     serve_cell, serve_signature,
                                     serve_stages)


# ------------------------------------------------------------------ cells
def test_parse_serve_cell_roundtrip():
    cell = parse_serve_cell("serve:smollm-135m:poisson_tiny")
    assert cell.arch == "serve-smollm-135m"
    assert cell.shape == "poisson_tiny"
    assert cell.spec() == "serve:smollm-135m:poisson_tiny"
    # three-part key: checkpoints / leases / reports behave identically
    assert cell.key().count("__") == 2
    # campaign's parse_cells dispatches on the serve: prefix
    [again] = parse_cells("serve:smollm-135m:poisson_tiny")
    assert again == cell


@pytest.mark.parametrize("bad", ["serve:smollm-135m", "serve:a:b:c",
                                 "kernel:smollm-135m:poisson_tiny"])
def test_parse_serve_cell_rejects_malformed(bad):
    with pytest.raises(ValueError):
        parse_serve_cell(bad)


def test_serve_cell_validates_arch_and_trace():
    with pytest.raises(ValueError, match="unknown arch"):
        serve_cell("nope", "poisson_tiny")
    with pytest.raises(ValueError, match="unknown trace"):
        serve_cell("smollm-135m", "nope")


def test_serve_signature_via_history_dispatch():
    sig = cell_signature("serve-smollm-135m", "poisson_tiny", False)
    assert sig == serve_signature("serve-smollm-135m", "poisson_tiny")
    assert sig["kind"] == "serve"
    assert sig["active_knobs"] == list(SERVE_KNOBS)


def test_serve_stages_propose_valid_deltas():
    stages = serve_stages(serve_cell("smollm-135m", "poisson_tiny"))
    assert stages, "serve cells need a stage tree"
    for st in stages:
        assert st.kinds == ("serve",)
        for alt in st.alternatives:
            SPACE.validate_delta(alt)   # includes the non-tunable knobs
    knobs = {k for st in stages for alt in st.alternatives for k in alt}
    assert knobs == set(SERVE_KNOBS)


# ------------------------------------------------- space / bit-identity
def test_serving_knobs_are_infrastructure():
    for name in ("max_wave_size", "wave_admission"):
        knob = SPACE[name]
        assert knob.tunable is False
        assert knob.reach == "analytic"
        assert name not in SPACE.domains()   # never swept for step cells
    # the serving knobs never reach a compile: the compile key of a
    # config with exotic serving settings equals the default's
    base = default_config()
    tweaked = base.replace(max_wave_size=8, wave_admission="full")
    assert tweaked.compile_key() == base.compile_key()


def _surface(wl, rt):
    c = 100.0 + 3.0 * len(wl.arch)
    if rt.compute_dtype == "bfloat16":
        c *= 0.7
    if rt.kv_cache_dtype == "int8":
        c *= 0.8
    return TrialResult(cost_s=round(c, 6))


def test_step_campaign_fingerprints_unchanged_by_serving_stack(tmp_path):
    """The serving-aware dispatch evaluator must leave every non-serving
    campaign bit-identical to the bare step evaluator (the PR-7
    regression bar)."""
    from repro.core.kernel_cell import DispatchEvaluator
    cells = [CellSpec("smollm-135m", "train_4k"),
             CellSpec("glm4-9b", "decode_32k")]
    bf = lambda spec: default_config(shard_strategy="fsdp_tp",
                                     attn_impl="pallas")
    bare = Campaign(cells, evaluator=_surface, baseline_factory=bf,
                    checkpoint_dir=tmp_path / "bare").run()
    dispatched = Campaign(cells,
                          evaluator=DispatchEvaluator(step=_surface,
                                                      slo_ttft=3.0),
                          baseline_factory=bf,
                          checkpoint_dir=tmp_path / "disp").run()
    assert list(bare) == list(dispatched)
    for key in bare:
        assert tuning_fingerprint(bare[key]) \
            == tuning_fingerprint(dispatched[key])


# ------------------------------------------------------------- SLO guard
def _guard(factor=2.0, ttft=1.0, qdelay=1.0, shadow=0.25):
    return SLOGuard(factor, {"mean_ttft_s": ttft, "p95_qdelay_s": qdelay},
                    shadow_frac=shadow)


def test_guard_passes_within_limits():
    g = _guard()
    for i in range(1, 9):
        g.observe(ttft_s=1.5, qdelay_s=1.5, served=i, total=8)


def test_guard_aborts_on_queue_delay_everywhere():
    g = _guard()
    with pytest.raises(SLOViolation, match="queue delay"):
        g.observe(ttft_s=0.1, qdelay_s=2.5, served=7, total=8)


def test_guard_shadow_slice_checks_per_request():
    g = _guard()                        # shadow = first 2 of 8
    with pytest.raises(SLOViolation, match="shadow slice"):
        g.observe(ttft_s=2.5, qdelay_s=0.0, served=1, total=8)


def test_guard_uses_running_mean_after_shadow():
    g = _guard()
    for i in range(1, 5):               # healthy shadow + early stream
        g.observe(ttft_s=0.1, qdelay_s=0.0, served=i, total=8)
    # one tail spike: per-request it exceeds 2x, but the running mean
    # does not — graduated candidates are judged on the mean
    g.observe(ttft_s=3.0, qdelay_s=0.0, served=5, total=8)
    # a sustained regression still aborts via the mean
    with pytest.raises(SLOViolation, match="mean TTFT"):
        for i in range(6, 9):
            g.observe(ttft_s=9.0, qdelay_s=0.0, served=i, total=8)


def test_guard_floors_protect_fast_incumbents():
    g = SLOGuard(2.0, {"mean_ttft_s": 1e-6, "p95_qdelay_s": 0.0})
    assert g.ttft_limit == 2.0 * SLO_TTFT_FLOOR_S
    assert g.qdelay_limit == 2.0 * SLO_QDELAY_FLOOR_S
    g.observe(ttft_s=0.3, qdelay_s=0.3, served=1, total=8)


def test_slo_violation_is_pretagged_deterministic():
    assert SLOViolation("slo-violation: x").failure \
        == FAILURE_DETERMINISTIC


# ----------------------------------------------------------- cost / keys
def test_cost_of_combines_ttft_qdelay_decode():
    stats = {"served": 4, "mean_ttft_s": 0.2, "p95_qdelay_s": 0.4,
             "decode_tok_per_s": 100.0, "decode_tokens": 40}
    # 1.0*0.2 + 0.5*0.4 + 1.0*(40/100/4)
    assert ServeEvaluator.cost_of(stats) == pytest.approx(0.5)
    assert ServeEvaluator.cost_of({"served": 0}) == 0.0


def test_cached_serve_key_folds_trace_content_and_slo():
    wl = serve_cell("smollm-135m", "poisson_tiny").workload()
    wl2 = serve_cell("smollm-135m", "bursty_tiny").workload()
    rt = default_config()
    k = CachedServe(ServeEvaluator(), repeats=1)._key(wl, rt)
    # pure function of (cell, trace bytes, slo, config): two workers
    # always agree
    assert CachedServe(ServeEvaluator(), repeats=1)._key(wl, rt) == k
    assert CachedServe(ServeEvaluator(slo_ttft=3.0),
                       repeats=1)._key(wl, rt) != k
    assert CachedServe(ServeEvaluator(), repeats=1)._key(wl2, rt) != k
    assert CachedServe(ServeEvaluator(),
                       repeats=1)._key(wl, rt.replace(
                           max_wave_size=8)) != k


def test_non_serve_workload_is_a_crashed_trial():
    res = ServeEvaluator()(CellSpec("smollm-135m", "train_4k").workload(),
                           default_config())
    assert res.crashed
    assert "not a serve cell" in res.error


# ------------------------------------------------------------- promotion
def test_promotion_board_lifecycle(tmp_path):
    board = PromotionBoard(tmp_path)
    assert board.live("c__t__pod") is None
    r1 = board.promote("c__t__pod", {"max_wave_size": 2}, 1.0, "w0")
    assert r1["action"] == "promoted" and r1["demoted"] is None
    live = board.live("c__t__pod")
    assert live["config"] == {"max_wave_size": 2}
    assert live["cost_s"] == 1.0

    # a worse candidate never lands: the live file is untouched
    r2 = board.promote("c__t__pod", {"max_wave_size": 8}, 2.0, "w1")
    assert r2["action"] == "kept-incumbent"
    assert board.live("c__t__pod")["config"] == {"max_wave_size": 2}

    # a strictly better one demotes the incumbent into the history
    r3 = board.promote("c__t__pod", {"max_wave_size": 4}, 0.5, "w1")
    assert r3["action"] == "promoted"
    assert r3["demoted"]["config"] == {"max_wave_size": 2}
    assert board.live("c__t__pod")["cost_s"] == 0.5
    assert [r["action"] for r in board.history()] \
        == ["promoted", "kept-incumbent", "promoted"]


def test_promote_winners_filters_and_overrides(tmp_path):
    def rep(cost, config, measured=None):
        return types.SimpleNamespace(final_cost=cost,
                                     final_config=config,
                                     measured=measured)
    reports = {
        "serve-a__t__pod": rep(1.5, {"max_wave_size": 2}),
        "serve-b__t__pod": rep(float("inf"), {"max_wave_size": 8}),
        "smollm-135m__train_4k__pod": rep(9.0, {}),   # step cell: skip
        "serve-c__t__pod": rep(
            2.0, {"max_wave_size": 4},
            measured={"winner": {"config": {"max_wave_size": 8},
                                 "cost_s": 1.0}}),
    }
    recs = promote_winners(tmp_path, reports, source="test")
    board = PromotionBoard(tmp_path)
    assert {r["cell"] for r in recs} \
        == {"serve-a__t__pod", "serve-c__t__pod"}
    assert board.live("serve-b__t__pod") is None      # crashed final
    assert board.live("smollm-135m__train_4k__pod") is None
    # the measured winner overrides the model winner
    assert board.live("serve-c__t__pod")["config"] \
        == {"max_wave_size": 8}
    assert board.live("serve-c__t__pod")["cost_s"] == 1.0


def test_live_file_is_valid_json(tmp_path):
    board = PromotionBoard(tmp_path)
    board.promote("serve-a__t__pod", {"wave_admission": "greedy"},
                  1.0, "w0", stats={"mean_ttft_s": 0.1})
    doc = json.loads(board.live_path("serve-a__t__pod").read_text())
    assert doc["stats"] == {"mean_ttft_s": 0.1}


# ------------------------------------------------------ end-to-end (slow)
@pytest.mark.slow
def test_serve_campaign_guard_aborts_and_promotes(tmp_path, monkeypatch):
    """One real serve cell through the campaign: the tree's
    wave_admission=full alternative regresses queue delay past the
    guardrail and is aborted mid-trace as a deterministic crash; the
    surviving winner is promoted to the live board."""
    from repro.launch import tune
    from repro.launch.tune import tune_campaign
    monkeypatch.setattr(tune, "RESULTS_DIR", tmp_path / "reports")
    cells = parse_cells("serve:smollm-135m:poisson_tiny")
    reports, stats = tune_campaign(cells, checkpoint_dir=tmp_path,
                                   slo_ttft=3.0, promote=True)
    [rep] = reports.values()
    assert rep.n_trials == 7             # baseline + 6 alternatives
    crashes = [e for e in rep.log if e["result"]["crashed"]]
    assert crashes, "the violator config must abort"
    for e in crashes:
        assert e["result"]["failure"] == FAILURE_DETERMINISTIC
        assert "slo-violation" in e["result"]["error"]
        # aborted mid-trace: the trace was never finished under it
        assert "/8 requests" in e["result"]["error"]
    assert rep.final_cost <= rep.baseline_cost
    board = PromotionBoard(tmp_path)
    live = board.live(cells[0].key())
    assert live is not None
    assert live["cost_s"] == pytest.approx(rep.final_cost)
    # the campaign summary renders the board
    assert "Serving: promoted live configs" \
        in (tmp_path / "campaign.md").read_text()
