"""Golden-string tests for core/report.py markdown emission.

The markdown lands verbatim in EXPERIMENTS.md artifacts, campaign.md
summaries and the CLI output — drift is user-visible, so these tests
pin the exact rendered text for every renderer: ``campaign_markdown``
(speedup matrix incl. crash/recovered cells), ``strategy_markdown``
(dispatch + mixed-type rejection), ``sensitivity_cell_markdown`` and
``tuning_markdown``/``cell_markdown``.
"""
import pytest

from repro.core import report
from repro.core.sensitivity import KnobImpact, SensitivityReport
from repro.core.tree import TuningReport


def entry(name, delta, cost, accepted, note="", crashed=False):
    return {"name": name, "delta": delta, "config": {},
            "result": {"cost_s": cost, "crashed": crashed},
            "accepted": accepted, "note": note}


def tuned_report():
    return TuningReport(
        workload="smollm-135m__train_4k__pod", baseline_cost=2.0,
        final_cost=1.25, final_config={"compute_dtype": "bfloat16"},
        n_trials=3,
        accepted=["serializer: {'compute_dtype': 'bfloat16'}"],
        log=[entry("baseline", {}, 2.0, True,
                   "baseline (defaults after cluster-level config)"),
             entry("serializer", {"compute_dtype": "bfloat16"}, 1.25,
                   True, "-37.5% vs incumbent"),
             entry("memoryFraction", {"remat_policy": "full"}, 0.0039,
                   False, "crashed (exceeds per-chip HBM)",
                   crashed=True)])


def recovered_report():
    return TuningReport(
        workload="xlstm-1.3b__decode_32k__pod",
        baseline_cost=float("inf"), final_cost=0.5, final_config={},
        n_trials=2, accepted=["serializer: recovered"],
        log=[entry("baseline", {}, float("inf"), True, "",
                   crashed=True),
             entry("serializer", {"compute_dtype": "bfloat16"}, 0.5,
                   True)])


def crashed_report():
    return TuningReport(
        workload="glm4-9b__train_4k__pod", baseline_cost=3.0,
        final_cost=float("inf"), final_config={}, n_trials=1,
        accepted=[],
        log=[entry("baseline", {}, float("inf"), True, "",
                   crashed=True)])


def sens_report():
    return SensitivityReport(
        workload="smollm-135m__train_4k__pod", baseline_cost=1.5,
        impacts=[
            KnobImpact("compute_dtype",
                       "spark.serializer (Java -> Kryo)",
                       ["bfloat16"], [-28.0], 0),
            KnobImpact("remat_policy",
                       "spark.shuffle.memoryFraction "
                       "+ spark.storage.memoryFraction",
                       ["none", "full"], [-16.0, float("nan")], 1)],
        n_trials=4)


CAMPAIGN_GOLDEN = """\
### Campaign: tuning-tree speedup per cell

| arch | train_4k__pod | decode_32k__pod |
|---|---|---|
| smollm-135m | x1.60 (3) | — |
| xlstm-1.3b | — | recovered (2) |
| glm4-9b | crash | — |

* cells tuned: 3
* total trials: 6 (cap 30)
* accepted changes: 2
* geometric-mean speedup: x1.60

Each cell: `x<speedup> (<trials used>)`.\
"""


def test_campaign_markdown_golden():
    reports = {r.workload: r for r in
               (tuned_report(), recovered_report(), crashed_report())}
    assert report.campaign_markdown(reports) == CAMPAIGN_GOLDEN


def test_campaign_gmean_skips_crashed_cells():
    """A crashed-final cell (speedup 0) and a crashed-baseline cell
    (speedup inf) must not drag the geometric mean to 0/inf."""
    reports = {r.workload: r for r in
               (tuned_report(), recovered_report(), crashed_report())}
    md = report.campaign_markdown(reports)
    assert "geometric-mean speedup: x1.60" in md


SENS_CELL_GOLDEN = """\
### Sensitivity: `smollm-135m__train_4k__pod`

* baseline cost: **1.500 s**
* trials used:   4

| knob (Spark analogue) | values | deviation % | mean abs % | crashes |
|---|---|---|---|---|
| compute_dtype (spark.serializer (Java -> Kryo)) | bfloat16 | -28.0 | \
28.0% | 0 |
| remat_policy (spark.shuffle.memoryFraction \
+ spark.storage.memoryFraction) | none, full | -16.0, crash | 16.0% | 1 |\
"""


def test_sensitivity_cell_markdown_golden():
    assert report.sensitivity_cell_markdown(sens_report()) \
        == SENS_CELL_GOLDEN


STRATEGY_SENS_GOLDEN = """\
### Campaign: sensitivity impact per cell (Table 2)

| knob (Spark analogue) | smollm-135m__train_4k__pod | Average |
|---|---|---|
| compute_dtype | 28.0% | 28.0% |
| remat_policy | 16.0% (1 crash) | 16.0% |\
"""


def test_strategy_markdown_dispatch():
    sens = sens_report()
    assert report.strategy_markdown({sens.workload: sens}) \
        == STRATEGY_SENS_GOLDEN
    tuned = tuned_report()
    assert report.strategy_markdown({tuned.workload: tuned}) \
        == report.campaign_markdown({tuned.workload: tuned})
    with pytest.raises(TypeError, match="mixed report types"):
        report.strategy_markdown({"cell-a": sens, "cell-b": tuned})


TUNING_GOLDEN = """\
### Case study: `smollm-135m__train_4k__pod`

* baseline cost: **2.000 s**
* final cost:    **1.250 s** (speedup x1.60)
* trials used:   3 (cap 10)
* accepted: serializer: {'compute_dtype': 'bfloat16'}

| # | stage | change | cost | vs incumbent | verdict |
|---|---|---|---|---|---|
| 0 | baseline | - | 2.000 s | baseline (defaults after cluster-level \
config) | baseline |
| 1 | serializer | compute_dtype=bfloat16 | 1.250 s | -37.5% vs \
incumbent | accept |
| 2 | memoryFraction | remat_policy=full | 3.90 ms | crashed (exceeds \
per-chip HBM) | CRASH |\
"""


def test_tuning_markdown_golden():
    assert report.tuning_markdown(tuned_report()) == TUNING_GOLDEN


def test_cell_markdown_dispatches_on_report_type():
    assert report.cell_markdown(sens_report()) == SENS_CELL_GOLDEN
    assert report.cell_markdown(tuned_report()) == TUNING_GOLDEN


def test_fmt_s_edges():
    assert report._fmt_s(float("nan")) == "crash"
    assert report._fmt_s(float("inf")) == "crash"
    assert report._fmt_s(1e30) == "crash"
    assert report._fmt_s(2.5) == "2.500 s"
    assert report._fmt_s(0.0039) == "3.90 ms"
