"""Golden-string tests for core/report.py markdown emission.

The markdown lands verbatim in EXPERIMENTS.md artifacts, campaign.md
summaries and the CLI output — drift is user-visible, so these tests
pin the exact rendered text for every renderer: ``campaign_markdown``
(speedup matrix incl. crash/recovered cells), ``strategy_markdown``
(dispatch + mixed-type rejection), ``sensitivity_cell_markdown`` and
``tuning_markdown``/``cell_markdown``.
"""
import pytest

from repro.core import report
from repro.core.sensitivity import KnobImpact, SensitivityReport
from repro.core.tree import TuningReport


def entry(name, delta, cost, accepted, note="", crashed=False):
    return {"name": name, "delta": delta, "config": {},
            "result": {"cost_s": cost, "crashed": crashed},
            "accepted": accepted, "note": note}


def tuned_report():
    return TuningReport(
        workload="smollm-135m__train_4k__pod", baseline_cost=2.0,
        final_cost=1.25, final_config={"compute_dtype": "bfloat16"},
        n_trials=3,
        accepted=["serializer: {'compute_dtype': 'bfloat16'}"],
        log=[entry("baseline", {}, 2.0, True,
                   "baseline (defaults after cluster-level config)"),
             entry("serializer", {"compute_dtype": "bfloat16"}, 1.25,
                   True, "-37.5% vs incumbent"),
             entry("memoryFraction", {"remat_policy": "full"}, 0.0039,
                   False, "crashed (exceeds per-chip HBM)",
                   crashed=True)])


def recovered_report():
    return TuningReport(
        workload="xlstm-1.3b__decode_32k__pod",
        baseline_cost=float("inf"), final_cost=0.5, final_config={},
        n_trials=2, accepted=["serializer: recovered"],
        log=[entry("baseline", {}, float("inf"), True, "",
                   crashed=True),
             entry("serializer", {"compute_dtype": "bfloat16"}, 0.5,
                   True)])


def crashed_report():
    return TuningReport(
        workload="glm4-9b__train_4k__pod", baseline_cost=3.0,
        final_cost=float("inf"), final_config={}, n_trials=1,
        accepted=[],
        log=[entry("baseline", {}, float("inf"), True, "",
                   crashed=True)])


def sens_report():
    return SensitivityReport(
        workload="smollm-135m__train_4k__pod", baseline_cost=1.5,
        impacts=[
            KnobImpact("compute_dtype",
                       "spark.serializer (Java -> Kryo)",
                       ["bfloat16"], [-28.0], 0),
            KnobImpact("remat_policy",
                       "spark.shuffle.memoryFraction "
                       "+ spark.storage.memoryFraction",
                       ["none", "full"], [-16.0, float("nan")], 1)],
        n_trials=4)


CAMPAIGN_GOLDEN = """\
### Campaign: tuning-tree speedup per cell

| arch | train_4k__pod | decode_32k__pod |
|---|---|---|
| smollm-135m | x1.60 (3) | — |
| xlstm-1.3b | — | recovered (2) |
| glm4-9b | crash | — |

* cells tuned: 3
* total trials: 6 (cap 30)
* accepted changes: 2
* geometric-mean speedup: x1.60

Each cell: `x<speedup> (<trials used>)`.\
"""


def test_campaign_markdown_golden():
    reports = {r.workload: r for r in
               (tuned_report(), recovered_report(), crashed_report())}
    assert report.campaign_markdown(reports) == CAMPAIGN_GOLDEN


def test_campaign_gmean_skips_crashed_cells():
    """A crashed-final cell (speedup 0) and a crashed-baseline cell
    (speedup inf) must not drag the geometric mean to 0/inf."""
    reports = {r.workload: r for r in
               (tuned_report(), recovered_report(), crashed_report())}
    md = report.campaign_markdown(reports)
    assert "geometric-mean speedup: x1.60" in md


SENS_CELL_GOLDEN = """\
### Sensitivity: `smollm-135m__train_4k__pod`

* baseline cost: **1.500 s**
* trials used:   4

| knob (Spark analogue) | values | deviation % | mean abs % | crashes |
|---|---|---|---|---|
| compute_dtype (spark.serializer (Java -> Kryo)) | bfloat16 | -28.0 | \
28.0% | 0 |
| remat_policy (spark.shuffle.memoryFraction \
+ spark.storage.memoryFraction) | none, full | -16.0, crash | 16.0% | 1 |\
"""


def test_sensitivity_cell_markdown_golden():
    assert report.sensitivity_cell_markdown(sens_report()) \
        == SENS_CELL_GOLDEN


STRATEGY_SENS_GOLDEN = """\
### Campaign: sensitivity impact per cell (Table 2)

| knob (Spark analogue) | smollm-135m__train_4k__pod | Average |
|---|---|---|
| compute_dtype | 28.0% | 28.0% |
| remat_policy | 16.0% (1 crash) | 16.0% |\
"""


def test_strategy_markdown_dispatch():
    sens = sens_report()
    assert report.strategy_markdown({sens.workload: sens}) \
        == STRATEGY_SENS_GOLDEN
    tuned = tuned_report()
    assert report.strategy_markdown({tuned.workload: tuned}) \
        == report.campaign_markdown({tuned.workload: tuned})
    with pytest.raises(TypeError, match="mixed report types"):
        report.strategy_markdown({"cell-a": sens, "cell-b": tuned})


TUNING_GOLDEN = """\
### Case study: `smollm-135m__train_4k__pod`

* baseline cost: **2.000 s**
* final cost:    **1.250 s** (speedup x1.60)
* trials used:   3 (cap 10)
* accepted: serializer: {'compute_dtype': 'bfloat16'}

| # | stage | change | cost | vs incumbent | verdict |
|---|---|---|---|---|---|
| 0 | baseline | - | 2.000 s | baseline (defaults after cluster-level \
config) | baseline |
| 1 | serializer | compute_dtype=bfloat16 | 1.250 s | -37.5% vs \
incumbent | accept |
| 2 | memoryFraction | remat_policy=full | 3.90 ms | crashed (exceeds \
per-chip HBM) | CRASH |\
"""


def test_tuning_markdown_golden():
    assert report.tuning_markdown(tuned_report()) == TUNING_GOLDEN


def test_cell_markdown_dispatches_on_report_type():
    assert report.cell_markdown(sens_report()) == SENS_CELL_GOLDEN
    assert report.cell_markdown(tuned_report()) == TUNING_GOLDEN


PROPOSER_GOLDEN = """\
**Learned proposer** (fit on 28 of 30 history records, \
digest `abcdef123456`)

| trial | predicted | actual | error |
|---|---|---|---|
| model:1.1 | 1.200 s | 1.250 s | -4.0% |
| model:1.2 | 1.100 s | CRASH | — |\
"""


def test_proposer_markdown_golden():
    pd = {"version": 1, "cold": False, "records": 28, "raw": 30,
          "digest": "abcdef1234567890", "rows": [
              {"name": "model:1.1", "predicted_s": 1.2,
               "cost_s": 1.25, "crashed": False},
              {"name": "model:1.2", "predicted_s": 1.1,
               "cost_s": float("inf"), "crashed": True}]}
    assert report.proposer_markdown(pd) == PROPOSER_GOLDEN
    # a warm walk whose rounds proposed nothing still shows the fit
    assert report.proposer_markdown({**pd, "rows": []}).endswith(
        "no model-proposed trials")
    # and tuning_markdown appends the block for model reports only
    rep = tuned_report()
    assert "Learned proposer" not in report.tuning_markdown(rep)
    rep.proposer = pd
    assert report.tuning_markdown(rep).endswith(PROPOSER_GOLDEN)


QUEUE_HEALTH_GOLDEN = """\
### Queue: 2 cells admitted (1 via intake), prioritize=arch

| cell | admitted | priority | state | health |
|---|---|---|---|---|
| a__s__pod | seed | 1.25 | done | 2 timeout; 1 retried; \
1 quarantined; DEGRADED |
| b__s__pod | intake | — | pending | — |\
"""


def test_queue_markdown_health_column_golden():
    queue = {"admitted": 2, "from_intake": 1, "prioritize": "arch",
             "cells": [
                 {"cell": "a__s__pod", "source": "seed", "score": 1.25,
                  "state": "done",
                  "health": {"failures": {"timeout": 2}, "retries": 1,
                             "quarantined": 1, "degraded": True}},
                 {"cell": "b__s__pod", "source": "intake",
                  "score": None, "state": "pending"}]}
    assert report.queue_markdown(queue) == QUEUE_HEALTH_GOLDEN


SERVING_GOLDEN = """\
### Serving: promoted live configs

| cell | live cost | promoted knobs | source |
|---|---|---|---|
| serve-glm__burst__pod | 500.00 ms | max_wave_size=8, \
kv_cache_dtype=bf16 | campaign:tree |
| serve-x__t__pod | — (nothing promoted) | — | — |

* promotion events: 1 promoted, 1 kept the incumbent (the live file \
never regresses)

| demoted at | cell | old cost | new cost |
|---|---|---|---|
| 100.0 | serve-glm__burst__pod | 750.00 ms | 500.00 ms |\
"""


def test_serving_markdown_golden():
    live = {"serve-glm__burst__pod": {
                "config": {"max_wave_size": 8, "kv_cache_dtype": "bf16"},
                "cost_s": 0.5, "source": "campaign:tree"},
            "serve-x__t__pod": None}
    history = [
        {"action": "promoted", "cell": "serve-glm__burst__pod",
         "ts": 100.0, "cost_s": 0.5,
         "demoted": {"config": {}, "cost_s": 0.75, "promoted_ts": 50.0}},
        {"action": "kept-incumbent"}]
    assert report.serving_markdown(live, history) == SERVING_GOLDEN


TELEMETRY_GOLDEN = """\
### Telemetry: where the time went

* events: 42 over 20.0s wall, 2 worker(s), 1.5 trials/s
* compile-cache hit rate: 50%; per-trial rates: 0.1 retry, 0.0 \
timeout, 0.0 quarantine, 0.05 crash
* fleet: 2 lease claim(s), 1 steal(s), 1 strike(s), 0 SLO abort(s)

| where | seconds |
|---|---|
| trials (total) | 18.0 |
| — compiles | 6.0 |
| — evaluation (net of compile) | 12.0 |
| measured tier | 2.0 |
| idle (worker-seconds) | 22.0 |

| worker | trials | busy | utilization |
|---|---|---|---|
| w0 | 16 | 10.0s | 50% |
| w1 | 14 | 8.0s | 40% |

| cell | trials | best cost | first improvement after |
|---|---|---|---|
| a__s__pod | 10 | 1.250 s | 3.5s |\
"""


def telemetry_metrics():
    return {
        "events": 42,
        "counters": {"lease_claims": 2, "lease_steals": 1,
                     "quarantine_strikes": 1, "slo_aborts": 0},
        "gauges": {"workers": 2, "trials_per_s": 1.5,
                   "cache_hit_rate": 0.5, "retry_rate": 0.1,
                   "timeout_rate": 0.0, "quarantine_rate": 0.0,
                   "crash_rate": 0.05},
        "attribution": {"wall_s": 20.0, "trial_s": 18.0,
                        "compile_s": 6.0, "eval_s": 12.0,
                        "measure_s": 2.0, "idle_s": 22.0},
        "per_worker": {"w0": {"trials": 16, "busy_s": 10.0,
                              "utilization": 0.5},
                       "w1": {"trials": 14, "busy_s": 8.0,
                              "utilization": 0.4}},
        "per_cell": {"a__s__pod": {"trials": 10, "best_cost_s": 1.25,
                                   "baseline_cost_s": 2.0,
                                   "first_improvement_s": 3.5}},
    }


def test_telemetry_markdown_golden():
    assert report.telemetry_markdown(telemetry_metrics()) \
        == TELEMETRY_GOLDEN


def test_telemetry_markdown_sparse_metrics():
    """No cache lookups (hit rate unknown), no workers/cells folded
    yet: every field degrades to a placeholder, nothing raises."""
    md = report.telemetry_markdown(
        {"events": 0, "counters": {}, "gauges": {"cache_hit_rate": None},
         "attribution": {}, "per_worker": {}, "per_cell": {}})
    assert "compile-cache hit rate: —" in md
    assert "| worker |" not in md and "| cell |" not in md
    md2 = report.telemetry_markdown(telemetry_metrics() | {
        "per_cell": {"c": {"trials": 1, "best_cost_s": None,
                           "first_improvement_s": None}}})
    assert "| c | 1 | — | — |" in md2


def test_fmt_s_edges():
    assert report._fmt_s(float("nan")) == "crash"
    assert report._fmt_s(float("inf")) == "crash"
    assert report._fmt_s(1e30) == "crash"
    assert report._fmt_s(2.5) == "2.500 s"
    assert report._fmt_s(0.0039) == "3.90 ms"
