"""Poison-config quarantine: evaluation-intent ledger protocol,
orphan reaping, the completion-reset strike rule, and fleet-wide
visibility (core/quarantine.py)."""
import json

from repro.core.params import default_config
from repro.core.quarantine import (DEFAULT_STRIKE_THRESHOLD, Quarantine,
                                   config_key)

CELL = "smollm-135m__train_4k__pod"


def test_config_key_is_stable_and_distinct():
    base = default_config()
    assert config_key(base) == config_key(default_config())
    assert config_key(base) != config_key(base.replace(microbatches=2))
    assert len(config_key(base)) == 16


def test_intent_complete_roundtrip(tmp_path):
    q = Quarantine(tmp_path, worker="w0")
    cfg = default_config()
    token = q.begin(CELL, cfg)
    q.complete(token, crashed=False)
    recs = q.records()
    assert [r["type"] for r in recs] == ["intent", "complete"]
    assert recs[0]["key"] == recs[1]["key"] == config_key(cfg)
    assert recs[0]["cell"] == CELL
    assert recs[0]["worker"] == "w0" and recs[0]["pid"]
    assert recs[0]["config"] == cfg.as_dict()   # full config for forensics
    assert recs[1]["crashed"] is False
    assert q.effective_strikes(config_key(cfg)) == 0


def test_reap_orphans_strikes_only_dead_attempts(tmp_path):
    q = Quarantine(tmp_path)
    done, orphan = default_config(), default_config().replace(microbatches=2)
    t1 = q.begin(CELL, done)
    q.complete(t1, crashed=True)            # crashed but *returned*
    q.begin(CELL, orphan)                   # worker died mid-trial
    reaped = q.reap_orphans(CELL)
    assert reaped == [config_key(orphan)]
    assert q.effective_strikes(config_key(orphan)) == 1
    assert q.effective_strikes(config_key(done)) == 0
    # reaping again is a no-op: the orphan is already struck
    assert q.reap_orphans(CELL) == []
    assert q.effective_strikes(config_key(orphan)) == 1


def test_reap_orphans_respects_cell_filter(tmp_path):
    """A stealer reaps only the cell whose lease it claimed — another
    worker may be legitimately mid-evaluation on a different cell."""
    q = Quarantine(tmp_path)
    q.begin(CELL, default_config())
    q.begin("other__cell__pod", default_config().replace(microbatches=2))
    assert q.reap_orphans(CELL) == [config_key(default_config())]
    assert q.reap_orphans() \
        == [config_key(default_config().replace(microbatches=2))]


def test_strike_is_idempotent_per_attempt(tmp_path):
    q = Quarantine(tmp_path)
    key = config_key(default_config())
    q.strike("att-1", key, CELL)
    q.strike("att-1", key, CELL)            # racing stealers converge
    q.strike("att-2", key, CELL)
    assert sum(r["type"] == "strike" for r in q.records()) == 2
    assert q.effective_strikes(key) == 2


def test_successful_completion_resets_strikes(tmp_path):
    """The completion-reset rule absolves collateral orphans: a benign
    batch-mate struck when the poison config killed its worker is
    cleared the moment it re-evaluates successfully."""
    q = Quarantine(tmp_path)
    cfg = default_config()
    key = config_key(cfg)
    q.strike("att-1", key, CELL)
    assert q.effective_strikes(key) == 1
    token = q.begin(CELL, cfg)
    q.complete(token, crashed=False)        # succeeded on re-evaluation
    assert q.effective_strikes(key) == 0
    q.strike("att-2", key, CELL)            # later strikes count again
    assert q.effective_strikes(key) == 1


def test_crashed_completion_does_not_reset(tmp_path):
    """Timeout strikes are written after a crashed completion — a
    crashed return is evidence against the config, not absolution."""
    q = Quarantine(tmp_path)
    cfg = default_config()
    key = config_key(cfg)
    for i in range(2):
        token = q.begin(CELL, cfg)
        q.complete(token, crashed=True, note="timeout")
        q.strike(token["attempt"], key, CELL, reason="deadline exceeded")
    assert q.effective_strikes(key) == 2


def test_threshold_quarantines_fleet_wide(tmp_path):
    q = Quarantine(tmp_path, strike_threshold=2)
    key = config_key(default_config())
    q.strike("a1", key, CELL)
    assert not q.is_quarantined(key)
    q.strike("a2", key, CELL)
    assert q.is_quarantined(key)
    assert q.quarantined_keys() == {key}
    # a second handle over the same directory (another worker) agrees
    assert Quarantine(tmp_path, strike_threshold=2).is_quarantined(key)


def test_default_threshold():
    assert Quarantine("unused").strike_threshold \
        == DEFAULT_STRIKE_THRESHOLD == 3


def test_summary_rollup(tmp_path):
    q = Quarantine(tmp_path, strike_threshold=1)
    cfg = default_config()
    token = q.begin(CELL, cfg)
    q.complete(token, crashed=False)
    q.begin(CELL, cfg.replace(microbatches=2))
    q.reap_orphans(CELL)
    s = q.summary()
    assert s["records"] == 4 and s["intents"] == 2
    assert s["completions"] == 1
    assert s["strikes"] == {config_key(cfg.replace(microbatches=2)): 1}
    assert s["quarantined"] == [config_key(cfg.replace(microbatches=2))]
    assert s["strike_threshold"] == 1


def test_reader_skips_garbage_lines(tmp_path):
    """Torn tails and foreign lines must not poison the ledger."""
    q = Quarantine(tmp_path)
    q.strike("a1", "somekey", CELL)
    with open(q.path, "ab") as f:
        f.write(b'{"torn": tr')             # crash mid-append
    q2 = Quarantine(tmp_path)
    assert [r["type"] for r in q2.records()] == ["strike"]
    q2.strike("a2", "somekey", CELL)        # healed: next append lands
    assert [r["type"] for r in Quarantine(tmp_path).records()] \
        == ["strike", "strike"]
    assert Quarantine(tmp_path).effective_strikes("somekey") == 2


def test_ledger_is_plain_jsonl(tmp_path):
    """Operators can read it with jq: one sorted-key JSON object per
    line, versioned."""
    q = Quarantine(tmp_path)
    q.begin(CELL, default_config())
    for line in q.path.read_text().splitlines():
        rec = json.loads(line)
        assert rec["v"] == 1 and rec["ts"]
