"""Trial-history store: durable appends, similarity, warm-start queries.

Load-bearing invariants:

  * appends are whole lines; readers skip torn/corrupt lines instead of
    failing (concurrent fabric workers share one file);
  * a cell's warm-start seeds come from the *nearest* already-tuned
    cells (kind-dominant similarity over the ParamSpace registry) and
    never from the cell's own records;
  * configs read back from history are registry-validated — records
    from an older knob space are skipped, never proposed.
"""
import json
import threading

import pytest

from repro.core.history import (TrialHistory, active_knobs,
                                cell_signature, cell_similarity,
                                config_from_dict)
from repro.core.params import default_config
from repro.core.trial import TrialResult, TrialRunner, Workload


def _rec(cell_args, cost, config=None, crashed=False, **over):
    arch, shape = cell_args
    wl = Workload(arch, shape)
    d = {"v": 1, "ts": 1.0, "cell": wl.key(), "arch": arch,
         "shape": shape, "multi_pod": False, "strategy": "tree",
         "name": "t", "delta": {},
         "config": (config or default_config().as_dict()),
         "cost_s": cost, "crashed": crashed, "compiles": 0,
         "compile_s": 0.0, "cached": False}
    d.update(over)
    return d


# ----------------------------------------------------------- the store
def test_append_and_read_roundtrip(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    assert list(h.records()) == []
    r1 = _rec(("smollm-135m", "train_4k"), 10.0)
    r2 = _rec(("glm4-9b", "train_4k"), 20.0)
    h.append(r1)
    h.append(r2)
    assert list(h.records()) == [r1, r2]
    assert h.n_records() == 2
    assert h.cells() == sorted([r1["cell"], r2["cell"]])


def test_torn_and_corrupt_lines_skipped(tmp_path):
    path = tmp_path / "h.jsonl"
    h = TrialHistory(path)
    good = _rec(("smollm-135m", "train_4k"), 10.0)
    h.append(good)
    with open(path, "a") as f:
        f.write("{not json}\n")
        f.write("[1, 2, 3]\n")              # parses but not a record
        f.write('{"cell": "torn tail, no newline')
    assert list(h.records()) == [good]
    # an append after the torn tail starts on the same line — the torn
    # line is lost (it was never durable), later records still parse
    late = _rec(("glm4-9b", "train_4k"), 5.0)
    h.append(late)
    assert late in list(h.records())


def test_concurrent_appends_keep_whole_lines(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")

    def writer(i):
        for j in range(50):
            h.append(_rec(("smollm-135m", "train_4k"), float(i * 100 + j)))

    ts = [threading.Thread(target=writer, args=(i,)) for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    recs = list(h.records())
    assert len(recs) == 200
    assert {r["cost_s"] for r in recs} \
        == {float(i * 100 + j) for i in range(4) for j in range(50)}


def test_torn_tail_self_heals_under_concurrent_append(tmp_path):
    """Satellite: a torn tail (crashed non-atomic writer, no trailing
    newline) must never corrupt records appended *concurrently* after
    it — every appender detects the unterminated line and starts on a
    fresh one; at worst the race emits blank lines, which readers
    skip."""
    path = tmp_path / "h.jsonl"
    h = TrialHistory(path)
    good = _rec(("smollm-135m", "train_4k"), 1.0)
    h.append(good)
    with open(path, "a") as f:
        f.write('{"cell": "torn mid-record, no newli')
    n_threads, per_thread = 4, 25

    def writer(i):
        hh = TrialHistory(path)          # own fd per thread, like workers
        for j in range(per_thread):
            hh.append(_rec(("glm4-9b", "train_4k"),
                           float(1000 + i * 100 + j)))

    ts = [threading.Thread(target=writer, args=(i,))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    recs = list(h.records())
    # the pre-existing record and every concurrent append are durable;
    # the torn line is dropped (it was never durable), nothing merged
    assert len(recs) == 1 + n_threads * per_thread
    assert good in recs
    assert {r["cost_s"] for r in recs if r["cost_s"] >= 1000} \
        == {float(1000 + i * 100 + j)
            for i in range(n_threads) for j in range(per_thread)}
    assert not any("torn" in json.dumps(r) for r in recs)


# ------------------------------------------------- signatures/similarity
def test_active_knobs_follow_compile_reach():
    train = active_knobs("train", "dense")
    decode = active_knobs("decode", "dense")
    # train-only knobs are active on train cells, not on decode cells
    assert "microbatches" in train and "microbatches" not in decode
    assert "remat_policy" in train and "remat_policy" not in decode
    # serve-only knob: the KV dtype
    assert "kv_cache_dtype" in decode and "kv_cache_dtype" not in train
    # ...and never for the ssm family (no attention KV cache)
    assert "kv_cache_dtype" not in active_knobs("decode", "ssm")
    # analytic tunables are always active
    for knobs in (train, decode):
        assert "attn_block_q" in knobs


def test_similarity_prefers_same_kind_over_same_arch():
    target = cell_signature("smollm-135m", "prefill_32k")
    same_kind = cell_signature("xlstm-1.3b", "prefill_32k")
    same_arch = cell_signature("smollm-135m", "train_4k")
    assert cell_similarity(target, same_kind) \
        > cell_similarity(target, same_arch)
    # identity dominates everything
    assert cell_similarity(target, target) \
        > cell_similarity(target, same_kind)


def test_config_from_dict_tolerates_space_drift():
    full = default_config().as_dict()
    # unknown knob from a future/retired space: dropped
    assert config_from_dict({**full, "gone_knob": 3}) \
        == default_config()
    # missing knobs take today's defaults
    assert config_from_dict({"compute_dtype": "bfloat16"}) \
        == default_config(compute_dtype="bfloat16")
    # out-of-domain value: rejected
    with pytest.raises(ValueError):
        config_from_dict({**full, "compute_dtype": "float64"})


# ------------------------------------------------------------ warm-start
def test_warmstart_prefers_nearest_cell_best_config(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    best_prefill = default_config(compute_dtype="bfloat16",
                                  kv_cache_dtype="int8").as_dict()
    best_train = default_config(remat_policy="none").as_dict()
    h.append(_rec(("xlstm-1.3b", "prefill_32k"), 9.0))
    h.append(_rec(("xlstm-1.3b", "prefill_32k"), 5.0,
                  config=best_prefill))
    h.append(_rec(("smollm-135m", "train_4k"), 4.0, config=best_train))
    ws = h.warmstart_configs("smollm-135m", "prefill_32k",
                             k_cells=2, per_cell=1)
    # nearest (same kind) first, then the same-arch train cell
    assert ws == [best_prefill, best_train]
    assert h.warmstart_configs("smollm-135m", "prefill_32k",
                               k_cells=1) == [best_prefill]


def test_warmstart_excludes_own_cell_and_crashes(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    own = default_config(compute_dtype="bfloat16").as_dict()
    h.append(_rec(("smollm-135m", "train_4k"), 1.0, config=own))
    h.append(_rec(("glm4-9b", "train_4k"), 2.0, crashed=True))
    h.append(_rec(("glm4-9b", "train_4k"), float("inf")))
    assert h.warmstart_configs("smollm-135m", "train_4k") == []


def test_warmstart_skips_foreign_space_records_and_dedups(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    bad = {**default_config().as_dict(), "compute_dtype": "float64"}
    good = default_config(compute_dtype="bfloat16").as_dict()
    h.append(_rec(("glm4-9b", "train_4k"), 1.0, config=bad))
    h.append(_rec(("glm4-9b", "train_4k"), 2.0, config=good))
    h.append(_rec(("xlstm-1.3b", "train_4k"), 3.0, config=good))
    ws = h.warmstart_configs("smollm-135m", "train_4k",
                             k_cells=3, per_cell=2)
    assert ws == [good]                  # bad skipped, duplicate deduped
    # an unknown arch in history is skipped, not fatal
    h.append(_rec(("glm4-9b", "train_4k"), 0.5, arch="no-such-arch"))
    assert good in h.warmstart_configs("smollm-135m", "train_4k",
                                       k_cells=3)


def test_warmstart_mixed_old_space_records_fall_through(tmp_path):
    """Satellite: per_cell=1 with a mixed-space cell — the *best*
    record's config is registry-invalid (older knob space), so the
    query must fall through to the same cell's next-best valid record
    rather than returning nothing or crashing."""
    h = TrialHistory(tmp_path / "h.jsonl")
    retired = {**default_config().as_dict(),
               "compute_dtype": "float64",          # out-of-domain
               "gone_knob": 7}                      # unknown field
    valid = default_config(compute_dtype="bfloat16").as_dict()
    worse = default_config(remat_policy="none").as_dict()
    h.append(_rec(("glm4-9b", "train_4k"), 1.0, config=retired))
    h.append(_rec(("glm4-9b", "train_4k"), 2.0, config=valid))
    h.append(_rec(("glm4-9b", "train_4k"), 3.0, config=worse))
    ws = h.warmstart_configs("smollm-135m", "train_4k",
                             k_cells=1, per_cell=1)
    assert ws == [valid]                 # invalid best skipped in-cell
    # a cell whose records are ALL from a retired space contributes
    # nothing but doesn't block other cells
    also_bad = {**default_config().as_dict(), "microbatches": 3}
    h.append(_rec(("xlstm-1.3b", "train_4k"), 0.1, config=also_bad))
    ws = h.warmstart_configs("smollm-135m", "train_4k",
                             k_cells=2, per_cell=1)
    assert ws == [valid]


# ------------------------------------------------------ expected speedup
def test_cell_speedups_baseline_vs_best(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    h.append(_rec(("smollm-135m", "train_4k"), 100.0, name="baseline"))
    h.append(_rec(("smollm-135m", "train_4k"), 50.0, name="serializer"))
    h.append(_rec(("smollm-135m", "train_4k"), 80.0, name="rejected"))
    sp = h.cell_speedups()["smollm-135m__train_4k__pod"]
    assert sp["baseline_cost"] == 100.0 and sp["best_cost"] == 50.0
    assert sp["speedup"] == 2.0 and sp["trials"] == 3
    # crashed-baseline cell: earliest viable record is the proxy base
    h.append(_rec(("glm4-9b", "train_4k"), 40.0, name="serializer",
                  ts=2.0))
    h.append(_rec(("glm4-9b", "train_4k"), 20.0, name="memory", ts=3.0))
    sp2 = h.cell_speedups()["glm4-9b__train_4k__pod"]
    assert sp2["baseline_cost"] == 40.0 and sp2["speedup"] == 2.0


def test_expected_speedup_best_of_nearest_same_kind(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    # same-kind neighbours with different demonstrated gains
    h.append(_rec(("xlstm-1.3b", "prefill_32k"), 100.0, name="baseline"))
    h.append(_rec(("xlstm-1.3b", "prefill_32k"), 50.0, name="t"))
    h.append(_rec(("zamba2-7b", "prefill_32k"), 100.0, name="baseline"))
    h.append(_rec(("zamba2-7b", "prefill_32k"), 80.0, name="t"))
    # a train cell with a huge gain must NOT leak into prefill targets
    h.append(_rec(("smollm-135m", "train_4k"), 100.0, name="baseline"))
    h.append(_rec(("smollm-135m", "train_4k"), 10.0, name="t"))
    est = h.expected_speedup("smollm-135m", "prefill_32k", k_cells=2)
    assert est == 2.0                    # best of the two prefill cells
    # kind isolation: no decode cell recorded -> unknown
    assert h.expected_speedup("smollm-135m", "decode_32k") is None
    # own records dominate when present
    assert h.expected_speedup("xlstm-1.3b", "prefill_32k",
                              k_cells=1) == 2.0
    # empty history -> unknown
    assert TrialHistory(tmp_path / "empty.jsonl") \
        .expected_speedup("smollm-135m", "train_4k") is None


def test_expected_speedup_skips_crashes_and_foreign_archs(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    h.append(_rec(("glm4-9b", "train_4k"), 2.0, crashed=True))
    h.append(_rec(("glm4-9b", "train_4k"), float("inf")))
    assert h.expected_speedup("smollm-135m", "train_4k") is None
    h.append(_rec(("glm4-9b", "train_4k"), 100.0, name="baseline",
                  arch="no-such-arch"))
    assert h.expected_speedup("smollm-135m", "train_4k") is None


# ----------------------------------------------------- runner emission
def test_trial_runner_emits_history_except_replays(tmp_path):
    h = TrialHistory(tmp_path / "h.jsonl")
    wl = Workload("smollm-135m", "train_4k")
    runner = TrialRunner(wl, lambda w, rt: TrialResult(cost_s=1.0),
                         history=h.sink("tree"))
    cfg = default_config()
    runner.record(cfg, "baseline", TrialResult(cost_s=1.0), {})
    runner.record(cfg, "replayed", TrialResult(cost_s=2.0), {},
                  replayed=True)
    recs = list(h.records())
    assert len(recs) == 1
    assert recs[0]["name"] == "baseline"
    assert recs[0]["cell"] == wl.key()
    assert recs[0]["strategy"] == "tree"
    assert recs[0]["config"] == cfg.as_dict()
    # both trials still hit the log (the run budget counts them)
    assert runner.n_trials == 2
