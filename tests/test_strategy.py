"""Strategy API: the SearchCursor protocol, registry, and the four
registered strategies.

Load-bearing invariants:

  * ``run_tuning`` / ``run_sensitivity`` are thin wrappers — their
    outputs are bit-identical to driving the cursor directly;
  * every strategy obeys the propose/absorb alternation and is
    reconstructible by replay (the campaign's resume contract);
  * the random baseline is deterministic per (seed, cell) and respects
    its trial budget.
"""
import dataclasses
import json

import numpy as np
import pytest

from repro.core.executor import SweepExecutor, run_trials
from repro.core.params import DOMAINS, default_config
from repro.core.sensitivity import SensitivityCursor, run_sensitivity
from repro.core.strategy import (RandomCursor, SearchCursor, drive,
                                 get_strategy, list_strategies,
                                 make_cursor)
from repro.core.tree import (MAX_TRIALS, TreeCursor, TuningReport,
                             run_tuning, short_tree)
from repro.core.trial import TrialResult, TrialRunner, Workload

WL = Workload("smollm-135m", "train_4k")
BASE = default_config(shard_strategy="fsdp_tp")


def surface(wl, rt):
    """Deterministic synthetic cost surface with one crash region."""
    if rt.remat_policy == "full":
        return TrialResult(cost_s=float("inf"), crashed=True)
    c = 100.0
    if rt.compute_dtype == "bfloat16":
        c *= 0.7
    if rt.shard_strategy == "tp":
        c *= 0.9
    if rt.remat_policy == "none":
        c *= 0.85
    if rt.microbatches == 2:
        c *= 0.97
    if rt.kv_cache_dtype == "int8":
        c *= 0.8
    if rt.attn_block_q == 256:
        c *= 0.92
    return TrialResult(cost_s=round(c, 6))


def fingerprint(rep):
    return json.dumps(dataclasses.asdict(rep), sort_keys=True,
                      default=str)


# ------------------------------------------------------------- registry
def test_registry_contents():
    assert set(list_strategies()) == {"tree", "short", "sensitivity",
                                      "random", "model"}
    for name in list_strategies():
        spec = get_strategy(name)
        assert spec.version >= 1 and callable(spec.factory)
    assert get_strategy("short-tree") is get_strategy("short")  # alias
    with pytest.raises(KeyError):
        get_strategy("hillclimb")


def test_every_strategy_satisfies_protocol():
    for name in list_strategies():
        cursor = make_cursor(name, TrialRunner(WL, surface), BASE)
        assert isinstance(cursor, SearchCursor), name
        assert cursor.signature_parts() is not None
        json.dumps(cursor.signature_parts(), default=str)  # serializable


def test_short_strategy_uses_short_tree():
    cursor = make_cursor("short", TrialRunner(WL, surface), BASE)
    assert [s.name for s in cursor.stages] \
        == [s.name for s in short_tree("train")]
    assert all(s.name != "file.buffer" for s in cursor.stages)


# ------------------------------------------- thin wrappers (no churn)
def test_run_tuning_is_thin_wrapper_over_tree_cursor():
    """Satellite: run_tuning output must be bit-identical to a direct
    SearchCursor drive — callers in examples/ and benchmarks/ see no
    change."""
    ref = run_tuning(TrialRunner(WL, surface), BASE, threshold=0.05)
    direct = drive(TreeCursor(TrialRunner(WL, surface), BASE,
                              threshold=0.05))
    assert ref.__dict__ == direct.__dict__
    via_registry = drive(make_cursor("tree", TrialRunner(WL, surface),
                                     BASE, threshold=0.05))
    assert ref.__dict__ == via_registry.__dict__


def test_run_sensitivity_is_thin_wrapper_over_cursor():
    ref = run_sensitivity(TrialRunner(WL, surface), BASE)
    direct = drive(SensitivityCursor(TrialRunner(WL, surface), BASE))
    assert fingerprint(ref) == fingerprint(direct)
    via_registry = drive(make_cursor("sensitivity",
                                     TrialRunner(WL, surface), BASE))
    assert fingerprint(ref) == fingerprint(via_registry)


def test_drive_with_executor_identical():
    ref = drive(make_cursor("sensitivity", TrialRunner(WL, surface),
                            BASE))
    with SweepExecutor(surface, max_workers=4) as ex:
        runner = TrialRunner(WL, surface)
        par = drive(make_cursor("sensitivity", runner, BASE),
                    executor=ex)
    assert fingerprint(ref) == fingerprint(par)


# -------------------------------------------------- sensitivity cursor
def test_sensitivity_cursor_protocol_discipline():
    cursor = SensitivityCursor(TrialRunner(WL, surface), BASE)
    with pytest.raises(RuntimeError):
        cursor.absorb([], [])                    # nothing proposed
    batch = cursor.propose()
    assert [c.name for c in batch] == ["baseline"]
    with pytest.raises(RuntimeError):
        cursor.propose()                         # batch not absorbed
    pairs = run_trials(cursor.runner, [c.as_trial() for c in batch])
    with pytest.raises(ValueError):
        cursor.absorb([r for _, r in pairs], [])  # length mismatch
    cursor.absorb([r for _, r in pairs], [i for i, _ in pairs])
    assert not cursor.done
    batch = cursor.propose()
    assert batch and all(c.name.startswith("ofat:") for c in batch)
    pairs = run_trials(cursor.runner, [c.as_trial() for c in batch])
    cursor.absorb([r for _, r in pairs], [i for i, _ in pairs])
    assert cursor.done and cursor.propose() == []
    rep = cursor.report()
    assert rep.n_trials == cursor.runner.n_trials == len(batch) + 1


def test_sensitivity_cursor_replay_reconstructs():
    """The campaign resume contract: replaying recorded results through
    a fresh cursor reproduces the identical report."""
    ref_runner = TrialRunner(WL, surface)
    ref = run_sensitivity(ref_runner, BASE)
    stored = [dataclasses.asdict(e) for e in ref_runner.log]
    replay_runner = TrialRunner(WL, lambda wl, rt: (_ for _ in ()).throw(
        AssertionError("replay must not evaluate")))
    cursor = SensitivityCursor(replay_runner, BASE)
    while True:
        batch = cursor.propose()
        if not batch:
            break
        start = replay_runner.n_trials
        results, indices = [], []
        for c, entry in zip(batch, stored[start:start + len(batch)]):
            assert entry["config"] == c.config.as_dict()
            res = TrialResult(**entry["result"])
            replay_runner.record(c.config, c.name, res, c.delta)
            results.append(res)
            indices.append(replay_runner.n_trials - 1)
        cursor.absorb(results, indices)
    assert fingerprint(cursor.report()) == fingerprint(ref)


def test_sensitivity_cursor_knob_subset():
    knobs = {"compute_dtype": ("float32", "bfloat16"),
             "microbatches": (1, 2, 4)}
    rep = drive(make_cursor("sensitivity", TrialRunner(WL, surface),
                            BASE, options={"knobs": knobs}))
    assert [i.knob for i in rep.impacts] == list(knobs)
    assert rep.n_trials == 1 + 1 + 2     # baseline + bf16 + mb 2/4


# ------------------------------------------------------ random baseline
def test_random_cursor_budget_and_determinism():
    rep = drive(make_cursor("random", TrialRunner(WL, surface), BASE))
    again = drive(make_cursor("random", TrialRunner(WL, surface), BASE))
    assert rep.__dict__ == again.__dict__          # seeded per cell
    assert rep.n_trials == MAX_TRIALS              # budget-matched
    assert rep.final_cost <= rep.baseline_cost + 1e-9
    other_cell = drive(make_cursor(
        "random", TrialRunner(Workload("glm4-9b", "train_4k"), surface),
        BASE))
    assert [e["config"] for e in other_cell.log[1:]] \
        != [e["config"] for e in rep.log[1:]]      # per-cell sampling


def test_random_cursor_seed_and_budget_options():
    a = drive(make_cursor("random", TrialRunner(WL, surface), BASE,
                          options={"seed": 1}))
    b = drive(make_cursor("random", TrialRunner(WL, surface), BASE,
                          options={"seed": 2}))
    assert [e["config"] for e in a.log] != [e["config"] for e in b.log]
    small = drive(make_cursor("random", TrialRunner(WL, surface), BASE,
                              options={"budget": 3}))
    assert small.n_trials == 3
    with pytest.raises(ValueError):
        make_cursor("random", TrialRunner(WL, surface), BASE,
                    options={"budget": 0})


def test_random_cursor_samples_within_domains():
    cursor = RandomCursor(TrialRunner(WL, surface), BASE, seed=3)
    for cand in cursor._sample(20):
        cand.config.validate()
        for k, v in cand.delta.items():
            assert v in DOMAINS[k]


def test_random_cursor_crash_handling():
    def always_crash(wl, rt):
        return TrialResult(cost_s=float("inf"), crashed=True)
    rep = drive(make_cursor("random", TrialRunner(WL, always_crash),
                            BASE))
    assert rep.baseline_cost == float("inf")
    assert rep.accepted == []
    assert all(e["result"]["crashed"] for e in rep.log)
    # crashed baseline + one viable candidate -> recovery is accepted
    def only_random_viable(wl, rt):
        if rt == BASE:
            return TrialResult(cost_s=float("inf"), crashed=True)
        return TrialResult(cost_s=5.0)
    rep = drive(make_cursor("random",
                            TrialRunner(WL, only_random_viable), BASE))
    assert rep.final_cost == 5.0 and len(rep.accepted) == 1


def test_random_report_is_tuning_report():
    rep = drive(make_cursor("random", TrialRunner(WL, surface), BASE))
    assert isinstance(rep, TuningReport)
    assert np.isfinite(rep.speedup)
