"""Online campaign scheduler: intake admission, priority, watch fabric.

All tests drive synthetic evaluators — no XLA compiles.  Load-bearing
invariants:

  * intake submissions are atomic whole files; torn/foreign files are
    skipped, re-submission dedups, ``--fresh`` clears them;
  * the ``arch`` prioritizer reproduces the historical first-seen-arch
    kickoff order bit-for-bit; ``history`` orders by expected speedup
    with unknown cells explore-first and arch grouping as tie-break;
  * a cell submitted while a campaign (or watch fabric worker) runs is
    admitted, tuned and reported without restart — and its decisions
    are bit-identical to a static campaign over the same cell;
  * priority changes scheduling order only: per-cell decisions stay
    bit-identical to the static arch-ordered campaign.
"""
import json
import threading
import time

import pytest

from repro.core.campaign import Campaign, CellSpec
from repro.core.fabric import FabricWorker, LeaseBoard, checkpoint_done
from repro.core.history import TrialHistory
from repro.core.schedule import (ArchPrioritizer, CellQueue,
                                 HistoryPrioritizer, clear_intake,
                                 get_prioritizer, intake_dir,
                                 queue_status, request_stop, scan_intake,
                                 stop_requested, submit_cells)
from repro.core.trial import TrialRunner, Workload
from repro.core.tree import run_tuning

from test_campaign import CELLS, CountingSurface, baseline_factory, \
    surface

DECODE = CELLS[3]                        # xlstm-1.3b decode_32k


def _hist_rec(arch, shape, name, cost, ts=1.0):
    from repro.core.params import default_config
    wl = Workload(arch, shape)
    return {"v": 1, "ts": ts, "cell": wl.key(), "arch": arch,
            "shape": shape, "multi_pod": False, "strategy": "tree",
            "name": name, "delta": {},
            "config": default_config().as_dict(),
            "cost_s": cost, "crashed": False, "compiles": 0,
            "compile_s": 0.0, "cached": False}


def prime_speedup(hist, arch, shape, speedup):
    """Record a (baseline, best) pair demonstrating ``speedup``."""
    hist.append(_hist_rec(arch, shape, "baseline", 100.0, ts=1.0))
    hist.append(_hist_rec(arch, shape, "best", 100.0 / speedup, ts=2.0))


# ---------------------------------------------------------------- intake
def test_submit_scan_roundtrip(tmp_path):
    assert scan_intake(tmp_path) == []
    paths = submit_cells(tmp_path, CELLS[:2])
    assert all(p.exists() for p in paths)
    assert scan_intake(tmp_path) == CELLS[:2]
    # re-submission is idempotent (same key, file overwritten — the
    # refreshed timestamp moves it to the back of the scan order)
    submit_cells(tmp_path, CELLS[:1])
    assert scan_intake(tmp_path) == [CELLS[1], CELLS[0]]


def test_scan_orders_by_submission_time(tmp_path):
    submit_cells(tmp_path, [CELLS[1]])
    time.sleep(0.01)
    submit_cells(tmp_path, [CELLS[0]])
    assert scan_intake(tmp_path) == [CELLS[1], CELLS[0]]


def test_scan_skips_torn_and_foreign_files(tmp_path):
    inbox = intake_dir(tmp_path)
    inbox.mkdir(parents=True)
    (inbox / "torn.cell").write_text("{not json")
    (inbox / "foreign.cell").write_text(json.dumps({"v": 1}))
    (inbox / "badcell.cell").write_text(
        json.dumps({"v": 1, "cell": "no-such-arch:train_4k"}))
    (inbox / "badts.cell").write_text(
        json.dumps({"v": 1, "cell": "smollm-135m:prefill_32k",
                    "submitted_at": "yesterday"}))
    (inbox / "nonstr.cell").write_text(json.dumps({"v": 1, "cell": 5}))
    submit_cells(tmp_path, [CELLS[0]])
    assert scan_intake(tmp_path) == [CELLS[0]]


def test_stop_requested_since_ignores_stale_sentinels(tmp_path):
    """A stop targets the sessions running when it was requested: a
    sentinel older than a session's start reads as no-stop for it
    (and is never deleted — one worker's notion of stale must not
    cancel a stop that is live for the rest of the fabric)."""
    from repro.core.schedule import stop_requested_since
    assert not stop_requested_since(tmp_path, 0.0)     # absent
    path = request_stop(tmp_path)
    ts = json.loads(path.read_text())["requested_at"]
    assert stop_requested_since(tmp_path, ts - 1.0)    # live
    assert stop_requested_since(tmp_path, ts)          # boundary: live
    assert not stop_requested_since(tmp_path, ts + 1.0)  # stale
    assert path.exists()                 # checks never delete the file
    # a foreign `touch`ed sentinel (no payload) falls back to mtime
    path.unlink()
    (intake_dir(tmp_path) / "STOP").touch()
    assert stop_requested_since(tmp_path, time.time() - 60)
    assert not stop_requested_since(tmp_path, time.time() + 60)


def test_clear_intake_and_stop(tmp_path):
    submit_cells(tmp_path, CELLS[:2])
    assert not stop_requested(tmp_path)
    request_stop(tmp_path)
    assert stop_requested(tmp_path)
    clear_intake(tmp_path, CELLS[:1])    # targeted: only that cell
    assert scan_intake(tmp_path) == [CELLS[1]]
    assert not stop_requested(tmp_path)  # STOP cleared with the cells
    clear_intake(tmp_path)               # cells=None: everything
    assert scan_intake(tmp_path) == []


# ----------------------------------------------------------- prioritizers
def test_get_prioritizer_resolution():
    assert isinstance(get_prioritizer("arch"), ArchPrioritizer)
    hist = TrialHistory.__new__(TrialHistory)   # never read
    assert isinstance(get_prioritizer("history", history=hist),
                      HistoryPrioritizer)
    custom = ArchPrioritizer()
    assert get_prioritizer(custom) is custom
    with pytest.raises(KeyError):
        get_prioritizer("no-such-mode")
    with pytest.raises(ValueError):
        get_prioritizer("history", history=None)


def test_arch_prioritizer_reproduces_first_seen_arch_order():
    shuffled = [CELLS[2], CELLS[0], CELLS[3], CELLS[1]]
    queue = CellQueue(shuffled, prioritizer="arch")
    first_seen = {}
    for i, c in enumerate(shuffled):
        first_seen.setdefault(c.arch, i)
    assert queue.order() \
        == sorted(shuffled, key=lambda c: first_seen[c.arch])


def test_history_prioritizer_orders_by_expected_speedup(tmp_path):
    hist = TrialHistory(tmp_path / "h.jsonl")
    prime_speedup(hist, DECODE.arch, DECODE.shape, 2.0)
    prime_speedup(hist, "smollm-135m", "train_4k", 1.2)
    prime_speedup(hist, "glm4-9b", "train_4k", 1.05)
    queue = CellQueue(CELLS, prioritizer="history", history=hist)
    order = queue.order()
    # prefill has no neighbour above the similarity floor -> unknown ->
    # explore-first; then the known cells by expected speedup: decode
    # (2.0), then smollm train (1.2) and glm4 train (pulled to 1.2 by
    # its same-kind same-family smollm neighbour) — the tie broken by
    # first-seen-arch order
    assert order == [CELLS[1], DECODE, CELLS[0], CELLS[2]]


def test_history_prioritizer_unknown_cells_explore_first(tmp_path):
    hist = TrialHistory(tmp_path / "h.jsonl")
    prime_speedup(hist, DECODE.arch, DECODE.shape, 2.0)
    queue = CellQueue(CELLS, prioritizer="history", history=hist)
    # only the decode cell clears the similarity floor; every other
    # cell is unknown and explores first, decode's known 2.0 goes last
    assert queue.order() == [CELLS[0], CELLS[1], CELLS[2], DECODE]
    # an empty history leaves everything unknown -> arch order
    cold = CellQueue(CELLS, prioritizer="history",
                     history=TrialHistory(tmp_path / "empty.jsonl"))
    assert cold.order() == CellQueue(CELLS, prioritizer="arch").order()


# ------------------------------------------------------------- the queue
def test_queue_admission_dedup_and_states(tmp_path):
    queue = CellQueue(CELLS[:2], directory=tmp_path)
    assert queue.admit(CELLS[:3]) == [CELLS[2]]      # dedup
    submit_cells(tmp_path, [CELLS[3], CELLS[0]])
    assert queue.scan_intake() == [CELLS[3]]          # CELLS[0] known
    assert len(queue) == 4
    assert queue.depth() == {"pending": 4, "active": 0, "done": 0}
    first = queue.pop_next()
    assert first == CELLS[0]
    queue.mark_done(first.key())
    assert queue.depth() == {"pending": 3, "active": 0, "done": 1}
    snap = queue.snapshot()
    assert snap["admitted"] == 4 and snap["from_intake"] == 1
    assert snap["cells"][0]["state"] == "done"
    assert {d["source"] for d in snap["cells"]} == {"seed", "intake"}


# -------------------------------------------------------- online campaign
def test_campaign_admits_intake_mid_run(tmp_path):
    """A cell submitted while the campaign runs is admitted between
    batches, tuned and reported — bit-identical to a static campaign."""
    late = DECODE
    submitted = threading.Event()

    def gated(wl, rt):
        if not submitted.is_set():
            submit_cells(tmp_path / "camp", [late])
            submitted.set()
        return surface(wl, rt)

    camp = Campaign(CELLS[:1], evaluator=gated,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=tmp_path / "camp", intake=True)
    reports = camp.run()
    assert set(reports) == {CELLS[0].key(), late.key()}
    runner = TrialRunner(late.workload(), surface)
    ref = run_tuning(runner, baseline_factory(late), threshold=0.05)
    assert reports[late.key()].__dict__ == ref.__dict__
    snap = camp.last_stats["queue"]
    assert snap["from_intake"] == 1
    assert all(d["state"] == "done" for d in snap["cells"])


def test_history_priority_runs_best_cell_first(tmp_path):
    """With ``prioritize='history'`` and one cell slot, the highest
    expected-speedup cell is evaluated first — and every cell's
    decisions stay bit-identical to the arch-ordered campaign."""
    d = tmp_path / "camp"
    hist = TrialHistory(d / "history.jsonl")
    prime_speedup(hist, DECODE.arch, DECODE.shape, 2.0)
    prime_speedup(hist, "smollm-135m", "train_4k", 1.2)
    prime_speedup(hist, "glm4-9b", "train_4k", 1.05)
    prime_speedup(hist, "smollm-135m", "prefill_32k", 1.1)
    counting = CountingSurface()
    camp = Campaign(CELLS, evaluator=counting,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=d, prioritize="history",
                    max_active_cells=1)
    reports = camp.run()
    first_seen = list(dict.fromkeys(k for k, _ in counting.calls))
    assert first_seen[0] == DECODE.key()
    ref = Campaign(CELLS, evaluator=surface,
                   baseline_factory=baseline_factory,
                   checkpoint_dir=tmp_path / "ref").run()
    for key in reports:
        assert reports[key].__dict__ == ref[key].__dict__


def test_max_active_cells_bounds_concurrency(tmp_path):
    calls = []
    lock = threading.Lock()

    def tracking(wl, rt):
        with lock:
            calls.append(wl.key())
        time.sleep(0.002)
        return surface(wl, rt)

    camp = Campaign(CELLS, evaluator=tracking,
                    baseline_factory=baseline_factory,
                    checkpoint_dir=None, max_active_cells=1,
                    max_workers=4)
    camp.run()
    # one cell slot: a later cell's first trial never precedes an
    # earlier cell's last trial
    first, last = {}, {}
    for i, key in enumerate(calls):
        first.setdefault(key, i)
        last[key] = i
    order = sorted(first, key=first.get)
    assert len(order) == len(CELLS)
    for a, b in zip(order, order[1:]):
        assert last[a] < first[b]


def test_campaign_rejects_bad_online_options():
    with pytest.raises(ValueError, match="max_active_cells"):
        Campaign(CELLS, evaluator=surface, checkpoint_dir=None,
                 max_active_cells=0)
    with pytest.raises(ValueError, match="intake"):
        Campaign(CELLS, evaluator=surface, checkpoint_dir=None,
                 intake=True)
    with pytest.raises(ValueError, match="history"):
        Campaign(CELLS, evaluator=surface, checkpoint_dir=None,
                 prioritize="history")
    with pytest.raises(ValueError, match="at least one cell"):
        Campaign([], evaluator=surface, checkpoint_dir=None)


# ---------------------------------------------------------- watch fabric
def test_watch_worker_claims_late_submission_and_stops(tmp_path):
    """The acceptance scenario, in-process: a watching worker drains
    its seed cell, idles, claims a cell submitted to the intake while
    it runs, and exits on STOP with no lease left held."""
    d = tmp_path / "fab"
    worker = FabricWorker(CELLS[:1], d, evaluator=surface,
                          baseline_factory=baseline_factory,
                          watch=True, poll_s=0.02, ttl_s=30)
    out = {}
    t = threading.Thread(
        target=lambda: out.setdefault("stats", worker.run()))
    t.start()
    deadline = time.time() + 20
    while not checkpoint_done(d, CELLS[0].key(), "tree") \
            and time.time() < deadline:
        time.sleep(0.01)
    assert checkpoint_done(d, CELLS[0].key(), "tree")
    time.sleep(0.1)
    assert t.is_alive()                  # watching, not exited
    submit_cells(d, [CELLS[2]])
    while not checkpoint_done(d, CELLS[2].key(), "tree") \
            and time.time() < deadline:
        time.sleep(0.01)
    assert checkpoint_done(d, CELLS[2].key(), "tree")
    request_stop(d)
    t.join(timeout=10)
    assert not t.is_alive()
    stats = out["stats"]
    assert sorted(stats["cells_completed"]) \
        == sorted([CELLS[0].key(), CELLS[2].key()])
    assert stats["intake_admitted"] == 1
    assert LeaseBoard(d).held() == []
    # the admitted cell's decisions match the static campaign
    runner = TrialRunner(CELLS[2].workload(), surface)
    ref = run_tuning(runner, baseline_factory(CELLS[2]), threshold=0.05)
    ck = json.loads((d / f"{CELLS[2].key()}.json").read_text())
    rep = worker.strategy.load_report(ck["report"])
    assert rep.__dict__ == ref.__dict__


def test_watch_worker_ignores_stale_stop_sentinel(tmp_path):
    """A STOP left behind by a previous session must not silently
    disable a NEW watch worker: the worker ignores the pre-start
    sentinel (without deleting it — deletion could cancel a stop that
    is live for older workers) and idles until a fresh stop lands."""
    d = tmp_path / "fab"
    stale = request_stop(d)              # stale, from a prior session
    worker = FabricWorker(CELLS[:1], d, evaluator=surface,
                          baseline_factory=baseline_factory,
                          watch=True, poll_s=0.02, ttl_s=30)
    out = {}
    t = threading.Thread(
        target=lambda: out.setdefault("stats", worker.run()))
    t.start()
    deadline = time.time() + 20
    while not checkpoint_done(d, CELLS[0].key(), "tree") \
            and time.time() < deadline:
        time.sleep(0.01)
    time.sleep(0.1)
    assert t.is_alive()                  # still watching — STOP was stale
    assert stale.exists()                # ignored, not deleted
    request_stop(d)                      # a fresh stop drains it
    t.join(timeout=10)
    assert not t.is_alive()
    assert out["stats"]["cells_completed"] == [CELLS[0].key()]


def test_worker_claims_in_history_priority_order(tmp_path):
    d = tmp_path / "fab"
    hist = TrialHistory(d / "history.jsonl")
    prime_speedup(hist, DECODE.arch, DECODE.shape, 2.0)
    prime_speedup(hist, "smollm-135m", "train_4k", 1.2)
    prime_speedup(hist, "glm4-9b", "train_4k", 1.05)
    prime_speedup(hist, "smollm-135m", "prefill_32k", 1.1)
    counting = CountingSurface()
    worker = FabricWorker(CELLS, d, evaluator=counting,
                          baseline_factory=baseline_factory,
                          prioritize="history", ttl_s=30)
    stats = worker.run()
    assert sorted(stats["cells_completed"]) \
        == sorted(c.key() for c in CELLS)
    first_seen = list(dict.fromkeys(k for k, _ in counting.calls))
    assert first_seen[0] == DECODE.key()


def test_worker_without_cells_needs_watch(tmp_path):
    with pytest.raises(ValueError, match="at least one cell"):
        FabricWorker([], tmp_path, evaluator=surface)


# ---------------------------------------------------------------- status
def test_queue_status_view(tmp_path):
    FabricWorker(CELLS[:2], tmp_path, evaluator=surface,
                 baseline_factory=baseline_factory).run()
    submit_cells(tmp_path, [CELLS[2]])
    board = LeaseBoard(tmp_path, worker_id="w-live", ttl_s=30)
    assert board.try_acquire(CELLS[3].key()) is not None
    st = queue_status(tmp_path, strategy="tree", cells=CELLS[:2])
    assert st["depth"] == {"pending": 1, "claimed": 1, "done": 2}
    by_cell = {d["cell"]: d for d in st["cells"]}
    assert by_cell[CELLS[0].key()]["done"]
    assert by_cell[CELLS[2].key()]["source"] == "intake"
    assert not by_cell[CELLS[2].key()]["done"]
    assert by_cell[CELLS[3].key()]["source"] == "lease"
    assert by_cell[CELLS[3].key()]["claimed_by"] == "w-live"
    assert len(st["leases"]) == 1
    assert st["leases"][0]["worker"] == "w-live"
    assert not st["leases"][0]["expired"]
    assert not st["stop_requested"]


# ------------------------------------------------------------- tune CLI
def test_tune_cli_add_cells_status_stop(tmp_path, monkeypatch, capsys):
    import repro.core.campaign as campaign_mod
    from repro.launch import tune
    monkeypatch.setattr(campaign_mod, "CAMPAIGN_DIR", tmp_path / "camp")
    assert tune.main(["--add-cells", "smollm-135m:train_4k"]) == 0
    out = capsys.readouterr().out
    assert "submitted smollm-135m__train_4k__pod" in out
    assert scan_intake(tmp_path / "camp") == [CELLS[0]]
    assert tune.main(["--status"]) == 0
    out = capsys.readouterr().out
    assert "queue depth:  1 pending / 0 claimed / 0 done" in out
    assert "(none held)" in out
    assert tune.main(["--stop"]) == 0
    capsys.readouterr()
    assert stop_requested(tmp_path / "camp")
    assert tune.main(["--status"]) == 0
    assert "STOP requested" in capsys.readouterr().out


def test_tune_cli_watch_requires_fabric_mode(capsys):
    from repro.launch import tune
    with pytest.raises(SystemExit):
        tune.main(["--cells", "smollm-135m:train_4k", "--watch"])
    assert "--watch only applies" in capsys.readouterr().err


def test_tune_cli_add_cells_and_stop_reject_mode_flags(capsys):
    """--add-cells/--stop must error on flags they would silently
    ignore, not leave the operator believing e.g. --fresh ran."""
    from repro.launch import tune
    with pytest.raises(SystemExit):
        tune.main(["--add-cells", "smollm-135m:train_4k", "--fresh"])
    assert "standalone action" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        tune.main(["--stop", "--watch"])
    assert "standalone action" in capsys.readouterr().err
    with pytest.raises(SystemExit):
        tune.main(["--add-cells", "smollm-135m:train_4k", "--stop"])
    assert "separate actions" in capsys.readouterr().err


def test_fresh_clears_intake(tmp_path, monkeypatch):
    import repro.core.campaign as campaign_mod
    from repro.launch import tune
    monkeypatch.setattr(campaign_mod, "CAMPAIGN_DIR", tmp_path / "camp")
    monkeypatch.setattr(tune, "RESULTS_DIR", tmp_path / "tuning")
    ckpt = tune.campaign_dir("tree", None)
    # one listed cell and one stale foreign --add-cells leftover: a
    # fresh campaign must not silently re-admit the foreign one
    submit_cells(ckpt, [CELLS[0], CELLS[2]])
    request_stop(ckpt)
    reports, _ = tune.tune_campaign(CELLS[:1], evaluator=surface,
                                    fresh=True)
    assert scan_intake(ckpt) == []       # the WHOLE intake is gone
    assert not stop_requested(ckpt)
    assert sorted(reports) == [CELLS[0].key()]   # foreign not admitted


# ---------------------------------------------------------- expected gain
def test_tree_cursor_expected_gain_shrinks():
    from repro.core.executor import run_trials
    from repro.core.tree import TreeCursor
    runner = TrialRunner(CELLS[0].workload(), surface)
    cursor = TreeCursor(runner, baseline_factory(CELLS[0]))
    assert cursor.expected_gain() is None        # pre-baseline: unknown
    gains = []
    while True:
        batch = cursor.propose()
        if not batch:
            break
        pairs = run_trials(runner, [c.as_trial() for c in batch])
        cursor.absorb([r for _, r in pairs], [i for i, _ in pairs])
        gains.append(cursor.expected_gain())
    assert gains[0] == 1.0                       # whole walk ahead
    assert gains == sorted(gains, reverse=True)  # monotone shrink
    assert cursor.expected_gain() == 0.0
